//! End-to-end driver (the EXPERIMENTS.md §e2e run): proves every layer
//! composes on a real small workload.
//!
//! 1. pre-trains the FP baseline *through the AOT train_step artifact*
//!    (Rust coordinator ⇄ XLA/PJRT ⇄ the JAX model that calls the Pallas
//!    kernels), logging the loss curve;
//! 2. evaluates the FP model on the synthetic CSR/MMLU/PPL suite;
//! 3. quantizes it with RTN, SmoothQuant, FlexRound, and LRQ under
//!    W8A8(static)KV8 via the block-wise PTQ pipeline;
//! 4. prints the Table-1/3-shaped comparison and writes reports/e2e.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! # faster smoke: --train-steps 120 --steps 60 --tasks 60
//! ```

use std::path::Path;

use anyhow::Result;
use lrq::config::{Args, Method, Scheme};
use lrq::coordinator::pretrain;
use lrq::data::{Corpus, CorpusConfig};
use lrq::report::{pct, Table};
use lrq::runtime::Runtime;
use lrq::tables::Lab;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let cfg = args.get_or("cfg", "tiny");
    let dir = args.get_or("artifacts", "artifacts");
    let seed: u64 = args.parse_as("seed", 1234)?;
    let train_steps: usize = args.parse_as("train-steps", 700)?;

    // --- 1. pre-train through the AOT train_step artifact -----------------
    let rt = Runtime::load(Path::new(&dir))?;
    let dim = rt.dim(&cfg)?;
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let wpath_s = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let wpath = Path::new(&wpath_s);
    if !wpath.exists() {
        println!("=== pre-training {cfg} ({:.1}M params) for {train_steps} \
                  steps ===", dim.param_count() as f64 / 1e6);
        let out = pretrain(&rt, &cfg, &corpus, train_steps, 1e-3, seed, 25)?;
        println!("loss curve:");
        for (s, l) in &out.losses {
            let bar = "#".repeat((l * 8.0) as usize);
            println!("  step {s:>5}  {l:.4}  {bar}");
        }
        println!("({:.1}s, {:.1} steps/s)", out.wall_secs,
                 train_steps as f64 / out.wall_secs);
        out.weights.save(wpath)?;
    } else {
        println!("=== using cached {wpath:?} (delete to retrain) ===");
    }
    drop(rt); // Lab opens its own runtime

    // --- 2..4. evaluate FP + all methods ---------------------------------
    let lab = Lab::new(&args, &cfg)?;
    let scheme = Scheme::w8a8_static();
    let mut table = Table::new(
        "e2e — CSR / MMLU / PPL after W8A8(static)KV8 quantization",
        &["Method", "#Bits", "CSR %", "MMLU %", "PPL"],
    );
    for m in [Method::Fp16, Method::Rtn, Method::SmoothQuant,
              Method::FlexRound, Method::Lrq] {
        let t0 = std::time::Instant::now();
        let s = lab.run_method(m, scheme)?;
        let bits = if m == Method::Fp16 { "16/16/16".into() }
                   else { scheme.label() };
        println!("{:<14} CSR {:>6.2}%  MMLU {:>6.2}%  PPL {:>7.3}   ({:.0}s)",
                 m.paper_name(), s.csr_acc * 100.0, s.mmlu_acc * 100.0,
                 s.ppl, t0.elapsed().as_secs_f64());
        table.row(vec![m.paper_name().into(), bits, pct(s.csr_acc),
                       pct(s.mmlu_acc), format!("{:.3}", s.ppl)]);
    }
    table.note("end-to-end: train_step (L2+L1) -> PTQ pipeline (L3 driving \
                recon_* artifacts) -> eval via embed/block/head artifacts");
    table.emit(&lab.reports, "e2e")?;
    println!("\nwrote reports/e2e.md");
    Ok(())
}
