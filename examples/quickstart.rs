//! Quickstart: load the AOT artifacts, pre-train (or load) a tiny FP
//! baseline, quantize it with LRQ under W8A8(static)KV8, and evaluate.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use lrq::config::{Args, Method, Scheme};
use lrq::tables::Lab;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let lab = Lab::new(&args, "tiny")?;

    println!("FP16 baseline:");
    let fp = lab.fp_summary()?;
    println!("  CSR {:.2}%  MMLU {:.2}%  PPL {:.3}", fp.csr_acc * 100.0,
             fp.mmlu_acc * 100.0, fp.ppl);

    let scheme = Scheme::w8a8_static();
    println!("\nquantizing with LRQ (W/A/KV {})…", scheme.label());
    let out = lab.quantize(Method::Lrq, scheme, lab.recon)?;
    println!("  done in {:.1}s, {} blocks", out.wall.as_secs_f64(),
             out.model.blocks.len());
    for (b, trace) in out.loss_traces.iter().enumerate() {
        if let (Some(f), Some(l)) = (trace.first(), trace.last()) {
            println!("  block {b}: recon loss {f:.6} -> {l:.6}");
        }
    }

    let s = lab.summary_of(&out, scheme)?;
    println!("\nLRQ ({}):", scheme.label());
    println!("  CSR {:.2}%  MMLU {:.2}%  PPL {:.3}", s.csr_acc * 100.0,
             s.mmlu_acc * 100.0, s.ppl);
    println!("\nmodel size {:.2} MB vs FP {:.2} MB",
             out.model.storage_bytes() as f64 / 1e6,
             out.model.fp_equivalent_bytes() as f64 / 1e6);
    Ok(())
}
