//! Rank study (Fig. 4a): sweep the LRQ rank r over the artifact set and
//! print CSR/MMLU accuracy plus the learnable-parameter ratio, showing the
//! interior sweet spot the paper reports.
//!
//! ```bash
//! cargo run --release --example rank_study -- --steps 150 --tasks 100
//! ```

use anyhow::Result;
use lrq::config::{Args, Method, ReconConfig, Scheme};
use lrq::quant::lrq::block_param_ratio;
use lrq::tables::Lab;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let cfg = args.get_or("cfg", "tiny");
    let lab = Lab::new(&args, &cfg)?;
    let ranks = lab.rt.ranks(&cfg);
    let dim = &lab.engine.dim;
    let scheme = Scheme::w8a8_static();

    println!("{:<10} {:>8} {:>8} {:>10}", "rank", "CSR %", "MMLU %",
             "ratio %");
    let fp = lab.fp_summary()?;
    println!("{:<10} {:>8.2} {:>8.2} {:>10}", "FP16", fp.csr_acc * 100.0,
             fp.mmlu_acc * 100.0, "-");
    for r in &ranks {
        let recon = ReconConfig { rank: *r, ..lab.recon };
        let out = lab.quantize(Method::Lrq, scheme, recon)?;
        let s = lab.summary_of(&out, scheme)?;
        let ratio = block_param_ratio(dim.d, dim.ff, *r) * 100.0;
        println!("{:<10} {:>8.2} {:>8.2} {:>10.1}", r, s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0, ratio);
    }
    let fr = lab.run_method(Method::FlexRound, scheme)?;
    println!("{:<10} {:>8.2} {:>8.2} {:>10.1}", "FR (full)",
             fr.csr_acc * 100.0, fr.mmlu_acc * 100.0, 100.0);
    Ok(())
}
