//! Serving example: stand up the batch-scoring server on a 4-bit LRQ model
//! and drive concurrent load, reporting latency percentiles / throughput /
//! model size — the Fig. 5 workload.
//!
//! ```bash
//! cargo run --release --example serving_quantized -- --requests 200
//! # FP16 baseline for comparison:
//! cargo run --release --example serving_quantized -- --fp
//! ```

use anyhow::Result;
use lrq::config::Args;
use lrq::tables;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let cfg = args.get_or("cfg", "tiny");
    let artifacts = args.get_or("artifacts", "artifacts");
    let weights = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let requests: usize = args.parse_as("requests", 200)?;
    let seed: u64 = args.parse_as("seed", 1234)?;
    let bits: u32 = args.parse_as("wbits", 4)?;

    // ensure the FP baseline exists (Lab trains + caches it)
    let _ = lrq::tables::Lab::new(&args, &cfg)?;

    if args.flag("fp") {
        println!("serving FP16 {cfg}…");
        tables::serving_run(&artifacts, &cfg, &weights, None, 16, requests,
                            seed)
    } else {
        println!("serving {bits}-bit LRQ {cfg} (quantizing first)…");
        tables::serving_run(&artifacts, &cfg, &weights, Some("lrq"), bits,
                            requests, seed)
    }
}
