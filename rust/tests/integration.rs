//! Integration tests over the PJRT runtime + AOT artifacts: cross-layer
//! golden checks (Rust quant math vs the Pallas kernels), artifact chaining
//! consistency, fold invariance through the real block, and pipeline smokes.
//!
//! These need `artifacts/manifest.txt` (run `make artifacts`); they are
//! skipped with a notice otherwise so `cargo test` stays green on a fresh
//! checkout.

use std::path::{Path, PathBuf};

use lrq::config::{ActScheme, Method, ReconConfig, Scheme};
use lrq::coordinator::{quantize_model, Engine};
use lrq::data::{Corpus, CorpusConfig};
use lrq::methods::fold::fold_block;
use lrq::methods::{recon_driver, BlockContext};
use lrq::model::Weights;
use lrq::quant::{self, fakequant_lrq, rtn_grid, ChannelGrid, LrqParams};
use lrq::rng::Rng;
use lrq::runtime::{to_lit, Runtime};
use lrq::tensor::Tensor;

fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join(cand);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    None
}

macro_rules! runtime_or_skip {
    () => {
        match artifacts_dir() {
            Some(dir) => Runtime::load(&dir).expect("runtime"),
            None => {
                eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn runtime_compiles_and_runs_embed() {
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let exec = rt.exec("embed_tiny").unwrap();
    let mut rng = Rng::new(1);
    let emb = Tensor::randn(&mut rng, &[dim.vocab, dim.d], 0.1);
    let ids: Vec<i32> = (0..dim.calib_batch * dim.seq)
        .map(|_| rng.below(dim.vocab) as i32)
        .collect();
    let out = exec
        .run(&[
            to_lit(&emb).unwrap(),
            lrq::runtime::ids_lit(&ids, &[dim.calib_batch, dim.seq]).unwrap(),
        ])
        .unwrap();
    let x = lrq::runtime::from_lit(&out[0], &[dim.calib_batch, dim.seq, dim.d])
        .unwrap();
    // gather semantics: row b,s equals emb[ids[b,s]]
    for check in [0usize, 7, 100] {
        let tok = ids[check] as usize;
        let got = &x.data[check * dim.d..(check + 1) * dim.d];
        let want = emb.row(tok);
        assert!(got
            .iter()
            .zip(want)
            .all(|(a, b)| (a - b).abs() < 1e-6));
    }
}

#[test]
fn cross_layer_fakequant_golden() {
    // Rust finalize math vs the L1 Pallas kernel artifact, same inputs.
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let exec = rt.exec("kernel_fakequant_tiny").unwrap();
    let (co, ci, r) = (dim.ff, dim.d, dim.rank);
    let mut rng = Rng::new(2);
    let w = Tensor::randn(&mut rng, &[co, ci], 0.05);
    let grid = rtn_grid(&w, 255.0);
    let p = LrqParams {
        ds1: vec![0.0; co],
        l2: Tensor::randn(&mut rng, &[co, r], 0.02),
        u2: Tensor::randn(&mut rng, &[r, ci], 0.02),
        r2: rng.normal_vec(co, 0.02),
        c2: rng.normal_vec(ci, 0.02),
    };
    let inputs = vec![
        to_lit(&w).unwrap(),
        to_lit(&Tensor::new(vec![co], grid.scale.clone())).unwrap(),
        to_lit(&Tensor::new(vec![co], grid.zp.clone())).unwrap(),
        to_lit(&p.l2).unwrap(),
        to_lit(&p.u2).unwrap(),
        to_lit(&Tensor::new(vec![co], p.r2.clone())).unwrap(),
        to_lit(&Tensor::new(vec![ci], p.c2.clone())).unwrap(),
        to_lit(&Tensor::scalar(255.0)).unwrap(),
    ];
    let out = exec.run(&inputs).unwrap();
    let kernel = lrq::runtime::from_lit(&out[0], &[co, ci]).unwrap();
    let rust = fakequant_lrq(&w, &grid, &p);
    let err = kernel.rmse(&rust);
    assert!(err < 1e-5, "kernel vs rust fakequant rmse {err}");
}

#[test]
fn qmm_kernel_matches_tensor_substrate() {
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let exec = rt.exec("kernel_qmm_tiny").unwrap();
    let t = dim.calib_batch * dim.seq;
    let (k, n) = (dim.d, dim.ff);
    let mut rng = Rng::new(3);
    let x = Tensor::randn(&mut rng, &[t, k], 1.0);
    let w = Tensor::randn(&mut rng, &[n, k], 0.05);
    let grid = rtn_grid(&w, 15.0);
    let codes = quant::quantize_int_codes(&w, &grid, None);
    let out = exec
        .run(&[
            to_lit(&x).unwrap(),
            to_lit(&codes).unwrap(),
            to_lit(&Tensor::new(vec![n], grid.scale.clone())).unwrap(),
            to_lit(&Tensor::new(vec![n], grid.zp.clone())).unwrap(),
        ])
        .unwrap();
    let y_kernel = lrq::runtime::from_lit(&out[0], &[t, n]).unwrap();
    // Rust: dequant then matmul_bt
    let mut deq = codes.clone();
    for r in 0..n {
        for c in 0..k {
            deq.data[r * k + c] = (codes.data[r * k + c] - grid.zp[r])
                * grid.scale[r];
        }
    }
    let y_rust = x.matmul_bt(&deq);
    let rel = y_kernel.rmse(&y_rust)
        / (y_rust.frob() / (y_rust.len() as f64).sqrt());
    assert!(rel < 1e-4, "qmm kernel vs tensor rel rmse {rel}");
}

#[test]
fn fold_preserves_block_function() {
    // SmoothQuant/AWQ fold must leave the FP block function unchanged —
    // checked through the real block_fwd artifact.
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let engine = Engine::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(4);
    let weights = Weights::init(&dim, &mut rng);
    let bw = &weights.blocks[0];
    let x = Tensor::randn(&mut rng, &[dim.calib_batch, dim.seq, dim.d], 1.0);

    let mut scales: [Vec<f32>; 4] = [
        vec![0.0; dim.d].iter().map(|_| 0.5 + rng.next_f32()).collect(),
        (0..dim.d).map(|_| 0.5 + rng.next_f32()).collect(),
        (0..dim.d).map(|_| 0.5 + rng.next_f32()).collect(),
        (0..dim.ff).map(|_| 0.5 + rng.next_f32()).collect(),
    ];
    scales[0] = (0..dim.d).map(|_| 0.5 + rng.next_f32()).collect();
    let folded = fold_block(bw, &scales).unwrap();

    let y0 = engine.block_fp(&x, bw).unwrap().y;
    let y1 = engine.block_fp(&x, &folded).unwrap().y;
    let rel = y0.rmse(&y1) / (y0.frob() / (y0.len() as f64).sqrt()).max(1e-9);
    assert!(rel < 1e-4, "fold changed block function: rel rmse {rel}");
}

#[test]
fn recon_step0_matches_engine_rtn_loss() {
    // Artifact-consistency: the recon artifact's step-0 loss (theta=0) must
    // equal the MSE between block_q(x; RTN Ŵ) and y_t computed through the
    // block_fwd_q artifact with the same grids.
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let engine = Engine::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(5);
    let weights = Weights::init(&dim, &mut rng);
    let bw = &weights.blocks[1];
    let x = Tensor::randn(&mut rng, &[dim.recon_batch, dim.seq, dim.d], 1.0);
    let y_t = {
        // block_fp needs calib_batch; tile x up
        let mut big = Tensor::zeros(&[dim.calib_batch, dim.seq, dim.d]);
        let inner = dim.seq * dim.d;
        for b in 0..dim.calib_batch {
            let src = b % dim.recon_batch;
            big.data[b * inner..(b + 1) * inner]
                .copy_from_slice(&x.data[src * inner..(src + 1) * inner]);
        }
        engine.block_fp(&big, bw).unwrap().y.slice_outer(0, dim.recon_batch)
    };

    // run 1 recon step with lr=0 (weight-only scheme: act quant off)
    let scheme = Scheme::weight_only(8);
    let recon = ReconConfig { steps: 1, lr: 0.0, calib_samples: 4,
                              rank: dim.rank, seed: 9 };
    let stats: lrq::coordinator::BlockStats = Default::default();
    let ctx = BlockContext {
        dim: &dim,
        weights: bw,
        x_q: &[x.clone()],
        y_t: &[y_t.clone()],
        acts_q: None,
        stats: &stats,
        scheme,
        recon,
        block_index: 0,
    };
    let out = recon_driver::run_recon(&rt, &engine, Method::Lrq, &ctx, bw,
                                      dim.rank)
        .unwrap();
    let recon_loss = out.loss_trace[0] as f64;

    // engine-side: Ŵ from the same grid-searched RTN init, block_q, MSE
    let grids: Vec<ChannelGrid> = bw
        .ws
        .iter()
        .map(|w| quant::grid_search_scales(w, 255.0, 40))
        .collect();
    let whats: Vec<Tensor> = bw
        .ws
        .iter()
        .zip(&grids)
        .map(|(w, g)| {
            let codes = quant::quantize_int_codes(w, g, None);
            let (rows, cols) = w.rc();
            let mut d = Vec::with_capacity(rows * cols);
            for r in 0..rows {
                for c in 0..cols {
                    d.push((codes.data[r * cols + c] - g.zp[r]) * g.scale[r]);
                }
            }
            Tensor::new(vec![rows, cols], d)
        })
        .collect();
    let mut big = Tensor::zeros(&[dim.calib_batch, dim.seq, dim.d]);
    let inner = dim.seq * dim.d;
    for b in 0..dim.calib_batch {
        let src = b % dim.recon_batch;
        big.data[b * inner..(b + 1) * inner]
            .copy_from_slice(&x.data[src * inner..(src + 1) * inner]);
    }
    let y_q = engine
        .block_q(&big, &whats, &bw.norm_attn, &bw.norm_ffn, &stats, &scheme)
        .unwrap()
        .slice_outer(0, dim.recon_batch);
    let manual = y_q.mse(&y_t);
    let rel = (recon_loss - manual).abs() / manual.max(1e-12);
    assert!(rel < 5e-3,
            "recon step-0 loss {recon_loss} vs engine MSE {manual}");
}

#[test]
fn recon_loss_decreases_through_artifact() {
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let engine = Engine::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(6);
    let weights = Weights::init(&dim, &mut rng);
    let bw = &weights.blocks[0];
    let x = Tensor::randn(&mut rng, &[dim.calib_batch, dim.seq, dim.d], 1.0);
    let y_t = engine.block_fp(&x, bw).unwrap().y;
    let scheme = Scheme::weight_only(4); // enough quant error to learn on
    let recon = ReconConfig { steps: 30, lr: 3e-3, calib_samples: 8,
                              rank: dim.rank, seed: 10 };
    let stats: lrq::coordinator::BlockStats = Default::default();
    let ctx = BlockContext {
        dim: &dim,
        weights: bw,
        x_q: &[x],
        y_t: &[y_t],
        acts_q: None,
        stats: &stats,
        scheme,
        recon,
        block_index: 0,
    };
    for method in [Method::Lrq, Method::FlexRound] {
        let out = recon_driver::run_recon(&rt, &engine, method, &ctx, bw,
                                          dim.rank)
            .unwrap();
        let first = out.loss_trace[0];
        let last = *out.loss_trace.last().unwrap();
        assert!(last < first * 0.95,
                "{method:?}: loss {first} -> {last} did not decrease");
    }
}

#[test]
fn pipeline_rtn_smoke_all_schemes() {
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let engine = Engine::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(7);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    for scheme in [Scheme::w8a8_static(), Scheme::w4a8_token(),
                   Scheme::weight_only(3)] {
        let recon = ReconConfig { steps: 0, calib_samples: 8,
                                  ..ReconConfig::default() };
        let out = quantize_model(&rt, &engine, &weights, &corpus, Method::Rtn,
                                 scheme, recon)
            .unwrap();
        assert_eq!(out.model.blocks.len(), dim.layers);
        assert_eq!(out.stats.len(), dim.layers);
        // activation ranges were actually calibrated for quantized schemes
        if !matches!(scheme.act, ActScheme::None) {
            assert!(out.stats[0][0].range.max > 0.0);
        }
        // packed storage is smaller than fp
        assert!(out.model.storage_bytes() < out.model.fp_equivalent_bytes());
    }
}

#[test]
fn quantized_model_close_to_fp_at_8bit() {
    // W8 weight-only RTN on a random-init model: outputs must stay close.
    let rt = runtime_or_skip!();
    let dim = rt.dim("tiny").unwrap();
    let engine = Engine::new(&rt, "tiny").unwrap();
    let mut rng = Rng::new(8);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let scheme = Scheme::weight_only(8);
    let recon = ReconConfig { steps: 0, calib_samples: 8,
                              ..ReconConfig::default() };
    let out = quantize_model(&rt, &engine, &weights, &corpus, Method::Rtn,
                             scheme, recon)
        .unwrap();
    let mut rng2 = Rng::new(9);
    let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng2);
    let (loss_fp, _) = engine.fp_forward(&weights, &ids, &tgt).unwrap();
    let (loss_q, _) = engine
        .q_forward(&out.model, &out.stats, &scheme, &ids, &tgt)
        .unwrap();
    assert!((loss_fp - loss_q).abs() < 0.05,
            "8-bit weight-only shifted loss too much: {loss_fp} vs {loss_q}");
}
