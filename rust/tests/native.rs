//! Correctness harness for the native quantized inference engine
//! (`lrq::infer`): the integer path must match the reference fake-quant path
//! (dequantize-then-matmul, the `block_fwd_q` semantics) within f32
//! accumulation tolerance, and packed W4A8 / W8A8 checkpoints must serve
//! end-to-end through the existing dynamic batcher. Runs entirely without
//! artifacts or PJRT.

use std::time::Duration;

use lrq::config::Scheme;
use lrq::data::{Corpus, CorpusConfig};
use lrq::infer::{calibrate_stats, prepare_native, quantize_weights,
                 reference, start_native_server, NativeModel, QuantBlock,
                 ScaleInit};
use lrq::model::{ModelDim, Weights};
use lrq::rng::Rng;
use lrq::serve::ServerConfig;
use lrq::tensor::Tensor;

/// The shared native-only smoke config (debug-build fast).
fn micro_dim() -> ModelDim {
    ModelDim::builtin("micro").expect("micro builtin")
}

fn rel_rmse(a: &Tensor, b: &Tensor) -> f64 {
    a.rmse(b) / (b.frob() / (b.len() as f64).sqrt()).max(1e-12)
}

fn schemes_under_test() -> Vec<Scheme> {
    vec![
        Scheme::w8a8_static(),
        Scheme::w4a8_token(),
        Scheme::weight_only(4),
        Scheme::weight_only(3),
    ]
}

#[test]
fn native_block_matches_reference_fakequant_path() {
    let dim = micro_dim();
    let mut rng = Rng::new(21);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 5));
    let stats = calibrate_stats(&weights, &corpus, 2, 9).unwrap();
    let x = Tensor::randn(&mut rng, &[2 * dim.seq, dim.d], 1.0);
    for scheme in schemes_under_test() {
        let qm = quantize_weights(&weights, scheme.w_bits,
                                  ScaleInit::GridSearch).unwrap();
        for (bi, qb) in qm.blocks.iter().enumerate() {
            let native_block = QuantBlock::from_quantized(qb).unwrap();
            let got = native_block
                .forward(&x, &dim, &stats[bi], &scheme, 1)
                .unwrap();
            let want = reference::ref_block_forward(
                &x, &qb.dequant_ws(), &qb.norm_attn, &qb.norm_ffn, &dim,
                &stats[bi], &scheme,
            )
            .unwrap();
            // tolerance covers f32 accumulation-order drift plus the rare
            // act-quant rounding-boundary flip it can cause
            let rel = rel_rmse(&got, &want);
            assert!(rel < 5e-3,
                    "scheme {} block {bi}: native vs reference rel rmse {rel}",
                    scheme.label());
        }
    }
}

#[test]
fn native_model_matches_reference_end_to_end() {
    let dim = micro_dim();
    let mut rng = Rng::new(22);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 6));
    let (ids, tgt) =
        corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    for scheme in [Scheme::w4a8_token(), Scheme::w8a8_static()] {
        let qm = quantize_weights(&weights, scheme.w_bits,
                                  ScaleInit::GridSearch).unwrap();
        let stats = calibrate_stats(&weights, &corpus, 2, 7).unwrap();
        let native =
            NativeModel::from_quantized(&qm, &stats, scheme, 1).unwrap();
        let (loss_n, logp_n) = native.forward(&ids, &tgt).unwrap();
        let (loss_r, logp_r) =
            reference::ref_forward(&qm, &stats, &scheme, &ids, &tgt)
                .unwrap();
        assert!((loss_n - loss_r).abs() < 5e-3,
                "{}: loss {loss_n} vs {loss_r}", scheme.label());
        let rel = rel_rmse(&logp_n, &logp_r);
        assert!(rel < 5e-3, "{}: logp rel rmse {rel}", scheme.label());
    }
}

#[test]
fn sharding_does_not_change_model_output() {
    let dim = micro_dim();
    let mut rng = Rng::new(23);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 8));
    let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    let scheme = Scheme::w4a8_token();
    let one = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus, 1,
                             11, 1).unwrap();
    let (loss1, logp1) = one.forward(&ids, &tgt).unwrap();
    for shards in [2usize, 3, 8] {
        let many = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus,
                                  1, 11, shards).unwrap();
        let (lossn, logpn) = many.forward(&ids, &tgt).unwrap();
        // row-sharding only moves work across threads; arithmetic per output
        // element is identical
        assert_eq!(loss1, lossn, "shards {shards}");
        assert_eq!(logp1, logpn, "shards {shards}");
    }
}

/// The acceptance-criteria test: packed W4A8 and W8A8 checkpoints served
/// through the *existing* dynamic batcher by the native scorer, answers
/// matching a direct forward of the same sequences.
#[test]
fn native_scorer_serves_w4a8_and_w8a8_through_batcher() {
    let dim = micro_dim();
    let mut rng = Rng::new(24);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 9));
    for scheme in [Scheme::w4a8_token(), Scheme::w8a8_static()] {
        let model = prepare_native(&weights, scheme, ScaleInit::GridSearch,
                                   &corpus, 2, 13, 2).unwrap();
        let local = model.clone(); // the engine is Clone + Send
        let server = start_native_server(
            model,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
            },
        )
        .unwrap();

        // 12 concurrent clients with random sequences
        let mut handles = Vec::new();
        for k in 0..12u64 {
            let client = server.client();
            let vocab = dim.vocab;
            let seq = dim.seq;
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xFEED ^ k);
                let len = rng.range(2, seq + 1);
                let ids: Vec<i32> =
                    (0..len).map(|_| rng.below(vocab) as i32).collect();
                let resp = client.score(ids.clone()).unwrap();
                (ids, resp)
            }));
        }
        let mut batched = false;
        for h in handles {
            let (ids, resp) = h.join().unwrap();
            batched |= resp.batch_size > 1;
            // direct single-row forward of the same padded sequence
            let mut row = ids.clone();
            row.resize(dim.seq, 0);
            let mut tgt: Vec<i32> = row[1..].to_vec();
            tgt.push(0);
            let (_, logp) = local.forward(&row, &tgt).unwrap();
            let want: f32 = logp.data[..ids.len() - 1].iter().sum();
            assert!((resp.logp_sum - want).abs() < 1e-3,
                    "{}: batched {} vs direct {want}", scheme.label(),
                    resp.logp_sum);
        }
        let m = server.metrics.lock().unwrap();
        assert_eq!(m.requests, 12, "{}", scheme.label());
        assert!(m.p50_latency() <= m.p99_latency());
        // with 12 concurrent clients and a 10ms window, at least one batch
        // should have coalesced
        assert!(batched || m.mean_batch() >= 1.0);
    }
}

#[test]
fn native_storage_matches_packed_accounting() {
    let dim = micro_dim();
    let mut rng = Rng::new(25);
    let weights = Weights::init(&dim, &mut rng);
    for bits in [3u32, 4, 8] {
        let qm = quantize_weights(&weights, bits, ScaleInit::Rtn).unwrap();
        let native = NativeModel::from_quantized(
            &qm, &[], Scheme::weight_only(bits), 1).unwrap();
        assert_eq!(native.storage_bytes(), qm.storage_bytes(),
                   "bits {bits}");
        assert!(native.storage_bytes() < qm.fp_equivalent_bytes());
    }
}
