//! Correctness harness for the native quantized inference engine
//! (`lrq::infer`): the integer path must match the reference fake-quant path
//! (dequantize-then-matmul, the `block_fwd_q` semantics) within f32
//! accumulation tolerance; incremental decode with the quantized KV cache
//! must reproduce the full-context forward token-for-token; and packed
//! W4A8 / W8A8 checkpoints must serve both score and generate workloads
//! end-to-end through the dynamic batcher. Runs entirely without artifacts
//! or PJRT.

use std::time::Duration;

use lrq::config::Scheme;
use lrq::data::{Corpus, CorpusConfig};
use lrq::infer::ops::head_logits;
use lrq::infer::simd::{self, Backend};
use lrq::infer::{calibrate_stats, prepare_native, prepare_native_from,
                 quantize_weights, reference, start_native_server, ExecMode,
                 ExecState, NativeModel, QuantBlock, ScaleInit};
use lrq::model::{ModelDim, QuantizedModel, Weights};
use lrq::obs::{trace, KernelKind};
use lrq::rng::Rng;
use lrq::serve::ServerConfig;
use lrq::tensor::Tensor;

/// The shared native-only smoke config (debug-build fast).
fn micro_dim() -> ModelDim {
    ModelDim::builtin("micro").expect("micro builtin")
}

fn rel_rmse(a: &Tensor, b: &Tensor) -> f64 {
    a.rmse(b) / (b.frob() / (b.len() as f64).sqrt()).max(1e-12)
}

fn schemes_under_test() -> Vec<Scheme> {
    vec![
        Scheme::w8a8_static(),
        Scheme::w4a8_token(),
        Scheme::weight_only(4),
        Scheme::weight_only(3),
    ]
}

#[test]
fn native_block_matches_reference_fakequant_path() {
    let dim = micro_dim();
    let mut rng = Rng::new(21);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 5));
    let stats = calibrate_stats(&weights, &corpus, 2, 9).unwrap();
    let x = Tensor::randn(&mut rng, &[2 * dim.seq, dim.d], 1.0);
    let mut ex = ExecState::new(1);
    for scheme in schemes_under_test() {
        let qm = quantize_weights(&weights, scheme.w_bits,
                                  ScaleInit::GridSearch).unwrap();
        for (bi, qb) in qm.blocks.iter().enumerate() {
            let native_block = QuantBlock::from_quantized(qb).unwrap();
            let got = native_block
                .forward(&x, &dim, &stats[bi], &scheme, &mut ex.exec())
                .unwrap();
            let want = reference::ref_block_forward(
                &x, &qb.dequant_ws(), &qb.norm_attn, &qb.norm_ffn, &dim,
                &stats[bi], &scheme,
            )
            .unwrap();
            // tolerance covers f32 accumulation-order drift plus the rare
            // act-quant rounding-boundary flip it can cause
            let rel = rel_rmse(&got, &want);
            assert!(rel < 5e-3,
                    "scheme {} block {bi}: native vs reference rel rmse {rel}",
                    scheme.label());
        }
    }
}

#[test]
fn native_model_matches_reference_end_to_end() {
    let dim = micro_dim();
    let mut rng = Rng::new(22);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 6));
    let (ids, tgt) =
        corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    for scheme in [Scheme::w4a8_token(), Scheme::w8a8_static()] {
        let qm = quantize_weights(&weights, scheme.w_bits,
                                  ScaleInit::GridSearch).unwrap();
        let stats = calibrate_stats(&weights, &corpus, 2, 7).unwrap();
        let native =
            NativeModel::from_quantized(&qm, &stats, scheme, 1).unwrap();
        let (loss_n, logp_n) = native.forward(&ids, &tgt).unwrap();
        let (loss_r, logp_r) =
            reference::ref_forward(&qm, &stats, &scheme, &ids, &tgt)
                .unwrap();
        assert!((loss_n - loss_r).abs() < 5e-3,
                "{}: loss {loss_n} vs {loss_r}", scheme.label());
        let rel = rel_rmse(&logp_n, &logp_r);
        assert!(rel < 5e-3, "{}: logp rel rmse {rel}", scheme.label());
    }
}

/// Pool-vs-single-thread bit-exactness: tile-sharding across the persistent
/// pool only moves tiles between threads; arithmetic per output element (and
/// the output column each shard writes) is identical, for both the
/// full-context forward and the cached decode path, integer and weight-only.
#[test]
fn sharding_does_not_change_model_output() {
    let dim = micro_dim();
    let mut rng = Rng::new(23);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 8));
    let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    let step_ids: Vec<i32> =
        (0..6).map(|_| rng.below(dim.vocab) as i32).collect();
    for scheme in [Scheme::w4a8_token(), Scheme::weight_only(4)] {
        let one = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus,
                                 1, 11, 1).unwrap();
        let (loss1, logp1) = one.forward(&ids, &tgt).unwrap();
        let mut cache1 = one.new_cache();
        let steps1: Vec<Tensor> = step_ids
            .iter()
            .map(|&id| {
                one.decode_step(&[id], std::slice::from_mut(&mut cache1))
                    .unwrap()
            })
            .collect();
        for shards in [2usize, 3, 8] {
            let many = prepare_native(&weights, scheme, ScaleInit::Rtn,
                                      &corpus, 1, 11, shards).unwrap();
            assert_eq!(many.threads(), shards);
            let (lossn, logpn) = many.forward(&ids, &tgt).unwrap();
            assert_eq!(loss1, lossn, "{} shards {shards}", scheme.label());
            assert_eq!(logp1, logpn, "{} shards {shards}", scheme.label());
            let mut cachen = many.new_cache();
            for (t, &id) in step_ids.iter().enumerate() {
                let sn = many
                    .decode_step(&[id], std::slice::from_mut(&mut cachen))
                    .unwrap();
                assert_eq!(steps1[t], sn,
                           "{} shards {shards} step {t}", scheme.label());
            }
        }
    }
}

/// The planned engine (interleaved tiles + micro-kernel + pool) must equal
/// the pre-plan reference engine (per-call unpack, scalar dots) **bit for
/// bit** — same per-element arithmetic, only layout/threading changed — for
/// W8A8(static), W4A8(per-token), and weight-only, across the full-context
/// forward, incremental decode, and prefill.
#[test]
fn planned_execution_is_bit_exact_vs_preplan_reference() {
    let dim = micro_dim();
    let mut rng = Rng::new(35);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 18));
    let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    let step_ids: Vec<i32> =
        (0..8).map(|_| rng.below(dim.vocab) as i32).collect();
    for scheme in schemes_under_test() {
        let planned = prepare_native(&weights, scheme, ScaleInit::GridSearch,
                                     &corpus, 2, 21, 1).unwrap();
        assert_eq!(planned.mode(), ExecMode::Planned);
        let reference = planned.clone().with_mode(ExecMode::Reference);
        // full-context forward: identical loss and per-position logprobs
        let (lp, pp) = planned.forward(&ids, &tgt).unwrap();
        let (lr, pr) = reference.forward(&ids, &tgt).unwrap();
        assert_eq!(lp, lr, "{} loss", scheme.label());
        assert_eq!(pp, pr, "{} logp", scheme.label());
        // incremental decode: identical logits at every step
        let mut cp = planned.new_cache();
        let mut cr = reference.new_cache();
        for (t, &id) in step_ids.iter().enumerate() {
            let sp = planned
                .decode_step(&[id], std::slice::from_mut(&mut cp))
                .unwrap();
            let sr = reference
                .decode_step(&[id], std::slice::from_mut(&mut cr))
                .unwrap();
            assert_eq!(sp, sr, "{} step {t}", scheme.label());
        }
        // vectorized prefill: identical next-token logits
        let mut fp = planned.new_cache();
        let mut fr = reference.new_cache();
        let gp = planned.prefill(&step_ids, &mut fp).unwrap();
        let gr = reference.prefill(&step_ids, &mut fr).unwrap();
        assert_eq!(gp, gr, "{} prefill", scheme.label());
    }
}

/// The acceptance-criteria test: packed W4A8 and W8A8 checkpoints served
/// through the *existing* dynamic batcher by the native scorer, answers
/// matching a direct forward of the same sequences.
#[test]
fn native_scorer_serves_w4a8_and_w8a8_through_batcher() {
    let dim = micro_dim();
    let mut rng = Rng::new(24);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 9));
    for scheme in [Scheme::w4a8_token(), Scheme::w8a8_static()] {
        let model = prepare_native(&weights, scheme, ScaleInit::GridSearch,
                                   &corpus, 2, 13, 2).unwrap();
        let local = model.clone(); // the engine is Clone + Send
        let server = start_native_server(
            model,
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(10),
            },
        )
        .unwrap();

        // 12 concurrent clients with random sequences
        let mut handles = Vec::new();
        for k in 0..12u64 {
            let client = server.client();
            let vocab = dim.vocab;
            let seq = dim.seq;
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xFEED ^ k);
                let len = rng.range(2, seq + 1);
                let ids: Vec<i32> =
                    (0..len).map(|_| rng.below(vocab) as i32).collect();
                let resp = client.score(ids.clone()).unwrap();
                (ids, resp)
            }));
        }
        let mut batched = false;
        for h in handles {
            let (ids, resp) = h.join().unwrap();
            batched |= resp.batch_size > 1;
            // direct single-row forward of the same padded sequence
            let mut row = ids.clone();
            row.resize(dim.seq, 0);
            let mut tgt: Vec<i32> = row[1..].to_vec();
            tgt.push(0);
            let (_, logp) = local.forward(&row, &tgt).unwrap();
            let want: f32 = logp.data[..ids.len() - 1].iter().sum();
            assert!((resp.logp_sum - want).abs() < 1e-3,
                    "{}: batched {} vs direct {want}", scheme.label(),
                    resp.logp_sum);
        }
        let m = server.metrics.lock().unwrap();
        assert_eq!(m.requests(), 12, "{}", scheme.label());
        assert!(m.p50_latency() <= m.p99_latency());
        // with 12 concurrent clients and a 10ms window, at least one batch
        // should have coalesced
        assert!(batched || m.mean_batch() >= 1.0);
    }
}

/// The acceptance-criteria test for the decode path: `decode_step` with a
/// (quantized) KV cache must reproduce the full-context forward
/// token-for-token — per-position next-token logits equal within
/// f32-accumulation tolerance — for W8A8(static), W4A8(per-token), and
/// weight-only configs.
#[test]
fn decode_with_kv_cache_matches_full_context_forward() {
    let dim = micro_dim();
    let mut rng = Rng::new(31);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 11));
    let ids: Vec<i32> =
        (0..dim.seq).map(|_| rng.below(dim.vocab) as i32).collect();
    for scheme in schemes_under_test() {
        let model = prepare_native(&weights, scheme, ScaleInit::GridSearch,
                                   &corpus, 2, 17, 1).unwrap();
        // full-context oracle: logits at every position in one pass
        let hidden = model.forward_hidden(&ids).unwrap();
        let full = head_logits(&hidden, &model.final_norm, &model.head);
        // incremental: one token at a time against the growing cache
        let mut cache = model.new_cache();
        assert_eq!(cache.is_quantized(), scheme.kv_quant);
        for (t, &id) in ids.iter().enumerate() {
            let step = model
                .decode_step(&[id], std::slice::from_mut(&mut cache))
                .unwrap();
            let got = Tensor::new(vec![1, dim.vocab], step.data.clone());
            let want = Tensor::new(vec![1, dim.vocab], full.row(t).to_vec());
            let rel = rel_rmse(&got, &want);
            assert!(rel < 1e-4,
                    "{} pos {t}: decode vs full-context rel rmse {rel}",
                    scheme.label());
        }
        assert_eq!(cache.len(), dim.seq);
        assert!(cache.storage_bytes() > 0);
        // the vectorized prefill must agree with the same oracle: its
        // last-token logits are the full forward's last row
        let mut pc = model.new_cache();
        let plog = model.prefill(&ids, &mut pc).unwrap();
        assert_eq!(pc.len(), dim.seq);
        let got = Tensor::new(vec![1, dim.vocab], plog);
        let want =
            Tensor::new(vec![1, dim.vocab], full.row(dim.seq - 1).to_vec());
        let rel = rel_rmse(&got, &want);
        assert!(rel < 1e-4, "{}: prefill vs full-context rel rmse {rel}",
                scheme.label());
        // context-window guard: the cache is full, one more step must fail
        assert!(model
            .decode_step(&[ids[0]], std::slice::from_mut(&mut pc))
            .is_err());
    }
}

/// Quantized KV cache stores u8 codes: ~4x smaller than the FP rows the
/// no-KV-quant scheme caches.
#[test]
fn quantized_kv_cache_compresses_storage() {
    let dim = micro_dim();
    let mut rng = Rng::new(32);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 12));
    let ids: Vec<i32> =
        (0..dim.seq).map(|_| rng.below(dim.vocab) as i32).collect();
    let q = prepare_native(&weights, Scheme::w4a8_token(), ScaleInit::Rtn,
                           &corpus, 1, 13, 1).unwrap();
    let f = prepare_native(&weights,
                           Scheme::w4a8_token().without_kv_quant(),
                           ScaleInit::Rtn, &corpus, 1, 13, 1).unwrap();
    let mut qc = q.new_cache();
    let mut fc = f.new_cache();
    q.prefill(&ids, &mut qc).unwrap();
    f.prefill(&ids, &mut fc).unwrap();
    assert_eq!(qc.len(), fc.len());
    assert!(qc.storage_bytes() * 2 < fc.storage_bytes(),
            "quantized cache {} vs fp cache {}", qc.storage_bytes(),
            fc.storage_bytes());
}

/// Batched decode across sequences is the same arithmetic as one-by-one
/// decode: interleaving two sequences through `decode_step` must equal each
/// sequence generated alone.
#[test]
fn batched_decode_steps_match_single_sequence_decode() {
    let dim = micro_dim();
    let mut rng = Rng::new(33);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 14));
    let model = prepare_native(&weights, Scheme::w4a8_token(),
                               ScaleInit::Rtn, &corpus, 1, 15, 1).unwrap();
    let a: Vec<i32> = (0..8).map(|_| rng.below(dim.vocab) as i32).collect();
    let b: Vec<i32> = (0..8).map(|_| rng.below(dim.vocab) as i32).collect();
    // batched: both sequences advance together
    let mut caches = vec![model.new_cache(), model.new_cache()];
    let mut batched_logits = Vec::new();
    for t in 0..8 {
        let step = model.decode_step(&[a[t], b[t]], &mut caches).unwrap();
        batched_logits.push(step);
    }
    // single: each sequence alone
    for (si, ids) in [&a, &b].into_iter().enumerate() {
        let mut cache = model.new_cache();
        for (t, &id) in ids.iter().enumerate() {
            let solo = model
                .decode_step(&[id], std::slice::from_mut(&mut cache))
                .unwrap();
            assert_eq!(solo.data.as_slice(),
                       batched_logits[t].row(si),
                       "seq {si} pos {t}");
        }
    }
}

/// Generation through the dynamic batcher (concurrent clients, decode-step
/// batching) must match a direct single-sequence greedy decode of the same
/// prompt, token for token.
#[test]
fn generate_through_batcher_matches_direct_decode() {
    let dim = micro_dim();
    let mut rng = Rng::new(34);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 16));
    let model = prepare_native(&weights, Scheme::w4a8_token(),
                               ScaleInit::GridSearch, &corpus, 1, 19, 1)
        .unwrap();
    let local = model.clone();
    let server = start_native_server(
        model,
        ServerConfig { max_batch: 4, max_wait: Duration::from_millis(10) },
    )
    .unwrap();
    let max_new = 6usize;
    let mut handles = Vec::new();
    for k in 0..8u64 {
        let client = server.client();
        let vocab = dim.vocab;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xCAFE ^ k);
            let plen = rng.range(1, 7);
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.below(vocab) as i32).collect();
            let resp = client.generate(prompt.clone(), max_new, 1, k).unwrap();
            (prompt, resp)
        }));
    }
    for h in handles {
        let (prompt, resp) = h.join().unwrap();
        assert_eq!(resp.prompt_len, prompt.len());
        let want = local.generate(&prompt, max_new, 1, 0).unwrap();
        // greedy decode is deterministic and batching is bit-exact
        assert_eq!(resp.tokens, want, "prompt {prompt:?}");
    }
    let m = server.metrics.lock().unwrap();
    assert_eq!(m.gen_requests(), 8);
    assert_eq!(m.gen_tokens(), 8 * max_new);
    assert!(m.decode_steps() > 0);
    // decode accounting: every generated token beyond the prefill's first
    // sample came from exactly one decode step
    assert_eq!(m.gen_tokens(), m.decode_step_tokens() + m.gen_requests());
}

/// Observability acceptance: after a batched generate run through the
/// server, (a) the serve counters and the model profiler agree on decode
/// accounting — `gen_tokens == decode_step_tokens + gen_requests` and every
/// layer stepped exactly `decode_step_tokens` tokens; (b) the per-layer
/// profile shows real kernel time with internally consistent sums; (c) the
/// trace file is loadable chrome-trace JSON containing the request → batch
/// → layer → kernel span tree.
#[test]
fn decode_accounting_and_trace_tree_after_batched_generate() {
    let dim = micro_dim();
    let mut rng = Rng::new(41);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 21));
    let model = prepare_native(&weights, Scheme::w4a8_token(), ScaleInit::Rtn,
                               &corpus, 1, 23, 1)
        .unwrap();
    let prof = model.profiler();
    prof.set_enabled(true);
    let tpath = std::env::temp_dir().join(format!(
        "lrq_native_trace_{}.json", std::process::id()));
    trace::init(&tpath).unwrap();

    let server = start_native_server(
        model,
        ServerConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
    )
    .unwrap();
    let max_new = 5usize;
    let mut handles = Vec::new();
    for k in 0..6u64 {
        let client = server.client();
        let vocab = dim.vocab;
        handles.push(std::thread::spawn(move || {
            let mut r = Rng::new(0xACC0 ^ k);
            let prompt: Vec<i32> =
                (0..4).map(|_| r.below(vocab) as i32).collect();
            client.generate(prompt, max_new, 1, k).unwrap()
        }));
    }
    for h in handles {
        let resp = h.join().unwrap();
        assert_eq!(resp.tokens.len(), max_new);
    }
    let m = server.metrics.lock().unwrap().clone();
    drop(server); // quiesce the engine thread before reading the profiler

    // (a) decode accounting: serve counters vs profiler token attribution
    assert_eq!(m.gen_requests(), 6);
    assert_eq!(m.gen_tokens(), 6 * max_new);
    assert!(m.decode_steps() > 0);
    assert_eq!(m.gen_tokens(), m.decode_step_tokens() + m.gen_requests());
    assert!(prof.layers() > 0);
    for l in 0..prof.layers() {
        assert_eq!(prof.step_tokens(l), m.decode_step_tokens() as u64,
                   "layer {l} stepped a different token count");
    }

    // (b) the profile carries real kernel time and sums consistently
    let report = prof.report();
    assert!(report.total() > Duration::ZERO);
    assert!(report.kind_ns(KernelKind::Gemm) > 0);
    assert!(report.kind_ns(KernelKind::Attn) > 0);
    assert!(report.kind_ns(KernelKind::KvAppend) > 0);
    let per_layer_ns: u64 = report.rows.iter().map(|r| r.total_ns()).sum();
    assert_eq!(report.total(), Duration::from_nanos(per_layer_ns));
    assert!(!report.render().is_empty());

    // (c) the trace is loadable JSON with the span tree. Other tests in
    // this binary may interleave their own spans — only presence is
    // asserted, never exclusivity.
    let events = trace::shutdown().unwrap();
    assert!(events > 0, "no trace events written");
    let txt = std::fs::read_to_string(&tpath).unwrap();
    assert!(txt.starts_with("[\n"), "not a JSON array");
    assert!(txt.trim_end().ends_with(']'));
    for needle in [
        "\"name\":\"generate\"", // request envelope (ph b/e)
        "\"ph\":\"b\"",
        "\"ph\":\"e\"",
        "\"name\":\"prefill\"",
        "\"name\":\"decode_step\"",
        "\"name\":\"layer0\"",
        "\"name\":\"gemm", // gemm{cout}x{cin} kernel spans
    ] {
        assert!(txt.contains(needle), "trace missing {needle}");
    }
    let _ = std::fs::remove_file(&tpath);
}

/// Tentpole acceptance (DESIGN.md §11): the SIMD-dispatched planned engine
/// must equal the forced-scalar planned engine — and the pre-plan
/// `ExecMode::Reference` engine — **bit for bit** end-to-end, across the
/// full-context forward, incremental decode, and prefill, for every scheme.
/// Backends are pinned per instance (`with_kernel`), never via the process
/// global, so this test cannot race other tests in the parallel harness;
/// the FP glue helpers resolve globally but are bit-equal across backends
/// by the mirrored-accumulator contract, so only the integer GEMM actually
/// differs between the instances compared here.
#[test]
fn forced_simd_and_forced_scalar_engines_are_bit_exact() {
    let dim = micro_dim();
    let mut rng = Rng::new(51);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 25));
    let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
    let step_ids: Vec<i32> =
        (0..6).map(|_| rng.below(dim.vocab) as i32).collect();
    for scheme in schemes_under_test() {
        let scalar = prepare_native(&weights, scheme, ScaleInit::Rtn,
                                    &corpus, 1, 27, 1)
            .unwrap()
            .with_kernel(Backend::Scalar);
        assert_eq!(scalar.kernel(), Backend::Scalar);
        let (ls, ps) = scalar.forward(&ids, &tgt).unwrap();
        // the pre-plan engine is always scalar; planned-SIMD must match it
        let reference = scalar.clone().with_mode(ExecMode::Reference);
        let (lr, pr) = reference.forward(&ids, &tgt).unwrap();
        assert_eq!(ls, lr, "{} vs reference", scheme.label());
        assert_eq!(ps, pr, "{} vs reference", scheme.label());
        for be in simd::backends() {
            let vec_model = prepare_native(&weights, scheme, ScaleInit::Rtn,
                                           &corpus, 1, 27, 1)
                .unwrap()
                .with_kernel(be);
            assert_eq!(vec_model.kernel(), be);
            let (lv, pv) = vec_model.forward(&ids, &tgt).unwrap();
            assert_eq!(ls, lv, "{} loss on {}", scheme.label(), be.name());
            assert_eq!(ps, pv, "{} logp on {}", scheme.label(), be.name());
            // incremental decode, step by step in lockstep
            let mut cs = scalar.new_cache();
            let mut cv = vec_model.new_cache();
            for (t, &id) in step_ids.iter().enumerate() {
                let ss = scalar
                    .decode_step(&[id], std::slice::from_mut(&mut cs))
                    .unwrap();
                let sv = vec_model
                    .decode_step(&[id], std::slice::from_mut(&mut cv))
                    .unwrap();
                assert_eq!(ss, sv, "{} step {t} on {}", scheme.label(),
                           be.name());
            }
            // vectorized prefill
            let mut fs = scalar.new_cache();
            let mut fv = vec_model.new_cache();
            let gs = scalar.prefill(&step_ids, &mut fs).unwrap();
            let gv = vec_model.prefill(&step_ids, &mut fv).unwrap();
            assert_eq!(gs, gv, "{} prefill on {}", scheme.label(),
                       be.name());
        }
    }
}

/// Satellite acceptance: the `lrq quantize --out` → LRQQ file →
/// `serve-native --checkpoint` round-trip, in-process. The engine built
/// from the reloaded checkpoint must be bit-identical to the engine built
/// from the in-memory quantized model, and must answer score requests
/// through the dynamic batcher.
#[test]
fn lrqq_checkpoint_roundtrips_through_file_and_serves() {
    let dim = micro_dim();
    let mut rng = Rng::new(52);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 26));
    let qm = quantize_weights(&weights, 4, ScaleInit::GridSearch).unwrap();
    let path = std::env::temp_dir()
        .join(format!("lrq_ckpt_roundtrip_{}.lrqq", std::process::id()));
    qm.save(&path).unwrap();
    let loaded = QuantizedModel::load(&dim, &path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.bits, qm.bits);

    let scheme = Scheme::w4a8_token();
    let direct =
        prepare_native_from(&qm, &weights, scheme, &corpus, 1, 29, 1)
            .unwrap();
    let reloaded =
        prepare_native_from(&loaded, &weights, scheme, &corpus, 1, 29, 1)
            .unwrap();
    let (ids, tgt) = {
        let mut r = Rng::new(61);
        corpus.eval_stream(dim.calib_batch, dim.seq, &mut r)
    };
    let (ld, pd) = direct.forward(&ids, &tgt).unwrap();
    let (lf, pf) = reloaded.forward(&ids, &tgt).unwrap();
    assert_eq!(ld, lf, "loss diverged across the file roundtrip");
    assert_eq!(pd, pf, "logp diverged across the file roundtrip");

    // a mismatched declared bit-width must fail loudly, not serve garbage
    assert!(prepare_native_from(&loaded, &weights, Scheme::w8a8_static(),
                                &corpus, 1, 29, 1)
        .is_err());

    // and the reloaded engine serves through the batcher
    let local = reloaded.clone();
    let server = start_native_server(
        reloaded,
        ServerConfig { max_batch: 2, max_wait: Duration::from_millis(2) },
    )
    .unwrap();
    let ids2: Vec<i32> =
        (0..6).map(|_| rng.below(dim.vocab) as i32).collect();
    let resp = server.client().score(ids2.clone()).unwrap();
    let mut row = ids2.clone();
    row.resize(dim.seq, 0);
    let mut tgt2: Vec<i32> = row[1..].to_vec();
    tgt2.push(0);
    let (_, logp) = local.forward(&row, &tgt2).unwrap();
    let want: f32 = logp.data[..ids2.len() - 1].iter().sum();
    assert!((resp.logp_sum - want).abs() < 1e-3,
            "served {} vs direct {want}", resp.logp_sum);
}

#[test]
fn native_storage_matches_packed_accounting() {
    let dim = micro_dim();
    let mut rng = Rng::new(25);
    let weights = Weights::init(&dim, &mut rng);
    for bits in [3u32, 4, 8] {
        let qm = quantize_weights(&weights, bits, ScaleInit::Rtn).unwrap();
        let native = NativeModel::from_quantized(
            &qm, &[], Scheme::weight_only(bits), 1).unwrap();
        assert_eq!(native.storage_bytes(), qm.storage_bytes(),
                   "bits {bits}");
        assert!(native.storage_bytes() < qm.fp_equivalent_bytes());
    }
}
