//! Property-based tests over the public API (in-repo harness — proptest is
//! unavailable offline; failures reproduce from the printed seed).

use lrq::infer::kernels::{dot_block_f32_u8_scalar, dot_block_u8_scalar,
                          dot_f32_u8, dot_u8, quantize_acts_per_token,
                          MAX_DOT_K};
use lrq::infer::simd::{self, LANE};
use lrq::infer::{quantize_weights, ExecMode, ExecState, QuantLinear,
                 ScaleInit, TilePlan, MR};
use lrq::methods::fold::{fold_block, smooth_scales, weight_col_amax};
use lrq::model::{BlockWeights, ModelDim, QuantizedModel, Weights};
use lrq::quant::{self, grid_search_scales, per_token_quant, rtn_grid,
                 PackedMatrix};
use lrq::quant::pack::{pack_bits, unpack_bits};
use lrq::rng::Rng;
use lrq::tensor::Tensor;
use lrq::testutil::check;

#[test]
fn prop_pack_unpack_bijective() {
    check("pack/unpack bijective", 50, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let n = rng.range(1, 500);
        let codes: Vec<u32> =
            (0..n).map(|_| rng.below(1 << bits) as u32).collect();
        let packed = pack_bits(&codes, bits);
        match unpack_bits(&packed, bits, n) {
            Ok(back) if back == codes => {}
            Ok(_) => return Err(format!("roundtrip failed bits={bits} n={n}")),
            Err(e) => return Err(format!("unpack failed: {e}")),
        }
        let expect = (n * bits as usize).div_ceil(8);
        if packed.len() != expect {
            return Err(format!("size {} != {expect}", packed.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_unpack_refuses_truncation() {
    check("unpack refuses truncation", 50, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let n = rng.range(2, 400);
        let codes: Vec<u32> =
            (0..n).map(|_| rng.below(1 << bits) as u32).collect();
        let packed = pack_bits(&codes, bits);
        let cut = rng.below(packed.len());
        if unpack_bits(&packed[..cut], bits, n).is_ok() {
            return Err(format!(
                "accepted {cut}/{} bytes for {n} codes at {bits} bits",
                packed.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_rtn_error_bounded_by_half_step() {
    check("rtn error bound", 30, |rng| {
        let rows = rng.range(1, 12);
        let cols = rng.range(2, 64);
        let bits = [3u32, 4, 8][rng.below(3)];
        let std = 0.1 + rng.next_f32();
        let w = Tensor::randn(rng, &[rows, cols], std);
        let g = rtn_grid(&w, quant::qmax(bits));
        let mut buf = vec![0.0f32; cols];
        for r in 0..rows {
            g.fq_row(r, w.row(r), &mut buf);
            for (o, &x) in buf.iter().zip(w.row(r)) {
                if (o - x).abs() > g.scale[r] * 0.5 + 1e-5 {
                    return Err(format!(
                        "row {r}: err {} > half-step {}", (o - x).abs(),
                        g.scale[r] * 0.5));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grid_search_never_worse_than_rtn() {
    check("grid search <= rtn", 20, |rng| {
        let rows = rng.range(1, 8);
        let cols = rng.range(8, 96);
        let bits = [3u32, 4][rng.below(2)];
        let w = Tensor::randn(rng, &[rows, cols], 0.05);
        let qm = quant::qmax(bits);
        let err_of = |g: &quant::ChannelGrid| -> f64 {
            let mut e = 0.0;
            let mut buf = vec![0.0f32; cols];
            for r in 0..rows {
                g.fq_row(r, w.row(r), &mut buf);
                for (o, &x) in buf.iter().zip(w.row(r)) {
                    e += ((o - x) as f64).powi(2);
                }
            }
            e
        };
        let e_rtn = err_of(&rtn_grid(&w, qm));
        let e_gs = err_of(&grid_search_scales(&w, qm, 40));
        if e_gs > e_rtn * 1.0001 {
            return Err(format!("gs {e_gs} > rtn {e_rtn}"));
        }
        Ok(())
    });
}

#[test]
fn prop_per_token_quant_error_monotone_in_bits() {
    check("per-token monotone bits", 20, |rng| {
        let t = rng.range(1, 16);
        let d = rng.range(4, 64);
        let x = Tensor::randn(rng, &[t, d], 1.0);
        // fewer bits => no less error (compare against the 8-bit floor)
        let e8 = per_token_quant(&x, quant::qmax(8)).mse(&x);
        let e4 = per_token_quant(&x, quant::qmax(4)).mse(&x);
        let e3 = per_token_quant(&x, quant::qmax(3)).mse(&x);
        if e4 + 1e-12 < e8 {
            return Err(format!("e4 {e4} < e8 {e8}"));
        }
        if e3 + 1e-12 < e4 {
            return Err(format!("e3 {e3} < e4 {e4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fold_roundtrip_identity() {
    check("fold roundtrip", 15, |rng| {
        let d = 8 + 4 * rng.below(3);
        let f = d + 4 + 4 * rng.below(3);
        let bw = BlockWeights {
            ws: vec![
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[f, d], 0.1),
                Tensor::randn(rng, &[f, d], 0.1),
                Tensor::randn(rng, &[d, f], 0.1),
            ],
            norm_attn: Tensor::ones(&[d]),
            norm_ffn: Tensor::ones(&[d]),
        };
        let mk = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| 0.3 + 2.0 * rng.next_f32()).collect()
        };
        let s = [mk(rng, d), mk(rng, d), mk(rng, d), mk(rng, f)];
        let inv = [
            s[0].iter().map(|v| 1.0 / v).collect::<Vec<_>>(),
            s[1].iter().map(|v| 1.0 / v).collect(),
            s[2].iter().map(|v| 1.0 / v).collect(),
            s[3].iter().map(|v| 1.0 / v).collect(),
        ];
        let back = fold_block(&fold_block(&bw, &s).unwrap(), &inv).unwrap();
        for i in 0..7 {
            if back.ws[i].rmse(&bw.ws[i]) > 1e-5 {
                return Err(format!("w{i} not restored"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_smooth_scales_reduce_act_dynamic_range() {
    check("smoothing flattens acts", 15, |rng| {
        let d = rng.range(8, 32);
        let mut amax_a: Vec<f32> =
            (0..d).map(|_| 0.5 + rng.next_f32()).collect();
        amax_a[0] = 60.0; // outlier channel
        let amax_w: Vec<f32> = (0..d).map(|_| 0.5 + rng.next_f32()).collect();
        let s = smooth_scales(&amax_a, &amax_w, 0.8);
        let after: Vec<f32> =
            amax_a.iter().zip(&s).map(|(a, sv)| a / sv).collect();
        let range_before = amax_a.iter().cloned().fold(0.0f32, f32::max)
            / amax_a.iter().cloned().fold(f32::INFINITY, f32::min).max(1e-6);
        let range_after = after.iter().cloned().fold(0.0f32, f32::max)
            / after.iter().cloned().fold(f32::INFINITY, f32::min).max(1e-6);
        if range_after > range_before {
            return Err(format!("range grew: {range_before} -> {range_after}"));
        }
        Ok(())
    });
}

#[test]
fn prop_packed_matrix_storage_ratio() {
    check("packed storage ratio", 10, |rng| {
        let rows = rng.range(4, 40);
        let cols = rng.range(16, 200);
        let bits = [3u32, 4, 8][rng.below(3)];
        let w = Tensor::randn(rng, &[rows, cols], 0.1);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quant::quantize_int_codes(&w, &g, None);
        let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
            .map_err(|e| e.to_string())?;
        if pm.codes() != codes {
            return Err("codes roundtrip".into());
        }
        let ratio = pm.fp_bytes() as f64 / pm.storage_bytes() as f64;
        if ratio > 32.0 / bits as f64 + 1e-9 {
            return Err(format!("impossible ratio {ratio}"));
        }
        Ok(())
    });
}

#[test]
fn prop_native_linear_matches_fakequant_reference() {
    // The native integer GEMM must equal the fake-quant reference
    // (dequantized acts × dequantized weights) up to f32 accumulation, for
    // random shapes and every packed bit-width.
    check("native linear vs fake-quant reference", 25, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let rows = rng.range(1, 9);
        let cout = rng.range(1, 33);
        let cin = rng.range(4, 64);
        let w = Tensor::randn(rng, &[cout, cin], 0.1);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quant::quantize_int_codes(&w, &g, None);
        let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
            .map_err(|e| e.to_string())?;
        let ql = QuantLinear::from_packed(&pm).map_err(|e| e.to_string())?;
        let x = Tensor::randn(rng, &[rows, cin], 1.0);
        let qa = quantize_acts_per_token(&x.data, rows, cin, 255.0);
        let mut ex = ExecState::new(1);
        let got = ql.forward_q(&qa, &mut ex.exec())
            .map_err(|e| e.to_string())?;
        // fake-quant acts = dequantized act codes
        let mut xq = vec![0.0f32; rows * cin];
        for t in 0..rows {
            for c in 0..cin {
                xq[t * cin + c] = (qa.codes[t * cin + c] as f32
                    - qa.zp[t] as f32) * qa.scale[t];
            }
        }
        let want = Tensor::new(vec![rows, cin], xq).matmul_bt(&pm.dequant());
        let denom = (want.frob() / (want.len() as f64).sqrt()).max(1e-9);
        let rel = got.rmse(&want) / denom;
        if rel > 1e-4 {
            return Err(format!(
                "bits {bits} {rows}x{cin}->{cout}: rel rmse {rel}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tile_plan_roundtrips_packed_codes() {
    // The interleaved [tile][col][row-in-tile] layout must round-trip to
    // exactly the codes `PackedMatrix::unpack` produces — bits 3/4/8,
    // ragged tail tiles (cout % MR in 0..=3) included — and the streaming
    // per-row code sums must match the unpacked rows.
    check("tile plan round-trips packed codes", 40, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let cout = rng.range(1, 42);
        let cin = rng.range(1, 70);
        let ints: Vec<u32> =
            (0..cout * cin).map(|_| rng.below(1 << bits) as u32).collect();
        let codes = Tensor::new(vec![cout, cin],
                                ints.iter().map(|&v| v as f32).collect());
        let scale = vec![1.0f32; cout];
        let zp = vec![0.0f32; cout];
        let pm = PackedMatrix::from_codes(&codes, &scale, &zp, bits)
            .map_err(|e| e.to_string())?;
        let flat = pm.unpack();
        let (plan, sums) = TilePlan::from_packed(&pm);
        if plan.n_tiles() != cout.div_ceil(MR) {
            return Err(format!("{} tiles for cout {cout}", plan.n_tiles()));
        }
        let mut row = vec![0u8; cin];
        for j in 0..cout {
            plan.row_codes(j, &mut row);
            let mut want_sum = 0i64;
            for c in 0..cin {
                let want = flat[j * cin + c];
                want_sum += want as i64;
                if row[c] as u32 != want {
                    return Err(format!(
                        "bits {bits} {cout}x{cin} j{j} c{c}: plan {} vs \
                         unpack {want}", row[c]));
                }
            }
            if sums[j] != want_sum {
                return Err(format!(
                    "bits {bits} row {j}: streamed sum {} vs {want_sum}",
                    sums[j]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_planned_linear_is_bit_exact_vs_reference_across_threads() {
    // Planned pool execution (any thread count) must equal the pre-plan
    // reference engine bit for bit on both GEMM paths.
    check("planned linear bit-exact vs reference", 15, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let rows = rng.range(1, 9);
        let cout = rng.range(1, 33);
        let cin = rng.range(4, 64);
        let w = Tensor::randn(rng, &[cout, cin], 0.1);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quant::quantize_int_codes(&w, &g, None);
        let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
            .map_err(|e| e.to_string())?;
        let ql = QuantLinear::from_packed(&pm).map_err(|e| e.to_string())?;
        let x = Tensor::randn(rng, &[rows, cin], 1.0);
        let qa = quantize_acts_per_token(&x.data, rows, cin, 255.0);
        let mut rf = ExecState::new(1).with_mode(ExecMode::Reference);
        let want_q =
            ql.forward_q(&qa, &mut rf.exec()).map_err(|e| e.to_string())?;
        let want_f = ql.forward_fp(&x.data, rows, &mut rf.exec())
            .map_err(|e| e.to_string())?;
        for threads in [1usize, 3] {
            let mut pl = ExecState::new(threads);
            let got_q = ql.forward_q(&qa, &mut pl.exec())
                .map_err(|e| e.to_string())?;
            if got_q != want_q {
                return Err(format!(
                    "q path diverged: bits {bits} {rows}x{cin}->{cout} \
                     threads {threads}"));
            }
            let got_f = ql.forward_fp(&x.data, rows, &mut pl.exec())
                .map_err(|e| e.to_string())?;
            if got_f != want_f {
                return Err(format!(
                    "fp path diverged: bits {bits} {rows}x{cin}->{cout} \
                     threads {threads}"));
            }
        }
        Ok(())
    });
}

fn micro_quantized(rng: &mut Rng, bits: u32) -> QuantizedModel {
    let dim = ModelDim::builtin("micro").unwrap();
    let w = Weights::init(&dim, rng);
    quantize_weights(&w, bits, ScaleInit::Rtn).unwrap()
}

#[test]
fn prop_lrqq_checkpoint_roundtrip() {
    // Serialized quantized checkpoints must reproduce every packed code,
    // grid entry, and FP tensor exactly after a byte roundtrip.
    check("lrqq checkpoint roundtrip", 12, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let qm = micro_quantized(rng, bits);
        let bytes = qm.to_bytes();
        let qm2 = QuantizedModel::from_bytes(&qm.dim, &bytes)
            .map_err(|e| format!("reload failed: {e}"))?;
        if qm2.bits != bits {
            return Err(format!("bits {} != {bits}", qm2.bits));
        }
        if qm2.emb != qm.emb || qm2.head != qm.head
            || qm2.final_norm != qm.final_norm {
            return Err("FP tensors changed across roundtrip".into());
        }
        for (l, (a, b)) in qm.blocks.iter().zip(&qm2.blocks).enumerate() {
            for (i, (pa, pb)) in a.ws.iter().zip(&b.ws).enumerate() {
                if pa.unpack() != pb.unpack() || pa.scale != pb.scale
                    || pa.zp != pb.zp {
                    return Err(format!("block {l} matrix {i} changed"));
                }
            }
            if a.norm_attn != b.norm_attn || a.norm_ffn != b.norm_ffn {
                return Err(format!("block {l} norms changed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lrqq_truncation_fails_closed() {
    // Any prefix of a valid checkpoint must be rejected with an error —
    // never a panic, and never a silently short model.
    check("lrqq truncation fails closed", 12, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let qm = micro_quantized(rng, bits);
        let bytes = qm.to_bytes();
        let cut = rng.below(bytes.len());
        match QuantizedModel::from_bytes(&qm.dim, &bytes[..cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!(
                "accepted truncated checkpoint ({cut}/{} bytes)",
                bytes.len())),
        }
    });
}

#[test]
fn prop_lrqq_bitflip_fails_closed() {
    // A single flipped bit anywhere in the stream must trip the checksum
    // (or a structural check) — corrupt weights must never load as Ok.
    check("lrqq bit flip fails closed", 20, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let qm = micro_quantized(rng, bits);
        let mut bytes = qm.to_bytes();
        let off = rng.below(bytes.len());
        let bit = rng.below(8) as u32;
        bytes[off] ^= 1u8 << bit;
        match QuantizedModel::from_bytes(&qm.dim, &bytes) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!(
                "accepted corrupt checkpoint (bit {bit} at byte {off} of \
                 {})", bytes.len())),
        }
    });
}

// ---- SIMD vs scalar-oracle differential battery (DESIGN.md §11) ----------
//
// Every vector backend runnable on this machine (simd::backends() — scalar
// always first) must reproduce the scalar oracle bit for bit. Integer
// kernels are exact by associativity; the f32 helpers are exact because the
// vector and mirror paths share one accumulator structure.

#[test]
fn simd_dot_u8_exhaustive_tails_and_alignments() {
    // Every tail length 0..=2*LANE at every misalignment offset 0..LANE
    // (unaligned loads are the contract — tiles are lane-padded but
    // activations are not), with codes spanning the 3/4/8-bit ranges.
    let mut rng = Rng::new(0x51D0);
    for be in simd::backends() {
        for bits in [3u32, 4, 8] {
            let hi = 1usize << bits;
            for k in 0..=2 * LANE {
                for off in 0..LANE {
                    let a: Vec<u8> =
                        (0..off + k).map(|_| rng.below(hi) as u8).collect();
                    let b: Vec<u8> =
                        (0..off + k).map(|_| rng.below(hi) as u8).collect();
                    let (sa, sb) = (&a[off..], &b[off..]);
                    assert_eq!(
                        simd::dot_u8(be, sa, sb), dot_u8(sa, sb),
                        "{} bits {bits} k {k} off {off}", be.name());
                }
            }
        }
    }
}

#[test]
fn simd_dot_u8_saturation_bound_is_exact() {
    // The documented worst case: k = MAX_DOT_K of all-255 codes. The total
    // 33_000 * 255 * 255 = 2_145_825_000 sits just under i32::MAX; every
    // backend must land on it exactly (no lane ever saturates).
    let a = vec![255u8; MAX_DOT_K];
    let want = (MAX_DOT_K as i64 * 255 * 255) as i32;
    assert!(i64::from(want) == MAX_DOT_K as i64 * 255 * 255);
    for be in simd::backends() {
        assert_eq!(simd::dot_u8(be, &a, &a), want, "{}", be.name());
        let mut acc = [0i32; 16];
        simd::dot_block_u8(be, &a, MAX_DOT_K, 1, &a, MAX_DOT_K, 1, &mut acc);
        assert_eq!(acc[0], want, "block {}", be.name());
    }
}

#[test]
fn prop_simd_block_dot_matches_scalar_oracle() {
    // The widened micro-kernel across backends: random (k, tn, rn), both a
    // tight stride (reference layout) and the lane-padded plan stride,
    // full-range codes per bit-width.
    check("simd block dot vs oracle", 60, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let hi = 1usize << bits;
        let k = rng.range(1, 80);
        let tn = rng.range(1, 5);
        let rn = rng.range(1, 5);
        let stride =
            if rng.below(2) == 0 { k } else { k.div_ceil(LANE) * LANE };
        let a: Vec<u8> = (0..tn * k).map(|_| rng.below(hi) as u8).collect();
        let wt: Vec<u8> = (0..(rn - 1) * stride + k)
            .map(|_| rng.below(hi) as u8)
            .collect();
        let mut want = [0i32; 16];
        dot_block_u8_scalar(&a, k, tn, &wt, stride, rn, &mut want);
        for be in simd::backends() {
            let mut got = [0i32; 16];
            simd::dot_block_u8(be, &a, k, tn, &wt, stride, rn, &mut got);
            if got != want {
                return Err(format!(
                    "{} bits {bits} k {k} tn {tn} rn {rn} stride {stride}: \
                     {got:?} != {want:?}", be.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simd_f32_helpers_are_bit_equal() {
    // The FP glue helpers (RMSNorm sum-of-squares, attention score dot,
    // softmax max, weighted-V axpy, KV dequant) must be bit-equal across
    // every backend — the vector code mirrors the scalar accumulator
    // structure exactly, so `==` on f32 is the right assertion.
    check("simd f32 helpers bit-equal", 60, |rng| {
        let k = rng.below(70);
        let a: Vec<f32> =
            (0..k).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let b: Vec<f32> =
            (0..k).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let codes: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
        let (s, z) = (0.01 + rng.next_f32(), rng.next_f32() * 16.0);
        let w = rng.next_f32() * 2.0 - 1.0;
        for be in simd::backends() {
            if simd::sum_sq_with(be, &a) != simd::sum_sq_scalar(&a) {
                return Err(format!("sum_sq diverged on {}", be.name()));
            }
            if simd::dot_f32_with(be, &a, &b) != simd::dot_f32_scalar(&a, &b)
            {
                return Err(format!("dot_f32 diverged on {}", be.name()));
            }
            if simd::max_f32_with(be, &a) != simd::max_f32_scalar(&a) {
                return Err(format!("max_f32 diverged on {}", be.name()));
            }
            let mut got = b.clone();
            let mut want = b.clone();
            simd::axpy_with(be, w, &a, &mut got);
            simd::axpy_scalar(w, &a, &mut want);
            if got != want {
                return Err(format!("axpy diverged on {}", be.name()));
            }
            let mut got = vec![0.0f32; k];
            let mut want = vec![0.0f32; k];
            simd::dequant_with(be, &codes, s, z, &mut got);
            simd::dequant_scalar(&codes, s, z, &mut want);
            if got != want {
                return Err(format!("dequant diverged on {}", be.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weight_only_block_dot_stays_sequential() {
    // The f32 weight-only micro-kernel is scalar by contract (kernels.rs):
    // its accumulation must equal the plain sequential dot exactly, for
    // both the tight and the lane-padded stride. A vectorized rewrite that
    // reassociates the f32 adds fails this immediately.
    check("weight-only block dot sequential", 40, |rng| {
        let bits = [3u32, 4, 8][rng.below(3)];
        let hi = 1usize << bits;
        let k = rng.range(1, 80);
        let tn = rng.range(1, 5);
        let rn = rng.range(1, 5);
        let stride =
            if rng.below(2) == 0 { k } else { k.div_ceil(LANE) * LANE };
        let x: Vec<f32> =
            (0..tn * k).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let wt: Vec<u8> = (0..(rn - 1) * stride + k)
            .map(|_| rng.below(hi) as u8)
            .collect();
        let mut acc = [0.0f32; 16];
        dot_block_f32_u8_scalar(&x, k, tn, &wt, stride, rn, &mut acc);
        for t in 0..tn {
            for r in 0..rn {
                let want = dot_f32_u8(&x[t * k..(t + 1) * k],
                                      &wt[r * stride..r * stride + k]);
                if acc[t * 4 + r] != want {
                    return Err(format!(
                        "bits {bits} k {k} t {t} r {r} stride {stride}: \
                         {} != {want}", acc[t * 4 + r]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weight_col_amax_dominates_members() {
    check("col amax dominates", 15, |rng| {
        let cols = rng.range(2, 20);
        let ra = rng.range(1, 6);
        let rb = rng.range(1, 6);
        let a = Tensor::randn(rng, &[ra, cols], 1.0);
        let b = Tensor::randn(rng, &[rb, cols], 1.0);
        let m = weight_col_amax(&[&a, &b]);
        for (j, &mv) in m.iter().enumerate() {
            for t in [&a, &b] {
                let (rows, _) = t.rc();
                for r in 0..rows {
                    if t.data[r * cols + j].abs() > mv + 1e-6 {
                        return Err(format!("col {j} exceeded"));
                    }
                }
            }
        }
        Ok(())
    });
}
