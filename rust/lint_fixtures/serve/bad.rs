//! Seeded violations for the linter self-test (never compiled, only
//! scanned): unjustified panic paths in request-reachable serving code,
//! plus one justified site that must NOT fire.

pub fn answer(resp: Option<&str>) -> &str {
    resp.unwrap()
}

pub fn boom() {
    panic!("request-reachable");
}

pub fn justified(resp: Option<&str>) -> &str {
    // PANIC: exercised by the linter self-test — a justified unwrap is
    // the escape hatch, and it must not be flagged.
    resp.unwrap()
}
