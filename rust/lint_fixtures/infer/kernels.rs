//! Seeded violation for the linter self-test (never compiled, only
//! scanned): a reassociated weight-only f32 kernel. The chunked
//! iterator reduction below changes the accumulation order that the
//! engine's planned == reference bit-equality contract depends on.

pub fn dot_f32_u8(x: &[f32], q: &[u8]) -> f32 {
    x.chunks(8)
        .zip(q.chunks(8))
        .map(|(xs, qs)| {
            xs.iter().zip(qs).map(|(&a, &b)| a * b as f32).sum::<f32>()
        })
        .sum()
}

pub fn dot_block_f32_u8_scalar(x: &[f32], q: &[u8]) -> f32 {
    dot_f32_u8(x, q)
}
