//! Seeded violations for the linter self-test (never compiled, only
//! scanned by `lint::tests`): an undocumented `unsafe` in an allowlisted
//! module, and a forbidden saturating intrinsic.

fn undocumented(p: *const u8) -> u8 {
    unsafe { *p }
}

fn saturating_dot(a: M256, b: M256) -> M256 {
    _mm256_maddubs_epi16(a, b)
}
