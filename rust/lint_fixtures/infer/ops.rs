//! Seeded violation for the linter self-test (never compiled, only
//! scanned): `unsafe` escaping the allowlisted module set. The SAFETY
//! comment is present on purpose — only the confinement rule may fire
//! here, not undocumented-unsafe.

fn sneaky(out: &mut [f32]) {
    // SAFETY: index 0 is in bounds — the caller hands a non-empty slice.
    unsafe {
        *out.get_unchecked_mut(0) = 1.0;
    }
}
