//! Seeded violation for the linter self-test (never compiled, only
//! scanned): a relaxed atomic outside obs/registry.rs with no
//! justification comment anywhere near it.

use std::sync::atomic::{AtomicU64, Ordering};

static SEQ: AtomicU64 = AtomicU64::new(0);

pub fn next() -> u64 {
    SEQ.fetch_add(1, Ordering::Relaxed)
}
