//! Paper-table regeneration smoke bench: times the cheap closed-form tables
//! and one fast end-to-end method run, and points at `lrq report` for the
//! full set (DESIGN.md §5). Run: `cargo bench --bench tables`.

use std::path::Path;

use lrq::bench::Bench;
use lrq::config::{Args, Method, ReconConfig, Scheme};
use lrq::quant::lrq::block_param_ratio;
use lrq::tables::Lab;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::quick();

    // Table 29 is pure arithmetic — verify + time it
    b.run("table29 param-ratio (4 Llama sizes)", || {
        for (d, f, r) in [(4096usize, 11008usize, 1024usize),
                          (5120, 13824, 1024), (6656, 17920, 2048),
                          (8192, 22016, 2048)] {
            std::hint::black_box(block_param_ratio(d, f, r));
        }
    });
    let r7b = block_param_ratio(4096, 11008, 1024);
    println!("  Llama-7B ratio = {:.2}% (paper: 39.51%)", r7b * 100.0);

    // one fast quantize+eval pass (RTN, tiny) if the testbed is set up
    let dir = std::env::var("LRQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if Path::new(&dir).join("manifest.txt").exists()
        && Path::new("weights_tiny.bin").exists()
    {
        let mut args = Args::default();
        args.options.insert("artifacts".into(), dir);
        args.options.insert("tasks".into(), "40".into());
        let lab = Lab::new(&args, "tiny")?;
        let recon = ReconConfig { steps: 0, calib_samples: 16,
                                  ..lab.recon };
        let t0 = std::time::Instant::now();
        let out = lab.quantize(Method::Rtn, Scheme::w8a8_static(), recon)?;
        let s = lab.summary_of(&out, Scheme::w8a8_static())?;
        println!("RTN tiny quantize+eval: {:.2}s (CSR {:.1}%, MMLU {:.1}%)",
                 t0.elapsed().as_secs_f64(), s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0);
    } else {
        println!("(skipping e2e table bench: need artifacts/ and \
                  weights_tiny.bin)");
    }
    println!("\nfull regeneration: `cargo run --release -- report` \
              (writes reports/*.md)");
    let _ = b;
    Ok(())
}
