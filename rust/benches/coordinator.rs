//! Coordinator hot-path benches: per-step latency of the AOT executables the
//! PTQ pipeline drives (block_fwd, block_fwd_q, recon step, train step) and
//! the L3 overhead around them (literal construction, input assembly).
//! Run: `cargo bench --bench coordinator`.

use std::path::Path;
use std::time::Duration;

use lrq::bench::Bench;
use lrq::config::{Method, ReconConfig, Scheme};
use lrq::coordinator::Engine;
use lrq::data::{Corpus, CorpusConfig};
use lrq::methods::recon_driver;
use lrq::methods::BlockContext;
use lrq::model::Weights;
use lrq::rng::Rng;
use lrq::runtime::{to_lit, Runtime};
use lrq::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("LRQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !Path::new(&dir).join("manifest.txt").exists() {
        println!("(artifacts missing — run `make artifacts` first)");
        return Ok(());
    }
    let rt = Runtime::load(Path::new(&dir))?;
    let cfg = "tiny";
    let dim = rt.dim(cfg)?;
    let engine = Engine::new(&rt, cfg)?;
    let mut rng = Rng::new(11);
    let weights = Weights::init(&dim, &mut rng);
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let mut b = Bench {
        budget: Duration::from_secs(3),
        ..Bench::default()
    };

    // embed + block_fwd + head: the eval/serving chain pieces
    let ids = corpus.calib_batch(dim.calib_batch, dim.seq, &mut rng);
    let toks = (dim.calib_batch * dim.seq) as f64;
    let x = engine.embed(&weights.emb, &ids)?;
    b.run_units("engine::embed (8x64)", Some(toks), &mut || {
        std::hint::black_box(engine.embed(&weights.emb, &ids).unwrap());
    });
    b.run_units("engine::block_fp (8x64x128)", Some(toks), &mut || {
        std::hint::black_box(
            engine.block_fp(&x, &weights.blocks[0]).unwrap());
    });
    let scheme = Scheme::w8a8_static();
    let out0 = engine.block_fp(&x, &weights.blocks[0])?;
    let whats: Vec<Tensor> = weights.blocks[0].ws.clone();
    b.run_units("engine::block_q (8x64x128, W8A8KV8)", Some(toks), &mut || {
        std::hint::black_box(
            engine
                .block_q(&x, &whats, &weights.blocks[0].norm_attn,
                         &weights.blocks[0].norm_ffn, &out0.stats, &scheme)
                .unwrap());
    });
    let tgt: Vec<i32> = {
        let mut t: Vec<i32> = ids[1..].to_vec();
        t.push(0);
        t
    };
    b.run_units("engine::head_logp (8x64)", Some(toks), &mut || {
        std::hint::black_box(
            engine
                .head_logp(&x, &weights.final_norm, &weights.head, &tgt)
                .unwrap());
    });
    b.run_units("engine::fp_forward full chain", Some(toks), &mut || {
        std::hint::black_box(
            engine.fp_forward(&weights, &ids, &tgt).unwrap());
    });

    // one reconstruction Adam step (the PTQ hot loop) per method
    let y_t = vec![out0.y.clone()];
    let x_q = vec![x.clone()];
    for (method, rank, label) in [
        (Method::Lrq, dim.rank, "recon step LRQ r32"),
        (Method::FlexRound, 0usize, "recon step FlexRound"),
    ] {
        let recon = ReconConfig { steps: 5, calib_samples: 8,
                                  ..ReconConfig::default() };
        let ctx = BlockContext {
            dim: &dim,
            weights: &weights.blocks[0],
            x_q: &x_q,
            y_t: &y_t,
            acts_q: None,
            stats: &out0.stats,
            scheme,
            recon,
            block_index: 0,
        };
        // measure per-step cost by running 5-step recon and dividing
        b.run_units(&format!("{label} (5 steps, amortized)"), Some(5.0),
                    &mut || {
            std::hint::black_box(
                recon_driver::run_recon(&rt, &engine, method, &ctx,
                                        &weights.blocks[0], rank)
                    .unwrap());
        });
    }

    // L3-side literal overhead: weight -> literal conversion
    let w = &weights.blocks[0].ws[4];
    b.run_units("runtime::to_lit 352x128", Some(w.len() as f64), &mut || {
        std::hint::black_box(to_lit(w).unwrap());
    });

    Ok(())
}
