//! Native-engine benches: planned vs pre-plan GEMM rates, activation
//! quantization, end-to-end tokens/sec of the packed-checkpoint forward at
//! each bit-width and shard count, prefill + incremental-decode tokens/sec
//! (planned engine vs the pre-plan per-call-unpack engine, and quantized KV
//! cache on vs off — the serving-side numbers behind the Appendix G /
//! Fig. 5 story, without PJRT).
//!
//! Run: `cargo bench --bench native` (full) or
//! `cargo bench --bench native -- --smoke` (CI: seconds, not minutes).
//! Either way the headline rates land in **`BENCH_native.json`**
//! (machine-readable: prefill tok/s, decode tok/s, the planned-vs-pre-plan
//! decode speedup per bit-width, and the observability-overhead row —
//! decode tok/s with the profiler + tracer on vs off) so the perf
//! trajectory is tracked across PRs. The `obs` row also records which
//! micro-kernel backend ran (`avx2`/`sse2`/`scalar` — the SIMD dispatch of
//! DESIGN.md §11; pin it with `LRQ_FORCE_SCALAR=1`), and `-- --out PATH`
//! redirects the JSON so CI's forced-scalar lane can emit its own artifact.
//! `-- --compare PATH` additionally gates against a committed baseline:
//! exit nonzero when planned decode tok/s regresses more than 30%
//! (zero-valued baseline entries are provisional and skipped).

use std::time::Duration;

use lrq::bench::{Bench, BenchStats};
use lrq::config::Scheme;
use lrq::data::{Corpus, CorpusConfig};
use lrq::infer::kernels::quantize_acts_per_token;
use lrq::infer::ops::head_logits;
use lrq::infer::{prepare_native, quantize_weights, start_native_server,
                 ExecMode, ExecState, QuantLinear, ScaleInit};
use lrq::model::{ModelDim, Weights};
use lrq::quant::{self, grid::rtn_grid, lrq::quantize_int_codes,
                 PackedMatrix};
use lrq::rng::{sample_top_k, Rng};
use lrq::serve::ServerConfig;
use lrq::tensor::Tensor;

/// Headline rates of one bit-width (the JSON row).
struct BitRates {
    bits: u32,
    prefill_tok_s: f64,
    decode_tok_s: f64,
    decode_preplan_tok_s: f64,
}

/// Decode tok/s with all instrumentation off vs profiler + tracing on (the
/// observability overhead row).
struct ObsRates {
    decode_tok_s_off: f64,
    decode_tok_s_on: f64,
}

fn rate(st: &BenchStats) -> f64 {
    st.units_per_iter.unwrap_or(0.0) / st.mean.as_secs_f64()
}

fn write_json(path: &str, smoke: bool, cfg: &str, rates: &[BitRates],
              obs: &ObsRates) -> std::io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"native\",\n  \"smoke\": {smoke},\n  \
         \"config\": \"{cfg}\",\n"));
    s.push_str("  \"per_bit\": [\n");
    for (i, r) in rates.iter().enumerate() {
        let speedup = r.decode_tok_s / r.decode_preplan_tok_s.max(1e-9);
        s.push_str(&format!(
            "    {{\"w_bits\": {}, \"prefill_tok_s\": {:.1}, \
             \"decode_tok_s\": {:.1}, \"decode_preplan_tok_s\": {:.1}, \
             \"decode_speedup\": {:.2}}}{}\n",
            r.bits, r.prefill_tok_s, r.decode_tok_s, r.decode_preplan_tok_s,
            speedup, if i + 1 < rates.len() { "," } else { "" }));
    }
    s.push_str("  ],\n");
    let overhead_pct = if obs.decode_tok_s_on > 0.0 {
        (obs.decode_tok_s_off / obs.decode_tok_s_on - 1.0) * 100.0
    } else {
        0.0
    };
    s.push_str(&format!(
        "  \"obs\": {{\"kernel\": \"{}\", \"decode_tok_s_off\": {:.1}, \
         \"decode_tok_s_on\": {:.1}, \"overhead_pct\": {:.1}}}\n",
        lrq::infer::simd::active().name(),
        obs.decode_tok_s_off, obs.decode_tok_s_on, overhead_pct));
    s.push_str("}\n");
    std::fs::write(path, &s)?;
    println!("\nwrote {path} ({} bytes)", s.len());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let compare = argv
        .iter()
        .position(|a| a == "--compare")
        .and_then(|i| argv.get(i + 1).cloned());
    let out_path = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1).cloned())
        .unwrap_or_else(|| "BENCH_native.json".to_string());
    println!("kernel dispatch: {}", lrq::infer::simd::describe());
    let mut b = if smoke {
        // CI mode: keep it compiling and emitting, not statistically deep
        Bench {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(40),
            max_iters: 50,
            results: Vec::new(),
        }
    } else {
        Bench::quick()
    };
    let mut rng = Rng::new(77);

    // ---- kernel level: one linear, 512 tokens × (352 out, 128 in) --------
    if !smoke {
        let (t, cout, cin) = (512usize, 352usize, 128usize);
        let x = Tensor::randn(&mut rng, &[t, cin], 1.0);
        let flops = 2.0 * t as f64 * cin as f64 * cout as f64;
        {
            let w = Tensor::randn(&mut rng, &[cout, cin], 0.05);
            b.run_units("f32 matmul_bt baseline 512x128 @ 352x128T",
                        Some(flops), &mut || {
                std::hint::black_box(x.matmul_bt(&w));
            });
        }
        b.run_units("act quant per-token 512x128", Some((t * cin) as f64),
                    &mut || {
            std::hint::black_box(
                quantize_acts_per_token(&x.data, t, cin, 255.0));
        });
        for bits in [3u32, 4, 8] {
            let w = Tensor::randn(&mut rng, &[cout, cin], 0.05);
            let g = rtn_grid(&w, quant::qmax(bits));
            let codes = quantize_int_codes(&w, &g, None);
            let pm =
                PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)?;
            let ql = QuantLinear::from_packed(&pm)?;
            let qa = quantize_acts_per_token(&x.data, t, cin, 255.0);
            let mut pl = ExecState::new(1);
            let mut rf = ExecState::new(1).with_mode(ExecMode::Reference);
            b.run_units(&format!("int GEMM {bits}-bit planned"),
                        Some(flops), &mut || {
                std::hint::black_box(
                    ql.forward_q(&qa, &mut pl.exec()).unwrap());
            });
            b.run_units(&format!("int GEMM {bits}-bit pre-plan unpack"),
                        Some(flops), &mut || {
                std::hint::black_box(
                    ql.forward_q(&qa, &mut rf.exec()).unwrap());
            });
            b.run_units(&format!("weight-only GEMM {bits}-bit planned"),
                        Some(flops), &mut || {
                std::hint::black_box(
                    ql.forward_fp(&x.data, t, &mut pl.exec()).unwrap());
            });
        }
    }

    // ---- model level: tiny config --------------------------------------
    let dim = ModelDim::builtin("tiny").expect("builtin tiny");
    let weights = Weights::init(&dim, &mut Rng::new(3));
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));

    if !smoke {
        let (ids, tgt) = {
            let mut r = Rng::new(5);
            corpus.eval_stream(dim.calib_batch, dim.seq, &mut r)
        };
        let tokens = (dim.calib_batch * dim.seq) as f64;
        println!("\ntokens/sec vs bit-width (tiny, W?A8 per-token, 1 shard):");
        for bits in [3u32, 4, 8] {
            let scheme = Scheme { w_bits: bits, ..Scheme::w4a8_token() };
            let model = prepare_native(&weights, scheme, ScaleInit::Rtn,
                                       &corpus, 1, 7, 1)?;
            b.run_units(&format!("NativeModel forward tiny W{bits}A8"),
                        Some(tokens), &mut || {
                std::hint::black_box(model.forward(&ids, &tgt).unwrap());
            });
        }
        println!("\ntokens/sec vs shard count (tiny, W4A8 per-token):");
        for shards in [1usize, 2, 4, 8] {
            let model = prepare_native(&weights, Scheme::w4a8_token(),
                                       ScaleInit::Rtn, &corpus, 1, 7,
                                       shards)?;
            b.run_units(
                &format!("NativeModel forward tiny W4A8 shards={shards}"),
                Some(tokens), &mut || {
                    std::hint::black_box(model.forward(&ids, &tgt).unwrap());
                });
        }
    }

    // ---- headline: prefill + decode tokens/sec, planned vs pre-plan ------
    // the pre-plan engine (ExecMode::Reference) is the engine this PR
    // replaced: per-call tile unpack, scalar dots, no persistent pool
    println!("\nprefill + decode tokens/sec, planned vs pre-plan engine \
              (tiny, 1 shard):");
    let prompt: Vec<i32> = {
        let mut r = Rng::new(11);
        (0..8).map(|_| r.below(dim.vocab) as i32).collect()
    };
    let pprompt: Vec<i32> = {
        let mut r = Rng::new(13);
        (0..48.min(dim.seq)).map(|_| r.below(dim.vocab) as i32).collect()
    };
    let gen_n = if smoke { 6usize } else { 24 };
    let mut rates: Vec<BitRates> = Vec::new();
    for bits in [3u32, 4, 8] {
        let scheme = Scheme { w_bits: bits, ..Scheme::w4a8_token() };
        let model = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus,
                                   1, 7, 1)?;
        let preplan = model.clone().with_mode(ExecMode::Reference);
        let prefill_tok_s = rate(b.run_units(
            &format!("prefill W{bits}A8 {} tokens", pprompt.len()),
            Some(pprompt.len() as f64), &mut || {
                let mut c = model.new_cache();
                std::hint::black_box(model.prefill(&pprompt, &mut c)
                                     .unwrap());
            }));
        let decode_tok_s = rate(b.run_units(
            &format!("decode W{bits}A8 planned"), Some(gen_n as f64),
            &mut || {
                std::hint::black_box(
                    model.generate(&prompt, gen_n, 1, 9).unwrap());
            }));
        let decode_preplan_tok_s = rate(b.run_units(
            &format!("decode W{bits}A8 pre-plan engine"),
            Some(gen_n as f64), &mut || {
                std::hint::black_box(
                    preplan.generate(&prompt, gen_n, 1, 9).unwrap());
            }));
        println!("  -> W{bits}A8 planned decode speedup vs pre-plan: \
                  {:.2}x", decode_tok_s / decode_preplan_tok_s.max(1e-9));
        rates.push(BitRates {
            bits,
            prefill_tok_s,
            decode_tok_s,
            decode_preplan_tok_s,
        });
    }

    // ---- observability overhead: decode tok/s, instrumentation off vs on -
    println!("\nobservability overhead (tiny W4A8 decode, profiler + \
              tracing on vs off):");
    let obs = {
        let model = prepare_native(&weights, Scheme::w4a8_token(),
                                   ScaleInit::Rtn, &corpus, 1, 7, 1)?;
        let decode_tok_s_off = rate(b.run_units(
            "decode W4A8 obs off", Some(gen_n as f64), &mut || {
                std::hint::black_box(
                    model.generate(&prompt, gen_n, 1, 9).unwrap());
            }));
        let tpath = std::env::temp_dir().join(format!(
            "lrq_bench_obs_{}.trace.json", std::process::id()));
        lrq::obs::trace::init(&tpath)?;
        model.profiler().set_enabled(true);
        let decode_tok_s_on = rate(b.run_units(
            "decode W4A8 obs on (profile + trace)", Some(gen_n as f64),
            &mut || {
                std::hint::black_box(
                    model.generate(&prompt, gen_n, 1, 9).unwrap());
            }));
        model.profiler().set_enabled(false);
        let events = lrq::obs::trace::shutdown()?;
        let _ = std::fs::remove_file(&tpath);
        println!("  -> {:.1} tok/s instrumented vs {:.1} plain \
                  ({:+.1}% overhead, {events} trace events)",
                 decode_tok_s_on, decode_tok_s_off,
                 (decode_tok_s_off / decode_tok_s_on.max(1e-9) - 1.0)
                     * 100.0);
        ObsRates { decode_tok_s_off, decode_tok_s_on }
    };

    // ---- decode level: quantized KV cache on vs full-context re-forward --
    if !smoke {
        println!("\ndecode tokens/sec: kv-cache incremental vs full-context \
                  re-forward (tiny):");
        for bits in [4u32, 8] {
            let scheme = Scheme { w_bits: bits, ..Scheme::w4a8_token() };
            let model = prepare_native(&weights, scheme, ScaleInit::Rtn,
                                       &corpus, 1, 7, 1)?;
            b.run_units(&format!("decode W{bits}A8 kv-cache ON"),
                        Some(gen_n as f64), &mut || {
                std::hint::black_box(
                    model.generate(&prompt, gen_n, 1, 9).unwrap());
            });
            b.run_units(&format!("decode W{bits}A8 kv-cache OFF"),
                        Some(gen_n as f64), &mut || {
                let mut r = Rng::new(9);
                let mut ids = prompt.clone();
                for _ in 0..gen_n {
                    let mut padded = ids.clone();
                    padded.resize(dim.seq, 0);
                    let hidden = model.forward_hidden(&padded).unwrap();
                    let logits =
                        head_logits(&hidden, &model.final_norm, &model.head);
                    let next =
                        sample_top_k(logits.row(ids.len() - 1), 1, &mut r);
                    ids.push(next as i32);
                }
                std::hint::black_box(ids);
            });
        }
    }

    // ---- serving level: dynamic batcher over the native scorer -----------
    if !smoke {
        println!("\nbatched serving (tiny, W4A8, 2 shards):");
        let model = prepare_native(&weights, Scheme::w4a8_token(),
                                   ScaleInit::Rtn, &corpus, 1, 7, 2)?;
        let qm = quantize_weights(&weights, 4, ScaleInit::Rtn)?;
        println!("packed checkpoint: {:.2} MB (fp32 {:.2} MB)",
                 qm.storage_bytes() as f64 / 1e6,
                 qm.fp_equivalent_bytes() as f64 / 1e6);
        let server = start_native_server(
            model,
            ServerConfig {
                max_batch: dim.calib_batch,
                max_wait: Duration::from_millis(2),
            },
        )?;
        let n = 64usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for k in 0..4u64 {
            let client = server.client();
            let vocab = dim.vocab;
            handles.push(std::thread::spawn(move || {
                let mut r = Rng::new(0xBE ^ k);
                for _ in 0..n / 4 {
                    let len = r.range(8, 48);
                    let ids: Vec<i32> =
                        (0..len).map(|_| r.below(vocab) as i32).collect();
                    client.score(ids).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed();
        let m = server.metrics.lock().unwrap().clone();
        println!("{}", m.summary(wall));
        println!("wall {:.2}s, {:.0} tokens/s at seq {}",
                 wall.as_secs_f64(),
                 m.throughput(wall) * dim.seq as f64, dim.seq);
    }

    write_json(&out_path, smoke, &dim.name, &rates, &obs)?;

    // ---- regression gate: --compare BASELINE.json ------------------------
    // fail (exit nonzero) when planned decode tok/s drops > 30% below the
    // committed baseline; zero-valued (provisional) baseline entries are
    // skipped so the gate only arms once real numbers are committed
    if let Some(bpath) = compare {
        let baseline = std::fs::read_to_string(&bpath)
            .map_err(|e| anyhow::anyhow!("reading baseline {bpath}: {e}"))?;
        let current = std::fs::read_to_string(&out_path)?;
        let provisional = lrq::bench::json_key_numbers(
            &baseline, "decode_tok_s")
            .iter()
            .filter(|v| **v <= 0.0)
            .count();
        if provisional > 0 {
            println!("bench compare: skipping {provisional} provisional \
                      (zero-valued) baseline entries");
        }
        let regs = lrq::bench::regressions(&baseline, &current,
                                           "decode_tok_s", 0.30);
        if regs.is_empty() {
            println!("bench compare vs {bpath}: ok");
        } else {
            for r in &regs {
                eprintln!("bench regression: {r}");
            }
            anyhow::bail!("{} decode-throughput regression(s) vs {bpath}",
                          regs.len());
        }
    }
    Ok(())
}
