//! Native-engine benches: integer GEMM vs the f32 substrate, activation
//! quantization, end-to-end tokens/sec of the packed-checkpoint forward at
//! each bit-width and shard count, and incremental-decode tokens/sec with
//! the quantized KV cache on vs off (the serving-side numbers behind the
//! Appendix G / Fig. 5 story, without PJRT). Run: `cargo bench --bench
//! native`.

use std::time::Duration;

use lrq::bench::Bench;
use lrq::config::Scheme;
use lrq::data::{Corpus, CorpusConfig};
use lrq::infer::kernels::quantize_acts_per_token;
use lrq::infer::ops::head_logits;
use lrq::infer::{prepare_native, quantize_weights, start_native_server,
                 QuantLinear, ScaleInit};
use lrq::model::{ModelDim, Weights};
use lrq::quant::{self, grid::rtn_grid, lrq::quantize_int_codes,
                 PackedMatrix};
use lrq::rng::{sample_top_k, Rng};
use lrq::serve::ServerConfig;
use lrq::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::quick();
    let mut rng = Rng::new(77);

    // ---- kernel level: one linear, 512 tokens × (352 out, 128 in) --------
    let (t, cout, cin) = (512usize, 352usize, 128usize);
    let x = Tensor::randn(&mut rng, &[t, cin], 1.0);
    let flops = 2.0 * t as f64 * cin as f64 * cout as f64;
    {
        let w = Tensor::randn(&mut rng, &[cout, cin], 0.05);
        b.run_units("f32 matmul_bt baseline 512x128 @ 352x128T",
                    Some(flops), &mut || {
            std::hint::black_box(x.matmul_bt(&w));
        });
    }
    b.run_units("act quant per-token 512x128", Some((t * cin) as f64),
                &mut || {
        std::hint::black_box(quantize_acts_per_token(&x.data, t, cin, 255.0));
    });
    for bits in [3u32, 4, 8] {
        let w = Tensor::randn(&mut rng, &[cout, cin], 0.05);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quantize_int_codes(&w, &g, None);
        let pm =
            PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)?;
        let ql = QuantLinear::from_packed(&pm)?;
        let qa = quantize_acts_per_token(&x.data, t, cin, 255.0);
        b.run_units(&format!("QuantLinear int8-act GEMM {bits}-bit"),
                    Some(flops), &mut || {
            std::hint::black_box(ql.forward_q(&qa, 1).unwrap());
        });
        b.run_units(&format!("QuantLinear weight-only GEMM {bits}-bit"),
                    Some(flops), &mut || {
            std::hint::black_box(ql.forward_fp(&x.data, t, 1).unwrap());
        });
    }

    // ---- model level: tiny config, tokens/sec vs bits and shards ---------
    let dim = ModelDim::builtin("tiny").expect("builtin tiny");
    let weights = Weights::init(&dim, &mut Rng::new(3));
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let (ids, tgt) = {
        let mut r = Rng::new(5);
        corpus.eval_stream(dim.calib_batch, dim.seq, &mut r)
    };
    let tokens = (dim.calib_batch * dim.seq) as f64;

    println!("\ntokens/sec vs bit-width (tiny, W?A8 per-token, 1 shard):");
    for bits in [3u32, 4, 8] {
        let scheme = Scheme { w_bits: bits, ..Scheme::w4a8_token() };
        let model = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus,
                                   1, 7, 1)?;
        b.run_units(&format!("NativeModel forward tiny W{bits}A8"),
                    Some(tokens), &mut || {
            std::hint::black_box(model.forward(&ids, &tgt).unwrap());
        });
    }
    println!("\ntokens/sec vs shard count (tiny, W4A8 per-token):");
    for shards in [1usize, 2, 4, 8] {
        let model = prepare_native(&weights, Scheme::w4a8_token(),
                                   ScaleInit::Rtn, &corpus, 1, 7, shards)?;
        b.run_units(&format!("NativeModel forward tiny W4A8 shards={shards}"),
                    Some(tokens), &mut || {
            std::hint::black_box(model.forward(&ids, &tgt).unwrap());
        });
    }

    // ---- decode level: tokens/sec, quantized KV cache on vs off ----------
    // "cache on" prefills the prompt then decodes token-by-token against
    // cached u8 K/V codes; "cache off" is the pre-decode serving story —
    // every new token re-runs the full-context forward over the padded
    // sequence and reads the logits at its position.
    println!("\ndecode tokens/sec: kv-cache incremental vs full-context \
              re-forward (tiny):");
    let prompt: Vec<i32> = {
        let mut r = Rng::new(11);
        (0..8).map(|_| r.below(dim.vocab) as i32).collect()
    };
    let gen_n = 24usize;
    for bits in [3u32, 4, 8] {
        let scheme = Scheme { w_bits: bits, ..Scheme::w4a8_token() };
        let model = prepare_native(&weights, scheme, ScaleInit::Rtn, &corpus,
                                   1, 7, 1)?;
        b.run_units(&format!("decode W{bits}A8 kv-cache ON"),
                    Some(gen_n as f64), &mut || {
            std::hint::black_box(
                model.generate(&prompt, gen_n, 1, 9).unwrap());
        });
        b.run_units(&format!("decode W{bits}A8 kv-cache OFF"),
                    Some(gen_n as f64), &mut || {
            let mut r = Rng::new(9);
            let mut ids = prompt.clone();
            for _ in 0..gen_n {
                let mut padded = ids.clone();
                padded.resize(dim.seq, 0);
                let hidden = model.forward_hidden(&padded).unwrap();
                let logits =
                    head_logits(&hidden, &model.final_norm, &model.head);
                let next =
                    sample_top_k(logits.row(ids.len() - 1), 1, &mut r);
                ids.push(next as i32);
            }
            std::hint::black_box(ids);
        });
    }

    // ---- serving level: dynamic batcher over the native scorer -----------
    println!("\nbatched serving (tiny, W4A8, 2 shards):");
    {
        let model = prepare_native(&weights, Scheme::w4a8_token(),
                                   ScaleInit::Rtn, &corpus, 1, 7, 2)?;
        let qm = quantize_weights(&weights, 4, ScaleInit::Rtn)?;
        println!("packed checkpoint: {:.2} MB (fp32 {:.2} MB)",
                 qm.storage_bytes() as f64 / 1e6,
                 qm.fp_equivalent_bytes() as f64 / 1e6);
        let server = start_native_server(
            model,
            ServerConfig {
                max_batch: dim.calib_batch,
                max_wait: Duration::from_millis(2),
            },
        )?;
        let n = 64usize;
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for k in 0..4u64 {
            let client = server.client();
            let vocab = dim.vocab;
            handles.push(std::thread::spawn(move || {
                let mut r = Rng::new(0xBE ^ k);
                for _ in 0..n / 4 {
                    let len = r.range(8, 48);
                    let ids: Vec<i32> =
                        (0..len).map(|_| r.below(vocab) as i32).collect();
                    client.score(ids).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed();
        let m = server.metrics.lock().unwrap().clone();
        println!("{}", m.summary(wall));
        println!("wall {:.2}s, {:.0} tokens/s at seq {}",
                 wall.as_secs_f64(),
                 m.throughput(wall) * dim.seq as f64, dim.seq);
    }
    Ok(())
}
