//! L1/L3 kernel microbenches: the AOT Pallas kernels (fused LRQ fake-quant,
//! dequant-matmul) through PJRT, and their native Rust counterparts (the
//! finalize path), plus packing. Run: `cargo bench --bench kernels`.
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (`lrq::bench`) with mean/p50/p95/min + throughput.

use std::path::Path;

use lrq::bench::Bench;
use lrq::quant::{self, fakequant_lrq, rtn_grid, LrqParams, PackedMatrix};
use lrq::rng::Rng;
use lrq::runtime::{to_lit, Runtime};
use lrq::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let mut b = Bench::default();
    let mut rng = Rng::new(7);

    // ---- native tensor substrate -----------------------------------------
    {
        let x = Tensor::randn(&mut rng, &[512, 128], 1.0);
        let w = Tensor::randn(&mut rng, &[352, 128], 1.0);
        let flops = 2.0 * 512.0 * 128.0 * 352.0;
        b.run_units("tensor::matmul_bt 512x128 @ 352x128T",
                    Some(flops), &mut || {
            std::hint::black_box(x.matmul_bt(&w));
        });
    }

    // ---- native LRQ fake-quant (finalize path) ---------------------------
    {
        let w = Tensor::randn(&mut rng, &[352, 128], 0.05);
        let grid = rtn_grid(&w, 255.0);
        let mut p = LrqParams::init(&mut rng, 352, 128, 32);
        p.l2 = Tensor::randn(&mut rng, &[352, 32], 0.02);
        let elems = (352 * 128) as f64;
        b.run_units("quant::fakequant_lrq 352x128 r32", Some(elems),
                    &mut || {
            std::hint::black_box(fakequant_lrq(&w, &grid, &p));
        });
    }

    // ---- packing ----------------------------------------------------------
    for bits in [3u32, 4, 8] {
        let w = Tensor::randn(&mut rng, &[352, 128], 0.05);
        let grid = rtn_grid(&w, quant::qmax(bits));
        let codes = quant::quantize_int_codes(&w, &grid, None);
        let pm = PackedMatrix::from_codes(&codes, &grid.scale, &grid.zp, bits)?;
        let elems = (352 * 128) as f64;
        b.run_units(&format!("pack::from_codes {bits}-bit 352x128"),
                    Some(elems), &mut || {
            std::hint::black_box(
                PackedMatrix::from_codes(&codes, &grid.scale, &grid.zp, bits)
                    .unwrap());
        });
        b.run_units(&format!("pack::dequant {bits}-bit 352x128"),
                    Some(elems), &mut || {
            std::hint::black_box(pm.dequant());
        });
    }

    // ---- AOT Pallas kernels through PJRT ----------------------------------
    let dir = std::env::var("LRQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !Path::new(&dir).join("manifest.txt").exists() {
        println!("(artifacts missing — run `make artifacts` for the AOT \
                  kernel benches)");
        return Ok(());
    }
    let rt = Runtime::load(Path::new(&dir))?;
    for cfg in ["tiny", "small"] {
        let dim = match rt.dim(cfg) {
            Ok(d) => d,
            Err(_) => continue,
        };
        // fused LRQ fake-quant kernel (gate projection shape)
        {
            let exec = rt.exec(&format!("kernel_fakequant_{cfg}"))?;
            let (co, ci, r) = (dim.ff, dim.d, dim.rank);
            let w = Tensor::randn(&mut rng, &[co, ci], 0.05);
            let grid = rtn_grid(&w, 255.0);
            let inputs = vec![
                to_lit(&w)?,
                to_lit(&Tensor::new(vec![co], grid.scale.clone()))?,
                to_lit(&Tensor::new(vec![co], grid.zp.clone()))?,
                to_lit(&Tensor::zeros(&[co, r]))?,
                to_lit(&Tensor::randn(&mut rng, &[r, ci], 0.01))?,
                to_lit(&Tensor::zeros(&[co]))?,
                to_lit(&Tensor::zeros(&[ci]))?,
                to_lit(&Tensor::scalar(255.0))?,
            ];
            let elems = (co * ci) as f64;
            b.run_units(&format!("pjrt kernel_fakequant_{cfg} {co}x{ci} r{r}"),
                        Some(elems), &mut || {
                std::hint::black_box(exec.run(&inputs).unwrap());
            });
        }
        // dequant-matmul serving kernel
        {
            let exec = rt.exec(&format!("kernel_qmm_{cfg}"))?;
            let t = dim.calib_batch * dim.seq;
            let (k, n) = (dim.d, dim.ff);
            let x = Tensor::randn(&mut rng, &[t, k], 1.0);
            let w = Tensor::randn(&mut rng, &[n, k], 0.05);
            let grid = rtn_grid(&w, 15.0);
            let codes = quant::quantize_int_codes(&w, &grid, None);
            let inputs = vec![
                to_lit(&x)?,
                to_lit(&codes)?,
                to_lit(&Tensor::new(vec![n], grid.scale.clone()))?,
                to_lit(&Tensor::new(vec![n], grid.zp.clone()))?,
            ];
            let flops = 2.0 * t as f64 * k as f64 * n as f64;
            b.run_units(&format!("pjrt kernel_qmm_{cfg} {t}x{k} @ {n}x{k}T"),
                        Some(flops), &mut || {
                std::hint::black_box(exec.run(&inputs).unwrap());
            });
        }
    }
    Ok(())
}
