//! Serving-path benches: batcher overhead with a mock scorer (pure L3), and
//! end-to-end request latency with the real PJRT engine (FP16 vs quantized
//! weights — the Fig. 5 measurement). Run: `cargo bench --bench serving`.

use std::path::Path;
use std::time::{Duration, Instant};

use lrq::bench::Bench;
use lrq::rng::Rng;
use lrq::serve::{BatchScorer, MockScorer, Server, ServerConfig};

fn drive(server: &Server, requests: usize, threads: usize) -> Duration {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for k in 0..threads {
        let c = server.client();
        let per = requests / threads;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(k as u64 ^ 0xABCD);
            for _ in 0..per {
                let len = rng.range(4, 16);
                let ids: Vec<i32> =
                    (0..len).map(|_| rng.below(100) as i32).collect();
                c.score(ids).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed()
}

fn main() -> anyhow::Result<()> {
    let mut b = Bench::quick();

    // pure batcher overhead (mock scorer: no model work)
    for max_batch in [1usize, 4, 8] {
        let server = Server::start(
            ServerConfig {
                max_batch,
                max_wait: Duration::from_micros(200),
            },
            move || {
                Ok(Box::new(MockScorer { batch: 8, seq: 64, calls: 0 })
                    as Box<dyn BatchScorer>)
            },
        )?;
        let client = server.client();
        b.run_units(&format!("batcher roundtrip (mock, max_batch={max_batch})"),
                    Some(1.0), &mut || {
            std::hint::black_box(client.score(vec![1, 2, 3]).unwrap());
        });
    }

    // concurrent-load throughput with the mock scorer
    {
        let server = Server::start(ServerConfig::default(), move || {
            Ok(Box::new(MockScorer { batch: 8, seq: 64, calls: 0 })
                as Box<dyn BatchScorer>)
        })?;
        let n = 2000usize;
        let wall = drive(&server, n, 4);
        let m = server.metrics.lock().unwrap();
        println!(
            "mock load: {n} reqs in {:?} -> {:.0} req/s, p50 {:?}, p95 {:?}, \
             mean batch {:.2}",
            wall,
            n as f64 / wall.as_secs_f64(),
            m.p50_latency(),
            m.p95_latency(),
            m.mean_batch()
        );
    }

    // real engine (only when artifacts + cached weights exist)
    let dir = std::env::var("LRQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let wpath = "weights_tiny.bin".to_string();
    if Path::new(&dir).join("manifest.txt").exists()
        && Path::new(&wpath).exists()
    {
        use lrq::config::Args;
        let mut args = Args::default();
        args.options.insert("artifacts".into(), dir.clone());
        args.options.insert("weights".into(), wpath.clone());
        println!("\nreal-engine serving (FP16, tiny):");
        lrq::tables::serving_run(&dir, "tiny", &wpath, None, 16, 64, 1)?;
    } else {
        println!("(skipping real-engine serving bench: need artifacts/ and \
                  weights_tiny.bin)");
    }
    Ok(())
}
