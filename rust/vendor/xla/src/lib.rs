//! Compile-time stub of the `xla` PJRT bindings (xla_extension 0.5.1 API
//! surface used by `lrq::runtime`).
//!
//! `Literal` construction, reshape, and host-side conversion work fully
//! in-memory so `lrq`'s literal plumbing stays unit-testable. Everything that
//! would touch PJRT itself — client creation, HLO parsing, compilation,
//! execution — returns [`Error::Unavailable`] with a pointer at
//! `rust/vendor/README.md`. The `lrq::infer` native engine never reaches any
//! of this.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (the real crate's `XlaError` analogue).
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT is unavailable in this build (vendored xla stub; \
             see rust/vendor/README.md to link the real bindings)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the coordinator exchanges with artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host value types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(lit: &Literal) -> Option<&[Self]>;
}

#[derive(Clone, Debug)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(lit: &Literal) -> Option<&[Self]> {
        match &lit.data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(lit: &Literal) -> Option<&[Self]> {
        match &lit.data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side literal: typed buffer + dims (rank 0 = scalar).
#[derive(Clone, Debug)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(vals: &[T]) -> Literal {
        Literal { data: T::wrap(vals.to_vec()), dims: vec![vals.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch", self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!(
                "literal dtype mismatch: holds {:?}, asked for {:?}",
                self.ty().unwrap(), T::TY
            )))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(self)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("empty or dtype-mismatched literal".into()))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module (stub: cannot be produced).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({path})"
        )))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.ty().unwrap(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(),
                   7);
    }

    #[test]
    fn pjrt_paths_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
