//! Minimal offline shim of the `anyhow` API surface used by the `lrq` crate.
//!
//! Semantics mirrored from the real crate:
//! * `Error` is a cheap, `Send + Sync` error value built from any
//!   `std::error::Error` (capturing its source chain) or from a message.
//! * `Context::context`/`with_context` push an outer message onto the chain.
//! * `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole chain joined by `": "` — the format every CLI error
//!   path in `lrq` relies on.
//!
//! Intentionally absent (unused by `lrq`): downcasting, backtraces,
//! `Error::new` from non-`Display` payloads.

use std::fmt;

/// Error value: a context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Push an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }

    /// The full cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts, capturing its source chain. (Coherent with
// `From<Error> for Error` because `Error` itself never implements
// `std::error::Error` — same trick as the real crate.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result`, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("open file")
            .unwrap_err();
        assert_eq!(format!("{e}"), "open file");
        assert_eq!(format!("{e:#}"), "open file: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "cond {} failed", 1);
            if !ok {
                bail!("unreachable");
            }
            Ok(3)
        }
        assert_eq!(f(true).unwrap(), 3);
        assert_eq!(format!("{}", f(false).unwrap_err()), "cond 1 failed");
        let msg = String::from("plain");
        assert_eq!(format!("{}", anyhow!(msg)), "plain");
        assert_eq!(format!("{}", anyhow!("x {}", 2)), "x 2");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }
}
