//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 experiment index). Each `fig*`/`t*` function reproduces the
//! *shape* of the corresponding paper artifact on the synthetic models and
//! writes a markdown table under `reports/`.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{Args, Method, ReconConfig, Scheme};
use crate::coordinator::{pretrain, quantize_model, Engine, QuantizeOutcome};
use crate::data::{Corpus, CorpusConfig, TaskKind, TaskSet};
use crate::eval::{evaluate, rmse_curve, EvalSummary,
                  ModelView};
use crate::model::Weights;
use crate::quant::lrq::block_param_ratio;
use crate::report::{pct, Table};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::serve::{BatchScorer, Server, ServerConfig};

/// Shared experiment context for one model config.
pub struct Lab {
    pub rt: Runtime,
    pub cfg: String,
    pub engine: Engine,
    pub weights: Weights,
    pub corpus: Corpus,
    pub csr: TaskSet,
    pub mmlu: TaskSet,
    pub seed: u64,
    pub recon: ReconConfig,
    pub reports: PathBuf,
    pub n_tasks: usize,
}

/// Default pre-training budget per config.
fn train_steps(cfg: &str) -> usize {
    match cfg {
        "small" => 400,
        _ => 700,
    }
}

impl Lab {
    pub fn new(args: &Args, cfg: &str) -> Result<Lab> {
        let dir = args.get_or("artifacts", "artifacts");
        let rt = Runtime::load(Path::new(&dir))?;
        let dim = rt.dim(cfg)?;
        let seed: u64 = args.parse_as("seed", 1234)?;
        let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
        let engine = Engine::new(&rt, cfg)?;

        // train-or-load the FP baseline
        let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
        let wpath = Path::new(&wpath);
        let weights = if wpath.exists() {
            Weights::load(&dim, wpath)?
        } else {
            let steps: usize =
                args.parse_as("train-steps", train_steps(cfg))?;
            eprintln!("[lab] no {wpath:?}; pre-training {cfg} for {steps} \
                       steps (cached afterwards)");
            let out = pretrain(&rt, cfg, &corpus, steps, 1e-3, seed, 50)?;
            for (s, l) in &out.losses {
                eprintln!("[lab]   step {s:>5} loss {l:.4}");
            }
            out.weights.save(wpath)?;
            out.weights
        };

        let n_tasks: usize = args.parse_as("tasks", 400)?;
        let mut rng = Rng::new(seed ^ 0x5EED);
        let csr = TaskSet::generate(&corpus, TaskKind::Csr, n_tasks,
                                    dim.seq / 2, 8, 4, &mut rng);
        let mmlu = TaskSet::generate(&corpus, TaskKind::Mmlu, n_tasks,
                                     dim.seq / 2, 8, 4, &mut rng);
        let recon = ReconConfig {
            steps: args.parse_as("steps", 200)?,
            lr: args.parse_as("lr", 3e-4)?,
            calib_samples: args.parse_as("calib", 64)?,
            rank: args.parse_as("rank", 0)?,
            seed,
        };
        Ok(Lab {
            rt,
            cfg: cfg.to_string(),
            engine,
            weights,
            corpus,
            csr,
            mmlu,
            seed,
            recon,
            reports: PathBuf::from(args.get_or("reports", "reports")),
            n_tasks,
        })
    }

    pub fn fp_summary(&self) -> Result<EvalSummary> {
        evaluate(&self.engine, &ModelView::Fp(&self.weights), &self.corpus,
                 &self.csr, &self.mmlu, 8, self.seed)
    }

    pub fn quantize(&self, method: Method, scheme: Scheme,
                    recon: ReconConfig) -> Result<QuantizeOutcome> {
        quantize_model(&self.rt, &self.engine, &self.weights, &self.corpus,
                       method, scheme, recon)
    }

    pub fn summary_of(&self, out: &QuantizeOutcome, scheme: Scheme)
                      -> Result<EvalSummary> {
        let view = ModelView::Quant {
            model: &out.model,
            stats: &out.stats,
            scheme,
        };
        evaluate(&self.engine, &view, &self.corpus, &self.csr, &self.mmlu, 8,
                 self.seed)
    }

    pub fn run_method(&self, method: Method, scheme: Scheme)
                      -> Result<EvalSummary> {
        if method == Method::Fp16 {
            return self.fp_summary();
        }
        let out = self.quantize(method, scheme, self.recon)?;
        self.summary_of(&out, scheme)
    }
}

// ---------------------------------------------------------------------------
// figures
// ---------------------------------------------------------------------------

/// Fig. 1: CSR + MMLU accuracy across model sizes, W8A8(static)KV8.
pub fn fig1(args: &Args) -> Result<()> {
    let cfgs: Vec<&str> = if args.flag("full") {
        vec!["tiny", "small"]
    } else {
        vec!["tiny"]
    };
    let mut t = Table::new(
        "Fig. 1 — zero-shot CSR and five-shot MMLU analogue, W8A8(static)KV8",
        &["Model", "Method", "CSR %", "MMLU %"],
    );
    for cfg in cfgs {
        let lab = Lab::new(args, cfg)?;
        for m in [Method::Fp16, Method::SmoothQuant, Method::FlexRound,
                  Method::Lrq] {
            let s = lab.run_method(m, Scheme::w8a8_static())?;
            t.row(vec![cfg.into(), m.paper_name().into(), pct(s.csr_acc),
                       pct(s.mmlu_acc)]);
            println!("[fig1] {cfg} {}: CSR {:.2} MMLU {:.2}", m.paper_name(),
                     s.csr_acc * 100.0, s.mmlu_acc * 100.0);
        }
    }
    t.note("paper: LRQ closes the MMLU gap to FP16 that FlexRound leaves \
            open (Fig. 1b); CSR stays near-FP16 for both");
    t.emit(Path::new(&args.get_or("reports", "reports")), "fig1")
}

/// Fig. 2: FlexRound accuracy vs calibration sample size.
pub fn fig2(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let mut t = Table::new(
        "Fig. 2 — FlexRound vs calibration sample size, W8A8(static)",
        &["Calib samples", "CSR %", "MMLU %"],
    );
    let fp = lab.fp_summary()?;
    for n in [16usize, 32, 64, 128] {
        let recon = ReconConfig { calib_samples: n, ..lab.recon };
        let out = lab.quantize(Method::FlexRound, Scheme::w8a8_static(),
                               recon)?;
        let s = lab.summary_of(&out, Scheme::w8a8_static())?;
        t.row(vec![n.to_string(), pct(s.csr_acc), pct(s.mmlu_acc)]);
        println!("[fig2] n={n}: CSR {:.2} MMLU {:.2}", s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0);
    }
    t.row(vec!["FP16".into(), pct(fp.csr_acc), pct(fp.mmlu_acc)]);
    t.note("paper: FlexRound improves with more calibration data but stays \
            below FP16 on MMLU");
    t.emit(&lab.reports, "fig2")
}

/// Fig. 3 (+ App. C/D): accumulated RMSE per block, calib vs unseen sample.
pub fn fig3(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static().without_kv_quant();
    let dim = &lab.engine.dim;
    let mut rng = Rng::new(lab.seed ^ 0xF16);
    let calib_ids = lab.corpus.calib_batch(dim.calib_batch, dim.seq, &mut rng);
    // unseen: held-out domains (the MMLU axis)
    let held = lab.corpus.heldout_domain_ids();
    let mut unseen_ids = Vec::new();
    for _ in 0..dim.calib_batch {
        let d = held[rng.below(held.len())];
        unseen_ids.extend(lab.corpus.sequence(d, dim.seq, &mut rng));
    }

    let mut t = Table::new(
        "Fig. 3 — accumulated RMSE between FP and quantized streams, W8A8",
        &["Method", "Sample", "per-block RMSE (first→last)"],
    );
    for m in [Method::Rtn, Method::FlexRound, Method::Lrq] {
        let out = lab.quantize(m, scheme, lab.recon)?;
        for (name, ids) in [("calib", &calib_ids), ("unseen", &unseen_ids)] {
            let curve = rmse_curve(&lab.engine, &lab.weights, &out.model,
                                   &out.stats, &scheme, ids)?;
            let series = curve
                .iter()
                .map(|v| format!("{v:.4}"))
                .collect::<Vec<_>>()
                .join(" ");
            println!("[fig3] {} {name}: {series}", m.paper_name());
            t.row(vec![m.paper_name().into(), name.into(), series]);
        }
    }
    t.note("paper: LRQ ≈ FlexRound on the calibration sample but clearly \
            lower on unseen samples — the low-rank generalization effect");
    t.emit(&lab.reports, "fig3")
}

/// Fig. 4(a): rank study.
pub fn fig4a(args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let lab = Lab::new(args, &cfg)?;
    let ranks = lab.rt.ranks(&cfg);
    if ranks.is_empty() {
        bail!("no rank artifacts for {cfg}");
    }
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Fig. 4(a) — LRQ rank study, W8A8(static)KV8",
        &["Rank r", "CSR %", "MMLU %"],
    );
    for r in &ranks {
        let recon = ReconConfig { rank: *r, ..lab.recon };
        let out = lab.quantize(Method::Lrq, scheme, recon)?;
        let s = lab.summary_of(&out, scheme)?;
        t.row(vec![r.to_string(), pct(s.csr_acc), pct(s.mmlu_acc)]);
        println!("[fig4a] r={r}: CSR {:.2} MMLU {:.2}", s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0);
    }
    let fr = lab.run_method(Method::FlexRound, scheme)?;
    t.row(vec!["FlexRound (full)".into(), pct(fr.csr_acc),
               pct(fr.mmlu_acc)]);
    t.note("paper: performance is stable/rising to a sweet-spot rank, then \
            decays toward FlexRound as r grows");
    t.emit(&lab.reports, "fig4a")
}

/// Fig. 4(b): LRQ calibration sample-size study.
pub fn fig4b(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Fig. 4(b) — LRQ vs calibration sample size, W8A8(static)KV8",
        &["Calib samples", "CSR %", "MMLU %"],
    );
    for n in [16usize, 32, 64, 128] {
        let recon = ReconConfig { calib_samples: n, ..lab.recon };
        let out = lab.quantize(Method::Lrq, scheme, recon)?;
        let s = lab.summary_of(&out, scheme)?;
        t.row(vec![n.to_string(), pct(s.csr_acc), pct(s.mmlu_acc)]);
        println!("[fig4b] n={n}: CSR {:.2} MMLU {:.2}", s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0);
    }
    t.note("paper: LRQ saturates beyond ~1024 samples and beats FlexRound at \
            every size");
    t.emit(&lab.reports, "fig4b")
}

// ---------------------------------------------------------------------------
// tables
// ---------------------------------------------------------------------------

fn methods_weight_act() -> Vec<Method> {
    vec![Method::Fp16, Method::Rtn, Method::SmoothQuant, Method::FlexRound,
         Method::Lrq]
}

/// Tables 1–2 / 16 / 18 shape: CSR accuracy under W8A8(static)KV8.
pub fn t1(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Tables 1–2 — CSR accuracy, W/A/KV = 8/8/8 (per-tensor static acts)",
        &["Method", "#Bits", "CSR %", "PPL"],
    );
    for m in methods_weight_act() {
        let s = lab.run_method(m, scheme)?;
        let bits = if m == Method::Fp16 { "16/16/16".into() }
                   else { scheme.label() };
        t.row(vec![m.paper_name().into(), bits, pct(s.csr_acc),
                   format!("{:.3}", s.ppl)]);
        println!("[t1] {}: CSR {:.2} PPL {:.3}", m.paper_name(),
                 s.csr_acc * 100.0, s.ppl);
    }
    t.note("paper shape: LRQ ≥ FlexRound > SmoothQuant > RTN, all near FP16 \
            on CSR");
    t.emit(&lab.reports, "t1")
}

/// Tables 3–4 / 17 / 20 shape: MMLU under W8A8(static)KV8.
pub fn t3(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Tables 3–4 — MMLU-analogue accuracy, W/A/KV = 8/8/8",
        &["Method", "#Bits", "MMLU %"],
    );
    for m in methods_weight_act() {
        let s = lab.run_method(m, scheme)?;
        let bits = if m == Method::Fp16 { "16/16/16".into() }
                   else { scheme.label() };
        t.row(vec![m.paper_name().into(), bits, pct(s.mmlu_acc)]);
        println!("[t3] {}: MMLU {:.2}", m.paper_name(), s.mmlu_acc * 100.0);
    }
    t.note("paper shape: the LRQ-vs-FlexRound gap is much larger here than \
            on CSR (generalization axis)");
    t.emit(&lab.reports, "t3")
}

/// Tables 5–6 / 22–25 shape: W4 A8(per-token) KV8.
pub fn t5(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w4a8_token();
    let mut t = Table::new(
        "Tables 5–6 — CSR + MMLU, W/A/KV = 4/8/8 (per-token acts)",
        &["Method", "#Bits", "CSR %", "MMLU %"],
    );
    for m in methods_weight_act() {
        let s = lab.run_method(m, scheme)?;
        let bits = if m == Method::Fp16 { "16/16/16".into() }
                   else { scheme.label() };
        t.row(vec![m.paper_name().into(), bits, pct(s.csr_acc),
                   pct(s.mmlu_acc)]);
        println!("[t5] {}: CSR {:.2} MMLU {:.2}", m.paper_name(),
                 s.csr_acc * 100.0, s.mmlu_acc * 100.0);
    }
    t.note("paper shape: 4-bit weights hurt RTN/SmoothQuant badly; \
            reconstruction methods stay near FP16, LRQ edges FlexRound");
    t.emit(&lab.reports, "t5")
}

/// Tables 7–8 / 11–12 shape: per-channel weight-only 3/4-bit.
pub fn t7(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let mut t = Table::new(
        "Tables 7–8 — weight-only per-channel quantization",
        &["Method", "#Bits", "CSR %", "MMLU %", "PPL"],
    );
    let fp = lab.fp_summary()?;
    t.row(vec!["FP16".into(), "16/16/16".into(), pct(fp.csr_acc),
               pct(fp.mmlu_acc), format!("{:.3}", fp.ppl)]);
    for bits in [3u32, 4] {
        let scheme = Scheme::weight_only(bits);
        for m in [Method::Rtn, Method::Gptq, Method::Awq, Method::FlexRound,
                  Method::Lrq] {
            let s = lab.run_method(m, scheme)?;
            t.row(vec![m.paper_name().into(), scheme.label(),
                       pct(s.csr_acc), pct(s.mmlu_acc),
                       format!("{:.3}", s.ppl)]);
            println!("[t7] {} {}: CSR {:.2} MMLU {:.2} PPL {:.3}",
                     m.paper_name(), scheme.label(), s.csr_acc * 100.0,
                     s.mmlu_acc * 100.0, s.ppl);
        }
    }
    t.note("paper shape: LRQ ≥ FlexRound ≥ AWQ/GPTQ ≥ RTN; 4-bit ≈ FP16, \
            3-bit shows a small gap");
    t.emit(&lab.reports, "t7")
}

/// Tables 9–10 (App. B): r2/c2 ablation.
pub fn t9(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let mut t = Table::new(
        "Tables 9–10 — ablation: FlexRound vs S2=L2U2 vs LRQ (+r2+c2)",
        &["Method", "#Bits", "CSR %", "MMLU %"],
    );
    for scheme in [Scheme::w8a8_static().without_kv_quant(),
                   Scheme::w8a8_static()] {
        for m in [Method::FlexRound, Method::LrqNoBias, Method::Lrq] {
            let s = lab.run_method(m, scheme)?;
            t.row(vec![m.paper_name().into(), scheme.label(),
                       pct(s.csr_acc), pct(s.mmlu_acc)]);
            println!("[t9] {} {}: CSR {:.2} MMLU {:.2}", m.paper_name(),
                     scheme.label(), s.csr_acc * 100.0, s.mmlu_acc * 100.0);
        }
    }
    t.note("paper: L2U2 alone already beats FlexRound on MMLU; r2+c2 adds \
            the rest (App. B)");
    t.emit(&lab.reports, "t9")
}

/// Tables 13–14 (App. F): quantization cost.
pub fn t13(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Tables 13–14 — quantization cost (this testbed)",
        &["Method", "Wall time (s)", "Working set (MB)"],
    );
    for m in [Method::SmoothQuant, Method::FlexRound, Method::Lrq] {
        let out = lab.quantize(m, scheme, lab.recon)?;
        t.row(vec![m.paper_name().into(),
                   format!("{:.1}", out.wall.as_secs_f64()),
                   format!("{:.1}", out.mem_bytes as f64 / 1e6)]);
        println!("[t13] {}: {:.1}s, {:.1} MB", m.paper_name(),
                 out.wall.as_secs_f64(), out.mem_bytes as f64 / 1e6);
    }
    t.note("paper: SmoothQuant is learning-free (minutes); FlexRound and LRQ \
            pay for reconstruction, with LRQ using *less* memory (fewer \
            learnable params) but slightly more time (L2·U2 matmul)");
    t.emit(&lab.reports, "t13")
}

/// Table 29 (App. J): learnable-parameter ratio.
pub fn t29(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Table 29 — LRQ learnable params / pre-trained weights per block",
        &["Model", "d", "ff", "rank", "Ratio %"],
    );
    for (name, d, f, r) in [
        ("Llama 7B", 4096usize, 11008usize, 1024usize),
        ("Llama 13B", 5120, 13824, 1024),
        ("Llama 33B", 6656, 17920, 2048),
        ("Llama 65B", 8192, 22016, 2048),
        ("tiny (ours)", 128, 352, 32),
        ("small (ours)", 256, 704, 64),
    ] {
        let ratio = block_param_ratio(d, f, r);
        t.row(vec![name.into(), d.to_string(), f.to_string(), r.to_string(),
                   format!("{:.2}", ratio * 100.0)]);
    }
    t.note("paper values: 39.51 / 31.57 / 48.60 / 39.51 % — matched exactly \
            by quant::lrq::block_param_ratio (unit-tested)");
    t.emit(Path::new(&args.get_or("reports", "reports")), "t29")
}

/// Table 30 (App. K): seed variance of FlexRound vs LRQ.
pub fn t30(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static();
    let mut t = Table::new(
        "Table 30 — mean ± std over 3 seeds, W8A8(static)KV8",
        &["Method", "CSR mean %", "CSR std", "MMLU mean %", "MMLU std"],
    );
    for m in [Method::FlexRound, Method::Lrq] {
        let mut csr = Vec::new();
        let mut mmlu = Vec::new();
        for k in 0..3u64 {
            let recon = ReconConfig { seed: lab.seed + 1000 * k, ..lab.recon };
            let out = lab.quantize(m, scheme, recon)?;
            let s = lab.summary_of(&out, scheme)?;
            csr.push(s.csr_acc * 100.0);
            mmlu.push(s.mmlu_acc * 100.0);
            println!("[t30] {} seed{k}: CSR {:.2} MMLU {:.2}",
                     m.paper_name(), csr[csr.len() - 1],
                     mmlu[mmlu.len() - 1]);
        }
        let stat = |v: &[f64]| {
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / v.len() as f64;
            (mean, var.sqrt())
        };
        let (cm, cs) = stat(&csr);
        let (mm, ms) = stat(&mmlu);
        t.row(vec![m.paper_name().into(), format!("{cm:.2}"),
                   format!("{cs:.2}"), format!("{mm:.2}"),
                   format!("{ms:.2}")]);
    }
    t.note("paper: LRQ has both a higher mean and a smaller std than \
            FlexRound — the overfitting-variance signature");
    t.emit(&lab.reports, "t30")
}

/// Tables 31–32 (App. L): SmoothQuant + reconstruction combinations.
pub fn t31(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let scheme = Scheme::w8a8_static().without_kv_quant();
    let mut t = Table::new(
        "Tables 31–32 — SQ preprocessing + reconstruction, W8A8/KV16",
        &["Method", "CSR %", "MMLU %"],
    );
    for m in [Method::FlexRound, Method::SqFlexRound, Method::Lrq,
              Method::SqLrq] {
        let s = lab.run_method(m, scheme)?;
        t.row(vec![m.paper_name().into(), pct(s.csr_acc), pct(s.mmlu_acc)]);
        println!("[t31] {}: CSR {:.2} MMLU {:.2}", m.paper_name(),
                 s.csr_acc * 100.0, s.mmlu_acc * 100.0);
    }
    t.note("paper: SQ preprocessing does not reliably help the \
            reconstruction methods; LRQ alone remains best on MMLU");
    t.emit(&lab.reports, "t31")
}

/// App. H: KV-cache quantization on/off deltas.
pub fn kvq(args: &Args) -> Result<()> {
    let lab = Lab::new(args, &args.get_or("cfg", "tiny"))?;
    let mut t = Table::new(
        "App. H — effect of per-token KV-cache quantization",
        &["Method", "#Bits", "CSR %", "MMLU %"],
    );
    for m in [Method::Rtn, Method::SmoothQuant, Method::FlexRound,
              Method::Lrq] {
        for scheme in [Scheme::w8a8_static().without_kv_quant(),
                       Scheme::w8a8_static()] {
            let s = lab.run_method(m, scheme)?;
            t.row(vec![m.paper_name().into(), scheme.label(),
                       pct(s.csr_acc), pct(s.mmlu_acc)]);
            println!("[kvq] {} {}: CSR {:.2} MMLU {:.2}", m.paper_name(),
                     scheme.label(), s.csr_acc * 100.0, s.mmlu_acc * 100.0);
        }
    }
    t.note("paper: KV8 per-token quantization is nearly free for every \
            method");
    t.emit(&lab.reports, "kvq")
}

// ---------------------------------------------------------------------------
// serving (Fig. 5 / Table 15)
// ---------------------------------------------------------------------------

struct EngineScorer {
    engine: Engine,
    weights: Option<Weights>,
    quant: Option<(crate::model::QuantizedModel,
                   Vec<crate::coordinator::BlockStats>, Scheme)>,
}

impl BatchScorer for EngineScorer {
    fn batch_size(&self) -> usize {
        self.engine.dim.calib_batch
    }
    fn seq_len(&self) -> usize {
        self.engine.dim.seq
    }
    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        let (_, logp) = match (&self.weights, &self.quant) {
            (Some(w), _) => self.engine.fp_forward(w, ids, targets)?,
            (None, Some((qm, stats, scheme))) =>
                self.engine.q_forward(qm, stats, scheme, ids, targets)?,
            _ => bail!("scorer has no model"),
        };
        Ok(logp.data)
    }
}

/// Fig. 5 / Table 15: accuracy vs serving latency vs model size for FP16 and
/// weight-only LRQ at 3/4 bits.
pub fn fig5(args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let lab = Lab::new(args, &cfg)?;
    let requests: usize = args.parse_as("requests", 120)?;
    let mut t = Table::new(
        "Fig. 5 / Table 15 — accuracy vs serving latency vs model size",
        &["Variant", "CSR %", "Size (MB)", "p50 lat (ms)", "p95 lat (ms)",
          "p99 lat (ms)", "req/s"],
    );
    let fp = lab.fp_summary()?;
    let fp_bytes = lab.weights.dim.param_count() * 4;

    let mut variants: Vec<(String, Option<u32>)> =
        vec![("FP16".into(), None)];
    for bits in [4u32, 3] {
        variants.push((format!("LRQ {bits}-bit"), Some(bits)));
    }
    for (name, bits) in variants {
        let (acc, size_bytes) = match bits {
            None => (fp.csr_acc, fp_bytes),
            Some(b) => {
                let scheme = Scheme::weight_only(b);
                let out = lab.quantize(Method::Lrq, scheme, lab.recon)?;
                let s = lab.summary_of(&out, scheme)?;
                (s.csr_acc, out.model.storage_bytes())
            }
        };
        let (m, wall) = serving_bench(args, &cfg, bits, requests)?;
        let rps = m.throughput(wall);
        t.row(vec![name.clone(), pct(acc),
                   format!("{:.2}", size_bytes as f64 / 1e6),
                   format!("{:.2}", m.p50_latency().as_secs_f64() * 1e3),
                   format!("{:.2}", m.p95_latency().as_secs_f64() * 1e3),
                   format!("{:.2}", m.p99_latency().as_secs_f64() * 1e3),
                   format!("{rps:.1}")]);
        println!("[fig5] {name}: CSR {:.2} size {:.2}MB p50 {:?} p99 {:?} \
                  rps {rps:.1}",
                 acc * 100.0, size_bytes as f64 / 1e6, m.p50_latency(),
                 m.p99_latency());
    }
    t.note("CPU-PJRT testbed: latency parity is expected (XLA executes f32 \
            either way); the paper's 2.3–2.8× speedups come from LUT-GEMM on \
            GPU — see DESIGN.md §Hardware-Adaptation for the TPU estimate. \
            The size column shows the real packed-storage compression.");
    t.emit(&lab.reports, "fig5")
}

/// Run a serving benchmark; returns (metrics, wall time).
fn serving_bench(args: &Args, cfg: &str, w_bits: Option<u32>,
                 requests: usize)
                 -> Result<(crate::serve::Metrics, Duration)> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let _seed: u64 = args.parse_as("seed", 1234)?;
    let cfg2 = cfg.to_string();
    let steps: usize = args.parse_as("steps", 100)?;
    let calib: usize = args.parse_as("calib", 32)?;

    let server = Server::start(ServerConfig::default(), move || {
        let rt = Runtime::load(Path::new(&artifacts))?;
        let dim = rt.dim(&cfg2)?;
        let engine = Engine::new(&rt, &cfg2)?;
        let weights = Weights::load(&dim, Path::new(&wpath))?;
        match w_bits {
            None => Ok(Box::new(EngineScorer {
                engine,
                weights: Some(weights),
                quant: None,
            }) as Box<dyn BatchScorer>),
            Some(bits) => {
                let corpus =
                    Corpus::new(CorpusConfig::for_vocab(dim.vocab));
                let scheme = Scheme::weight_only(bits);
                let recon = ReconConfig {
                    steps,
                    calib_samples: calib,
                    ..ReconConfig::default()
                };
                let out = quantize_model(&rt, &engine, &weights, &corpus,
                                         Method::Lrq, scheme, recon)?;
                Ok(Box::new(EngineScorer {
                    engine,
                    weights: None,
                    quant: Some((out.model, out.stats, scheme)),
                }) as Box<dyn BatchScorer>)
            }
        }
    })?;

    // drive load from 4 client threads
    let t0 = Instant::now();
    let per_thread = requests / 4;
    let mut handles = Vec::new();
    for k in 0..4u64 {
        let client = server.client();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xBEEF ^ k);
            for _ in 0..per_thread {
                let len = rng.range(8, 48);
                let ids: Vec<i32> =
                    (0..len).map(|_| rng.below(256) as i32).collect();
                client.score(ids)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??;
    }
    let wall = t0.elapsed();
    let m = server.metrics.lock().unwrap().clone();
    Ok((m, wall))
}

/// `lrq serve` entry: run the serving loop once and print metrics.
pub fn serving_run(artifacts: &str, cfg: &str, weights: &str,
                   method: Option<&str>, w_bits: u32, requests: usize,
                   seed: u64) -> Result<()> {
    let mut args = Args::default();
    args.options.insert("artifacts".into(), artifacts.into());
    args.options.insert("weights".into(), weights.into());
    args.options.insert("seed".into(), seed.to_string());
    let bits = method.map(|_| w_bits);
    let (m, wall) = serving_bench(&args, cfg, bits, requests)?;
    println!("{} (wall {:.2}s)", m.summary(wall), wall.as_secs_f64());
    Ok(())
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

pub const ALL_TABLES: &[&str] = &["t29", "fig3", "t1", "t3", "t5", "t7", "t9",
                                  "t13", "t30", "t31", "kvq", "fig1", "fig2",
                                  "fig4a", "fig4b", "fig5"];

pub fn run_table(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => fig1(args),
        "fig2" => fig2(args),
        "fig3" => fig3(args),
        "fig4a" => fig4a(args),
        "fig4b" => fig4b(args),
        "fig5" => fig5(args),
        "t1" => t1(args),
        "t3" => t3(args),
        "t5" => t5(args),
        "t7" => t7(args),
        "t9" => t9(args),
        "t13" => t13(args),
        "t29" => t29(args),
        "t30" => t30(args),
        "t31" => t31(args),
        "kvq" => kvq(args),
        other => bail!("unknown table id {other}; known: {ALL_TABLES:?}"),
    }
}

pub fn run_all(args: &Args) -> Result<()> {
    for id in ALL_TABLES {
        println!("\n=== regenerating {id} ===");
        run_table(id, args).with_context(|| format!("table {id}"))?;
    }
    Ok(())
}
