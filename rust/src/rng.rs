//! Deterministic RNG substrate (SplitMix64 + xoshiro256**), so every
//! experiment in EXPERIMENTS.md is exactly reproducible from its seed.
//!
//! No external crates: the offline build environment only ships the `xla`
//! closure, and determinism across machines matters more than speed here.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal sample from Box-Muller
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-block / per-layer seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Bernoulli with probability p.
    pub fn coin(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.next_f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Sample a token id from next-token logits. `top_k <= 1` is greedy argmax
/// (deterministic, rng untouched); otherwise softmax over the `top_k`
/// largest logits, sampled with the caller's deterministic [`Rng`]. Lives
/// here (not in `infer` or `serve`) because both the native decode path and
/// the engine-agnostic batcher sample — this keeps their dependency one-way.
pub fn sample_top_k(logits: &[f32], top_k: usize, rng: &mut Rng) -> usize {
    debug_assert!(!logits.is_empty());
    if top_k <= 1 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best;
    }
    let k = top_k.min(logits.len());
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        // partition (O(V)) instead of fully sorting the vocabulary: after
        // this the first k indices are the k largest logits (unordered)
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    let mx = idx
        .iter()
        .map(|&i| logits[i])
        .fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> =
        idx.iter().map(|&i| (logits[i] - mx).exp()).collect();
    idx[rng.weighted(&weights)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(43);
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(sample_top_k(&logits, 0, &mut rng), 1);
        assert_eq!(sample_top_k(&logits, 1, &mut rng), 1);
    }

    #[test]
    fn top_k_samples_within_top_set_and_is_seed_deterministic() {
        let logits = vec![5.0f32, 4.5, -10.0, 4.8, -20.0];
        let top: Vec<usize> = vec![0, 3, 1]; // three largest
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..50 {
            let sa = sample_top_k(&logits, 3, &mut a);
            assert!(top.contains(&sa), "sampled {sa} outside top-3");
            assert_eq!(sa, sample_top_k(&logits, 3, &mut b));
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let k = r.range(3, 10);
            assert!((3..10).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let w = [0.1, 0.8, 0.1];
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > counts[0] + counts[2]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
