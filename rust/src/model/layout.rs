//! The layout contract with `python/compile/configs.py` / `train.py`:
//! canonical weight order, shapes, and the activation-quant points.
//!
//! Per-block order: `wq wk wv wo wg wu wd norm_attn norm_ffn`;
//! full model: `emb, blocks[0..L], final_norm, head` — exactly the flatten
//! order of the `train_step` / `recon_*` artifacts.

/// The 7 quantized linear projections of one block, in canonical order.
pub const BLOCK_WEIGHT_NAMES: [&str; 7] =
    ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

/// The 4 activation-quantization points of Fig. 8 (inputs of the linears,
/// deduplicated: qkv share, gate/up share).
pub const ACT_POINTS: [&str; 4] = ["attn_in", "o_in", "ffn_in", "down_in"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightKind {
    Wq,
    Wk,
    Wv,
    Wo,
    Wg,
    Wu,
    Wd,
}

impl WeightKind {
    pub fn all() -> [WeightKind; 7] {
        use WeightKind::*;
        [Wq, Wk, Wv, Wo, Wg, Wu, Wd]
    }

    pub fn name(&self) -> &'static str {
        BLOCK_WEIGHT_NAMES[*self as usize]
    }
}

/// Model dimensions (parsed from `artifacts/manifest.txt` at runtime).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelDim {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub ff: usize,
    pub seq: usize,
    pub train_batch: usize,
    pub calib_batch: usize,
    pub recon_batch: usize,
    pub rank: usize,
}

impl ModelDim {
    /// Built-in dimensions mirroring `python/compile/configs.py` — used by
    /// artifact-free paths (the native inference engine, `serve-native`) so
    /// they never require `artifacts/manifest.txt`. The manifest, when
    /// present, remains authoritative. `micro` has no Python/artifact
    /// counterpart: it is the native-only smoke config shared by the test
    /// suites and fast `serve-native` dry runs.
    pub fn builtin(name: &str) -> Option<ModelDim> {
        match name {
            "micro" => Some(ModelDim {
                name: "micro".into(),
                vocab: 64,
                d: 32,
                heads: 2,
                layers: 2,
                ff: 48,
                seq: 16,
                train_batch: 4,
                calib_batch: 4,
                recon_batch: 2,
                rank: 8,
            }),
            "tiny" => Some(ModelDim {
                name: "tiny".into(),
                vocab: 512,
                d: 128,
                heads: 4,
                layers: 4,
                ff: 352,
                seq: 64,
                train_batch: 16,
                calib_batch: 8,
                recon_batch: 4,
                rank: 32,
            }),
            "small" => Some(ModelDim {
                name: "small".into(),
                vocab: 2048,
                d: 256,
                heads: 8,
                layers: 8,
                ff: 704,
                seq: 64,
                train_batch: 8,
                calib_batch: 8,
                recon_batch: 4,
                rank: 64,
            }),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    /// (Cout, Cin) of each block linear, canonical order.
    pub fn block_weight_shapes(&self) -> [(usize, usize); 7] {
        let (d, f) = (self.d, self.ff);
        [(d, d), (d, d), (d, d), (d, d), (f, d), (f, d), (d, f)]
    }

    /// Feature dim at each activation-quant point.
    pub fn act_point_dim(&self, point: &str) -> usize {
        match point {
            "attn_in" | "o_in" | "ffn_in" => self.d,
            "down_in" => self.ff,
            _ => panic!("unknown act point {point}"),
        }
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn param_count(&self) -> usize {
        let block: usize = self
            .block_weight_shapes()
            .iter()
            .map(|(a, b)| a * b)
            .sum::<usize>()
            + 2 * self.d;
        2 * self.vocab * self.d + self.layers * block + self.d
    }

    /// Weights quantized by PTQ (block linears only, as in the paper).
    pub fn quantized_weight_count(&self) -> usize {
        self.layers
            * self
                .block_weight_shapes()
                .iter()
                .map(|(a, b)| a * b)
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelDim {
        ModelDim {
            name: "tiny".into(),
            vocab: 512,
            d: 128,
            heads: 4,
            layers: 4,
            ff: 352,
            seq: 64,
            train_batch: 16,
            calib_batch: 8,
            recon_batch: 4,
            rank: 32,
        }
    }

    #[test]
    fn shapes_consistent() {
        let m = tiny();
        assert_eq!(m.head_dim(), 32);
        let shapes = m.block_weight_shapes();
        assert_eq!(shapes[0], (128, 128));
        assert_eq!(shapes[4], (352, 128));
        assert_eq!(shapes[6], (128, 352));
    }

    #[test]
    fn param_count_tiny() {
        let m = tiny();
        // emb + head: 2*512*128 = 131072; block: 4*128^2 + 3*352*128 + 256
        let block = 4 * 128 * 128 + 3 * 352 * 128 + 256;
        assert_eq!(m.param_count(), 131072 + 4 * block + 128);
    }

    #[test]
    fn builtin_configs() {
        // tiny/small mirror python/compile/configs.py; micro is native-only
        let t = ModelDim::builtin("tiny").unwrap();
        assert_eq!((t.vocab, t.d, t.layers, t.ff), (512, 128, 4, 352));
        let s = ModelDim::builtin("small").unwrap();
        assert_eq!((s.vocab, s.d, s.layers, s.ff), (2048, 256, 8, 704));
        let m = ModelDim::builtin("micro").unwrap();
        assert_eq!(m.d % m.heads, 0);
        assert!(m.param_count() < t.param_count());
        assert!(ModelDim::builtin("huge").is_none());
    }

    #[test]
    fn act_point_dims() {
        let m = tiny();
        assert_eq!(m.act_point_dim("attn_in"), 128);
        assert_eq!(m.act_point_dim("down_in"), 352);
    }
}
