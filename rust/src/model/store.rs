//! Weight store: FP weights, quantized checkpoints, init, and binary I/O.
//!
//! Checkpoint format (little-endian): magic `LRQW`, version u32, then for each
//! tensor: name-len u32, name bytes, rank u32, dims u64…, f32 data. Quantized
//! checkpoints (`LRQQ`) store packed integer codes + per-channel grids.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::PackedMatrix;
use crate::rng::Rng;
use crate::tensor::Tensor;

use super::layout::ModelDim;

/// One Transformer block's FP weights (canonical order).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ws: Vec<Tensor>, // wq wk wv wo wg wu wd
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

impl BlockWeights {
    pub fn norms(&self) -> [&Tensor; 2] {
        [&self.norm_attn, &self.norm_ffn]
    }
}

/// Full FP model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub dim: ModelDim,
    pub emb: Tensor,
    pub blocks: Vec<BlockWeights>,
    pub final_norm: Tensor,
    pub head: Tensor,
}

impl Weights {
    /// GPT-style init: N(0, 0.02) embeddings/projections, residual-out
    /// projections scaled by 1/sqrt(2L), unit norms.
    pub fn init(dim: &ModelDim, rng: &mut Rng) -> Self {
        let std = 0.02f32;
        let resid = std / ((2 * dim.layers) as f32).sqrt();
        let mut blocks = Vec::with_capacity(dim.layers);
        for _ in 0..dim.layers {
            let shapes = dim.block_weight_shapes();
            let mut ws = Vec::with_capacity(7);
            for (i, (co, ci)) in shapes.iter().enumerate() {
                // wo (3) and wd (6) write into the residual stream
                let s = if i == 3 || i == 6 { resid } else { std };
                ws.push(Tensor::randn(rng, &[*co, *ci], s));
            }
            blocks.push(BlockWeights {
                ws,
                norm_attn: Tensor::ones(&[dim.d]),
                norm_ffn: Tensor::ones(&[dim.d]),
            });
        }
        Weights {
            dim: dim.clone(),
            emb: Tensor::randn(rng, &[dim.vocab, dim.d], std),
            blocks,
            final_norm: Tensor::ones(&[dim.d]),
            head: Tensor::randn(rng, &[dim.vocab, dim.d], std),
        }
    }

    /// Flat canonical-order view matching the train_step artifact inputs:
    /// emb, per-block (7 ws + 2 norms), final_norm, head.
    pub fn flat(&self) -> Vec<&Tensor> {
        let mut out = vec![&self.emb];
        for b in &self.blocks {
            out.extend(b.ws.iter());
            out.push(&b.norm_attn);
            out.push(&b.norm_ffn);
        }
        out.push(&self.final_norm);
        out.push(&self.head);
        out
    }

    /// Rebuild from the flat canonical-order list (train_step outputs).
    pub fn from_flat(dim: &ModelDim, flat: Vec<Tensor>) -> Result<Self> {
        let expect = 1 + dim.layers * 9 + 2;
        if flat.len() != expect {
            bail!("flat weight count {} != {expect}", flat.len());
        }
        let mut it = flat.into_iter();
        let emb = it.next().unwrap();
        let mut blocks = Vec::with_capacity(dim.layers);
        for _ in 0..dim.layers {
            let ws: Vec<Tensor> = (0..7).map(|_| it.next().unwrap()).collect();
            let norm_attn = it.next().unwrap();
            let norm_ffn = it.next().unwrap();
            blocks.push(BlockWeights { ws, norm_attn, norm_ffn });
        }
        let final_norm = it.next().unwrap();
        let head = it.next().unwrap();
        Ok(Weights { dim: dim.clone(), emb, blocks, final_norm, head })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(b"LRQW")?;
        w.write_all(&1u32.to_le_bytes())?;
        let flat = self.flat();
        w.write_all(&(flat.len() as u32).to_le_bytes())?;
        for t in flat {
            write_tensor(&mut w, t)?;
        }
        Ok(())
    }

    pub fn load(dim: &ModelDim, path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"LRQW" {
            bail!("bad magic in {path:?}");
        }
        let _ver = read_u32(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        let flat: Result<Vec<Tensor>> =
            (0..n).map(|_| read_tensor(&mut r)).collect();
        Weights::from_flat(dim, flat?)
    }
}

/// One block's weights in quantized (packed) form.
#[derive(Clone, Debug)]
pub struct QuantizedBlock {
    pub ws: Vec<PackedMatrix>, // canonical order
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

impl QuantizedBlock {
    /// Dequantized (Ŵ) tensors, canonical order — the block_fwd_q inputs.
    pub fn dequant_ws(&self) -> Vec<Tensor> {
        self.ws.iter().map(|p| p.dequant()).collect()
    }

    pub fn storage_bytes(&self) -> usize {
        self.ws.iter().map(|p| p.storage_bytes()).sum::<usize>()
            + (self.norm_attn.len() + self.norm_ffn.len()) * 4
    }
}

/// A fully quantized model checkpoint (embeddings/head/norms stay FP, as in
/// the paper: only attention/FFN linears are quantized).
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub dim: ModelDim,
    pub bits: u32,
    pub emb: Tensor,
    pub blocks: Vec<QuantizedBlock>,
    pub final_norm: Tensor,
    pub head: Tensor,
}

impl QuantizedModel {
    /// Total storage including FP pieces — the Fig. 5 "model size".
    pub fn storage_bytes(&self) -> usize {
        let fp = (self.emb.len() + self.final_norm.len() + self.head.len()) * 4;
        fp + self.blocks.iter().map(|b| b.storage_bytes()).sum::<usize>()
    }

    pub fn fp_equivalent_bytes(&self) -> usize {
        self.dim.param_count() * 4
    }
}

fn write_tensor<W: Write>(w: &mut W, t: &Tensor) -> Result<()> {
    w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
    for &d in &t.dims {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    for &v in &t.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_tensor<R: Read>(r: &mut R) -> Result<Tensor> {
    let rank = read_u32(r)? as usize;
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        dims.push(u64::from_le_bytes(b) as usize);
    }
    let n: usize = dims.iter().product();
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelDim {
        ModelDim {
            name: "tiny".into(),
            vocab: 512,
            d: 128,
            heads: 4,
            layers: 4,
            ff: 352,
            seq: 64,
            train_batch: 16,
            calib_batch: 8,
            recon_batch: 4,
            rank: 32,
        }
    }

    #[test]
    fn init_shapes() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(1));
        assert_eq!(w.blocks.len(), 4);
        assert_eq!(w.emb.dims, vec![512, 128]);
        assert_eq!(w.blocks[0].ws[4].dims, vec![352, 128]);
        assert_eq!(w.flat().len(), 1 + 4 * 9 + 2);
    }

    #[test]
    fn flat_roundtrip() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(2));
        let flat: Vec<Tensor> = w.flat().into_iter().cloned().collect();
        let w2 = Weights::from_flat(&dim, flat).unwrap();
        assert_eq!(w.emb, w2.emb);
        assert_eq!(w.blocks[3].ws[6], w2.blocks[3].ws[6]);
        assert_eq!(w.head, w2.head);
    }

    #[test]
    fn save_load_roundtrip() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(3));
        let tmp = std::env::temp_dir().join("lrq_test_weights.bin");
        w.save(&tmp).unwrap();
        let w2 = Weights::load(&dim, &tmp).unwrap();
        assert_eq!(w.emb, w2.emb);
        assert_eq!(w.blocks[1].norm_ffn, w2.blocks[1].norm_ffn);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn residual_projections_scaled_down() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(4));
        let std_of = |t: &Tensor| {
            (t.sq_norm() / t.len() as f64).sqrt()
        };
        // wo (idx 3) should have smaller std than wq (idx 0)
        assert!(std_of(&w.blocks[0].ws[3]) < std_of(&w.blocks[0].ws[0]) * 0.6);
    }
}
