//! Weight store: FP weights, quantized checkpoints, init, and binary I/O.
//!
//! FP checkpoint format (little-endian): magic `LRQW`, version u32, tensor
//! count u32, then per tensor: rank u32, dims u64…, f32 data. Quantized
//! checkpoints (`LRQQ`) store packed integer codes + per-channel grids:
//! magic, version u32, bits u32, six u64 dim fields (vocab/d/heads/layers/
//! ff/seq — validated against the caller's [`ModelDim`]), then emb, per
//! block 7 [`PackedMatrix`] records (rows u64, cols u64, bits u32, scale
//! f32·rows, zp f32·rows, packed-len u64, packed bytes) + 2 norm tensors,
//! final_norm, head, and a trailing FNV-1a-64 checksum over everything
//! before it.
//!
//! Both readers fail closed: every length is validated against a hard cap
//! and the remaining input *before* allocation, so a truncated, corrupt, or
//! adversarial stream produces an error — never a panic, an out-of-memory
//! allocation, or silently garbage weights.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::pack::packed_len;
use crate::quant::PackedMatrix;
use crate::rng::Rng;
use crate::tensor::Tensor;

use super::layout::ModelDim;

/// Hard cap on tensor rank accepted from a checkpoint stream.
const MAX_RANK: usize = 8;

/// Hard cap on elements per tensor (512 MiB of f32) — far above any model
/// this crate builds, low enough that a corrupt header can't demand an
/// absurd allocation.
const MAX_ELEMS: usize = 1 << 27;

/// One Transformer block's FP weights (canonical order).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ws: Vec<Tensor>, // wq wk wv wo wg wu wd
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

impl BlockWeights {
    pub fn norms(&self) -> [&Tensor; 2] {
        [&self.norm_attn, &self.norm_ffn]
    }
}

/// Full FP model weights.
#[derive(Clone, Debug)]
pub struct Weights {
    pub dim: ModelDim,
    pub emb: Tensor,
    pub blocks: Vec<BlockWeights>,
    pub final_norm: Tensor,
    pub head: Tensor,
}

impl Weights {
    /// GPT-style init: N(0, 0.02) embeddings/projections, residual-out
    /// projections scaled by 1/sqrt(2L), unit norms.
    pub fn init(dim: &ModelDim, rng: &mut Rng) -> Self {
        let std = 0.02f32;
        let resid = std / ((2 * dim.layers) as f32).sqrt();
        let mut blocks = Vec::with_capacity(dim.layers);
        for _ in 0..dim.layers {
            let shapes = dim.block_weight_shapes();
            let mut ws = Vec::with_capacity(7);
            for (i, (co, ci)) in shapes.iter().enumerate() {
                // wo (3) and wd (6) write into the residual stream
                let s = if i == 3 || i == 6 { resid } else { std };
                ws.push(Tensor::randn(rng, &[*co, *ci], s));
            }
            blocks.push(BlockWeights {
                ws,
                norm_attn: Tensor::ones(&[dim.d]),
                norm_ffn: Tensor::ones(&[dim.d]),
            });
        }
        Weights {
            dim: dim.clone(),
            emb: Tensor::randn(rng, &[dim.vocab, dim.d], std),
            blocks,
            final_norm: Tensor::ones(&[dim.d]),
            head: Tensor::randn(rng, &[dim.vocab, dim.d], std),
        }
    }

    /// Flat canonical-order view matching the train_step artifact inputs:
    /// emb, per-block (7 ws + 2 norms), final_norm, head.
    pub fn flat(&self) -> Vec<&Tensor> {
        let mut out = vec![&self.emb];
        for b in &self.blocks {
            out.extend(b.ws.iter());
            out.push(&b.norm_attn);
            out.push(&b.norm_ffn);
        }
        out.push(&self.final_norm);
        out.push(&self.head);
        out
    }

    /// Rebuild from the flat canonical-order list (train_step outputs).
    pub fn from_flat(dim: &ModelDim, flat: Vec<Tensor>) -> Result<Self> {
        let expect = 1 + dim.layers * 9 + 2;
        if flat.len() != expect {
            bail!("flat weight count {} != {expect}", flat.len());
        }
        let mut it = flat.into_iter();
        let emb = it.next().unwrap();
        let mut blocks = Vec::with_capacity(dim.layers);
        for _ in 0..dim.layers {
            let ws: Vec<Tensor> = (0..7).map(|_| it.next().unwrap()).collect();
            let norm_attn = it.next().unwrap();
            let norm_ffn = it.next().unwrap();
            blocks.push(BlockWeights { ws, norm_attn, norm_ffn });
        }
        let final_norm = it.next().unwrap();
        let head = it.next().unwrap();
        Ok(Weights { dim: dim.clone(), emb, blocks, final_norm, head })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(b"LRQW")?;
        w.write_all(&1u32.to_le_bytes())?;
        let flat = self.flat();
        w.write_all(&(flat.len() as u32).to_le_bytes())?;
        for t in flat {
            write_tensor(&mut w, t)?;
        }
        Ok(())
    }

    pub fn load(dim: &ModelDim, path: &Path) -> Result<Self> {
        let mut r = BufReader::new(
            File::open(path).with_context(|| format!("open {path:?}"))?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"LRQW" {
            bail!("bad magic in {path:?}");
        }
        let _ver = read_u32(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        let flat: Result<Vec<Tensor>> =
            (0..n).map(|_| read_tensor(&mut r)).collect();
        Weights::from_flat(dim, flat?)
    }
}

/// One block's weights in quantized (packed) form.
#[derive(Clone, Debug)]
pub struct QuantizedBlock {
    pub ws: Vec<PackedMatrix>, // canonical order
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

impl QuantizedBlock {
    /// Dequantized (Ŵ) tensors, canonical order — the block_fwd_q inputs.
    pub fn dequant_ws(&self) -> Vec<Tensor> {
        self.ws.iter().map(|p| p.dequant()).collect()
    }

    pub fn storage_bytes(&self) -> usize {
        self.ws.iter().map(|p| p.storage_bytes()).sum::<usize>()
            + (self.norm_attn.len() + self.norm_ffn.len()) * 4
    }
}

/// A fully quantized model checkpoint (embeddings/head/norms stay FP, as in
/// the paper: only attention/FFN linears are quantized).
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub dim: ModelDim,
    pub bits: u32,
    pub emb: Tensor,
    pub blocks: Vec<QuantizedBlock>,
    pub final_norm: Tensor,
    pub head: Tensor,
}

impl QuantizedModel {
    /// Total storage including FP pieces — the Fig. 5 "model size".
    pub fn storage_bytes(&self) -> usize {
        let fp = (self.emb.len() + self.final_norm.len() + self.head.len()) * 4;
        fp + self.blocks.iter().map(|b| b.storage_bytes()).sum::<usize>()
    }

    pub fn fp_equivalent_bytes(&self) -> usize {
        self.dim.param_count() * 4
    }

    /// Serialize to the `LRQQ` wire format (checksummed; see module doc).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.storage_bytes() + 128);
        out.extend_from_slice(LRQQ_MAGIC);
        out.extend_from_slice(&LRQQ_VERSION.to_le_bytes());
        out.extend_from_slice(&self.bits.to_le_bytes());
        for v in [self.dim.vocab, self.dim.d, self.dim.heads,
                  self.dim.layers, self.dim.ff, self.dim.seq] {
            out.extend_from_slice(&(v as u64).to_le_bytes());
        }
        push_tensor(&mut out, &self.emb);
        for b in &self.blocks {
            for p in &b.ws {
                push_packed(&mut out, p);
            }
            push_tensor(&mut out, &b.norm_attn);
            push_tensor(&mut out, &b.norm_ffn);
        }
        push_tensor(&mut out, &self.final_norm);
        push_tensor(&mut out, &self.head);
        let sum = fnv1a_64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse an `LRQQ` checkpoint, failing closed on any inconsistency:
    /// checksum mismatch, bad magic/version, dim fields that disagree with
    /// `dim`, shape mismatches, truncation, or trailing garbage.
    pub fn from_bytes(dim: &ModelDim, bytes: &[u8]) -> Result<Self> {
        if bytes.len() < 8 {
            bail!("LRQQ checkpoint truncated: {} bytes", bytes.len());
        }
        let (payload, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let computed = fnv1a_64(payload);
        if stored != computed {
            bail!("LRQQ checksum mismatch (stored {stored:#018x}, computed \
                   {computed:#018x}) — corrupt or truncated checkpoint");
        }
        let mut c = Cursor::new(payload);
        if c.take(4)? != LRQQ_MAGIC {
            bail!("bad LRQQ magic");
        }
        let ver = c.u32()?;
        if ver != LRQQ_VERSION {
            bail!("unsupported LRQQ version {ver} (supported: \
                   {LRQQ_VERSION})");
        }
        let bits = c.u32()?;
        if !(1..=8).contains(&bits) {
            bail!("LRQQ bits {bits} out of range [1, 8]");
        }
        for (name, expect) in [("vocab", dim.vocab), ("d", dim.d),
                               ("heads", dim.heads), ("layers", dim.layers),
                               ("ff", dim.ff), ("seq", dim.seq)] {
            let got = c.dim_usize()?;
            if got != expect {
                bail!("LRQQ {name} {got} != model {name} {expect}");
            }
        }
        let emb = read_tensor_buf(&mut c)?;
        expect_dims(&emb, &[dim.vocab, dim.d], "emb")?;
        let shapes = dim.block_weight_shapes();
        let mut blocks = Vec::with_capacity(dim.layers);
        for l in 0..dim.layers {
            let mut ws = Vec::with_capacity(7);
            for (i, &(co, ci)) in shapes.iter().enumerate() {
                let p = read_packed(&mut c)?;
                if p.rows != co || p.cols != ci {
                    bail!("LRQQ block {l} matrix {i}: {}x{} != expected \
                           {co}x{ci}", p.rows, p.cols);
                }
                if p.bits != bits {
                    bail!("LRQQ block {l} matrix {i}: bits {} != header \
                           bits {bits}", p.bits);
                }
                ws.push(p);
            }
            let norm_attn = read_tensor_buf(&mut c)?;
            expect_dims(&norm_attn, &[dim.d], "norm_attn")?;
            let norm_ffn = read_tensor_buf(&mut c)?;
            expect_dims(&norm_ffn, &[dim.d], "norm_ffn")?;
            blocks.push(QuantizedBlock { ws, norm_attn, norm_ffn });
        }
        let final_norm = read_tensor_buf(&mut c)?;
        expect_dims(&final_norm, &[dim.d], "final_norm")?;
        let head = read_tensor_buf(&mut c)?;
        expect_dims(&head, &[dim.vocab, dim.d], "head")?;
        if c.remaining() != 0 {
            bail!("LRQQ checkpoint has {} trailing bytes", c.remaining());
        }
        Ok(QuantizedModel {
            dim: dim.clone(),
            bits,
            emb,
            blocks,
            final_norm,
            head,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("write {path:?}"))
    }

    pub fn load(dim: &ModelDim, path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("open {path:?}"))?;
        QuantizedModel::from_bytes(dim, &bytes)
    }
}

const LRQQ_MAGIC: &[u8; 4] = b"LRQQ";
const LRQQ_VERSION: u32 = 1;

/// FNV-1a 64-bit — cheap integrity check for the LRQQ trailer; catches
/// truncation and random corruption (it is not cryptographic).
fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bounds-checked reader over an in-memory checkpoint: every `take`
/// validates against the remaining input before slicing, so no parse path
/// can over-read or over-allocate.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("LRQQ truncated: need {n} bytes at offset {}, have {}",
                  self.pos, self.buf.len() - self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn dim_usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        if v > MAX_ELEMS as u64 {
            bail!("LRQQ dimension {v} exceeds cap {MAX_ELEMS}");
        }
        Ok(v as usize)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let Some(bytes) = n.checked_mul(4) else {
            bail!("LRQQ f32 run length overflows");
        };
        Ok(self.take(bytes)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn push_tensor(out: &mut Vec<u8>, t: &Tensor) {
    write_tensor(out, t).expect("write to Vec cannot fail");
}

fn push_packed(out: &mut Vec<u8>, p: &PackedMatrix) {
    out.extend_from_slice(&(p.rows as u64).to_le_bytes());
    out.extend_from_slice(&(p.cols as u64).to_le_bytes());
    out.extend_from_slice(&p.bits.to_le_bytes());
    for &s in &p.scale {
        out.extend_from_slice(&s.to_le_bytes());
    }
    for &z in &p.zp {
        out.extend_from_slice(&z.to_le_bytes());
    }
    out.extend_from_slice(&(p.packed.len() as u64).to_le_bytes());
    out.extend_from_slice(&p.packed);
}

fn read_packed(c: &mut Cursor) -> Result<PackedMatrix> {
    let rows = c.dim_usize()?;
    let cols = c.dim_usize()?;
    let bits = c.u32()?;
    if !(1..=8).contains(&bits) {
        bail!("LRQQ packed matrix bits {bits} out of range [1, 8]");
    }
    let n = match rows.checked_mul(cols) {
        Some(m) if m <= MAX_ELEMS => m,
        _ => bail!("LRQQ packed matrix {rows}x{cols} exceeds element cap \
                    {MAX_ELEMS}"),
    };
    let scale = c.f32s(rows)?;
    let zp = c.f32s(rows)?;
    let plen = c.dim_usize()?;
    if plen != packed_len(n, bits) {
        bail!("LRQQ packed stream length {plen} != expected {} for \
               {rows}x{cols} at {bits} bits", packed_len(n, bits));
    }
    let packed = c.take(plen)?.to_vec();
    PackedMatrix::new(rows, cols, bits, scale, zp, packed)
}

fn read_tensor_buf(c: &mut Cursor) -> Result<Tensor> {
    let rank = c.u32()? as usize;
    if rank > MAX_RANK {
        bail!("LRQQ tensor rank {rank} exceeds cap {MAX_RANK}");
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(c.dim_usize()?);
    }
    let mut n = 1usize;
    for &d in &dims {
        n = match n.checked_mul(d) {
            Some(m) if m <= MAX_ELEMS => m,
            _ => bail!("LRQQ tensor {dims:?} exceeds element cap {MAX_ELEMS}"),
        };
    }
    let data = c.f32s(n)?;
    Ok(Tensor::new(dims, data))
}

fn expect_dims(t: &Tensor, want: &[usize], what: &str) -> Result<()> {
    if t.dims.as_slice() != want {
        bail!("LRQQ {what}: dims {:?} != expected {want:?}", t.dims);
    }
    Ok(())
}

fn write_tensor<W: Write>(w: &mut W, t: &Tensor) -> Result<()> {
    w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
    for &d in &t.dims {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    for &v in &t.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_tensor<R: Read>(r: &mut R) -> Result<Tensor> {
    let rank = read_u32(r)? as usize;
    if rank > MAX_RANK {
        bail!("checkpoint tensor rank {rank} exceeds cap {MAX_RANK}");
    }
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        let d = u64::from_le_bytes(b);
        if d > MAX_ELEMS as u64 {
            bail!("checkpoint tensor dim {d} exceeds cap {MAX_ELEMS}");
        }
        dims.push(d as usize);
    }
    let mut n = 1usize;
    for &d in &dims {
        n = match n.checked_mul(d) {
            Some(m) if m <= MAX_ELEMS => m,
            _ => bail!("checkpoint tensor {dims:?} exceeds element cap \
                        {MAX_ELEMS}"),
        };
    }
    // Read in bounded chunks: a corrupt header cannot force a single huge
    // allocation, and a truncated stream errors at the first short chunk.
    let mut data = Vec::with_capacity(n.min(1 << 20));
    let mut remaining = n * 4; // n ≤ MAX_ELEMS, so no overflow
    let mut chunk = [0u8; 4096];
    while remaining > 0 {
        let take = remaining.min(chunk.len());
        r.read_exact(&mut chunk[..take])?;
        data.extend(chunk[..take].chunks_exact(4).map(|c| {
            f32::from_le_bytes([c[0], c[1], c[2], c[3]])
        }));
        remaining -= take;
    }
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelDim {
        ModelDim {
            name: "tiny".into(),
            vocab: 512,
            d: 128,
            heads: 4,
            layers: 4,
            ff: 352,
            seq: 64,
            train_batch: 16,
            calib_batch: 8,
            recon_batch: 4,
            rank: 32,
        }
    }

    #[test]
    fn init_shapes() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(1));
        assert_eq!(w.blocks.len(), 4);
        assert_eq!(w.emb.dims, vec![512, 128]);
        assert_eq!(w.blocks[0].ws[4].dims, vec![352, 128]);
        assert_eq!(w.flat().len(), 1 + 4 * 9 + 2);
    }

    #[test]
    fn flat_roundtrip() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(2));
        let flat: Vec<Tensor> = w.flat().into_iter().cloned().collect();
        let w2 = Weights::from_flat(&dim, flat).unwrap();
        assert_eq!(w.emb, w2.emb);
        assert_eq!(w.blocks[3].ws[6], w2.blocks[3].ws[6]);
        assert_eq!(w.head, w2.head);
    }

    #[test]
    fn save_load_roundtrip() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(3));
        let tmp = std::env::temp_dir().join("lrq_test_weights.bin");
        w.save(&tmp).unwrap();
        let w2 = Weights::load(&dim, &tmp).unwrap();
        assert_eq!(w.emb, w2.emb);
        assert_eq!(w.blocks[1].norm_ffn, w2.blocks[1].norm_ffn);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn residual_projections_scaled_down() {
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(4));
        let std_of = |t: &Tensor| {
            (t.sq_norm() / t.len() as f64).sqrt()
        };
        // wo (idx 3) should have smaller std than wq (idx 0)
        assert!(std_of(&w.blocks[0].ws[3]) < std_of(&w.blocks[0].ws[0]) * 0.6);
    }

    fn quantized_tiny(seed: u64, bits: u32) -> QuantizedModel {
        use crate::infer::{quantize_weights, ScaleInit};
        let dim = tiny();
        let w = Weights::init(&dim, &mut Rng::new(seed));
        quantize_weights(&w, bits, ScaleInit::Rtn).unwrap()
    }

    #[test]
    fn lrqq_roundtrip_is_exact() {
        for bits in [3u32, 4, 8] {
            let qm = quantized_tiny(5, bits);
            let dim = qm.dim.clone();
            let bytes = qm.to_bytes();
            let qm2 = QuantizedModel::from_bytes(&dim, &bytes).unwrap();
            assert_eq!(qm2.bits, bits);
            assert_eq!(qm.emb, qm2.emb);
            assert_eq!(qm.head, qm2.head);
            for (a, b) in qm.blocks.iter().zip(&qm2.blocks) {
                for (pa, pb) in a.ws.iter().zip(&b.ws) {
                    assert_eq!(pa.scale, pb.scale);
                    assert_eq!(pa.zp, pb.zp);
                    assert_eq!(pa.unpack(), pb.unpack());
                }
                assert_eq!(a.norm_attn, b.norm_attn);
                assert_eq!(a.norm_ffn, b.norm_ffn);
            }
        }
    }

    #[test]
    fn lrqq_save_load_roundtrip() {
        let qm = quantized_tiny(6, 4);
        let dim = qm.dim.clone();
        let tmp = std::env::temp_dir().join("lrq_test_quant.lrqq");
        qm.save(&tmp).unwrap();
        let qm2 = QuantizedModel::load(&dim, &tmp).unwrap();
        assert_eq!(qm.storage_bytes(), qm2.storage_bytes());
        assert_eq!(qm.blocks[2].ws[4].unpack(), qm2.blocks[2].ws[4].unpack());
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn lrqq_rejects_truncation_anywhere() {
        let qm = quantized_tiny(7, 4);
        let dim = qm.dim.clone();
        let bytes = qm.to_bytes();
        // cut at a spread of prefixes, including header-only and mid-tensor
        for cut in [0, 3, 4, 11, 60, bytes.len() / 3, bytes.len() / 2,
                    bytes.len() - 9, bytes.len() - 1] {
            let err = QuantizedModel::from_bytes(&dim, &bytes[..cut]);
            assert!(err.is_err(), "truncation at {cut} must fail closed");
        }
    }

    #[test]
    fn lrqq_rejects_corruption() {
        let qm = quantized_tiny(8, 3);
        let dim = qm.dim.clone();
        let bytes = qm.to_bytes();
        // flip one bit at a spread of offsets: checksum must catch each
        for off in [4usize, 16, 100, bytes.len() / 2, bytes.len() - 20] {
            let mut bad = bytes.clone();
            bad[off] ^= 0x10;
            let err = QuantizedModel::from_bytes(&dim, &bad).unwrap_err();
            assert!(format!("{err}").contains("checksum")
                        || format!("{err}").contains("magic"),
                    "unexpected corruption error: {err}");
        }
    }

    #[test]
    fn lrqq_rejects_dim_mismatch() {
        let qm = quantized_tiny(9, 4);
        let bytes = qm.to_bytes();
        let mut other = tiny();
        other.layers = 2;
        let err = QuantizedModel::from_bytes(&other, &bytes).unwrap_err();
        assert!(format!("{err}").contains("layers"), "{err}");
    }

    #[test]
    fn lrqw_reader_caps_bogus_headers() {
        // a hand-built stream claiming an absurd tensor must error without
        // attempting the allocation
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"LRQW");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&1u32.to_le_bytes()); // one tensor
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // absurd dim
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let tmp = std::env::temp_dir().join("lrq_test_bogus.bin");
        std::fs::write(&tmp, &bytes).unwrap();
        let err = Weights::load(&tiny(), &tmp).unwrap_err();
        assert!(format!("{err}").contains("cap"), "{err}");
        std::fs::remove_file(&tmp).ok();
    }
}
