//! Model substrate: Llama-architecture dimensions, the weight store, init,
//! and binary checkpoint I/O. The layout contract with the Python compile
//! path lives in [`layout`].

pub mod layout;
pub mod store;

pub use layout::{ModelDim, WeightKind, BLOCK_WEIGHT_NAMES};
pub use store::{BlockWeights, QuantizedBlock, QuantizedModel, Weights};
