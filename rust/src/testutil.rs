//! Minimal property-testing harness (proptest is unavailable in the offline
//! build image): run a property over many seeded random cases and report the
//! first failing seed, which reproduces deterministically.

use crate::rng::Rng;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed on error.
/// Properties return `Err(msg)` to fail.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two f32 slices are close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, ctx: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{ctx}: len {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!("{ctx}: idx {i}: {x} vs {y} (atol {atol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes() {
        check("trivial", 10, |rng| {
            let x = rng.next_f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failing")]
    fn check_reports_failure() {
        check("failing", 5, |_| Err("boom".into()));
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0005], 1e-3, "t").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3, "t").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, "t").is_err());
    }
}
