//! Serving metrics: request latency distribution, execution time, batch
//! occupancy, throughput — the measurements behind Fig. 5 / Table 15.

use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: usize,
    pub batches: usize,
    latencies_us: Vec<u64>,
    exec_us: Vec<u64>,
    batch_sizes: Vec<usize>,
}

impl Metrics {
    pub fn record(&mut self, latency: Duration, exec: Duration,
                  batch_size: usize) {
        self.requests += 1;
        self.latencies_us.push(latency.as_micros() as u64);
        self.exec_us.push(exec.as_micros() as u64);
        self.batch_sizes.push(batch_size);
        if batch_size > 0 {
            self.batches += 1;
        }
    }

    fn pct(mut v: Vec<u64>, p: f64) -> Duration {
        if v.is_empty() {
            return Duration::ZERO;
        }
        v.sort_unstable();
        let idx = ((v.len() as f64 - 1.0) * p) as usize;
        Duration::from_micros(v[idx])
    }

    pub fn p50_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.50)
    }

    pub fn p95_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.95)
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>()
                / self.latencies_us.len() as u64,
        )
    }

    pub fn mean_exec(&self) -> Duration {
        if self.exec_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.exec_us.iter().sum::<u64>() / self.exec_us.len() as u64)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64
            / self.batch_sizes.len() as f64
    }

    /// Requests per second over the recorded latency mass.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.requests as f64 / wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 10),
                     Duration::from_micros(i), 2);
        }
        assert!(m.p50_latency() < m.p95_latency());
        assert_eq!(m.requests, 100);
        assert!((m.mean_batch() - 2.0).abs() < 1e-9);
        assert!(m.throughput(Duration::from_secs(1)) > 0.0);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_latency(), Duration::ZERO);
        assert_eq!(m.mean_latency(), Duration::ZERO);
        assert_eq!(m.mean_batch(), 0.0);
    }
}
