//! Serving metrics: request latency distribution (p50/p95/p99), per-batch
//! execution time, batch occupancy, throughput, and incremental-decode
//! counters — the measurements behind Fig. 5 / Table 15 and the `serve` /
//! `serve-native` / `generate-native` CLI summaries.
//!
//! Every scalar counter lives in an [`obs::Registry`](crate::obs::Registry)
//! as an `Arc<Counter>`, so the same numbers the CLI summary prints are
//! exportable as a Prometheus text snapshot (and servable over HTTP by
//! [`crate::obs::HttpExporter`]) with no parallel bookkeeping. The one
//! deliberate exception is the raw latency sample vector: fixed-bucket
//! histograms can only bound a percentile, and the existing tests (and Fig. 5
//! replication) assert exact nearest-rank values, so `latencies_us` keeps
//! every sample while the registry's histogram carries the exportable
//! bucketed view of the same stream.
//!
//! Accounting contract:
//! * [`Metrics::record`] — once per completed *request* (score or generate,
//!   success or scorer-error). Requests rejected up front (invalid length)
//!   never executed and are not recorded.
//! * [`Metrics::record_batch`] — once per executed *score batch*: exec time
//!   is per batch, so `mean_exec` is a per-execution mean rather than being
//!   skewed toward large batches.
//! * [`Metrics::record_decode`] — once per executed *decode step* across
//!   however many active sequences were batched into it.
//! * Percentiles use nearest-rank (ceil), so small sample counts no longer
//!   understate tail latency.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::registry::LATENCY_US_BOUNDS;
use crate::obs::{Counter, EventLog, Gauge, Histogram, Registry};

/// Default capacity of the per-server lifecycle event log: enough for a CI
/// soak run's full event stream, bounded under sustained production load.
pub const EVENT_LOG_CAP: usize = 65_536;

/// Serving counters on top of an [`obs::Registry`](Registry). `Clone` shares
/// the underlying instruments (`Arc`), so a cloned snapshot keeps reading
/// live counters; only the exact latency sample vector is copied at clone
/// time.
#[derive(Clone, Debug)]
pub struct Metrics {
    registry: Arc<Registry>,
    /// completed requests (score + generate)
    requests: Arc<Counter>,
    /// executed score batches
    batches: Arc<Counter>,
    /// completed generate requests
    gen_requests: Arc<Counter>,
    /// generated tokens across all completed generate requests
    gen_tokens: Arc<Counter>,
    /// executed decode steps (each covers >= 1 active sequences)
    decode_steps: Arc<Counter>,
    /// tokens produced by decode steps (Σ per-step sequence counts)
    decode_step_tokens: Arc<Counter>,
    /// total decode execution time (µs)
    decode_exec_us: Arc<Counter>,
    /// total score-batch execution time (µs)
    batch_exec_us: Arc<Counter>,
    /// Σ valid rows across executed score batches
    batch_rows: Arc<Counter>,
    /// bucketed request-latency view for export
    latency_hist: Arc<Histogram>,
    /// per-request lifecycle event log (DESIGN.md §10)
    events: Arc<EventLog>,
    /// sequences currently decoding in the engine
    active_seqs: Arc<Gauge>,
    /// admitted-but-waiting generate requests
    queued_reqs: Arc<Gauge>,
    /// admission-control state: 1 while the engine is shedding new arrivals
    shedding: Arc<Gauge>,
    /// degrade-controller state: 1 while the cheap (degraded) plan is active
    degraded: Arc<Gauge>,
    /// degrade-controller transitions (downshifts + restores)
    degrade_shifts: Arc<Counter>,
    /// engine-thread restarts by the unwind-supervision loop
    engine_restarts: Arc<Counter>,
    /// exact latency samples for nearest-rank percentiles
    latencies_us: Vec<u64>,
    /// first/last record times — the observation window for the built-in
    /// requests/sec counter
    first_record: Option<Instant>,
    last_record: Option<Instant>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        let registry = Arc::new(Registry::new());
        let requests = registry.counter(
            "lrq_requests_total",
            "completed requests (score + generate)");
        let batches = registry.counter(
            "lrq_score_batches_total", "executed score batches");
        let gen_requests = registry.counter(
            "lrq_gen_requests_total", "completed generate requests");
        let gen_tokens = registry.counter(
            "lrq_gen_tokens_total",
            "generated tokens across completed generate requests");
        let decode_steps = registry.counter(
            "lrq_decode_steps_total", "executed decode steps");
        let decode_step_tokens = registry.counter(
            "lrq_decode_step_tokens_total",
            "tokens produced by decode steps");
        let decode_exec_us = registry.counter(
            "lrq_decode_exec_us_total",
            "total decode execution time in microseconds");
        let batch_exec_us = registry.counter(
            "lrq_batch_exec_us_total",
            "total score-batch execution time in microseconds");
        let batch_rows = registry.counter(
            "lrq_batch_rows_total",
            "valid rows across executed score batches");
        let latency_hist = registry.histogram(
            "lrq_request_latency_us",
            "request latency in microseconds",
            LATENCY_US_BOUNDS);
        let events = Arc::new(EventLog::new(EVENT_LOG_CAP, &registry));
        let active_seqs = registry.gauge(
            "lrq_active_seqs",
            "sequences currently decoding in the engine");
        let queued_reqs = registry.gauge(
            "lrq_queued_requests",
            "generate requests admitted but waiting for a decode slot");
        let shedding = registry.gauge(
            "lrq_shedding",
            "1 while admission control is shedding new arrivals");
        let degraded = registry.gauge(
            "lrq_degraded",
            "1 while the degraded (cheaper) execution plan is active");
        let degrade_shifts = registry.counter(
            "lrq_degrade_shifts_total",
            "degrade-controller plan transitions (downshifts + restores)");
        let engine_restarts = registry.counter(
            "lrq_engine_restarts_total",
            "engine-thread restarts by the unwind-supervision loop");
        Metrics {
            registry,
            requests,
            batches,
            gen_requests,
            gen_tokens,
            decode_steps,
            decode_step_tokens,
            decode_exec_us,
            batch_exec_us,
            batch_rows,
            latency_hist,
            events,
            active_seqs,
            queued_reqs,
            shedding,
            degraded,
            degrade_shifts,
            engine_restarts,
            latencies_us: Vec::new(),
            first_record: None,
            last_record: None,
        }
    }

    /// The registry backing these counters (for export / HTTP snapshots).
    pub fn registry(&self) -> Arc<Registry> {
        self.registry.clone()
    }

    /// The per-request lifecycle event log shared by the server and its
    /// clients (DESIGN.md §10). Clones of this `Metrics` share the same log.
    pub fn events(&self) -> Arc<EventLog> {
        self.events.clone()
    }

    /// Update the engine-occupancy gauges: sequences actively decoding and
    /// admitted-but-waiting generate requests. Called once per engine loop
    /// iteration.
    pub fn set_occupancy(&self, active: usize, queued: usize) {
        self.active_seqs.set(active as i64);
        self.queued_reqs.set(queued as i64);
    }

    /// Flip the admission-control gauge (DESIGN.md §13).
    pub fn set_shedding(&self, on: bool) {
        self.shedding.set(i64::from(on));
    }

    /// Whether admission control is currently shedding.
    pub fn is_shedding(&self) -> bool {
        self.shedding.get() != 0
    }

    /// Flip the degraded-plan gauge and count the transition.
    pub fn set_degraded(&self, on: bool) {
        self.degraded.set(i64::from(on));
        self.degrade_shifts.inc();
    }

    /// Whether the degraded execution plan is currently active.
    pub fn is_degraded(&self) -> bool {
        self.degraded.get() != 0
    }

    /// Degrade-controller transitions so far (downshifts + restores).
    pub fn degrade_shifts(&self) -> usize {
        self.degrade_shifts.get() as usize
    }

    /// Count one engine-thread restart by the supervision loop.
    pub fn record_engine_restart(&self) {
        self.engine_restarts.inc();
    }

    /// Engine-thread restarts so far.
    pub fn engine_restarts(&self) -> usize {
        self.engine_restarts.get() as usize
    }

    fn touch(&mut self) {
        let now = Instant::now();
        self.first_record.get_or_insert(now);
        self.last_record = Some(now);
    }

    /// Record one completed score request (called once per request, on the
    /// success *and* the scorer-error path).
    pub fn record(&mut self, latency: Duration) {
        self.touch();
        self.requests.inc();
        let us = latency.as_micros() as u64;
        self.latencies_us.push(us);
        self.latency_hist.record(us);
    }

    /// Record one executed score batch (called once per engine execution).
    pub fn record_batch(&mut self, exec: Duration, batch_size: usize) {
        self.batches.inc();
        self.batch_exec_us.add(exec.as_micros() as u64);
        self.batch_rows.add(batch_size as u64);
    }

    /// Record one completed generate request and its token count.
    pub fn record_gen(&mut self, latency: Duration, tokens: usize) {
        self.touch();
        self.requests.inc();
        self.gen_requests.inc();
        self.gen_tokens.add(tokens as u64);
        let us = latency.as_micros() as u64;
        self.latencies_us.push(us);
        self.latency_hist.record(us);
    }

    /// Record one executed decode step batched across `seqs` sequences.
    pub fn record_decode(&mut self, seqs: usize, exec: Duration) {
        self.decode_steps.inc();
        self.decode_step_tokens.add(seqs as u64);
        self.decode_exec_us.add(exec.as_micros() as u64);
    }

    pub fn requests(&self) -> usize {
        self.requests.get() as usize
    }

    pub fn batches(&self) -> usize {
        self.batches.get() as usize
    }

    pub fn gen_requests(&self) -> usize {
        self.gen_requests.get() as usize
    }

    pub fn gen_tokens(&self) -> usize {
        self.gen_tokens.get() as usize
    }

    pub fn decode_steps(&self) -> usize {
        self.decode_steps.get() as usize
    }

    /// Tokens produced by decode steps (one per stepped sequence). Prefill's
    /// first sampled token is *not* a decode-step token, so after a batched
    /// generate run `gen_tokens == decode_step_tokens + gen_requests`.
    pub fn decode_step_tokens(&self) -> usize {
        self.decode_step_tokens.get() as usize
    }

    /// Nearest-rank percentile over a sorted sample: the smallest value
    /// whose rank covers fraction `p` (ceil), so p95/p99 of a small sample
    /// report a real observed tail value instead of flooring toward p50.
    fn pct_sorted(v: &[u64], p: f64) -> Duration {
        if v.is_empty() {
            return Duration::ZERO;
        }
        let rank = (v.len() as f64 * p).ceil() as usize;
        Duration::from_micros(v[rank.clamp(1, v.len()) - 1])
    }

    fn pct(mut v: Vec<u64>, p: f64) -> Duration {
        v.sort_unstable();
        Self::pct_sorted(&v, p)
    }

    pub fn p50_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.50)
    }

    pub fn p95_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.95)
    }

    pub fn p99_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.99)
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>()
                / self.latencies_us.len() as u64,
        )
    }

    /// Mean execution time per score batch (0 before any batch executed).
    pub fn mean_exec(&self) -> Duration {
        let n = self.batches.get();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.batch_exec_us.get() / n)
    }

    /// Mean occupancy per executed score batch (0.0 before any batch).
    pub fn mean_batch(&self) -> f64 {
        let n = self.batches.get();
        if n == 0 {
            return 0.0;
        }
        self.batch_rows.get() as f64 / n as f64
    }

    /// Mean active sequences per decode step (decode-batching occupancy).
    pub fn mean_decode_batch(&self) -> f64 {
        let n = self.decode_steps.get();
        if n == 0 {
            return 0.0;
        }
        self.decode_step_tokens.get() as f64 / n as f64
    }

    /// Decode throughput: tokens produced per second of decode execution.
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let us = self.decode_exec_us.get();
        if us == 0 {
            return 0.0;
        }
        self.decode_step_tokens.get() as f64 / (us as f64 * 1e-6)
    }

    /// Requests per second over an externally measured wall window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.requests() as f64 / wall.as_secs_f64()
    }

    /// Steady-state completion rate: requests per second over the window
    /// between the first and last recorded response. A single record has no
    /// window (first == last), and sub-microsecond windows collapse to zero
    /// — both report 0.0 rather than dividing by zero or claiming infinite
    /// throughput. Caveat: the window excludes the first batch's queue +
    /// exec time, so with few batches this overstates throughput — CLI
    /// summaries use [`Metrics::throughput`] with an external wall clock.
    pub fn requests_per_sec(&self) -> f64 {
        match (self.first_record, self.last_record) {
            (Some(a), Some(b)) if self.requests() > 1 => {
                let w = b.saturating_duration_since(a);
                if w.is_zero() {
                    0.0
                } else {
                    (self.requests() - 1) as f64 / w.as_secs_f64()
                }
            }
            _ => 0.0,
        }
    }

    /// Prometheus text snapshot of every serving counter (plus the bucketed
    /// latency histogram) — what the HTTP exporter and `--metrics-out` emit.
    pub fn render(&self) -> String {
        self.registry.render()
    }

    /// One-line CLI summary (shared by `serve`, `serve-native`, and
    /// `generate-native`), with throughput over the caller-measured wall
    /// window. Sorts the latency history once for all three percentiles;
    /// decode counters are appended only when decoding happened.
    pub fn summary(&self, wall: Duration) -> String {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        let mut s = format!(
            "{} requests in {} batches (mean batch {:.2}): latency p50 \
             {:.2}ms p95 {:.2}ms p99 {:.2}ms, mean exec {:.2}ms, {:.1} req/s",
            self.requests(),
            self.batches(),
            self.mean_batch(),
            Self::pct_sorted(&lat, 0.50).as_secs_f64() * 1e3,
            Self::pct_sorted(&lat, 0.95).as_secs_f64() * 1e3,
            Self::pct_sorted(&lat, 0.99).as_secs_f64() * 1e3,
            self.mean_exec().as_secs_f64() * 1e3,
            self.throughput(wall),
        );
        if self.decode_steps() > 0 {
            s.push_str(&format!(
                "; {} generations, {} tokens in {} decode steps (mean step \
                 batch {:.2}, {:.0} tok/s decode)",
                self.gen_requests(),
                self.gen_tokens(),
                self.decode_steps(),
                self.mean_decode_batch(),
                self.decode_tokens_per_sec(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            // two requests per executed batch
            if i % 2 == 1 {
                m.record_batch(Duration::from_micros(i), 2);
            }
            m.record(Duration::from_micros(i * 10));
        }
        assert!(m.p50_latency() < m.p95_latency());
        assert!(m.p95_latency() <= m.p99_latency());
        assert_eq!(m.requests(), 100);
        assert_eq!(m.batches(), 50);
        assert!((m.mean_batch() - 2.0).abs() < 1e-9);
        assert!(m.throughput(Duration::from_secs(1)) > 0.0);
    }

    #[test]
    fn nearest_rank_covers_small_tails() {
        let mut m = Metrics::default();
        // 5 samples: 10, 20, 30, 40, 1000us. Floor indexing reported p99 =
        // v[3] = 40us; nearest-rank must surface the real 1000us outlier.
        for us in [10u64, 20, 30, 40, 1000] {
            m.record(Duration::from_micros(us));
        }
        assert_eq!(m.p99_latency(), Duration::from_micros(1000));
        assert_eq!(m.p95_latency(), Duration::from_micros(1000));
        assert_eq!(m.p50_latency(), Duration::from_micros(30));
    }

    #[test]
    fn exec_is_per_batch_not_per_request() {
        let mut m = Metrics::default();
        // one big slow batch + one small fast batch; per-request accounting
        // would weight the slow exec 4x and report 820us
        m.record_batch(Duration::from_micros(1000), 4);
        for _ in 0..4 {
            m.record(Duration::from_micros(1100));
        }
        m.record_batch(Duration::from_micros(100), 1);
        m.record(Duration::from_micros(150));
        assert_eq!(m.mean_exec(), Duration::from_micros(550));
        assert!((m.mean_batch() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn decode_counters_aggregate() {
        let mut m = Metrics::default();
        m.record_decode(4, Duration::from_micros(200));
        m.record_decode(2, Duration::from_micros(100));
        m.record_gen(Duration::from_millis(3), 7);
        assert_eq!(m.decode_steps(), 2);
        assert_eq!(m.decode_step_tokens(), 6);
        assert_eq!(m.gen_requests(), 1);
        assert_eq!(m.gen_tokens(), 7);
        assert_eq!(m.requests(), 1);
        assert!((m.mean_decode_batch() - 3.0).abs() < 1e-9);
        // 6 tokens over 300us = 20k tok/s
        assert!((m.decode_tokens_per_sec() - 20_000.0).abs() < 1.0);
        assert!(m.summary(Duration::from_secs(1)).contains("decode"));
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_latency(), Duration::ZERO);
        assert_eq!(m.p99_latency(), Duration::ZERO);
        assert_eq!(m.mean_latency(), Duration::ZERO);
        assert_eq!(m.mean_exec(), Duration::ZERO);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.mean_decode_batch(), 0.0);
        assert_eq!(m.decode_tokens_per_sec(), 0.0);
        assert_eq!(m.requests_per_sec(), 0.0);
        assert!(!m.summary(Duration::ZERO).is_empty());
    }

    #[test]
    fn single_record_window_is_finite() {
        // first_record == last_record after one request: the observation
        // window is empty, and the rate must be 0.0 — not a division by
        // zero, not +inf
        let mut m = Metrics::default();
        m.record(Duration::from_micros(5));
        let rps = m.requests_per_sec();
        assert_eq!(rps, 0.0);
        assert!(rps.is_finite());
        // two records in (almost) the same instant can still collapse to a
        // zero-length window; the guard must hold there too
        m.record(Duration::from_micros(5));
        let rps = m.requests_per_sec();
        assert!(rps.is_finite(), "rps {rps}");
        assert!(rps >= 0.0, "rps {rps}");
    }

    #[test]
    fn requests_per_sec_counts_window() {
        let mut m = Metrics::default();
        m.record(Duration::from_micros(5));
        // single request: no window yet
        assert_eq!(m.requests_per_sec(), 0.0);
        std::thread::sleep(Duration::from_millis(5));
        m.record(Duration::from_micros(5));
        let rps = m.requests_per_sec();
        // one inter-arrival over a >=5ms sleep: positive, below 1000 req/s
        assert!(rps > 0.0 && rps < 1000.0, "rps {rps}");
    }

    #[test]
    fn events_and_occupancy_share_registry() {
        use crate::obs::{EventKind, ReqKind};
        let m = Metrics::default();
        m.set_occupancy(3, 2);
        let ev = m.events();
        ev.record(11, ReqKind::Score, EventKind::Enqueue, 1);
        ev.record(11, ReqKind::Score, EventKind::BatchJoin, 1);
        ev.record(11, ReqKind::Score, EventKind::Exec, 25);
        ev.record(11, ReqKind::Score, EventKind::Respond, 0);
        // clones are live views onto the same log
        assert_eq!(m.clone().events().summaries().len(), 1);
        let txt = m.render();
        assert!(txt.contains("lrq_active_seqs 3"), "{txt}");
        assert!(txt.contains("lrq_queued_requests 2"), "{txt}");
        assert!(txt.contains("lrq_requests_responded_total 1"), "{txt}");
        assert!(txt.contains("lrq_exec_time_us_sum 25"), "{txt}");
    }

    #[test]
    fn overload_gauges_render_and_count() {
        let m = Metrics::default();
        assert!(!m.is_shedding());
        assert!(!m.is_degraded());
        assert_eq!(m.degrade_shifts(), 0);
        m.set_shedding(true);
        m.set_degraded(true);
        m.set_degraded(false);
        m.record_engine_restart();
        assert!(m.is_shedding());
        assert!(!m.is_degraded());
        assert_eq!(m.degrade_shifts(), 2);
        assert_eq!(m.engine_restarts(), 1);
        let txt = m.render();
        assert!(txt.contains("lrq_shedding 1"), "{txt}");
        assert!(txt.contains("lrq_degraded 0"), "{txt}");
        assert!(txt.contains("lrq_degrade_shifts_total 2"), "{txt}");
        assert!(txt.contains("lrq_engine_restarts_total 1"), "{txt}");
        m.set_shedding(false);
        assert!(m.render().contains("lrq_shedding 0"));
    }

    #[test]
    fn clone_shares_counters_and_renders() {
        let mut m = Metrics::default();
        m.record_batch(Duration::from_micros(10), 3);
        m.record(Duration::from_micros(42));
        let snap = m.clone();
        // counters are shared through the registry: the clone sees later
        // increments (it is a live view, not a frozen copy)
        m.record(Duration::from_micros(50));
        assert_eq!(snap.requests(), 2);
        let txt = snap.render();
        assert!(txt.contains("lrq_requests_total 2"), "{txt}");
        assert!(txt.contains("lrq_batch_rows_total 3"), "{txt}");
        assert!(txt.contains("lrq_request_latency_us_bucket"), "{txt}");
    }
}
