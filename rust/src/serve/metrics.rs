//! Serving metrics: request latency distribution (p50/p95/p99), execution
//! time, batch occupancy, throughput — the measurements behind Fig. 5 /
//! Table 15 and the `serve` / `serve-native` CLI summaries.

use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: usize,
    pub batches: usize,
    latencies_us: Vec<u64>,
    exec_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    /// first/last record times — the observation window for the built-in
    /// requests/sec counter
    first_record: Option<Instant>,
    last_record: Option<Instant>,
}

impl Metrics {
    /// Record one request's response (called once per request).
    pub fn record(&mut self, latency: Duration, exec: Duration,
                  batch_size: usize) {
        let now = Instant::now();
        self.first_record.get_or_insert(now);
        self.last_record = Some(now);
        self.requests += 1;
        self.latencies_us.push(latency.as_micros() as u64);
        self.exec_us.push(exec.as_micros() as u64);
        self.batch_sizes.push(batch_size);
    }

    /// Record one executed model batch (called once per engine execution).
    pub fn record_batch(&mut self) {
        self.batches += 1;
    }

    fn pct_sorted(v: &[u64], p: f64) -> Duration {
        if v.is_empty() {
            return Duration::ZERO;
        }
        let idx = ((v.len() as f64 - 1.0) * p) as usize;
        Duration::from_micros(v[idx])
    }

    fn pct(mut v: Vec<u64>, p: f64) -> Duration {
        v.sort_unstable();
        Self::pct_sorted(&v, p)
    }

    pub fn p50_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.50)
    }

    pub fn p95_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.95)
    }

    pub fn p99_latency(&self) -> Duration {
        Self::pct(self.latencies_us.clone(), 0.99)
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>()
                / self.latencies_us.len() as u64,
        )
    }

    pub fn mean_exec(&self) -> Duration {
        if self.exec_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.exec_us.iter().sum::<u64>() / self.exec_us.len() as u64)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64
            / self.batch_sizes.len() as f64
    }

    /// Requests per second over an externally measured wall window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.requests as f64 / wall.as_secs_f64()
    }

    /// Steady-state completion rate: requests per second over the window
    /// between the first and last recorded response (0.0 until two requests
    /// have landed). Caveat: the window excludes the first batch's queue +
    /// exec time, so with few batches this overstates throughput — CLI
    /// summaries use [`Metrics::throughput`] with an external wall clock.
    pub fn requests_per_sec(&self) -> f64 {
        match (self.first_record, self.last_record) {
            (Some(a), Some(b)) if self.requests > 1 => {
                let w = b.saturating_duration_since(a);
                if w.is_zero() {
                    0.0
                } else {
                    (self.requests - 1) as f64 / w.as_secs_f64()
                }
            }
            _ => 0.0,
        }
    }

    /// One-line CLI summary (shared by `serve` and `serve-native`), with
    /// throughput over the caller-measured wall window. Sorts the latency
    /// history once for all three percentiles.
    pub fn summary(&self, wall: Duration) -> String {
        let mut lat = self.latencies_us.clone();
        lat.sort_unstable();
        format!(
            "{} requests in {} batches (mean batch {:.2}): latency p50 \
             {:.2}ms p95 {:.2}ms p99 {:.2}ms, mean exec {:.2}ms, {:.1} req/s",
            self.requests,
            self.batches,
            self.mean_batch(),
            Self::pct_sorted(&lat, 0.50).as_secs_f64() * 1e3,
            Self::pct_sorted(&lat, 0.95).as_secs_f64() * 1e3,
            Self::pct_sorted(&lat, 0.99).as_secs_f64() * 1e3,
            self.mean_exec().as_secs_f64() * 1e3,
            self.throughput(wall),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for i in 1..=100u64 {
            // two requests per executed batch
            if i % 2 == 1 {
                m.record_batch();
            }
            m.record(Duration::from_micros(i * 10),
                     Duration::from_micros(i), 2);
        }
        assert!(m.p50_latency() < m.p95_latency());
        assert!(m.p95_latency() <= m.p99_latency());
        assert_eq!(m.requests, 100);
        assert_eq!(m.batches, 50);
        assert!((m.mean_batch() - 2.0).abs() < 1e-9);
        assert!(m.throughput(Duration::from_secs(1)) > 0.0);
    }

    #[test]
    fn empty_safe() {
        let m = Metrics::default();
        assert_eq!(m.p50_latency(), Duration::ZERO);
        assert_eq!(m.p99_latency(), Duration::ZERO);
        assert_eq!(m.mean_latency(), Duration::ZERO);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.requests_per_sec(), 0.0);
        assert!(!m.summary(Duration::ZERO).is_empty());
    }

    #[test]
    fn requests_per_sec_counts_window() {
        let mut m = Metrics::default();
        m.record(Duration::from_micros(5), Duration::from_micros(1), 1);
        // single request: no window yet
        assert_eq!(m.requests_per_sec(), 0.0);
        std::thread::sleep(Duration::from_millis(5));
        m.record(Duration::from_micros(5), Duration::from_micros(1), 1);
        let rps = m.requests_per_sec();
        // one inter-arrival over a >=5ms sleep: positive, below 1000 req/s
        assert!(rps > 0.0 && rps < 1000.0, "rps {rps}");
    }
}
