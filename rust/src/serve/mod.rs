//! Batch-scoring server (the Fig. 5 serving-side substrate): a dynamic
//! batcher in front of a single-threaded PJRT scoring engine, with
//! request-level latency metrics.
//!
//! tokio is unavailable in the offline build image, so this is a std-thread
//! design: client threads submit [`ScoreRequest`]s over an mpsc channel; the
//! engine thread drains up to `max_batch` requests (or `max_wait`), pads them
//! into one model batch, executes, and answers each request on its own
//! oneshot channel. The PJRT runtime is not `Send`, so the engine is *built
//! inside* the engine thread by the supplied constructor closure.

pub mod metrics;

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

pub use metrics::Metrics;

/// A batch scorer: given padded id/target rows, return the per-position
/// target log-probs for each row (row-major [rows × seq]).
pub trait BatchScorer {
    /// batch capacity (rows per model execution)
    fn batch_size(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// Whether `score` accepts fewer than `batch_size()` rows. Fixed-shape
    /// backends (PJRT artifacts) keep the default `false` and always receive
    /// `batch_size()` padded rows; variable backends (the native engine)
    /// return `true` and are handed only the occupied rows.
    fn variable_batch(&self) -> bool {
        false
    }
    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>>;
}

/// One scoring request: a token sequence; the response is the total log-prob
/// of `ids[1..]` under the model (the serving analogue of batched scoring /
/// reranking workloads).
pub struct ScoreRequest {
    pub ids: Vec<i32>,
    resp: Sender<Result<ScoreResponse, String>>,
    submitted: Instant,
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub logp_sum: f32,
    pub latency: Duration,
    pub batch_size: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct Client {
    tx: Sender<ScoreRequest>,
}

impl Client {
    /// Blocking score call.
    pub fn score(&self, ids: Vec<i32>) -> Result<ScoreResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(ScoreRequest { ids, resp: tx, submitted: Instant::now() })
            .map_err(|_| anyhow!("server stopped"))?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }
}

pub struct Server {
    tx: Option<Sender<ScoreRequest>>,
    handle: Option<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Start the engine thread. `make_scorer` runs inside the thread (PJRT
    /// state is not Send).
    pub fn start<F>(cfg: ServerConfig, make_scorer: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn BatchScorer>> + Send + 'static,
    {
        let (tx, rx) = channel::<ScoreRequest>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || {
            let mut scorer = match make_scorer() {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            engine_loop(&mut *scorer, cfg, rx, m2);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Server { tx: Some(tx), handle: Some(handle), metrics })
    }

    pub fn client(&self) -> Client {
        Client { tx: self.tx.as_ref().expect("server running").clone() }
    }

    /// Stop the engine and join.
    pub fn shutdown(&mut self) {
        self.tx.take(); // close channel → engine loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn engine_loop(scorer: &mut dyn BatchScorer, cfg: ServerConfig,
               rx: Receiver<ScoreRequest>, metrics: Arc<Mutex<Metrics>>) {
    let bcap = cfg.max_batch.min(scorer.batch_size()).max(1);
    let seq = scorer.seq_len();
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < bcap {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        run_batch(scorer, seq, batch, &metrics);
    }
}

fn run_batch(scorer: &mut dyn BatchScorer, seq: usize,
             batch: Vec<ScoreRequest>, metrics: &Arc<Mutex<Metrics>>) {
    let n = batch.len();
    // fixed-shape scorers always get full capacity; variable ones only the
    // occupied rows (no padded-row compute)
    let b = if scorer.variable_batch() {
        n.min(scorer.batch_size())
    } else {
        scorer.batch_size()
    };
    let mut ids = vec![0i32; b * seq];
    let mut tgt = vec![0i32; b * seq];
    let mut lens = vec![0usize; n];
    let mut bad: Vec<Option<String>> = vec![None; n];
    for (i, r) in batch.iter().enumerate() {
        if r.ids.len() < 2 || r.ids.len() > seq {
            bad[i] = Some(format!("sequence length {} not in [2, {seq}]",
                                  r.ids.len()));
            continue;
        }
        lens[i] = r.ids.len();
        ids[i * seq..i * seq + r.ids.len()].copy_from_slice(&r.ids);
        for (p, w) in r.ids[1..].iter().enumerate() {
            tgt[i * seq + p] = *w;
        }
    }
    let t0 = Instant::now();
    let scored = scorer.score(&ids, &tgt);
    let exec_time = t0.elapsed();
    match scored {
        Ok(logp) => {
            metrics.lock().unwrap().record_batch();
            for (i, r) in batch.into_iter().enumerate() {
                if let Some(msg) = bad[i].take() {
                    let _ = r.resp.send(Err(msg));
                    continue;
                }
                let row = &logp[i * seq..(i + 1) * seq];
                let sum: f32 = row[..lens[i] - 1].iter().sum();
                let latency = r.submitted.elapsed();
                metrics
                    .lock()
                    .unwrap()
                    .record(latency, exec_time, n);
                let _ = r.resp.send(Ok(ScoreResponse {
                    logp_sum: sum,
                    latency,
                    batch_size: n,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in batch {
                let _ = r.resp.send(Err(msg.clone()));
            }
        }
    }
}

/// A trivial in-process scorer for tests: logp = -(token value) per position.
pub struct MockScorer {
    pub batch: usize,
    pub seq: usize,
    pub calls: usize,
}

impl BatchScorer for MockScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq
    }
    fn score(&mut self, _ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        self.calls += 1;
        Ok(targets.iter().map(|&t| -(t as f32)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_mock(max_batch: usize, wait_ms: u64) -> Server {
        Server::start(
            ServerConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            },
            || Ok(Box::new(MockScorer { batch: 8, seq: 16, calls: 0 })),
        )
        .unwrap()
    }

    #[test]
    fn scores_single_request() {
        let s = start_mock(4, 1);
        let c = s.client();
        // ids [5, 3, 2]: targets are [3, 2] -> logp = -(3+2)
        let r = c.score(vec![5, 3, 2]).unwrap();
        assert_eq!(r.logp_sum, -5.0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let s = start_mock(8, 50);
        let mut handles = Vec::new();
        for k in 0..8 {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                c.score(vec![1, k as i32 + 1]).unwrap()
            }));
        }
        let results: Vec<ScoreResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // every request answered with its own target sum
        for (k, r) in results.iter().enumerate() {
            assert_eq!(r.logp_sum, -((k as f32) + 1.0));
        }
        // at least one response saw a batch > 1 (they arrived within the
        // batching window)
        assert!(results.iter().any(|r| r.batch_size > 1));
    }

    #[test]
    fn rejects_oversized() {
        let s = start_mock(2, 1);
        let c = s.client();
        let err = c.score((0..64).collect()).unwrap_err();
        assert!(format!("{err}").contains("length"));
    }

    #[test]
    fn never_drops_or_duplicates() {
        let s = start_mock(3, 5);
        let n = 50;
        let mut handles = Vec::new();
        for k in 0..n {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                c.score(vec![0, k as i32]).unwrap().logp_sum
            }));
        }
        let mut got: Vec<f32> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..n).map(|k| -(k as f32)).rev().collect();
        let mut want = want;
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want);
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.requests, n);
    }

    #[test]
    fn metrics_percentiles() {
        let s = start_mock(4, 1);
        let c = s.client();
        for _ in 0..20 {
            c.score(vec![1, 2, 3]).unwrap();
        }
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.requests, 20);
        assert!(m.p50_latency() <= m.p95_latency());
        assert!(m.mean_batch() >= 1.0);
    }
}
