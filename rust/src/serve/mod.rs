//! Batch serving (the Fig. 5 serving-side substrate): a dynamic batcher in
//! front of a single engine thread, serving two workload kinds over one
//! request channel:
//!
//! * **score** — total log-prob of a sequence (reranking-style), batched
//!   into padded model executions exactly as before;
//! * **generate** — incremental decode with an engine-owned per-sequence KV
//!   cache ([`crate::infer::KvCache`] for the native engine): the prompt is
//!   prefilled once, then decode steps are **batched across all active
//!   sequences**, so concurrent generations share each step's unpack/GEMM
//!   work. Sampling (greedy / top-k) happens in the engine loop with a
//!   per-request deterministic RNG seed.
//!
//! tokio is unavailable in the offline build image, so this is a std-thread
//! design: client threads submit [`Request`]s over an mpsc channel; the
//! engine thread drains up to `max_batch` requests (or `max_wait`) when
//! idle, never stalling active decode sequences, and answers each request on
//! its own oneshot channel. The PJRT runtime is not `Send`, so the engine is
//! *built inside* the engine thread by the supplied constructor closure.
//!
//! Validation happens *before* batch assembly: an invalid request is
//! rejected immediately and never occupies a batch row, so it neither wastes
//! engine compute (variable-batch engines execute only occupied rows) nor
//! inflates the `batch_size` reported to the other requests in its batch.
//!
//! Observability: every request carries a process-unique trace ID assigned
//! at submission. When tracing is active ([`crate::obs::trace`]), its
//! lifetime renders as an async `ph:"b"`/`ph:"e"` envelope, and the engine
//! loop emits `score_batch` / `decode_step` spans that nest the per-layer
//! and per-kernel spans recorded inside the model — the request → batch →
//! layer → kernel tree. Independently of tracing, every request's lifecycle
//! (enqueue → admit/batch-join → exec → first-token → respond/reject/
//! disconnect) is recorded into the server's bounded
//! [`EventLog`](crate::obs::EventLog) (shared through [`Metrics::events`]),
//! which derives per-request queue-time / exec-time / TTFT and detects stuck
//! sequences — the substrate of the soak harness's SLO evaluator
//! (DESIGN.md §10).
//!
//! Overload & failure model (DESIGN.md §13): requests may carry deadlines —
//! enforced before batch assembly, before admission, and per decode step,
//! with expired sequences evicted mid-generation and their KV caches
//! released ([`EventKind::Expire`]). Admission control sheds arrivals with
//! a fast retriable rejection ([`EventKind::Shed`], distinct from
//! invalid-request rejects) when queue-depth / KV-pressure watermarks are
//! breached, with hysteresis so the controller cannot flap. Under sustained
//! backlog the engine downshifts to a cheaper pre-built execution plan
//! ([`BatchScorer::set_degraded`]) and restores on recovery. Every scorer
//! call is unwind-isolated, so a panicking model (or a panicked worker-pool
//! job surfacing as an error) fails only the work in that call, never the
//! engine thread; [`Server::shutdown`] bounds its drain of active sequences
//! with a deadline. Fault injection for all of this lives in [`chaos`].

pub mod chaos;
pub mod metrics;

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender,
                      TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::trace;
use crate::obs::{EventKind, EventLog, ReqKind};
use crate::rng::{sample_top_k, Rng};

pub use chaos::{ChaosScorer, FaultPlan, FaultsFired};
pub use metrics::Metrics;

/// Error-message prefix for deadline expiries. Clients (and the load
/// generator's outcome classifier) match on it, so it is part of the API.
pub const EXPIRED_PREFIX: &str = "deadline exceeded";

/// Error-message prefix for retriable overload rejections: admission
/// control and shutdown-time shedding. Distinct from invalid-request
/// rejects — the request was fine, the server was not.
pub const SHED_PREFIX: &str = "overloaded";

/// How often a fully idle engine wakes from its blocking receive to check
/// for a shutdown request (clients may still hold live senders).
const IDLE_POLL: Duration = Duration::from_millis(25);

/// Process-unique request trace IDs (the async-envelope key in trace files).
static NEXT_RID: AtomicU64 = AtomicU64::new(1);

fn next_rid() -> u64 {
    // Relaxed: a uniqueness tick — no other memory is published with it.
    NEXT_RID.fetch_add(1, Ordering::Relaxed)
}

/// Poison-tolerant metrics access for the serving path. A thread that
/// panicked while holding the lock leaves plain accumulator state behind —
/// still safe to read and update — and losing telemetry must never take
/// the batch loop (and every in-flight request) down with it.
fn lock_metrics(m: &Mutex<Metrics>) -> std::sync::MutexGuard<'_, Metrics> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Engine-side handle of an active decode sequence (its KV cache lives
/// inside the scorer).
pub type SeqId = u64;

/// A batch engine: scores padded id/target rows, and (optionally) runs
/// incremental decode over engine-owned per-sequence KV caches.
pub trait BatchScorer {
    /// batch capacity (rows per model execution)
    fn batch_size(&self) -> usize;
    fn seq_len(&self) -> usize;
    /// Whether `score` accepts fewer than `batch_size()` rows. Fixed-shape
    /// backends (PJRT artifacts) keep the default `false` and always receive
    /// `batch_size()` padded rows; variable backends (the native engine)
    /// return `true` and are handed only the occupied rows.
    fn variable_batch(&self) -> bool {
        false
    }
    /// Given padded id/target rows, return the per-position target log-probs
    /// for each row (row-major [rows × seq]).
    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>>;

    /// Whether this engine supports incremental decode (generation). The
    /// remaining decode methods are only called when this returns `true`.
    fn supports_decode(&self) -> bool {
        false
    }
    /// Prefill `prompt` into a fresh engine-owned sequence; returns its
    /// handle plus the next-token logits after the last prompt token.
    fn begin_decode(&mut self, _prompt: &[i32]) -> Result<(SeqId, Vec<f32>)> {
        Err(anyhow!("this engine does not support incremental decode"))
    }
    /// One decode step batched across sequences: `batch[i]` is a sequence
    /// handle plus its newest token; returns next-token logits per sequence.
    fn decode_step(&mut self, _batch: &[(SeqId, i32)])
                   -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("this engine does not support incremental decode"))
    }
    /// Release a sequence's KV cache (finished or failed).
    fn end_decode(&mut self, _seq: SeqId) {}

    /// Whether a cheaper pre-built execution plan is available to downshift
    /// to under load (e.g. the same checkpoint packed at a lower bit-width).
    /// The remaining degrade methods are only called when this is `true`.
    fn supports_degrade(&self) -> bool {
        false
    }
    /// Route subsequent score/prefill/decode work through the degraded plan
    /// (`true`) or the primary (`false`). Live KV caches must stay valid
    /// across the switch — active sequences keep decoding.
    fn set_degraded(&mut self, _on: bool) {}
    /// Whether the degraded plan is currently active.
    fn degraded(&self) -> bool {
        false
    }
}

/// One scoring request: a token sequence; the response is the total log-prob
/// of `ids[1..]` under the model (the serving analogue of batched scoring /
/// reranking workloads).
pub struct ScoreRequest {
    pub ids: Vec<i32>,
    resp: Sender<Result<ScoreResponse, String>>,
    submitted: Instant,
    /// complete-by instant (explicit via [`Client::with_deadline`]; the
    /// server's `default_deadline` applies at enforcement time otherwise)
    deadline: Option<Instant>,
    /// trace ID (async-envelope key; assigned at submission)
    rid: u64,
}

#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub logp_sum: f32,
    pub latency: Duration,
    /// valid requests sharing this request's model execution
    pub batch_size: usize,
}

/// One generation request: prompt + sampling knobs; the response is the
/// generated continuation.
pub struct GenerateRequest {
    pub prompt: Vec<i32>,
    /// tokens to generate (the context budget is `seq_len`)
    pub max_new: usize,
    /// `<= 1` = greedy argmax; otherwise sample from the top-k logits
    pub top_k: usize,
    /// per-request sampling seed (deterministic under greedy regardless)
    pub seed: u64,
    resp: Sender<Result<GenerateResponse, String>>,
    submitted: Instant,
    /// complete-by instant (explicit via [`Client::with_deadline`]; the
    /// server's `default_deadline` applies at enforcement time otherwise)
    deadline: Option<Instant>,
    /// trace ID (async-envelope key; assigned at submission)
    rid: u64,
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    /// generated tokens (continuation only, `max_new` of them)
    pub tokens: Vec<i32>,
    pub latency: Duration,
    pub prompt_len: usize,
}

/// Anything a client can submit to the engine thread.
pub enum Request {
    Score(ScoreRequest),
    Generate(GenerateRequest),
}

/// Hysteresis watermark pair for the overload controllers: arm at `high`,
/// disarm only once the signal is back at/below `low` (DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Watermarks {
    /// arm the controller when the signal reaches this value
    pub high: usize,
    /// disarm once the signal is back at/below this value
    pub low: usize,
}

impl Watermarks {
    /// `high` is floored at 1 and `low` clamped strictly below it, so the
    /// controller always has a real hysteresis band and cannot flap.
    pub fn new(high: usize, low: usize) -> Watermarks {
        let high = high.max(1);
        Watermarks { high, low: low.min(high - 1) }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// deadline applied to requests that carry no explicit one (measured
    /// from submission); `None` = no implicit deadline
    pub default_deadline: Option<Duration>,
    /// admission control on engine-owned waiting work (scores + generates)
    pub shed_queue: Option<Watermarks>,
    /// admission control on KV pressure (active + waiting generations,
    /// each of which holds or will hold a KV cache)
    pub shed_kv: Option<Watermarks>,
    /// degrade controller on waiting work: downshift to the scorer's
    /// cheaper plan at `high`, restore at `low` (needs `supports_degrade`)
    pub degrade: Option<Watermarks>,
    /// bound on draining active decode sequences at shutdown; stragglers
    /// past it are evicted with a deadline expiry
    pub drain_deadline: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            default_deadline: None,
            shed_queue: None,
            shed_kv: None,
            degrade: None,
            drain_deadline: Duration::from_secs(5),
        }
    }
}

/// Handle for submitting requests. Clones share the server's request
/// channel and lifecycle event log.
#[derive(Clone)]
pub struct Client {
    tx: Sender<Request>,
    events: Arc<EventLog>,
    deadline: Option<Duration>,
}

impl Client {
    /// A clone of this handle whose submissions carry `deadline` (measured
    /// from submission). The engine expires the request wherever it is once
    /// the deadline passes — queued, awaiting admission, or mid-decode —
    /// and answers with an [`EXPIRED_PREFIX`] error.
    pub fn with_deadline(mut self, deadline: Duration) -> Client {
        self.deadline = Some(deadline);
        self
    }

    /// Submit a score request without blocking; the response arrives on the
    /// returned channel (dropping it is safe — the engine ignores send
    /// failures, so a disconnected client never poisons its batch).
    pub fn submit(&self, ids: Vec<i32>)
                  -> Result<Receiver<Result<ScoreResponse, String>>> {
        let (tx, rx) = channel();
        let rid = next_rid();
        trace::async_begin("score", rid);
        self.events.record(rid, ReqKind::Score, EventKind::Enqueue,
                           ids.len() as u64);
        let submitted = Instant::now();
        self.tx
            .send(Request::Score(ScoreRequest {
                ids,
                resp: tx,
                submitted,
                deadline: self.deadline.map(|d| submitted + d),
                rid,
            }))
            .map_err(|_| {
                trace::async_end("score", rid);
                // the request never reached the engine: close its lifecycle
                self.events.record(rid, ReqKind::Score, EventKind::Reject, 0);
                anyhow!("server stopped")
            })?;
        Ok(rx)
    }

    /// Blocking score call.
    pub fn score(&self, ids: Vec<i32>) -> Result<ScoreResponse> {
        self.submit(ids)?
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }

    /// Submit a generation request without blocking; the response arrives on
    /// the returned channel. Dropping the receiver mid-generation is safe
    /// and surfaces as a `disconnect` lifecycle event when the engine's
    /// answer fails to send.
    pub fn submit_generate(&self, prompt: Vec<i32>, max_new: usize,
                           top_k: usize, seed: u64)
                           -> Result<Receiver<Result<GenerateResponse,
                                                     String>>> {
        let (tx, rx) = channel();
        let rid = next_rid();
        trace::async_begin("generate", rid);
        self.events.record(rid, ReqKind::Generate, EventKind::Enqueue,
                           prompt.len() as u64);
        let submitted = Instant::now();
        self.tx
            .send(Request::Generate(GenerateRequest {
                prompt,
                max_new,
                top_k,
                seed,
                resp: tx,
                submitted,
                deadline: self.deadline.map(|d| submitted + d),
                rid,
            }))
            .map_err(|_| {
                trace::async_end("generate", rid);
                self.events.record(rid, ReqKind::Generate, EventKind::Reject,
                                   0);
                anyhow!("server stopped")
            })?;
        Ok(rx)
    }

    /// Blocking generate call: decode `max_new` tokens after `prompt`
    /// (greedy when `top_k <= 1`).
    pub fn generate(&self, prompt: Vec<i32>, max_new: usize, top_k: usize,
                    seed: u64) -> Result<GenerateResponse> {
        self.submit_generate(prompt, max_new, top_k, seed)?
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
            .map_err(|e| anyhow!(e))
    }
}

pub struct Server {
    tx: Option<Sender<Request>>,
    handle: Option<JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Start the engine thread. `make_scorer` runs inside the thread (PJRT
    /// state is not Send).
    pub fn start<F>(cfg: ServerConfig, make_scorer: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn BatchScorer>> + Send + 'static,
    {
        Self::start_with(cfg, None, make_scorer)
    }

    /// [`Server::start`] with an optional fault-injection plan (the chaos
    /// harness's entry point): the engine consults `chaos` before dropping
    /// injected responses; scorer-side faults are injected by wrapping the
    /// scorer in a [`ChaosScorer`] inside `make_scorer`.
    pub fn start_with<F>(cfg: ServerConfig, chaos: Option<Arc<FaultPlan>>,
                         make_scorer: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn BatchScorer>> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        let stopping = Arc::new(AtomicBool::new(false));
        let stop2 = stopping.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = std::thread::spawn(move || {
            let mut scorer = match make_scorer() {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            // supervision: scorer-call panics are caught (and answered)
            // inside the loop by `guarded`; this outer isolation covers
            // panics in engine bookkeeping itself. Requests owned by the
            // panicking iteration lose their response senders — clients
            // observe a closed channel — but the server keeps serving.
            loop {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    engine_loop(&mut *scorer, cfg, &rx, &m2, &stop2,
                                chaos.as_ref());
                }));
                match r {
                    Ok(()) => break,
                    Err(_) => {
                        lock_metrics(&m2).record_engine_restart();
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died"))?
            .map_err(|e| anyhow!(e))?;
        Ok(Server { tx: Some(tx), handle: Some(handle), stopping, metrics })
    }

    /// A submission handle. After [`Server::shutdown`] the handle is wired
    /// to a closed channel, so every submit reports "server stopped"
    /// (recorded as a Reject on the event log) instead of panicking in
    /// the caller's thread.
    pub fn client(&self) -> Client {
        let tx = match self.tx.as_ref() {
            Some(tx) => tx.clone(),
            // dropping the receiver half makes every send fail, which
            // submit/submit_generate map onto the error path
            None => channel().0,
        };
        Client {
            tx,
            events: lock_metrics(&self.metrics).events(),
            deadline: None,
        }
    }

    /// The server's lifecycle event log (for JSONL export, stuck-sequence
    /// checks, and SLO aggregation after shutdown).
    pub fn events(&self) -> Arc<EventLog> {
        lock_metrics(&self.metrics).events()
    }

    /// Stop the engine and join, with a bounded drain: queued and active
    /// work keeps executing for up to `drain_deadline`, after which queued
    /// requests are shed and active decode sequences evicted with a
    /// deadline expiry — shutdown completes no matter how long a
    /// generation's `max_new` is.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.tx.take(); // close our sender half too
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// An admitted generation: engine-side sequence handle + sampling state.
struct ActiveSeq {
    sid: SeqId,
    prompt_len: usize,
    max_new: usize,
    top_k: usize,
    rng: Rng,
    tokens: Vec<i32>,
    resp: Sender<Result<GenerateResponse, String>>,
    submitted: Instant,
    /// resolved complete-by instant (explicit or server default)
    deadline: Option<Instant>,
    rid: u64,
}

fn sort_request(r: Request, scores: &mut Vec<ScoreRequest>,
                gens: &mut VecDeque<GenerateRequest>) {
    match r {
        Request::Score(s) => scores.push(s),
        Request::Generate(g) => gens.push_back(g),
    }
}

/// Reusable padded-row buffers for score batches: capacities converge after
/// the first full batch, so the steady-state batch-assembly path stops
/// allocating (the serving-side twin of the engine's scratch arena).
#[derive(Default)]
struct ScoreRows {
    ids: Vec<i32>,
    tgt: Vec<i32>,
    lens: Vec<usize>,
}

fn engine_loop(scorer: &mut dyn BatchScorer, cfg: ServerConfig,
               rx: &Receiver<Request>, metrics: &Arc<Mutex<Metrics>>,
               stopping: &AtomicBool, chaos: Option<&Arc<FaultPlan>>) {
    let events = lock_metrics(metrics).events();
    let bcap = cfg.max_batch.min(scorer.batch_size()).max(1);
    let seq = scorer.seq_len();
    let mut rows = ScoreRows::default();
    let mut scores: Vec<ScoreRequest> = Vec::new();
    let mut gens: VecDeque<GenerateRequest> = VecDeque::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut open = true;
    let mut shedding = false;
    let mut degraded = false;
    let mut drain_started: Option<Instant> = None;
    loop {
        // ---- intake ----
        if stopping.load(Ordering::SeqCst) {
            open = false;
        }
        if open && scores.is_empty() && gens.is_empty() && active.is_empty()
        {
            // fully idle: block for the next request, waking periodically
            // so a shutdown request is observed even while clients still
            // hold live senders
            match rx.recv_timeout(IDLE_POLL) {
                Ok(r) => intake(r, &cfg, &mut shedding, &mut scores,
                                &mut gens, active.len(), metrics, &events),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
            if stopping.load(Ordering::SeqCst) {
                open = false;
            }
        }
        if open && active.is_empty()
            && !(scores.is_empty() && gens.is_empty())
        {
            // batching window: coalesce up to bcap while nothing decodes
            let window = Instant::now() + cfg.max_wait;
            while scores.len() < bcap && gens.len() < bcap {
                let left = window.saturating_duration_since(Instant::now());
                match rx.recv_timeout(left) {
                    Ok(r) => intake(r, &cfg, &mut shedding, &mut scores,
                                    &mut gens, active.len(), metrics,
                                    &events),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        // drain everything already queued without waiting (even during
        // shutdown — channel residents must be answered, never stranded):
        // backlog has to be engine-visible for the admission/degrade
        // controllers, and a request can only expire once the engine owns
        // it
        loop {
            match rx.try_recv() {
                Ok(r) => intake(r, &cfg, &mut shedding, &mut scores,
                                &mut gens, active.len(), metrics, &events),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break,
            }
        }
        if !open {
            // bounded shutdown drain: work keeps executing below until the
            // drain deadline, after which everything left is flushed
            drain_on_shutdown(scorer, &cfg, &mut scores, &mut gens,
                              &mut active, &mut drain_started, metrics,
                              &events);
        }
        if !open && scores.is_empty() && gens.is_empty() && active.is_empty()
        {
            let m = lock_metrics(metrics);
            m.set_occupancy(0, 0);
            m.set_shedding(false);
            return;
        }
        // ---- overload controllers (hysteresis; DESIGN.md §13) ----
        shed_controller(&cfg, &mut shedding, scores.len() + gens.len(),
                        active.len() + gens.len(), metrics);
        degrade_controller(scorer, &cfg, &mut degraded,
                           scores.len() + gens.len(), metrics);
        // ---- one score batch ----
        if !scores.is_empty() {
            let take = scores.len().min(bcap);
            let batch: Vec<ScoreRequest> = scores.drain(..take).collect();
            run_batch(scorer, seq, batch, &mut rows, cfg.default_deadline,
                      metrics, &events, chaos);
        }
        // ---- admit new generations (validate, prefill, first sample) ----
        // bounded admission: each active sequence pins a KV cache in the
        // engine, so excess requests wait in `gens` (they are admitted as
        // sequences finish) instead of growing memory with offered load
        let max_active = bcap.saturating_mul(4);
        while active.len() < max_active {
            match gens.pop_front() {
                Some(g) => admit(scorer, seq, g, cfg.default_deadline,
                                 &mut active, metrics, &events, chaos),
                None => break,
            }
        }
        // ---- one decode step across active sequences ----
        if !active.is_empty() {
            decode_round(scorer, &mut active, bcap, metrics, &events, chaos);
        }
        lock_metrics(metrics).set_occupancy(active.len(), gens.len());
    }
}

/// Route one arriving request: shed with a fast retriable rejection when
/// admission control is armed, queue it otherwise. The controller is
/// re-evaluated per arrival, so a single drained burst sheds its own tail
/// instead of being admitted wholesale.
#[allow(clippy::too_many_arguments)]
fn intake(r: Request, cfg: &ServerConfig, shedding: &mut bool,
          scores: &mut Vec<ScoreRequest>,
          gens: &mut VecDeque<GenerateRequest>, active_len: usize,
          metrics: &Arc<Mutex<Metrics>>, events: &EventLog) {
    shed_controller(cfg, shedding, scores.len() + gens.len(),
                    active_len + gens.len(), metrics);
    if *shedding {
        shed(r, events);
    } else {
        sort_request(r, scores, gens);
    }
}

/// Answer one arriving request with the retriable overload rejection and
/// close its lifecycle with the shed-distinct terminal event.
fn shed(r: Request, events: &EventLog) {
    match r {
        Request::Score(s) => {
            let _ = s.resp.send(Err(format!("{SHED_PREFIX}: retry later")));
            trace::async_end("score", s.rid);
            events.record(s.rid, ReqKind::Score, EventKind::Shed, 0);
        }
        Request::Generate(g) => {
            let _ = g.resp.send(Err(format!("{SHED_PREFIX}: retry later")));
            trace::async_end("generate", g.rid);
            events.record(g.rid, ReqKind::Generate, EventKind::Shed, 0);
        }
    }
}

/// Admission-control hysteresis: arm when either watermark's `high` is
/// breached, disarm only once every configured signal is back at/below its
/// `low`. `queue_depth` counts engine-owned waiting work; `kv_depth`
/// counts sequences that hold (active) or will hold (waiting) a KV cache.
fn shed_controller(cfg: &ServerConfig, shedding: &mut bool,
                   queue_depth: usize, kv_depth: usize,
                   metrics: &Arc<Mutex<Metrics>>) {
    if cfg.shed_queue.is_none() && cfg.shed_kv.is_none() {
        return;
    }
    let want = if *shedding {
        cfg.shed_queue.is_some_and(|w| queue_depth > w.low)
            || cfg.shed_kv.is_some_and(|w| kv_depth > w.low)
    } else {
        cfg.shed_queue.is_some_and(|w| queue_depth >= w.high)
            || cfg.shed_kv.is_some_and(|w| kv_depth >= w.high)
    };
    if want != *shedding {
        *shedding = want;
        lock_metrics(metrics).set_shedding(want);
    }
}

/// Degradation hysteresis: downshift the scorer to its cheaper pre-built
/// plan when the waiting-work signal breaches `high`, restore once it is
/// back at/below `low`. Transitions flip the `lrq_degraded` gauge, count a
/// shift, and emit a zero-width trace span so the switch is visible on
/// timelines.
fn degrade_controller(scorer: &mut dyn BatchScorer, cfg: &ServerConfig,
                      degraded: &mut bool, depth: usize,
                      metrics: &Arc<Mutex<Metrics>>) {
    let Some(w) = cfg.degrade else { return };
    if !scorer.supports_degrade() {
        return;
    }
    let want = if *degraded { depth > w.low } else { depth >= w.high };
    if want != *degraded {
        *degraded = want;
        scorer.set_degraded(want);
        lock_metrics(metrics).set_degraded(want);
        trace::complete_at(Instant::now(), Duration::ZERO, || {
            (if want { "degrade_downshift" } else { "degrade_restore" }
                 .to_string(),
             None)
        });
    }
}

/// The instant a request must complete by: its explicit per-request
/// deadline if set, else the server default measured from submission.
fn deadline_for(submitted: Instant, explicit: Option<Instant>,
                default: Option<Duration>) -> Option<Instant> {
    explicit.or_else(|| default.map(|d| submitted + d))
}

/// Unwind isolation for engine calls: a panic inside the scorer (model
/// bug, injected fault) becomes an error that fails only the work handed
/// to this call — the engine thread keeps serving.
fn guarded<T>(what: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow!("engine panicked in {what}: {msg}"))
        }
    }
}

/// Evict an admitted sequence whose deadline passed (or that shutdown
/// could not drain): release its KV cache, answer with the retriable
/// expiry error, close its lifecycle. Partial work executed, so it still
/// counts as a completed request, mirroring the scorer-error path.
fn expire_active(scorer: &mut dyn BatchScorer, a: ActiveSeq, why: &str,
                 metrics: &Arc<Mutex<Metrics>>, events: &EventLog) {
    scorer.end_decode(a.sid);
    lock_metrics(metrics).record(a.submitted.elapsed());
    let n = a.tokens.len() as u64;
    let sent = a.resp.send(Err(format!(
        "{EXPIRED_PREFIX} {why} after {} generated tokens",
        a.tokens.len())));
    trace::async_end("generate", a.rid);
    events.record(a.rid, ReqKind::Generate,
                  if sent.is_ok() { EventKind::Expire }
                  else { EventKind::Disconnect },
                  n);
}

/// Shutdown drain: within `drain_deadline`, return immediately so queued
/// and active work keeps executing normally; past it, shed everything
/// still queued and evict the remaining active sequences — shutdown is
/// bounded no matter how long a generation's `max_new` is.
fn drain_on_shutdown(scorer: &mut dyn BatchScorer, cfg: &ServerConfig,
                     scores: &mut Vec<ScoreRequest>,
                     gens: &mut VecDeque<GenerateRequest>,
                     active: &mut Vec<ActiveSeq>,
                     drain_started: &mut Option<Instant>,
                     metrics: &Arc<Mutex<Metrics>>, events: &EventLog) {
    let started = *drain_started.get_or_insert_with(Instant::now);
    if started.elapsed() < cfg.drain_deadline {
        return;
    }
    for s in scores.drain(..) {
        let _ = s.resp
            .send(Err(format!("{SHED_PREFIX}: server shutting down")));
        trace::async_end("score", s.rid);
        events.record(s.rid, ReqKind::Score, EventKind::Shed, 0);
    }
    for g in gens.drain(..) {
        let _ = g.resp
            .send(Err(format!("{SHED_PREFIX}: server shutting down")));
        trace::async_end("generate", g.rid);
        events.record(g.rid, ReqKind::Generate, EventKind::Shed, 0);
    }
    while let Some(a) = active.pop() {
        expire_active(scorer, a, "at shutdown (drain deadline)", metrics,
                      events);
    }
}

/// Execute one score batch. Invalid requests were rejected before assembly
/// ([`engine_loop`] admits anything; the length check lives here so tests
/// can drive it directly) — only valid rows reach the scorer, and
/// `batch_size` reflects valid rows only.
#[allow(clippy::too_many_arguments)]
fn run_batch(scorer: &mut dyn BatchScorer, seq: usize,
             batch: Vec<ScoreRequest>, rows: &mut ScoreRows,
             default_deadline: Option<Duration>,
             metrics: &Arc<Mutex<Metrics>>, events: &EventLog,
             chaos: Option<&Arc<FaultPlan>>) {
    // reject invalid requests up front: no batch row, no reported occupancy
    let now = Instant::now();
    let mut valid: Vec<ScoreRequest> = Vec::with_capacity(batch.len());
    for r in batch {
        if deadline_for(r.submitted, r.deadline, default_deadline)
            .is_some_and(|d| now >= d)
        {
            // expired in queue: never occupies a batch row, never executes
            let _ = r.resp.send(Err(format!(
                "{EXPIRED_PREFIX} in queue after {}us",
                r.submitted.elapsed().as_micros())));
            trace::async_end("score", r.rid);
            events.record(r.rid, ReqKind::Score, EventKind::Expire, 0);
        } else if r.ids.len() < 2 || r.ids.len() > seq {
            let _ = r.resp.send(Err(format!(
                "sequence length {} not in [2, {seq}]", r.ids.len())));
            trace::async_end("score", r.rid);
            events.record(r.rid, ReqKind::Score, EventKind::Reject, 0);
        } else {
            valid.push(r);
        }
    }
    if valid.is_empty() {
        return; // never execute an empty batch
    }
    let n = valid.len();
    for r in &valid {
        events.record(r.rid, ReqKind::Score, EventKind::BatchJoin, n as u64);
    }
    // fixed-shape scorers always get full capacity; variable ones only the
    // occupied rows (no padded-row compute)
    let b = if scorer.variable_batch() {
        n.min(scorer.batch_size())
    } else {
        scorer.batch_size()
    };
    // clear + resize refills the reused buffers with the padding zeros
    rows.ids.clear();
    rows.ids.resize(b * seq, 0);
    rows.tgt.clear();
    rows.tgt.resize(b * seq, 0);
    rows.lens.clear();
    rows.lens.resize(n, 0);
    for (i, r) in valid.iter().enumerate() {
        rows.lens[i] = r.ids.len();
        rows.ids[i * seq..i * seq + r.ids.len()].copy_from_slice(&r.ids);
        for (p, w) in r.ids[1..].iter().enumerate() {
            rows.tgt[i * seq + p] = *w;
        }
    }
    let t0 = Instant::now();
    let scored = guarded("score", || scorer.score(&rows.ids, &rows.tgt));
    let exec_time = t0.elapsed();
    trace::complete_at(t0, exec_time, || {
        ("score_batch".to_string(), Some(format!("{{\"rows\":{n}}}")))
    });
    lock_metrics(metrics).record_batch(exec_time, n);
    let exec_us = exec_time.as_micros() as u64;
    match scored {
        Ok(logp) => {
            for (i, r) in valid.into_iter().enumerate() {
                let row = &logp[i * seq..(i + 1) * seq];
                let sum: f32 = row[..rows.lens[i] - 1].iter().sum();
                let latency = r.submitted.elapsed();
                lock_metrics(metrics).record(latency);
                events.record(r.rid, ReqKind::Score, EventKind::Exec,
                              exec_us);
                if chaos.is_some_and(|p| p.should_drop_response()) {
                    // injected client-vanish: the answer never leaves the
                    // engine; the lifecycle still closes terminally
                    drop(r.resp);
                    trace::async_end("score", r.rid);
                    events.record(r.rid, ReqKind::Score,
                                  EventKind::Disconnect, 0);
                    continue;
                }
                let sent = r.resp.send(Ok(ScoreResponse {
                    logp_sum: sum,
                    latency,
                    batch_size: n,
                }));
                trace::async_end("score", r.rid);
                // a failed send means the client dropped its receiver
                events.record(r.rid, ReqKind::Score,
                              if sent.is_ok() { EventKind::Respond }
                              else { EventKind::Disconnect },
                              0);
            }
        }
        Err(e) => {
            // scorer-error path: the batch executed (and failed) — latency
            // and exec metrics still count
            let msg = format!("{e:#}");
            for r in valid {
                lock_metrics(metrics).record(r.submitted.elapsed());
                events.record(r.rid, ReqKind::Score, EventKind::Exec,
                              exec_us);
                let sent = r.resp.send(Err(msg.clone()));
                trace::async_end("score", r.rid);
                events.record(r.rid, ReqKind::Score,
                              if sent.is_ok() { EventKind::Reject }
                              else { EventKind::Disconnect },
                              0);
            }
        }
    }
}

/// Validate + prefill one generation request; on success it joins `active`
/// with its first sampled token (a `max_new == 1` request completes here).
#[allow(clippy::too_many_arguments)]
fn admit(scorer: &mut dyn BatchScorer, seq: usize, g: GenerateRequest,
         default_deadline: Option<Duration>, active: &mut Vec<ActiveSeq>,
         metrics: &Arc<Mutex<Metrics>>, events: &EventLog,
         chaos: Option<&Arc<FaultPlan>>) {
    let deadline = deadline_for(g.submitted, g.deadline, default_deadline);
    if deadline.is_some_and(|d| Instant::now() >= d) {
        // expired while waiting for admission: no prefill, no KV cache
        let _ = g.resp.send(Err(format!(
            "{EXPIRED_PREFIX} before admission after {}us",
            g.submitted.elapsed().as_micros())));
        trace::async_end("generate", g.rid);
        events.record(g.rid, ReqKind::Generate, EventKind::Expire, 0);
        return;
    }
    if g.prompt.is_empty() || g.max_new == 0 {
        let _ = g.resp.send(Err(
            "generate needs a non-empty prompt and max_new >= 1".into()));
        trace::async_end("generate", g.rid);
        events.record(g.rid, ReqKind::Generate, EventKind::Reject, 0);
        return;
    }
    if g.prompt.len() + g.max_new > seq {
        let _ = g.resp.send(Err(format!(
            "prompt {} + max_new {} exceeds the {seq}-token context",
            g.prompt.len(), g.max_new)));
        trace::async_end("generate", g.rid);
        events.record(g.rid, ReqKind::Generate, EventKind::Reject, 0);
        return;
    }
    if !scorer.supports_decode() {
        let _ = g.resp.send(Err(
            "this engine does not support incremental decode".into()));
        trace::async_end("generate", g.rid);
        events.record(g.rid, ReqKind::Generate, EventKind::Reject, 0);
        return;
    }
    // validated: the request now enters the engine (queue time ends here)
    events.record(g.rid, ReqKind::Generate, EventKind::Admit,
                  g.prompt.len() as u64);
    match guarded("prefill", || scorer.begin_decode(&g.prompt)) {
        Err(e) => {
            // engine-error path: the prefill executed (and failed) — the
            // request still counts, like the score-batch error path
            lock_metrics(metrics).record(g.submitted.elapsed());
            let sent = g.resp.send(Err(format!("{e:#}")));
            trace::async_end("generate", g.rid);
            events.record(g.rid, ReqKind::Generate,
                          if sent.is_ok() { EventKind::Reject }
                          else { EventKind::Disconnect },
                          0);
        }
        Ok((sid, logits)) => {
            let mut rng = Rng::new(g.seed);
            let first = sample_top_k(&logits, g.top_k, &mut rng) as i32;
            events.record(g.rid, ReqKind::Generate, EventKind::FirstToken,
                          0);
            let seq_state = ActiveSeq {
                sid,
                prompt_len: g.prompt.len(),
                max_new: g.max_new,
                top_k: g.top_k,
                rng,
                tokens: vec![first],
                resp: g.resp,
                submitted: g.submitted,
                deadline,
                rid: g.rid,
            };
            if seq_state.tokens.len() >= seq_state.max_new {
                finish(scorer, seq_state, metrics, events, chaos);
            } else {
                active.push(seq_state);
            }
        }
    }
}

/// Complete one generation: release its KV cache, record metrics, respond.
fn finish(scorer: &mut dyn BatchScorer, a: ActiveSeq,
          metrics: &Arc<Mutex<Metrics>>, events: &EventLog,
          chaos: Option<&Arc<FaultPlan>>) {
    scorer.end_decode(a.sid);
    let latency = a.submitted.elapsed();
    let n_tokens = a.tokens.len();
    lock_metrics(metrics).record_gen(latency, n_tokens);
    if chaos.is_some_and(|p| p.should_drop_response()) {
        // injected client-vanish: the answer never leaves the engine; the
        // lifecycle still closes terminally
        drop(a.resp);
        trace::async_end("generate", a.rid);
        events.record(a.rid, ReqKind::Generate, EventKind::Disconnect,
                      n_tokens as u64);
        return;
    }
    let sent = a.resp.send(Ok(GenerateResponse {
        tokens: a.tokens,
        latency,
        prompt_len: a.prompt_len,
    }));
    trace::async_end("generate", a.rid);
    events.record(a.rid, ReqKind::Generate,
                  if sent.is_ok() { EventKind::Respond }
                  else { EventKind::Disconnect },
                  n_tokens as u64);
}

/// One decode step batched across up to `bcap` active sequences; finished
/// sequences respond and release their caches, the rest rotate so every
/// sequence gets steps under overload.
fn decode_round(scorer: &mut dyn BatchScorer, active: &mut Vec<ActiveSeq>,
                bcap: usize, metrics: &Arc<Mutex<Metrics>>,
                events: &EventLog, chaos: Option<&Arc<FaultPlan>>) {
    // per-step deadline enforcement: expired sequences are evicted before
    // the step, so they stop consuming KV memory and decode batch rows
    let now = Instant::now();
    let mut idx = 0usize;
    while idx < active.len() {
        if active[idx].deadline.is_some_and(|d| now >= d) {
            let a = active.remove(idx);
            expire_active(scorer, a, "mid-decode", metrics, events);
        } else {
            idx += 1;
        }
    }
    // admit() guarantees every active sequence carries >= 1 sampled token;
    // if that invariant ever breaks, fail the sequence onto its event log
    // instead of panicking the batch loop for every in-flight request
    let mut idx = 0usize;
    while idx < active.len() {
        if active[idx].tokens.is_empty() {
            let a = active.remove(idx);
            scorer.end_decode(a.sid);
            lock_metrics(metrics).record(a.submitted.elapsed());
            let sent = a.resp.send(Err(
                "internal: sequence lost its sampling state".into()));
            trace::async_end("generate", a.rid);
            events.record(a.rid, ReqKind::Generate,
                          if sent.is_ok() { EventKind::Reject }
                          else { EventKind::Disconnect },
                          0);
        } else {
            idx += 1;
        }
    }
    if active.is_empty() {
        return;
    }
    let n = active.len().min(bcap);
    let batch: Vec<(SeqId, i32)> = active[..n]
        .iter()
        .map(|a| (a.sid, a.tokens.last().copied().unwrap_or(0)))
        .collect();
    let t0 = Instant::now();
    let stepped = guarded("decode_step", || scorer.decode_step(&batch));
    let exec = t0.elapsed();
    trace::complete_at(t0, exec, || {
        ("decode_step".to_string(), Some(format!("{{\"seqs\":{n}}}")))
    });
    match stepped {
        Ok(all_logits) => {
            // recorded only on success: a failed step produced no tokens
            lock_metrics(metrics).record_decode(n, exec);
            debug_assert_eq!(all_logits.len(), n);
            let mut done: Vec<usize> = Vec::new();
            for (i, logits) in all_logits.iter().enumerate().take(n) {
                let a = &mut active[i];
                let t = sample_top_k(logits, a.top_k, &mut a.rng) as i32;
                a.tokens.push(t);
                if a.tokens.len() >= a.max_new {
                    done.push(i);
                }
            }
            let finished = done.len();
            for i in done.into_iter().rev() {
                let a = active.remove(i);
                finish(scorer, a, metrics, events, chaos);
            }
            // round-robin fairness across > bcap active sequences: rotate
            // the stepped *survivors* to the back so un-stepped sequences
            // come first next round
            if !active.is_empty() {
                let rot = (n - finished).min(active.len());
                active.rotate_left(rot);
            }
        }
        Err(e) => {
            // decode failure poisons exactly the stepped sequences; they
            // executed (and failed), so they still count as requests
            let msg = format!("{e:#}");
            for a in active.drain(..n) {
                scorer.end_decode(a.sid);
                lock_metrics(metrics).record(a.submitted.elapsed());
                let sent = a.resp.send(Err(msg.clone()));
                trace::async_end("generate", a.rid);
                events.record(a.rid, ReqKind::Generate,
                              if sent.is_ok() { EventKind::Reject }
                              else { EventKind::Disconnect },
                              0);
            }
        }
    }
}

/// A trivial in-process scorer for tests: logp = -(token value) per position.
pub struct MockScorer {
    pub batch: usize,
    pub seq: usize,
    pub calls: usize,
}

impl BatchScorer for MockScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq
    }
    fn score(&mut self, _ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        self.calls += 1;
        Ok(targets.iter().map(|&t| -(t as f32)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn start_mock(max_batch: usize, wait_ms: u64) -> Server {
        Server::start(
            ServerConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
                ..Default::default()
            },
            || Ok(Box::new(MockScorer { batch: 8, seq: 16, calls: 0 })),
        )
        .unwrap()
    }

    #[test]
    fn scores_single_request() {
        let s = start_mock(4, 1);
        let c = s.client();
        // ids [5, 3, 2]: targets are [3, 2] -> logp = -(3+2)
        let r = c.score(vec![5, 3, 2]).unwrap();
        assert_eq!(r.logp_sum, -5.0);
    }

    #[test]
    fn batches_concurrent_requests() {
        let s = start_mock(8, 50);
        let mut handles = Vec::new();
        for k in 0..8 {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                c.score(vec![1, k as i32 + 1]).unwrap()
            }));
        }
        let results: Vec<ScoreResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // every request answered with its own target sum
        for (k, r) in results.iter().enumerate() {
            assert_eq!(r.logp_sum, -((k as f32) + 1.0));
        }
        // at least one response saw a batch > 1 (they arrived within the
        // batching window)
        assert!(results.iter().any(|r| r.batch_size > 1));
    }

    #[test]
    fn rejects_oversized() {
        let s = start_mock(2, 1);
        let c = s.client();
        let err = c.score((0..64).collect()).unwrap_err();
        assert!(format!("{err}").contains("length"));
    }

    #[test]
    fn never_drops_or_duplicates() {
        let s = start_mock(3, 5);
        let n = 50;
        let mut handles = Vec::new();
        for k in 0..n {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                c.score(vec![0, k as i32]).unwrap().logp_sum
            }));
        }
        let mut got: Vec<f32> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..n).map(|k| -(k as f32)).rev().collect();
        let mut want = want;
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want);
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.requests(), n);
    }

    #[test]
    fn metrics_percentiles() {
        let s = start_mock(4, 1);
        let c = s.client();
        for _ in 0..20 {
            c.score(vec![1, 2, 3]).unwrap();
        }
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.requests(), 20);
        assert!(m.p50_latency() <= m.p95_latency());
        assert!(m.mean_batch() >= 1.0);
    }

    /// A scorer that counts executions and the row occupancy it was handed
    /// (variable-batch, like the native engine).
    struct CountingScorer {
        seq: usize,
        calls: Arc<AtomicUsize>,
        rows_seen: Arc<Mutex<Vec<usize>>>,
    }

    impl BatchScorer for CountingScorer {
        fn batch_size(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn variable_batch(&self) -> bool {
            true
        }
        fn score(&mut self, ids: &[i32], targets: &[i32])
                 -> Result<Vec<f32>> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            self.rows_seen.lock().unwrap().push(ids.len() / self.seq);
            Ok(targets.iter().map(|&t| -(t as f32)).collect())
        }
    }

    #[test]
    fn invalid_requests_never_occupy_batch_rows() {
        let calls = Arc::new(AtomicUsize::new(0));
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (c2, r2) = (calls.clone(), rows.clone());
        let s = Server::start(
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(50),
                ..Default::default()
            },
            move || Ok(Box::new(CountingScorer {
                seq: 16,
                calls: c2,
                rows_seen: r2,
            })),
        )
        .unwrap();
        // mixed batch: 2 valid + 2 invalid submitted together
        let mut handles = Vec::new();
        for ids in [vec![1, 2], vec![9], (0..40).collect(), vec![3, 4, 5]] {
            let c = s.client();
            handles.push(std::thread::spawn(move || c.score(ids)));
        }
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let ok: Vec<_> = results.iter().filter_map(|r| r.as_ref().ok())
            .collect();
        let errs = results.iter().filter(|r| r.is_err()).count();
        assert_eq!(ok.len(), 2);
        assert_eq!(errs, 2);
        for r in &ok {
            // reported occupancy counts valid rows only
            assert!(r.batch_size <= 2, "batch_size {}", r.batch_size);
        }
        // the engine only ever executed the valid rows — no zeroed padding
        let total_rows: usize = rows.lock().unwrap().iter().sum();
        assert_eq!(total_rows, 2);
    }

    #[test]
    fn all_invalid_batch_never_executes_scorer() {
        let calls = Arc::new(AtomicUsize::new(0));
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (c2, r2) = (calls.clone(), rows.clone());
        let s = Server::start(
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                ..Default::default()
            },
            move || Ok(Box::new(CountingScorer {
                seq: 16,
                calls: c2,
                rows_seen: r2,
            })),
        )
        .unwrap();
        let mut handles = Vec::new();
        for ids in [vec![1], vec![], (0..99).collect::<Vec<i32>>()] {
            let c = s.client();
            handles.push(std::thread::spawn(move || c.score(ids)));
        }
        for h in handles {
            assert!(h.join().unwrap().is_err());
        }
        assert_eq!(calls.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn disconnected_client_does_not_poison_its_batch() {
        let s = start_mock(4, 30);
        let c = s.client();
        // submit and immediately drop the response channel (client died)
        let rx = c.submit(vec![1, 7]).unwrap();
        drop(rx);
        // a live request in the same window still gets its answer
        let r = c.score(vec![1, 5]).unwrap();
        assert_eq!(r.logp_sum, -5.0);
        // both were valid and executed -> both recorded
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.requests(), 2);
    }

    #[test]
    fn lifecycle_events_cover_score_outcomes() {
        let s = start_mock(4, 5);
        let c = s.client();
        // respond: a normal request
        c.score(vec![5, 3, 2]).unwrap();
        // reject: an oversized request (never executes)
        assert!(c.score((0..64).collect()).is_err());
        // disconnect: drop the receiver before the batch answers, then sync
        // on a follow-up request (same engine thread, so its response
        // ordering guarantees the dropped one was handled)
        drop(c.submit(vec![1, 7]).unwrap());
        c.score(vec![1, 5]).unwrap();
        let ev = s.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        assert_eq!(agg.responded, 2);
        assert_eq!(agg.rejected, 1);
        assert_eq!(agg.disconnected, 1);
        // per-request identity: stage times never exceed the total
        for r in ev.summaries() {
            assert!(r.queue_us + r.exec_us <= r.total_us,
                    "rid {}: queue {} + exec {} > total {}",
                    r.rid, r.queue_us, r.exec_us, r.total_us);
        }
        // the JSONL export carries every lifecycle stage seen above
        let txt = ev.jsonl("test");
        for stage in ["enqueue", "batch_join", "exec", "respond", "reject",
                      "disconnect"] {
            assert!(txt.contains(&format!("\"event\":\"{stage}\"")),
                    "missing {stage} in {txt}");
        }
        // mid-run, an unanswered request shows as stuck
        let ev2 = EventLog::new(16, &crate::obs::Registry::new());
        ev2.record(99, ReqKind::Score, EventKind::Enqueue, 2);
        assert_eq!(ev2.stuck(), vec![99]);
    }

    /// Decode-capable mock: the "model" deterministically continues with
    /// `(last token + 1) % 100`, so generations are checkable counting
    /// sequences. Tracks live caches to prove none leak.
    struct GenMock {
        next: SeqId,
        caches: HashMap<SeqId, i32>,
        live: Arc<AtomicUsize>,
        /// artificial per-step latency (drives the deadline/drain tests)
        step_delay: Duration,
    }

    impl GenMock {
        fn logits_for(last: i32) -> Vec<f32> {
            let mut l = vec![0.0f32; 100];
            l[((last + 1) % 100) as usize] = 10.0;
            l
        }
    }

    impl BatchScorer for GenMock {
        fn batch_size(&self) -> usize {
            8
        }
        fn seq_len(&self) -> usize {
            32
        }
        fn score(&mut self, _ids: &[i32], targets: &[i32])
                 -> Result<Vec<f32>> {
            Ok(targets.iter().map(|&t| -(t as f32)).collect())
        }
        fn supports_decode(&self) -> bool {
            true
        }
        fn begin_decode(&mut self, prompt: &[i32])
                        -> Result<(SeqId, Vec<f32>)> {
            let sid = self.next;
            self.next += 1;
            let last = *prompt.last().unwrap();
            self.caches.insert(sid, last);
            self.live.fetch_add(1, Ordering::SeqCst);
            Ok((sid, Self::logits_for(last)))
        }
        fn decode_step(&mut self, batch: &[(SeqId, i32)])
                       -> Result<Vec<Vec<f32>>> {
            if !self.step_delay.is_zero() {
                std::thread::sleep(self.step_delay);
            }
            batch
                .iter()
                .map(|&(sid, tok)| {
                    let c = self
                        .caches
                        .get_mut(&sid)
                        .ok_or_else(|| anyhow!("unknown seq {sid}"))?;
                    *c = tok;
                    Ok(Self::logits_for(tok))
                })
                .collect()
        }
        fn end_decode(&mut self, sid: SeqId) {
            if self.caches.remove(&sid).is_some() {
                self.live.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }

    fn start_gen_with(live: Arc<AtomicUsize>, cfg: ServerConfig,
                      step_delay: Duration) -> Server {
        Server::start(cfg, move || Ok(Box::new(GenMock {
            next: 0,
            caches: HashMap::new(),
            live,
            step_delay,
        })))
        .unwrap()
    }

    fn start_gen_mock(live: Arc<AtomicUsize>) -> Server {
        start_gen_with(
            live,
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
                ..Default::default()
            },
            Duration::ZERO,
        )
    }

    #[test]
    fn generates_counting_sequences_concurrently() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_mock(live.clone());
        let mut handles = Vec::new();
        for k in 0..6i32 {
            let c = s.client();
            handles.push(std::thread::spawn(move || {
                (k, c.generate(vec![k * 10], 5, 1, 0).unwrap())
            }));
        }
        for h in handles {
            let (k, r) = h.join().unwrap();
            let want: Vec<i32> =
                (1..=5).map(|i| (k * 10 + i) % 100).collect();
            assert_eq!(r.tokens, want, "client {k}");
            assert_eq!(r.prompt_len, 1);
        }
        // every cache released
        assert_eq!(live.load(Ordering::SeqCst), 0);
        let m = s.metrics.lock().unwrap();
        assert_eq!(m.gen_requests(), 6);
        assert_eq!(m.gen_tokens(), 30);
        assert!(m.decode_steps() > 0);
        // prefill's first sampled token is not a decode-step token
        assert_eq!(m.gen_tokens(),
                   m.decode_step_tokens() + m.gen_requests());
        assert!(m.mean_decode_batch() >= 1.0);
    }

    #[test]
    fn lifecycle_events_cover_generate() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_mock(live.clone());
        let c = s.client();
        c.generate(vec![3], 4, 1, 0).unwrap();
        assert!(c.generate(vec![], 4, 1, 0).is_err()); // validation reject
        let ev = s.events();
        assert!(ev.stuck().is_empty());
        let agg = ev.agg();
        assert_eq!(agg.responded, 1);
        assert_eq!(agg.rejected, 1);
        // the completed generation recorded a first-token time within its
        // total latency
        let done: Vec<_> = ev.summaries().into_iter()
            .filter(|r| r.outcome == EventKind::Respond).collect();
        assert_eq!(done.len(), 1);
        let ttft = done[0].ttft_us.expect("generate records TTFT");
        assert!(ttft <= done[0].total_us);
        assert!(done[0].queue_us + done[0].exec_us <= done[0].total_us);
    }

    #[test]
    fn mixed_score_and_generate_traffic() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_mock(live.clone());
        let mut gen_handles = Vec::new();
        let mut score_handles = Vec::new();
        for k in 0..4i32 {
            let c = s.client();
            gen_handles.push(std::thread::spawn(move || {
                c.generate(vec![k], 4, 1, 0).unwrap()
            }));
            let c = s.client();
            score_handles.push(std::thread::spawn(move || {
                c.score(vec![1, k + 1]).unwrap()
            }));
        }
        for (k, h) in gen_handles.into_iter().enumerate() {
            let r = h.join().unwrap();
            assert_eq!(r.tokens.len(), 4);
            assert_eq!(r.tokens[0], k as i32 + 1);
        }
        for (k, h) in score_handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap().logp_sum, -(k as f32 + 1.0));
        }
        assert_eq!(live.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn generate_validates_before_prefill() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_mock(live.clone());
        let c = s.client();
        // empty prompt
        assert!(c.generate(vec![], 4, 1, 0).is_err());
        // zero tokens requested
        assert!(c.generate(vec![1], 0, 1, 0).is_err());
        // context overflow (seq_len = 32)
        assert!(c.generate(vec![0; 30], 10, 1, 0).is_err());
        // nothing was admitted
        assert_eq!(live.load(Ordering::SeqCst), 0);
        assert_eq!(s.metrics.lock().unwrap().gen_requests(), 0);
    }

    #[test]
    fn generate_on_score_only_engine_errors() {
        let s = start_mock(4, 1);
        let c = s.client();
        let err = c.generate(vec![1, 2], 3, 1, 0).unwrap_err();
        assert!(format!("{err}").contains("decode"));
        // score traffic is unaffected
        assert_eq!(c.score(vec![1, 2]).unwrap().logp_sum, -2.0);
    }

    #[test]
    fn watermarks_clamp_low_below_high() {
        let w = Watermarks::new(4, 9);
        assert_eq!((w.high, w.low), (4, 3));
        let w = Watermarks::new(0, 0);
        assert_eq!((w.high, w.low), (1, 0));
    }

    #[test]
    fn expired_score_never_executes() {
        let calls = Arc::new(AtomicUsize::new(0));
        let rows = Arc::new(Mutex::new(Vec::new()));
        let (c2, r2) = (calls.clone(), rows.clone());
        let s = Server::start(
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            move || Ok(Box::new(CountingScorer {
                seq: 16,
                calls: c2,
                rows_seen: r2,
            })),
        )
        .unwrap();
        let c = s.client().with_deadline(Duration::ZERO);
        let err = c.score(vec![1, 2, 3]).unwrap_err();
        assert!(format!("{err}").starts_with(EXPIRED_PREFIX), "{err}");
        assert_eq!(calls.load(Ordering::SeqCst), 0);
        let ev = s.events();
        assert!(ev.stuck().is_empty());
        let agg = ev.agg();
        assert_eq!(agg.expired, 1);
        assert_eq!(agg.rejected, 0);
        for r in ev.summaries() {
            assert_eq!(r.outcome, EventKind::Expire);
            assert_eq!(r.exec_us, 0);
            assert!(r.queue_us + r.exec_us <= r.total_us,
                    "rid {}: queue {} + exec {} > total {}",
                    r.rid, r.queue_us, r.exec_us, r.total_us);
        }
    }

    #[test]
    fn expired_generate_never_admits() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_mock(live.clone());
        let c = s.client().with_deadline(Duration::ZERO);
        let err = c.generate(vec![1], 5, 1, 0).unwrap_err();
        assert!(format!("{err}").starts_with(EXPIRED_PREFIX), "{err}");
        // no prefill happened: no KV cache was ever built
        assert_eq!(live.load(Ordering::SeqCst), 0);
        assert_eq!(s.events().agg().expired, 1);
    }

    #[test]
    fn default_deadline_applies_to_undated_requests() {
        let s = Server::start(
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                default_deadline: Some(Duration::ZERO),
                ..Default::default()
            },
            || Ok(Box::new(MockScorer { batch: 8, seq: 16, calls: 0 })),
        )
        .unwrap();
        let c = s.client();
        let err = c.score(vec![1, 2]).unwrap_err();
        assert!(format!("{err}").starts_with(EXPIRED_PREFIX), "{err}");
    }

    #[test]
    fn deadline_evicts_mid_decode() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_with(
            live.clone(),
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            Duration::from_millis(10),
        );
        let c = s.client().with_deadline(Duration::from_millis(60));
        // 30 tokens x 10ms/step >> the 60ms deadline: must be evicted
        let err = c.generate(vec![1], 30, 1, 0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.starts_with(EXPIRED_PREFIX), "{msg}");
        assert!(msg.contains("mid-decode"), "{msg}");
        // the evicted sequence released its KV cache
        assert_eq!(live.load(Ordering::SeqCst), 0);
        let ev = s.events();
        assert!(ev.stuck().is_empty());
        assert_eq!(ev.agg().expired, 1);
        // partial work still satisfies the stage-time identity, with TTFT
        let exp: Vec<_> = ev.summaries().into_iter()
            .filter(|r| r.outcome == EventKind::Expire).collect();
        assert_eq!(exp.len(), 1);
        assert!(exp[0].ttft_us.is_some());
        assert!(exp[0].queue_us + exp[0].exec_us <= exp[0].total_us);
    }

    /// A scorer whose score call stalls, so arrivals pile up while one
    /// batch executes (drives the admission-control tests).
    struct StallScorer {
        delay: Duration,
        started: Arc<AtomicUsize>,
    }

    impl BatchScorer for StallScorer {
        fn batch_size(&self) -> usize {
            1
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn variable_batch(&self) -> bool {
            true
        }
        fn score(&mut self, _ids: &[i32], targets: &[i32])
                 -> Result<Vec<f32>> {
            self.started.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            Ok(targets.iter().map(|&t| -(t as f32)).collect())
        }
    }

    #[test]
    fn admission_control_sheds_then_recovers() {
        let started = Arc::new(AtomicUsize::new(0));
        let st2 = started.clone();
        let s = Server::start(
            ServerConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                shed_queue: Some(Watermarks::new(2, 0)),
                ..Default::default()
            },
            move || Ok(Box::new(StallScorer {
                delay: Duration::from_millis(60),
                started: st2,
            })),
        )
        .unwrap();
        // r1 occupies the engine...
        let c = s.client();
        let r1 = c.submit(vec![1, 1]).unwrap();
        while started.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        // ...then a burst lands while it executes: the first two are
        // queued (depth 0 and 1 at evaluation), the rest shed with the
        // retriable overload error
        let burst: Vec<_> =
            (0..4).map(|_| c.submit(vec![1, 2]).unwrap()).collect();
        let mut ok = 0usize;
        let mut shed = 0usize;
        for rx in burst {
            match rx.recv().unwrap() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(e.starts_with(SHED_PREFIX), "{e}");
                    shed += 1;
                }
            }
        }
        assert!(r1.recv().unwrap().is_ok());
        assert_eq!((ok, shed), (2, 2));
        // backlog drained: the controller disarms and serves again
        assert!(c.score(vec![1, 3]).is_ok());
        let ev = s.events();
        assert_eq!(ev.agg().shed, 2);
        assert!(ev.stuck().is_empty());
        assert!(!lock_metrics(&s.metrics).is_shedding());
    }

    #[test]
    fn kv_pressure_sheds_generates() {
        let live = Arc::new(AtomicUsize::new(0));
        let s = start_gen_with(
            live.clone(),
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(40),
                shed_kv: Some(Watermarks::new(2, 0)),
                ..Default::default()
            },
            Duration::from_millis(5),
        );
        let c = s.client();
        let rxs: Vec<_> = (0..4)
            .map(|k| c.submit_generate(vec![k], 8, 1, 0).unwrap())
            .collect();
        let mut ok = 0usize;
        let mut shed = 0usize;
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(e.starts_with(SHED_PREFIX), "{e}");
                    shed += 1;
                }
            }
        }
        assert_eq!((ok, shed), (2, 2));
        // pressure released: a new generation is admitted again
        assert!(c.generate(vec![9], 2, 1, 0).is_ok());
        assert_eq!(live.load(Ordering::SeqCst), 0);
        assert_eq!(s.events().agg().shed, 2);
    }

    /// Degrade-capable scorer: score stalls briefly so a burst builds
    /// backlog; plan switches are recorded for the hysteresis assertions.
    struct DegradableScorer {
        delay: Duration,
        degraded: bool,
        shifts: Arc<Mutex<Vec<bool>>>,
        started: Arc<AtomicUsize>,
    }

    impl BatchScorer for DegradableScorer {
        fn batch_size(&self) -> usize {
            1
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn variable_batch(&self) -> bool {
            true
        }
        fn score(&mut self, _ids: &[i32], targets: &[i32])
                 -> Result<Vec<f32>> {
            self.started.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            Ok(targets.iter().map(|&t| -(t as f32)).collect())
        }
        fn supports_degrade(&self) -> bool {
            true
        }
        fn set_degraded(&mut self, on: bool) {
            self.degraded = on;
            self.shifts.lock().unwrap().push(on);
        }
        fn degraded(&self) -> bool {
            self.degraded
        }
    }

    #[test]
    fn degrade_downshifts_under_backlog_and_restores() {
        let shifts = Arc::new(Mutex::new(Vec::new()));
        let started = Arc::new(AtomicUsize::new(0));
        let (sh2, st2) = (shifts.clone(), started.clone());
        let s = Server::start(
            ServerConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                degrade: Some(Watermarks::new(3, 0)),
                ..Default::default()
            },
            move || Ok(Box::new(DegradableScorer {
                delay: Duration::from_millis(40),
                degraded: false,
                shifts: sh2,
                started: st2,
            })),
        )
        .unwrap();
        let c = s.client();
        let r1 = c.submit(vec![1, 1]).unwrap();
        while started.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        let burst: Vec<_> =
            (0..4).map(|_| c.submit(vec![1, 2]).unwrap()).collect();
        for rx in burst {
            // nothing is shed: the degrade controller absorbs the burst
            assert!(rx.recv().unwrap().is_ok());
        }
        assert!(r1.recv().unwrap().is_ok());
        // backlog reached the ceiling -> one downshift; drained ->
        // restore (lands on the first idle controller pass)
        let wait_until = Instant::now() + Duration::from_secs(5);
        loop {
            let sh = shifts.lock().unwrap().clone();
            if sh == vec![true, false] {
                break;
            }
            assert!(Instant::now() < wait_until, "shifts {sh:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        let m = lock_metrics(&s.metrics);
        assert_eq!(m.degrade_shifts(), 2);
        assert!(!m.is_degraded());
    }

    #[test]
    fn shutdown_under_load_completes_within_drain_deadline() {
        let live = Arc::new(AtomicUsize::new(0));
        let mut s = start_gen_with(
            live.clone(),
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                drain_deadline: Duration::from_millis(100),
                ..Default::default()
            },
            Duration::from_millis(10),
        );
        let c = s.client();
        // a long generation: 25 steps x 10ms would hold shutdown ~250ms
        let rx = c.submit_generate(vec![1], 25, 1, 0).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // admitted, decoding
        let t0 = Instant::now();
        s.shutdown();
        let took = t0.elapsed();
        assert!(took < Duration::from_secs(2), "shutdown took {took:?}");
        // the straggler was evicted with an expiry, not stranded
        let msg = rx.recv().unwrap().unwrap_err();
        assert!(msg.starts_with(EXPIRED_PREFIX), "{msg}");
        assert!(msg.contains("shutdown"), "{msg}");
        assert_eq!(live.load(Ordering::SeqCst), 0);
        assert!(s.events().stuck().is_empty());
    }

    /// Panics on the first score call, then recovers (drives the
    /// unwind-isolation test).
    struct PanicOnceScorer {
        panicked: bool,
    }

    impl BatchScorer for PanicOnceScorer {
        fn batch_size(&self) -> usize {
            4
        }
        fn seq_len(&self) -> usize {
            16
        }
        fn variable_batch(&self) -> bool {
            true
        }
        fn score(&mut self, _ids: &[i32], targets: &[i32])
                 -> Result<Vec<f32>> {
            if !self.panicked {
                self.panicked = true;
                panic!("injected scorer panic");
            }
            Ok(targets.iter().map(|&t| -(t as f32)).collect())
        }
    }

    #[test]
    fn scorer_panic_fails_batch_not_server() {
        let s = Server::start(
            ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            || Ok(Box::new(PanicOnceScorer { panicked: false })),
        )
        .unwrap();
        let c = s.client();
        let err = c.score(vec![1, 2]).unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        // the engine thread survived: the next request serves normally
        assert_eq!(c.score(vec![1, 5]).unwrap().logp_sum, -5.0);
        let agg = s.events().agg();
        assert_eq!(agg.responded, 1);
        assert_eq!(agg.rejected, 1);
        // the panic was absorbed by the per-call guard, not the
        // supervision restart loop
        assert_eq!(lock_metrics(&s.metrics).engine_restarts(), 0);
        assert!(s.events().stuck().is_empty());
    }
}
