//! Server-side fault injection for the chaos harness (DESIGN.md §13).
//!
//! A [`FaultPlan`] counts engine calls and fires configured faults at exact
//! call indices: a worker-pool job panic (surfacing through `JobPanicked`
//! as a failed batch, exercising the pool's containment contract end to
//! end), a direct engine-thread panic (absorbed by the serve layer's
//! unwind guards), a kernel stall (a stand-in for a hung kernel — long
//! enough to trip request deadlines), and a dropped response send (a
//! client whose answer vanishes in flight). [`ChaosScorer`] wraps any
//! [`BatchScorer`] and consults the plan before every delegated engine
//! call; `lrq soak --chaos` wires one into a live server and asserts zero
//! stuck and zero lost requests afterwards, with every injected failure
//! mapped to a terminal lifecycle event.
//!
//! The plan's counters are all `SeqCst` atomics so a single `Arc<FaultPlan>`
//! can be shared between the engine thread (which fires faults) and the
//! soak driver (which audits [`FaultPlan::fired`] after shutdown).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::infer::WorkerPool;

use super::{BatchScorer, SeqId};

/// Which engine calls / responses should fail, and how. Call indices are
/// 1-based over the wrapped scorer's fallible calls (`score`,
/// `begin_decode`, `decode_step`); the response index is 1-based over
/// successful response sends. Construct with [`FaultPlan::new`] and assign
/// the public fields, then share via `Arc`.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Panic the Nth engine call from inside a worker-pool job: the pool
    /// reports `JobPanicked`, the batch fails with an error response, the
    /// server keeps serving.
    pub pool_panic_call: Option<u64>,
    /// Panic the Nth engine call directly on the engine thread: the serve
    /// layer's `guarded` wrapper converts it to an error response.
    pub engine_panic_call: Option<u64>,
    /// Stall the Nth engine call for [`FaultPlan::stall`] before running it
    /// (it still completes — the fault is latency, not failure).
    pub stall_call: Option<u64>,
    /// Duration of an injected stall (default zero).
    pub stall: Duration,
    /// Drop the Nth successful response instead of sending it: the client
    /// observes a closed channel, the engine records a Disconnect.
    pub drop_response: Option<u64>,
    calls: AtomicU64,
    responses: AtomicU64,
    pool_panics: AtomicU64,
    engine_panics: AtomicU64,
    stalls: AtomicU64,
    drops: AtomicU64,
}

/// Audit of which faults actually fired — the chaos soak's ledger for
/// asserting every configured fault was exercised and every lost response
/// is accounted for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultsFired {
    pub pool_panics: u64,
    pub engine_panics: u64,
    pub stalls: u64,
    pub drops: u64,
}

impl FaultsFired {
    pub fn total(&self) -> u64 {
        self.pool_panics + self.engine_panics + self.stalls + self.drops
    }
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Fallible engine calls observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Audit of which faults have actually fired so far.
    pub fn fired(&self) -> FaultsFired {
        FaultsFired {
            pool_panics: self.pool_panics.load(Ordering::SeqCst),
            engine_panics: self.engine_panics.load(Ordering::SeqCst),
            stalls: self.stalls.load(Ordering::SeqCst),
            drops: self.drops.load(Ordering::SeqCst),
        }
    }

    /// How many responses the engine dropped on this plan's instruction —
    /// the exact number of requests a chaos client should count as lost.
    pub fn drops_fired(&self) -> u64 {
        self.drops.load(Ordering::SeqCst)
    }

    /// Count one successful response; `true` if the plan says to drop it.
    /// Called by the engine at each response-send site when chaos is wired.
    pub fn should_drop_response(&self) -> bool {
        let n = self.responses.fetch_add(1, Ordering::SeqCst) + 1;
        if self.drop_response == Some(n) {
            self.drops.fetch_add(1, Ordering::SeqCst);
            return true;
        }
        false
    }
}

/// A [`BatchScorer`] decorator that consults a [`FaultPlan`] before every
/// delegated fallible call. Faults are injected through the same machinery
/// production failures would take: the pool panic runs on a real
/// [`WorkerPool`], the engine panic unwinds into the serve layer's guards,
/// the stall burns wall-clock against real deadlines.
pub struct ChaosScorer {
    inner: Box<dyn BatchScorer>,
    plan: Arc<FaultPlan>,
    /// a real two-thread pool, so an injected job panic exercises the
    /// production `JobPanicked` containment path rather than simulating it
    pool: WorkerPool,
}

impl ChaosScorer {
    pub fn new(inner: Box<dyn BatchScorer>, plan: Arc<FaultPlan>)
               -> ChaosScorer {
        ChaosScorer { inner, plan, pool: WorkerPool::new(2) }
    }

    /// Count one fallible call and fire any fault scheduled for it. Returns
    /// an error when the fault surfaces as one (the pool-job panic); the
    /// engine panic unwinds from here by design.
    fn fault(&self) -> Result<()> {
        let call = self.plan.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if self.plan.stall_call == Some(call) {
            self.plan.stalls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.plan.stall);
        }
        if self.plan.pool_panic_call == Some(call) {
            self.plan.pool_panics.fetch_add(1, Ordering::SeqCst);
            let r = self.pool.run(2, |i| {
                if i == 1 {
                    // PANIC: chaos fault injection — deliberately panics a
                    // pool job to prove the pool contains it (DESIGN.md §13)
                    panic!("chaos: injected pool-job panic");
                }
            });
            if let Err(e) = r {
                return Err(anyhow!("chaos pool fault: {e}; batch discarded"));
            }
        }
        if self.plan.engine_panic_call == Some(call) {
            self.plan.engine_panics.fetch_add(1, Ordering::SeqCst);
            // PANIC: chaos fault injection — deliberately unwinds into the
            // serve layer's `guarded` wrapper to prove unwind isolation
            panic!("chaos: injected engine panic");
        }
        Ok(())
    }
}

impl BatchScorer for ChaosScorer {
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }
    fn variable_batch(&self) -> bool {
        self.inner.variable_batch()
    }
    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        self.fault()?;
        self.inner.score(ids, targets)
    }
    fn supports_decode(&self) -> bool {
        self.inner.supports_decode()
    }
    fn begin_decode(&mut self, prompt: &[i32]) -> Result<(SeqId, Vec<f32>)> {
        self.fault()?;
        self.inner.begin_decode(prompt)
    }
    fn decode_step(&mut self, batch: &[(SeqId, i32)])
                   -> Result<Vec<Vec<f32>>> {
        self.fault()?;
        self.inner.decode_step(batch)
    }
    fn end_decode(&mut self, seq: SeqId) {
        // cleanup is never fault-injected: a fault here could leak KV state
        // and turn every injected failure into a stuck sequence
        self.inner.end_decode(seq)
    }
    fn supports_degrade(&self) -> bool {
        self.inner.supports_degrade()
    }
    fn set_degraded(&mut self, on: bool) {
        self.inner.set_degraded(on)
    }
    fn degraded(&self) -> bool {
        self.inner.degraded()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{MockScorer, Server, ServerConfig};
    use super::*;

    fn mock() -> Box<dyn BatchScorer> {
        Box::new(MockScorer { batch: 4, seq: 8, calls: 0 })
    }

    #[test]
    fn pool_panic_fires_once_at_exact_call() {
        let plan = Arc::new(FaultPlan {
            pool_panic_call: Some(2),
            ..FaultPlan::default()
        });
        let mut cs = ChaosScorer::new(mock(), plan.clone());
        assert!(cs.score(&[1, 2], &[2, 0]).is_ok()); // call 1: healthy
        let err = cs.score(&[1, 2], &[2, 0]).unwrap_err(); // call 2: fault
        assert!(format!("{err}").contains("chaos pool fault"), "{err}");
        assert!(cs.score(&[1, 2], &[2, 0]).is_ok()); // call 3: healthy again
        let f = plan.fired();
        assert_eq!(f.pool_panics, 1);
        assert_eq!(f.total(), 1);
        assert_eq!(plan.calls(), 3);
    }

    #[test]
    fn stall_and_drop_fire_and_count() {
        let mut p = FaultPlan::new();
        p.stall_call = Some(1);
        p.stall = Duration::from_millis(20);
        p.drop_response = Some(2);
        let plan = Arc::new(p);
        let mut cs = ChaosScorer::new(mock(), plan.clone());
        let t0 = std::time::Instant::now();
        assert!(cs.score(&[1, 2], &[2, 0]).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(20), "stall skipped");
        assert!(!plan.should_drop_response()); // response 1 passes
        assert!(plan.should_drop_response()); // response 2 dropped
        assert!(!plan.should_drop_response()); // response 3 passes
        let f = plan.fired();
        assert_eq!((f.stalls, f.drops), (1, 1));
        assert_eq!(plan.drops_fired(), 1);
    }

    #[test]
    fn injected_engine_panic_fails_only_its_batch() {
        let plan = Arc::new(FaultPlan {
            engine_panic_call: Some(1),
            ..FaultPlan::default()
        });
        let p2 = plan.clone();
        let s = Server::start_with(
            ServerConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            Some(plan.clone()),
            move || Ok(Box::new(ChaosScorer::new(
                Box::new(MockScorer { batch: 4, seq: 8, calls: 0 }), p2))),
        )
        .unwrap();
        let c = s.client();
        // call 1 panics inside the scorer; `guarded` answers with an error
        let err = c.score(vec![1, 2]).unwrap_err();
        assert!(format!("{err}").contains("panicked"), "{err}");
        // the very next request is served normally by the same engine
        assert_eq!(c.score(vec![1, 3]).unwrap().logp_sum, -3.0);
        assert_eq!(plan.fired().engine_panics, 1);
        assert!(s.events().stuck().is_empty());
    }

    #[test]
    fn dropped_response_surfaces_as_disconnect_not_stuck() {
        let plan = Arc::new(FaultPlan {
            drop_response: Some(1),
            ..FaultPlan::default()
        });
        let p2 = plan.clone();
        let s = Server::start_with(
            ServerConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            Some(plan.clone()),
            move || Ok(Box::new(ChaosScorer::new(
                Box::new(MockScorer { batch: 4, seq: 8, calls: 0 }), p2))),
        )
        .unwrap();
        let c = s.client();
        // first answer is dropped in flight: the client sees a closed
        // channel, the event log sees a terminal Disconnect — never stuck
        let rx = c.submit(vec![1, 2]).unwrap();
        assert!(rx.recv().is_err(), "dropped response was delivered");
        // the next request is unaffected
        assert_eq!(c.score(vec![1, 3]).unwrap().logp_sum, -3.0);
        assert_eq!(plan.fired().drops, 1);
        let ev = s.events();
        assert!(ev.stuck().is_empty());
        assert_eq!(ev.agg().disconnected, 1);
    }
}
