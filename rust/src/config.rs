//! Run configuration: the quantization scheme / method / pipeline knobs that
//! parameterize every experiment, plus a dependency-free CLI argument parser
//! (clap is unavailable in the offline build image — see Cargo.toml note).

use std::collections::HashMap;
use std::fmt;
use std::str::FromStr;

use anyhow::{anyhow, bail, Result};

/// Quantization method under test (paper baselines + ours).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Fp16,
    Rtn,
    SmoothQuant,
    Gptq,
    Awq,
    FlexRound,
    LrqNoBias, // Appendix B ablation: S2 = L2U2 (no r2/c2)
    Lrq,
    /// SmoothQuant preprocessing + reconstruction (Appendix L)
    SqFlexRound,
    SqLrq,
}

impl Method {
    pub fn all() -> &'static [Method] {
        use Method::*;
        &[Fp16, Rtn, SmoothQuant, Gptq, Awq, FlexRound, LrqNoBias, Lrq,
          SqFlexRound, SqLrq]
    }

    pub fn paper_name(&self) -> &'static str {
        match self {
            Method::Fp16 => "FP16",
            Method::Rtn => "RTN",
            Method::SmoothQuant => "SmoothQuant",
            Method::Gptq => "GPTQ",
            Method::Awq => "AWQ",
            Method::FlexRound => "FlexRound",
            Method::LrqNoBias => "LRQ (S2=L2U2)",
            Method::Lrq => "LRQ (Ours)",
            Method::SqFlexRound => "SQ+FlexRound",
            Method::SqLrq => "SQ+LRQ",
        }
    }

    /// Does this method run block-wise reconstruction (gradient-based)?
    pub fn uses_recon(&self) -> bool {
        matches!(self, Method::FlexRound | Method::LrqNoBias | Method::Lrq
                 | Method::SqFlexRound | Method::SqLrq)
    }

    /// Does this method apply SmoothQuant preprocessing first?
    pub fn uses_smooth(&self) -> bool {
        matches!(self, Method::SmoothQuant | Method::SqFlexRound
                 | Method::SqLrq)
    }
}

impl FromStr for Method {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fp16" | "fp" => Method::Fp16,
            "rtn" => Method::Rtn,
            "smoothquant" | "sq" => Method::SmoothQuant,
            "gptq" => Method::Gptq,
            "awq" => Method::Awq,
            "flexround" | "fr" => Method::FlexRound,
            "lrq_nobias" | "lrq-nobias" => Method::LrqNoBias,
            "lrq" => Method::Lrq,
            "sq+flexround" | "sq_fr" => Method::SqFlexRound,
            "sq+lrq" | "sq_lrq" => Method::SqLrq,
            other => bail!("unknown method {other}"),
        })
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.paper_name())
    }
}

/// Activation quantization scheme (paper §3.2 vs §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActScheme {
    /// weight-only: activations stay FP16
    None,
    /// per-tensor asymmetric static (calibrated scales) — Tables 1-4
    PerTensorStatic,
    /// per-token asymmetric dynamic — Tables 5-6
    PerToken,
}

impl FromStr for ActScheme {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "fp16" | "off" => ActScheme::None,
            "static" | "per-tensor" | "per_tensor" => ActScheme::PerTensorStatic,
            "token" | "per-token" | "per_token" => ActScheme::PerToken,
            other => bail!("unknown act scheme {other}"),
        })
    }
}

/// Full quantization scheme: the W/A/KV triple of every table header.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scheme {
    pub w_bits: u32,
    pub act: ActScheme,
    pub a_bits: u32,
    pub kv_quant: bool,
    pub kv_bits: u32,
}

impl Scheme {
    /// W8A8(static)KV8 — Tables 1-4.
    pub fn w8a8_static() -> Self {
        Scheme { w_bits: 8, act: ActScheme::PerTensorStatic, a_bits: 8,
                 kv_quant: true, kv_bits: 8 }
    }

    /// W4A8(per-token)KV8 — Tables 5-6.
    pub fn w4a8_token() -> Self {
        Scheme { w_bits: 4, act: ActScheme::PerToken, a_bits: 8,
                 kv_quant: true, kv_bits: 8 }
    }

    /// Weight-only (Tables 7-8, Fig. 5).
    pub fn weight_only(bits: u32) -> Self {
        Scheme { w_bits: bits, act: ActScheme::None, a_bits: 16,
                 kv_quant: false, kv_bits: 16 }
    }

    pub fn without_kv_quant(mut self) -> Self {
        self.kv_quant = false;
        self.kv_bits = 16;
        self
    }

    /// "8/8/8"-style label used in every paper table.
    pub fn label(&self) -> String {
        let a = match self.act {
            ActScheme::None => "16".to_string(),
            _ => self.a_bits.to_string(),
        };
        let kv = if self.kv_quant { self.kv_bits.to_string() }
                 else { "16".to_string() };
        format!("{}/{}/{}", self.w_bits, a, kv)
    }
}

/// Reconstruction hyper-parameters (paper Appendix I).
#[derive(Clone, Copy, Debug)]
pub struct ReconConfig {
    pub steps: usize,
    pub lr: f32,
    pub calib_samples: usize,
    pub rank: usize,
    pub seed: u64,
}

impl Default for ReconConfig {
    fn default() -> Self {
        // 5000 steps in the paper; scaled to the synthetic models.
        ReconConfig { steps: 250, lr: 3e-4, calib_samples: 64, rank: 0,
                      seed: 1234 }
    }
}

/// Minimal CLI argument parser: positional commands + `--key value` /
/// `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match argv.peek() {
                    Some(v) if !v.starts_with("--") => argv.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.options.insert(key.to_string(), val);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_as<T: FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow!("bad --{key} value {s:?}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            // every method has a paper name; selected ones parse back
            assert!(!m.paper_name().is_empty());
        }
        assert_eq!("lrq".parse::<Method>().unwrap(), Method::Lrq);
        assert_eq!("FR".parse::<Method>().unwrap(), Method::FlexRound);
        assert!("nope".parse::<Method>().is_err());
    }

    #[test]
    fn scheme_labels() {
        assert_eq!(Scheme::w8a8_static().label(), "8/8/8");
        assert_eq!(Scheme::w4a8_token().label(), "4/8/8");
        assert_eq!(Scheme::weight_only(3).label(), "3/16/16");
        assert_eq!(Scheme::w8a8_static().without_kv_quant().label(), "8/8/16");
    }

    #[test]
    fn args_parse() {
        let a = Args::parse(
            ["quantize", "--method", "lrq", "--steps", "100", "--verbose"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert_eq!(a.positional, vec!["quantize"]);
        assert_eq!(a.get("method"), Some("lrq"));
        assert_eq!(a.parse_as::<usize>("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_as::<usize>("missing", 7).unwrap(), 7);
    }
}
