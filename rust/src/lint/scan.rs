//! A minimal Rust lexer for `lrq lint` (DESIGN.md §12).
//!
//! The build image has no crates.io, so the linter cannot lean on `syn`;
//! instead this module splits a source file into just enough structure
//! for the rules to anchor on without a real parser:
//!
//! * a flat stream of **code tokens** ([`Tok`]: identifiers, single
//!   punctuation characters, numeric literals) with 1-based line numbers.
//!   String literals, char literals, lifetimes and comments are consumed
//!   but emit nothing, so brace matching over the stream is reliable and
//!   a `"maddubs"` inside a doc string can never trip a rule;
//! * per-line structure ([`LineInfo`]): does the line hold code, is its
//!   first code token a `#` (attribute lines are transparent to the
//!   justification walks), and the concatenated text of every comment
//!   touching the line — the raw material for the `SAFETY:` / `PANIC:` /
//!   ordering-justification walks;
//! * the line ranges of `#[cfg(test)] mod … { … }` bodies, so rules can
//!   exempt test code.

/// One code token. `::` arrives as two `:` puncts; the rules match on
/// short token sequences instead of grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident(String),
    /// A single punctuation character.
    Punct(char),
    /// A numeric literal (the value is irrelevant to every rule).
    Num,
}

#[derive(Debug, Clone)]
pub struct Tok {
    /// 1-based source line.
    pub line: usize,
    pub kind: TokKind,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.kind, TokKind::Ident(t) if t == s)
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(t) => Some(t),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct LineInfo {
    pub has_code: bool,
    /// The first code token on the line is `#` — an attribute (or the
    /// crate-level `#![…]` form).
    pub is_attr: bool,
    /// Concatenated text of every comment that touches this line.
    pub comment: Option<String>,
}

#[derive(Debug)]
pub struct Scanned {
    /// Path relative to the scan root, `/`-separated.
    pub rel: String,
    pub tokens: Vec<Tok>,
    /// 1-based: `lines[0]` is a placeholder.
    pub lines: Vec<LineInfo>,
    /// Inclusive line ranges of `#[cfg(test)] mod … { … }` bodies.
    pub test_ranges: Vec<(usize, usize)>,
}

impl Scanned {
    pub fn in_test(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| line >= s && line <= e)
    }

    /// The justification walk shared by the comment-anchored rules: a
    /// marker comment (`marker == None` accepts *any* comment) counts if
    /// it sits on `line` itself or within `max_up` lines above, with only
    /// blank lines, attribute lines and other comment lines in between.
    /// The first real code line above ends the walk — a comment separated
    /// from its subject by code justifies nothing. Markers are matched
    /// case-insensitively, so `// SAFETY:` and `/// # Safety` both hit
    /// `"safety"`.
    pub fn justified(&self, line: usize, marker: Option<&str>,
                     max_up: usize) -> bool {
        let hit = |l: usize| -> bool {
            let comment = self.lines.get(l).and_then(|i| i.comment.as_deref());
            match (comment, marker) {
                (Some(c), Some(m)) => c.to_lowercase().contains(m),
                (Some(_), None) => true,
                (None, _) => false,
            }
        };
        if hit(line) {
            return true;
        }
        let mut l = line;
        for _ in 0..max_up {
            if l <= 1 {
                break;
            }
            l -= 1;
            if hit(l) {
                return true;
            }
            if let Some(info) = self.lines.get(l) {
                if info.has_code && !info.is_attr {
                    return false;
                }
            }
        }
        false
    }

    /// Token-index range (exclusive of the braces) of the body of the
    /// first `fn <name>` in the stream. `None` if the fn is absent or is
    /// a bodyless trait declaration.
    pub fn fn_body(&self, name: &str) -> Option<(usize, usize)> {
        let ts = &self.tokens;
        let n = ts.len();
        for i in 0..n.saturating_sub(1) {
            if !(ts[i].is_ident("fn") && ts[i + 1].is_ident(name)) {
                continue;
            }
            let mut b = i + 2;
            while b < n && !ts[b].is_punct('{') {
                if ts[b].is_punct(';') {
                    return None;
                }
                b += 1;
            }
            if b >= n {
                return None;
            }
            let mut depth = 1usize;
            let mut e = b + 1;
            while e < n && depth > 0 {
                if ts[e].is_punct('{') {
                    depth += 1;
                } else if ts[e].is_punct('}') {
                    depth -= 1;
                }
                e += 1;
            }
            return Some((b + 1, e.saturating_sub(1)));
        }
        None
    }
}

fn note_comment(lines: &mut [LineInfo], l: usize, text: &str) {
    if let Some(info) = lines.get_mut(l) {
        match &mut info.comment {
            Some(c) => {
                c.push(' ');
                c.push_str(text);
            }
            None => info.comment = Some(text.to_string()),
        }
    }
}

fn note_code(lines: &mut [LineInfo], l: usize, first_is_hash: bool) {
    if let Some(info) = lines.get_mut(l) {
        if !info.has_code {
            info.has_code = true;
            info.is_attr = first_is_hash;
        }
    }
}

fn push_tok(tokens: &mut Vec<Tok>, lines: &mut [LineInfo], line: usize,
            kind: TokKind) {
    note_code(lines, line, kind == TokKind::Punct('#'));
    tokens.push(Tok { line, kind });
}

/// Consume a `"…"` string (escapes, multi-line) starting at the opening
/// quote; returns the index just past the closing quote.
fn consume_str(chars: &[char], mut i: usize, line: &mut usize,
               lines: &mut [LineInfo]) -> usize {
    let n = chars.len();
    i += 1;
    while i < n {
        match chars[i] {
            '\\' => {
                if chars.get(i + 1) == Some(&'\n') {
                    *line += 1;
                    note_code(lines, *line, false);
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                note_code(lines, *line, false);
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Consume a raw string starting at its opening quote; ends at `"` followed
/// by `hashes` `#`s.
fn consume_raw_str(chars: &[char], mut i: usize, hashes: usize,
                   line: &mut usize, lines: &mut [LineInfo]) -> usize {
    let n = chars.len();
    i += 1;
    while i < n {
        match chars[i] {
            '\n' => {
                *line += 1;
                note_code(lines, *line, false);
                i += 1;
            }
            '"' => {
                let mut k = 0;
                while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                    k += 1;
                }
                if k == hashes {
                    return i + 1 + hashes;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Consume a char-literal body starting just past the opening `'`.
fn consume_char_lit(chars: &[char], mut i: usize) -> usize {
    let n = chars.len();
    while i < n {
        match chars[i] {
            '\\' => i += 2,
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

pub fn scan(rel: &str, src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let nlines = src.lines().count().max(1);
    let mut lines = vec![LineInfo::default(); nlines + 2];
    let mut tokens: Vec<Tok> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            note_comment(&mut lines, line, text.trim());
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            // block comment, nesting per the Rust grammar
            let mut depth = 1usize;
            let mut seg = String::new();
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    note_comment(&mut lines, line, seg.trim());
                    seg.clear();
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    seg.push_str("/*");
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    seg.push(chars[i]);
                    i += 1;
                }
            }
            note_comment(&mut lines, line, seg.trim());
            continue;
        }
        if c == '"' {
            note_code(&mut lines, line, false);
            i = consume_str(&chars, i, &mut line, &mut lines);
            continue;
        }
        if c == '\'' {
            // lifetime vs char literal: `'a>` is a lifetime, `'a'` a char
            let c1 = chars.get(i + 1).copied();
            let c2 = chars.get(i + 2).copied();
            let lifetime = matches!(c1, Some(x) if x == '_' || x.is_alphabetic())
                && c2 != Some('\'');
            note_code(&mut lines, line, false);
            if lifetime {
                i += 2;
                while i < n && (chars[i] == '_' || chars[i].is_alphanumeric()) {
                    i += 1;
                }
            } else {
                i = consume_char_lit(&chars, i + 1);
            }
            continue;
        }
        if c == 'r' || c == 'b' {
            // the literal prefixes: b'…', b"…", r"…", br"…", r#"…"#,
            // br#"…"#, and raw identifiers r#foo
            let c1 = chars.get(i + 1).copied();
            if c == 'b' && c1 == Some('\'') {
                note_code(&mut lines, line, false);
                i = consume_char_lit(&chars, i + 2);
                continue;
            }
            if c1 == Some('"') {
                note_code(&mut lines, line, false);
                i = consume_str(&chars, i + 1, &mut line, &mut lines);
                continue;
            }
            let (pref, rest) = if c == 'b' && c1 == Some('r') {
                (2usize, chars.get(i + 2).copied())
            } else {
                (1usize, c1)
            };
            if pref == 2 && rest == Some('"') {
                note_code(&mut lines, line, false);
                i = consume_str(&chars, i + pref, &mut line, &mut lines);
                continue;
            }
            if rest == Some('#') {
                let mut h = i + pref;
                let mut hashes = 0usize;
                while chars.get(h) == Some(&'#') {
                    h += 1;
                    hashes += 1;
                }
                if chars.get(h) == Some(&'"') {
                    note_code(&mut lines, line, false);
                    i = consume_raw_str(&chars, h, hashes, &mut line,
                                        &mut lines);
                    continue;
                }
                if c == 'r' && hashes == 1
                    && matches!(chars.get(h),
                                Some(x) if *x == '_' || x.is_alphabetic())
                {
                    // raw identifier r#foo lexes as the ident `foo`
                    let start = h;
                    let mut j = h;
                    while j < n && (chars[j] == '_' || chars[j].is_alphanumeric())
                    {
                        j += 1;
                    }
                    let text: String = chars[start..j].iter().collect();
                    push_tok(&mut tokens, &mut lines, line,
                             TokKind::Ident(text));
                    i = j;
                    continue;
                }
            }
            // plain identifier that happens to start with r/b
        }
        if c == '_' || c.is_alphabetic() {
            let start = i;
            while i < n && (chars[i] == '_' || chars[i].is_alphanumeric()) {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            push_tok(&mut tokens, &mut lines, line, TokKind::Ident(text));
            continue;
        }
        if c.is_ascii_digit() {
            // loose: suffixes and hex digits ride along, `.` does not (so
            // `0..k` and tuple access stay puncts)
            while i < n && (chars[i] == '_' || chars[i].is_alphanumeric()) {
                i += 1;
            }
            push_tok(&mut tokens, &mut lines, line, TokKind::Num);
            continue;
        }
        push_tok(&mut tokens, &mut lines, line, TokKind::Punct(c));
        i += 1;
    }

    let test_ranges = test_regions(&tokens);
    Scanned { rel: rel.to_string(), tokens, lines, test_ranges }
}

/// `start` indexes the `[` of an attribute; returns the index just past
/// the matching `]` and whether the attribute tokens contain a literal
/// `cfg ( test )` sequence (`cfg(not(test))` deliberately does not match).
fn scan_attr(tokens: &[Tok], start: usize) -> (usize, bool) {
    let n = tokens.len();
    let mut depth = 0usize;
    let mut j = start;
    let mut end = n;
    while j < n {
        if tokens[j].is_punct('[') {
            depth += 1;
        } else if tokens[j].is_punct(']') {
            depth = depth.saturating_sub(1);
            if depth == 0 {
                end = j + 1;
                break;
            }
        }
        j += 1;
    }
    let attr = &tokens[start..end.min(n)];
    let mut cfg_test = false;
    for w in 0..attr.len().saturating_sub(3) {
        if attr[w].is_ident("cfg") && attr[w + 1].is_punct('(')
            && attr[w + 2].is_ident("test") && attr[w + 3].is_punct(')')
        {
            cfg_test = true;
            break;
        }
    }
    (end.min(n), cfg_test)
}

fn test_regions(tokens: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let n = tokens.len();
    let mut i = 0usize;
    while i < n {
        if !(tokens[i].is_punct('#') && i + 1 < n && tokens[i + 1].is_punct('['))
        {
            i += 1;
            continue;
        }
        let (attr_end, is_cfg_test) = scan_attr(tokens, i + 1);
        if !is_cfg_test {
            i = attr_end;
            continue;
        }
        // skip further attributes stacked between cfg(test) and the item
        let mut k = attr_end;
        while k + 1 < n && tokens[k].is_punct('#') && tokens[k + 1].is_punct('[')
        {
            let (e, _) = scan_attr(tokens, k + 1);
            k = e;
        }
        if k < n && tokens[k].is_ident("mod") {
            let mut b = k + 1;
            while b < n && !tokens[b].is_punct('{') && !tokens[b].is_punct(';')
            {
                b += 1;
            }
            if b < n && tokens[b].is_punct('{') {
                let start_line = tokens[b].line;
                let mut depth = 1usize;
                let mut e = b + 1;
                while e < n && depth > 0 {
                    if tokens[e].is_punct('{') {
                        depth += 1;
                    } else if tokens[e].is_punct('}') {
                        depth -= 1;
                    }
                    e += 1;
                }
                let end_line = tokens[e.saturating_sub(1).min(n - 1)].line;
                out.push((start_line, end_line));
                i = e;
                continue;
            }
        }
        i = attr_end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_comments_and_lifetimes_emit_no_tokens() {
        let src = "fn f<'a>(x: &'a str) -> char {\n\
                   let s = \"unsafe // not code\"; // trailing\n\
                   let r = r#\"raw \"quoted\" body\"#;\n\
                   let b = b\"bytes\";\n\
                   /* block /* nested */ comment */\n\
                   let c = 'x'; let nl = '\\n';\n\
                   'y'\n}\n";
        let sc = scan("t.rs", src);
        assert!(!sc.tokens.iter().any(|t| t.is_ident("unsafe")),
                "string contents leaked into the token stream");
        assert!(!sc.tokens.iter().any(|t| t.is_ident("trailing")));
        assert!(!sc.tokens.iter().any(|t| t.is_ident("nested")));
        assert!(!sc.tokens.iter().any(|t| t.is_ident("quoted")));
        // lifetime idents are consumed, the fn/let skeleton survives
        assert!(sc.tokens.iter().any(|t| t.is_ident("fn")));
        assert!(sc.tokens.iter().filter(|t| t.is_ident("let")).count() == 5);
        // trailing comment landed on line 2
        assert!(sc.lines[2].comment.as_deref().unwrap().contains("trailing"));
        assert!(sc.lines[2].has_code);
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let a = \"line one\n line two\";\nfn marker() {}\n";
        let sc = scan("t.rs", src);
        let m = sc.tokens.iter().find(|t| t.is_ident("marker")).unwrap();
        assert_eq!(m.line, 3);
        assert!(sc.lines[2].has_code, "string continuation counts as code");
    }

    #[test]
    fn cfg_test_mod_region_is_detected() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   #[allow(dead_code)]\n\
                   mod tests {\n\
                   fn t() { x.unwrap(); }\n\
                   }\n\
                   fn after() {}\n";
        let sc = scan("t.rs", src);
        assert_eq!(sc.test_ranges, vec![(4, 6)]);
        assert!(sc.in_test(5));
        assert!(!sc.in_test(1));
        assert!(!sc.in_test(7));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let src = "#[cfg(not(test))]\nmod real { fn f() {} }\n";
        let sc = scan("t.rs", src);
        assert!(sc.test_ranges.is_empty());
    }

    #[test]
    fn justification_walk_skips_attrs_and_stops_at_code() {
        let src = "// SAFETY: top comment\n\
                   #[inline]\n\
                   fn a() {}\n\
                   fn b() {}\n";
        let sc = scan("t.rs", src);
        // line 3 (fn a): walk crosses the attr on line 2 to the comment
        assert!(sc.justified(3, Some("safety"), 3));
        // line 4 (fn b): line 3 is real code — the walk must stop
        assert!(!sc.justified(4, Some("safety"), 8));
        // marker=None accepts any comment
        assert!(sc.justified(3, None, 3));
        assert!(!sc.justified(4, None, 2));
    }

    #[test]
    fn fn_body_brace_matching() {
        let src = "fn outer(x: usize) -> usize {\n\
                   if x > 0 { inner() } else { 0 }\n\
                   }\n\
                   fn tail() { other.sum() }\n";
        let sc = scan("t.rs", src);
        let (b, e) = sc.fn_body("outer").unwrap();
        let body = &sc.tokens[b..e];
        assert!(body.iter().any(|t| t.is_ident("inner")));
        assert!(!body.iter().any(|t| t.is_ident("sum")),
                "body range leaked into the next fn");
        assert!(sc.fn_body("missing").is_none());
    }
}
