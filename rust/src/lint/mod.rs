//! `lrq lint` — the repo-native invariant linter (DESIGN.md §12).
//!
//! The engine's headline claims — bit-exact SIMD-vs-scalar kernels, a
//! contractually *sequential* weight-only f32 GEMM, lock-free telemetry on
//! relaxed atomics, a panic-free request path — are invariants that tests
//! can only witness and comments can only describe. This module makes them
//! machine-checked: a hand-rolled lexer ([`scan`]) feeds six rules driven
//! by an allowlist config (`rust/lint.toml`), findings render as human
//! text and as `LINT.json`, and the `lrq lint` subcommand exits nonzero on
//! any violation (a blocking CI step). The rules:
//!
//! * **unsafe-confinement** — `unsafe` appears only in the allowlisted
//!   module set (`[unsafe] allow`).
//! * **undocumented-unsafe** — every `unsafe` carries a `// SAFETY:` (or
//!   `/// # Safety`) comment.
//! * **forbidden-intrinsic** — no identifier matches a forbidden pattern
//!   (the saturating `maddubs` family, `[intrinsics] forbidden`).
//! * **sequential-f32** — the contracted weight-only f32 kernels contain
//!   no iterator reductions, chunking, or SIMD (`[sequential_f32]`).
//! * **atomic-ordering** — `Ordering::{Relaxed,Acquire,Release,AcqRel}`
//!   outside the exempt files needs a nearby justification comment.
//! * **serving-panic** — no `unwrap()`/`expect()`/`panic!` in the
//!   request-reachable serving path without a `// PANIC:` justification.
//!
//! Seeded-violation fixtures under `rust/lint_fixtures/` prove each rule
//! fires (see the tests below); the real `src/` tree must stay clean.

mod scan;

use crate::{anyhow, bail, Context, Result};
use scan::Scanned;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub const UNSAFE_CONFINEMENT: &str = "unsafe-confinement";
pub const UNDOCUMENTED_UNSAFE: &str = "undocumented-unsafe";
pub const FORBIDDEN_INTRINSIC: &str = "forbidden-intrinsic";
pub const SEQUENTIAL_F32: &str = "sequential-f32";
pub const ATOMIC_ORDERING: &str = "atomic-ordering";
pub const SERVING_PANIC: &str = "serving-panic";

/// Every rule id, in report order.
pub const RULES: &[&str] = &[
    UNSAFE_CONFINEMENT,
    UNDOCUMENTED_UNSAFE,
    FORBIDDEN_INTRINSIC,
    SEQUENTIAL_F32,
    ATOMIC_ORDERING,
    SERVING_PANIC,
];

/// How far a `SAFETY` comment may sit above its `unsafe` (doc comments on
/// an attributed fn cross several attribute lines).
const SAFETY_WALK: usize = 12;
/// How far ordering / panic justifications may sit above their line.
const NEAR_WALK: usize = 3;

#[derive(Debug, Clone, Default)]
pub struct LintConfig {
    /// Files (relative to the scan root) where `unsafe` may appear.
    pub unsafe_allow: Vec<String>,
    /// Substring patterns no identifier may match.
    pub forbidden_idents: Vec<String>,
    /// Contractually-sequential fns as `(file, fn_name)`.
    pub seq_fns: Vec<(String, String)>,
    /// Method names (after `.`) forbidden inside those fns.
    pub seq_methods: Vec<String>,
    /// Bare identifiers forbidden inside those fns.
    pub seq_idents: Vec<String>,
    /// Identifier prefixes forbidden inside those fns.
    pub seq_prefixes: Vec<String>,
    /// Files exempt from the atomic-ordering rule.
    pub ordering_exempt: Vec<String>,
    /// Request-reachable paths (`dir/` prefix or exact file).
    pub panic_paths: Vec<String>,
}

impl LintConfig {
    /// Hand-rolled parser for the subset of TOML `lint.toml` uses:
    /// `[section]` headers, `#` comments, and `key = ["…", …]` string
    /// arrays (single- or multi-line). Unknown keys are errors so a typo
    /// cannot silently disable a rule.
    pub fn parse(text: &str) -> Result<LintConfig> {
        let mut cfg = LintConfig::default();
        let mut section = String::new();
        let mut lines = text.lines().enumerate();
        while let Some((ln, raw)) = lines.next() {
            let line = strip_toml_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                section = name.trim().to_string();
                continue;
            }
            let (key, mut val) = line
                .split_once('=')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                .ok_or_else(|| {
                    anyhow!("lint.toml:{}: expected `key = [..]`", ln + 1)
                })?;
            while val.matches('[').count() > val.matches(']').count() {
                let (_, cont) = lines.next().ok_or_else(|| {
                    anyhow!("lint.toml:{}: unterminated array", ln + 1)
                })?;
                val.push(' ');
                val.push_str(strip_toml_comment(cont).trim());
            }
            let items = parse_string_array(&val)
                .with_context(|| format!("lint.toml:{}: key `{key}`", ln + 1))?;
            match (section.as_str(), key.as_str()) {
                ("unsafe", "allow") => cfg.unsafe_allow = items,
                ("intrinsics", "forbidden") => cfg.forbidden_idents = items,
                ("sequential_f32", "functions") => {
                    for it in items {
                        let (f, name) = it.split_once("::").ok_or_else(|| {
                            anyhow!(
                                "lint.toml:{}: expected `file.rs::fn_name`, \
                                 got `{it}`",
                                ln + 1
                            )
                        })?;
                        cfg.seq_fns.push((f.to_string(), name.to_string()));
                    }
                }
                ("sequential_f32", "methods") => cfg.seq_methods = items,
                ("sequential_f32", "idents") => cfg.seq_idents = items,
                ("sequential_f32", "prefixes") => cfg.seq_prefixes = items,
                ("atomics", "exempt") => cfg.ordering_exempt = items,
                ("serving", "paths") => cfg.panic_paths = items,
                _ => bail!(
                    "lint.toml:{}: unknown key `[{section}] {key}`",
                    ln + 1
                ),
            }
        }
        Ok(cfg)
    }
}

fn strip_toml_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_string_array(val: &str) -> Result<Vec<String>> {
    let inner = val
        .trim()
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| anyhow!("expected a [\"…\", …] array, got `{val}`"))?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let s = p
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .ok_or_else(|| anyhow!("expected a quoted string, got `{p}`"))?;
        out.push(s.to_string());
    }
    Ok(out)
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    /// Path relative to the scan root.
    pub file: String,
    /// 1-based line; 0 for file-level findings.
    pub line: usize,
    pub message: String,
}

#[derive(Debug)]
pub struct LintReport {
    pub root: String,
    pub files: usize,
    pub violations: Vec<Violation>,
}

impl LintReport {
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for v in &self.violations {
            *m.entry(v.rule).or_insert(0) += 1;
        }
        m
    }

    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for v in &self.violations {
            s.push_str(&format!("{}:{}: [{}] {}\n", v.file, v.line, v.rule,
                                v.message));
        }
        if self.violations.is_empty() {
            s.push_str(&format!(
                "lint: clean — {} files under {}, 0 violations\n",
                self.files, self.root
            ));
        } else {
            s.push_str(&format!(
                "lint: {} violation(s) across {} files under {}:",
                self.violations.len(),
                self.files,
                self.root
            ));
            for (rule, n) in self.counts() {
                s.push_str(&format!(" {rule}={n}"));
            }
            s.push('\n');
        }
        s
    }

    /// Hand-rolled JSON (the build image has no serde): the `LINT.json`
    /// CI artifact. Every rule appears in `by_rule` (zero-filled) so a
    /// dashboard can chart rules that never fire.
    pub fn render_json(&self) -> String {
        let counts = self.counts();
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"root\": \"{}\",\n", esc(&self.root)));
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files));
        s.push_str(&format!("  \"total\": {},\n", self.violations.len()));
        s.push_str("  \"by_rule\": {");
        for (i, rule) in RULES.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{rule}\": {}",
                                counts.get(rule).copied().unwrap_or(0)));
        }
        s.push_str("},\n  \"violations\": [\n");
        for (i, v) in self.violations.iter().enumerate() {
            let sep = if i + 1 == self.violations.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                 \"message\": \"{}\"}}{sep}\n",
                v.rule,
                esc(&v.file),
                v.line,
                esc(&v.message)
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Scan every `.rs` file under `root` and apply the rules.
pub fn run(root: &Path, cfg: &LintConfig) -> Result<LintReport> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    if files.is_empty() {
        bail!("no .rs files under {}", root.display());
    }
    let mut violations = Vec::new();
    let mut seen_rels: Vec<String> = Vec::new();
    for (rel, path) in &files {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let sc = scan::scan(rel, &src);
        seen_rels.push(rel.clone());
        check_unsafe(&sc, cfg, &mut violations);
        check_intrinsics(&sc, cfg, &mut violations);
        check_sequential(&sc, cfg, &mut violations);
        check_ordering(&sc, cfg, &mut violations);
        check_serving_panic(&sc, cfg, &mut violations);
    }
    // a contracted fn's file going missing must fail loudly, not silently
    // stop being checked
    for (file, name) in &cfg.seq_fns {
        if !seen_rels.iter().any(|r| r == file) {
            violations.push(Violation {
                rule: SEQUENTIAL_F32,
                file: file.clone(),
                line: 0,
                message: format!(
                    "contracted file not found under the scan root \
                     (fn `{name}`) — if it moved, update lint.toml"
                ),
            });
        }
    }
    violations.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    Ok(LintReport {
        root: root.display().to_string(),
        files: files.len(),
        violations,
    })
}

fn collect_rs(root: &Path, dir: &Path,
              out: &mut Vec<(String, PathBuf)>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

fn v(rule: &'static str, sc: &Scanned, line: usize,
     message: String) -> Violation {
    Violation { rule, file: sc.rel.clone(), line, message }
}

/// Rules 1+2 of the unsafe contract: confinement to the allowlisted
/// modules, and a `SAFETY` justification on every occurrence (test code
/// included — a test touching raw pointers owes the same explanation).
fn check_unsafe(sc: &Scanned, cfg: &LintConfig, out: &mut Vec<Violation>) {
    let allowed = cfg.unsafe_allow.iter().any(|a| a == &sc.rel);
    for t in &sc.tokens {
        if !t.is_ident("unsafe") {
            continue;
        }
        if !allowed {
            out.push(v(
                UNSAFE_CONFINEMENT,
                sc,
                t.line,
                format!(
                    "`unsafe` outside the allowlisted modules ({}) — keep \
                     raw-pointer and intrinsic code confined, or extend \
                     [unsafe] allow in lint.toml",
                    cfg.unsafe_allow.join(", ")
                ),
            ));
        }
        if !sc.justified(t.line, Some("safety"), SAFETY_WALK) {
            out.push(v(
                UNDOCUMENTED_UNSAFE,
                sc,
                t.line,
                "`unsafe` without a `// SAFETY:` (or `/// # Safety`) \
                 comment explaining why the contract holds"
                    .into(),
            ));
        }
    }
}

fn check_intrinsics(sc: &Scanned, cfg: &LintConfig,
                    out: &mut Vec<Violation>) {
    for t in &sc.tokens {
        let Some(id) = t.ident() else { continue };
        let low = id.to_lowercase();
        for pat in &cfg.forbidden_idents {
            if low.contains(pat.as_str()) {
                out.push(v(
                    FORBIDDEN_INTRINSIC,
                    sc,
                    t.line,
                    format!(
                        "identifier `{id}` matches forbidden intrinsic \
                         pattern `{pat}` — the saturating multiply-add \
                         family breaks the integer exactness contract \
                         (DESIGN.md §11)"
                    ),
                ));
            }
        }
    }
}

fn check_sequential(sc: &Scanned, cfg: &LintConfig,
                    out: &mut Vec<Violation>) {
    for (file, name) in &cfg.seq_fns {
        if file != &sc.rel {
            continue;
        }
        let Some((b, e)) = sc.fn_body(name) else {
            out.push(v(
                SEQUENTIAL_F32,
                sc,
                0,
                format!(
                    "contracted fn `{name}` not found in {} — if it was \
                     renamed, update lint.toml",
                    sc.rel
                ),
            ));
            continue;
        };
        for idx in b..e.min(sc.tokens.len()) {
            let t = &sc.tokens[idx];
            let Some(id) = t.ident() else { continue };
            let after_dot = idx > 0 && sc.tokens[idx - 1].is_punct('.');
            if after_dot && cfg.seq_methods.iter().any(|m| m == id) {
                out.push(v(
                    SEQUENTIAL_F32,
                    sc,
                    t.line,
                    format!(
                        "`.{id}(…)` inside contractually-sequential \
                         `{name}` — iterator/chunked reductions \
                         reassociate the f32 accumulation that planned == \
                         reference bit-equality depends on (DESIGN.md §11)"
                    ),
                ));
            }
            if cfg.seq_idents.iter().any(|m| m == id)
                || cfg.seq_prefixes.iter().any(|p| id.starts_with(p.as_str()))
            {
                out.push(v(
                    SEQUENTIAL_F32,
                    sc,
                    t.line,
                    format!(
                        "`{id}` inside contractually-sequential `{name}` — \
                         no SIMD in the sequential f32 path"
                    ),
                ));
            }
        }
    }
}

fn check_ordering(sc: &Scanned, cfg: &LintConfig, out: &mut Vec<Violation>) {
    if cfg.ordering_exempt.iter().any(|e| e == &sc.rel) {
        return;
    }
    const MODES: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel"];
    let ts = &sc.tokens;
    for i in 0..ts.len().saturating_sub(3) {
        if !(ts[i].is_ident("Ordering") && ts[i + 1].is_punct(':')
            && ts[i + 2].is_punct(':'))
        {
            continue;
        }
        let Some(mode) = ts[i + 3].ident() else { continue };
        if !MODES.contains(&mode) {
            continue;
        }
        let line = ts[i + 3].line;
        if sc.in_test(line) {
            continue;
        }
        if !sc.justified(line, None, NEAR_WALK) {
            out.push(v(
                ATOMIC_ORDERING,
                sc,
                line,
                format!(
                    "`Ordering::{mode}` without a nearby justification \
                     comment — say why the weak ordering is sound here \
                     (obs/registry.rs documents the one exempt lock-free \
                     core)"
                ),
            ));
        }
    }
}

fn check_serving_panic(sc: &Scanned, cfg: &LintConfig,
                       out: &mut Vec<Violation>) {
    let scoped = cfg.panic_paths.iter().any(|p| {
        if p.ends_with('/') {
            sc.rel.starts_with(p.as_str())
        } else {
            &sc.rel == p
        }
    });
    if !scoped {
        return;
    }
    let ts = &sc.tokens;
    for i in 0..ts.len() {
        let Some(id) = ts[i].ident() else { continue };
        let flagged = match id {
            "unwrap" | "expect" => i > 0 && ts[i - 1].is_punct('.'),
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                ts.get(i + 1).is_some_and(|t| t.is_punct('!'))
            }
            _ => false,
        };
        if !flagged {
            continue;
        }
        let line = ts[i].line;
        if sc.in_test(line) {
            continue;
        }
        if !sc.justified(line, Some("panic:"), NEAR_WALK) {
            out.push(v(
                SERVING_PANIC,
                sc,
                line,
                format!(
                    "`{id}` in request-reachable serving code — propagate \
                     an error onto the reject/error lifecycle events \
                     instead, or justify with `// PANIC:`"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn repo_config() -> LintConfig {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/lint.toml");
        LintConfig::parse(&std::fs::read_to_string(p).unwrap()).unwrap()
    }

    #[test]
    fn config_parses_every_section() {
        let cfg = repo_config();
        assert!(cfg.unsafe_allow.contains(&"infer/simd.rs".to_string()));
        assert!(!cfg.forbidden_idents.is_empty());
        assert!(cfg.seq_fns.iter().any(|(f, n)| f == "infer/kernels.rs"
            && n == "dot_f32_u8"));
        assert!(!cfg.seq_methods.is_empty());
        assert!(cfg.ordering_exempt.contains(&"obs/registry.rs".to_string()));
        assert!(cfg.panic_paths.contains(&"serve/".to_string()));
    }

    #[test]
    fn config_rejects_unknown_keys() {
        assert!(LintConfig::parse("[unsafe]\ntypo = [\"x\"]\n").is_err());
        assert!(LintConfig::parse("[serving]\npaths = [unquoted]\n").is_err());
    }

    #[test]
    fn the_tree_as_merged_is_clean() {
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
        let rep = run(Path::new(root), &repo_config()).unwrap();
        assert!(rep.violations.is_empty(),
                "src/ must lint clean:\n{}", rep.render_text());
        assert!(rep.files >= 12, "expected the whole tree to be scanned");
    }

    #[test]
    fn every_rule_fires_on_its_seeded_fixture() {
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/lint_fixtures");
        let rep = run(Path::new(root), &repo_config()).unwrap();
        let counts = rep.counts();
        for rule in RULES {
            assert!(
                counts.get(rule).copied().unwrap_or(0) > 0,
                "rule {rule} never fired on the fixtures:\n{}",
                rep.render_text()
            );
        }
        // the `// PANIC:` escape hatch: bad.rs seeds two unjustified
        // panic sites plus one justified site that must NOT fire
        assert_eq!(counts[SERVING_PANIC], 2, "{}", rep.render_text());
        // the allowlisted fixture with a SAFETY comment must not also
        // trip undocumented-unsafe (ops.rs is confinement-only)
        assert!(!rep.violations.iter().any(|f| f.rule == UNDOCUMENTED_UNSAFE
            && f.file == "infer/ops.rs"), "{}", rep.render_text());
        let json = rep.render_json();
        for rule in RULES {
            assert!(json.contains(rule));
        }
        assert!(json.contains("\"total\""));
    }

    #[test]
    fn report_renders_clean_and_dirty() {
        let rep = LintReport {
            root: "src".into(),
            files: 3,
            violations: vec![],
        };
        assert!(rep.render_text().contains("clean"));
        assert!(rep.render_json().contains("\"total\": 0"));
        let rep = LintReport {
            root: "src".into(),
            files: 3,
            violations: vec![Violation {
                rule: SERVING_PANIC,
                file: "serve/mod.rs".into(),
                line: 7,
                message: "say \"why\"".into(),
            }],
        };
        assert!(rep.render_text().contains("serve/mod.rs:7"));
        // quotes in messages must stay valid JSON
        assert!(rep.render_json().contains("say \\\"why\\\""));
    }
}
