//! Integer kernel primitives for the native engine: activation quantization
//! to u8 codes, unrolled u8×u8→i32 dot products, and fused unpacking of
//! 3/4/8-bit weight rows into cache-resident tiles.
//!
//! Grid math is kept bit-identical to [`crate::quant::act`] (the Rust oracle
//! of the Pallas per-token kernel): same `(hi-lo)/qmax` scale floor, same
//! zero-point rounding — so the integer path dequantizes to exactly the
//! values the fake-quant reference produces, and any output difference is
//! pure f32 accumulation order.

use anyhow::{bail, Result};

use crate::quant::pack::packed_len;

/// Largest inner dimension for which a u8×u8 dot fits an i32 accumulator
/// (255·255·K < 2^31).
pub const MAX_DOT_K: usize = 33_000;

/// Quantized activations: per-row u8 codes + asymmetric grid,
/// `x ≈ (code - zp)·scale` per row. For per-tensor static quantization every
/// row shares the same grid entries.
#[derive(Clone, Debug)]
pub struct QuantActs {
    pub rows: usize,
    pub cols: usize,
    /// row-major `[rows, cols]` integer codes in `[0, qmax]`
    pub codes: Vec<u8>,
    /// per-row scale
    pub scale: Vec<f32>,
    /// per-row integral zero-point
    pub zp: Vec<i32>,
    /// per-row Σ codes (epilogue correction term)
    pub code_sum: Vec<i64>,
}

fn quantize_rows(x: &[f32], rows: usize, cols: usize,
                 grid_of: impl Fn(&[f32]) -> (f32, f32), qmax: f32)
                 -> QuantActs {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert!(qmax <= 255.0, "u8 codes need qmax <= 255, got {qmax}");
    let mut codes = vec![0u8; rows * cols];
    let mut scale = Vec::with_capacity(rows);
    let mut zp = Vec::with_capacity(rows);
    let mut code_sum = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let (s, z) = grid_of(row);
        // the epilogue correction is integer arithmetic, so the zero-point
        // must be an integral code — round (never truncate) and use the same
        // rounded value for the codes, keeping both sides consistent
        debug_assert!(z.fract() == 0.0 && (0.0..=qmax).contains(&z),
                      "zero-point {z} is not an integral code in [0, {qmax}]");
        let zi = z.round();
        let crow = &mut codes[r * cols..(r + 1) * cols];
        let mut sum = 0i64;
        for (o, &v) in crow.iter_mut().zip(row) {
            let q = crate::quant::act::quantize_code(v, s, zi, qmax) as u8;
            sum += q as i64;
            *o = q;
        }
        scale.push(s);
        zp.push(zi as i32);
        code_sum.push(sum);
    }
    QuantActs { rows, cols, codes, scale, zp, code_sum }
}

/// Per-token asymmetric quantization over the trailing dim — the integer
/// twin of [`crate::quant::act::per_token_quant`], sharing its grid math
/// via [`crate::quant::act::row_grid`].
pub fn quantize_acts_per_token(x: &[f32], rows: usize, cols: usize,
                               qmax: f32) -> QuantActs {
    quantize_rows(x, rows, cols,
                  |row| crate::quant::act::row_grid(row, qmax), qmax)
}

/// Per-tensor static quantization with a calibrated `(scale, zp)` — the
/// integer twin of [`crate::quant::act::per_tensor_quant`].
pub fn quantize_acts_static(x: &[f32], rows: usize, cols: usize, scale: f32,
                            zp: f32, qmax: f32) -> QuantActs {
    quantize_rows(x, rows, cols, |_| (scale, zp), qmax)
}

/// Unrolled u8×u8 dot product with i32 accumulation. Caller guarantees
/// `a.len() == b.len() <= MAX_DOT_K` (checked at `QuantLinear` build).
#[inline]
pub fn dot_u8(a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / 4;
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    for c in 0..chunks {
        let p = c * 4;
        acc0 += a[p] as i32 * b[p] as i32;
        acc1 += a[p + 1] as i32 * b[p + 1] as i32;
        acc2 += a[p + 2] as i32 * b[p + 2] as i32;
        acc3 += a[p + 3] as i32 * b[p + 3] as i32;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for p in chunks * 4..k {
        acc += a[p] as i32 * b[p] as i32;
    }
    acc
}

/// f32×u8 dot product (weight-only path: FP activations, integer weights).
#[inline]
pub fn dot_f32_u8(x: &[f32], q: &[u8]) -> f32 {
    debug_assert_eq!(x.len(), q.len());
    let k = x.len();
    let chunks = k / 4;
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    for c in 0..chunks {
        let p = c * 4;
        acc0 += x[p] * q[p] as f32;
        acc1 += x[p + 1] * q[p + 1] as f32;
        acc2 += x[p + 2] * q[p + 2] as f32;
        acc3 += x[p + 3] * q[p + 3] as f32;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for p in chunks * 4..k {
        acc += x[p] * q[p] as f32;
    }
    acc
}

/// Fused unpack of weight rows `[r0, r0+n)` from an LSB-first packed
/// bitstream into `out[0..n*cols]` (u8 codes). This is the "unpack tile,
/// then matmul against it" half of the fused 3/4-bit kernels: tiles stay
/// small enough to live in L1 while every token row streams past them.
///
/// The stream layout is validated at [`crate::quant::PackedMatrix`]
/// construction; this only debug-checks.
pub fn unpack_rows(packed: &[u8], bits: u32, cols: usize, r0: usize, n: usize,
                   out: &mut [u8]) {
    debug_assert!(out.len() >= n * cols);
    debug_assert!(packed.len() >= packed_len((r0 + n) * cols, bits));
    match bits {
        8 => {
            out[..n * cols]
                .copy_from_slice(&packed[r0 * cols..(r0 + n) * cols]);
        }
        4 if cols % 2 == 0 => {
            // rows are byte-aligned: expand two nibbles per byte
            let src = &packed[r0 * cols / 2..(r0 + n) * cols / 2];
            for (i, &b) in src.iter().enumerate() {
                out[2 * i] = b & 0x0F;
                out[2 * i + 1] = b >> 4;
            }
        }
        _ => {
            // generic bit cursor (3-bit rows start mid-byte)
            let mask = (1u32 << bits) - 1;
            let mut bitpos = r0 * cols * bits as usize;
            for o in out[..n * cols].iter_mut() {
                let byte = bitpos / 8;
                let off = (bitpos % 8) as u32;
                // splice up to 16 bits so any <=8-bit code is covered
                let lo = packed[byte] as u32;
                let hi = *packed.get(byte + 1).unwrap_or(&0) as u32;
                *o = (((lo | (hi << 8)) >> off) & mask) as u8;
                bitpos += bits as usize;
            }
        }
    }
}

/// Contiguous shard ranges splitting `n` rows across `shards` workers.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, n.max(1));
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for i in 0..shards {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Validate an inner dimension against the i32 accumulator bound.
pub fn check_dot_k(k: usize) -> Result<()> {
    if k > MAX_DOT_K {
        bail!("inner dim {k} exceeds i32-safe u8 GEMM bound {MAX_DOT_K}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::act::per_token_quant;
    use crate::quant::pack::pack_bits;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn per_token_codes_dequant_to_oracle() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[6, 40], 1.3);
        for qmax in [255.0f32, 15.0] {
            let qa = quantize_acts_per_token(&x.data, 6, 40, qmax);
            let oracle = per_token_quant(&x, qmax);
            for r in 0..6 {
                for c in 0..40 {
                    let deq = (qa.codes[r * 40 + c] as f32 - qa.zp[r] as f32)
                        * qa.scale[r];
                    let want = oracle.data[r * 40 + c];
                    assert!((deq - want).abs() < 1e-6,
                            "qmax {qmax} r{r} c{c}: {deq} vs {want}");
                }
            }
        }
    }

    #[test]
    fn static_codes_dequant_to_oracle() {
        use crate::quant::act::{per_tensor_quant, ActRange};
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&mut rng, &[4, 24], 1.1);
        let mut r = ActRange::default();
        r.update(x.min(), x.max());
        let (s, z) = r.grid(255.0);
        let qa = quantize_acts_static(&x.data, 4, 24, s, z, 255.0);
        let oracle = per_tensor_quant(&x, s, z, 255.0);
        for (i, &want) in oracle.data.iter().enumerate() {
            let row = i / 24;
            let deq = (qa.codes[i] as f32 - qa.zp[row] as f32)
                * qa.scale[row];
            assert!((deq - want).abs() < 1e-6, "i{i}: {deq} vs {want}");
        }
    }

    #[test]
    fn code_sums_consistent() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[3, 17], 0.7);
        let qa = quantize_acts_per_token(&x.data, 3, 17, 255.0);
        for r in 0..3 {
            let s: i64 = qa.codes[r * 17..(r + 1) * 17]
                .iter()
                .map(|&c| c as i64)
                .sum();
            assert_eq!(s, qa.code_sum[r]);
        }
    }

    #[test]
    fn dots_match_naive() {
        let mut rng = Rng::new(3);
        for k in [1usize, 3, 4, 7, 64, 129] {
            let a: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let want: i32 = a.iter().zip(&b)
                .map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_u8(&a, &b), want);
            let xf: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            let wantf: f32 = xf.iter().zip(&b)
                .map(|(&x, &y)| x * y as f32).sum();
            let tol = wantf.abs() * 1e-5 + 1e-2;
            assert!((dot_f32_u8(&xf, &b) - wantf).abs() < tol);
        }
    }

    #[test]
    fn unpack_rows_matches_bitstream() {
        let mut rng = Rng::new(4);
        for bits in [3u32, 4, 8] {
            for cols in [5usize, 8, 33] {
                let rows = 9;
                let codes: Vec<u32> = (0..rows * cols)
                    .map(|_| rng.below(1 << bits) as u32)
                    .collect();
                let packed = pack_bits(&codes, bits);
                let mut tile = vec![0u8; 4 * cols];
                for r0 in [0usize, 1, 5] {
                    let n = 4.min(rows - r0);
                    unpack_rows(&packed, bits, cols, r0, n, &mut tile);
                    for i in 0..n * cols {
                        assert_eq!(tile[i] as u32, codes[r0 * cols + i],
                                   "bits {bits} cols {cols} r0 {r0} i {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn shard_ranges_cover() {
        for (n, s) in [(10usize, 3usize), (7, 7), (5, 9), (352, 4), (1, 1)] {
            let r = shard_ranges(n, s);
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn shard_ranges_empty_input_is_single_empty_range() {
        // n = 0 must not panic or emit shards < 1 — the empty-batch guard
        // upstream never executes, but the primitive stays total
        for s in [1usize, 4, 9] {
            assert_eq!(shard_ranges(0, s), vec![(0, 0)]);
        }
    }
}
