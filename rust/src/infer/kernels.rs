//! Integer kernel primitives for the native engine: activation quantization
//! to u8 codes, the register-blocked 4×4 **scalar-oracle** micro-kernels of
//! the planned path ([`dot_block_u8_scalar`] / [`dot_block_f32_u8_scalar`],
//! streaming lane-padded row-major [`crate::infer::plan::TilePlan`] tiles),
//! the scalar dots of the reference path, and fused unpacking of 3/4/8-bit
//! weight rows into cache-resident tiles (plan construction + reference
//! execution).
//!
//! These scalar kernels are the bit-exact oracle of the runtime-dispatched
//! vector kernels in [`crate::infer::simd`] (DESIGN.md §11): every SIMD
//! path is differentially tested against them, and `--kernel scalar` /
//! `LRQ_FORCE_SCALAR=1` pins execution here so both codegen paths stay
//! live in CI.
//!
//! Grid math is kept bit-identical to [`crate::quant::act`] (the Rust oracle
//! of the Pallas per-token kernel): same `(hi-lo)/qmax` scale floor, same
//! zero-point rounding — so the integer path dequantizes to exactly the
//! values the fake-quant reference produces, and any output difference is
//! pure f32 accumulation order.

use anyhow::{bail, Result};

use crate::quant::pack::packed_len;

/// Largest inner dimension for which a u8×u8 dot fits an i32 accumulator
/// (255·255·K < 2^31).
pub const MAX_DOT_K: usize = 33_000;

/// Quantized activations: per-row u8 codes + asymmetric grid,
/// `x ≈ (code - zp)·scale` per row. For per-tensor static quantization every
/// row shares the same grid entries. Holders are recyclable through
/// [`crate::infer::plan::Scratch`] — the `_into` quantizers below refill an
/// existing instance without reallocating.
#[derive(Clone, Debug, Default)]
pub struct QuantActs {
    pub rows: usize,
    pub cols: usize,
    /// row-major `[rows, cols]` integer codes in `[0, qmax]`
    pub codes: Vec<u8>,
    /// per-row scale
    pub scale: Vec<f32>,
    /// per-row integral zero-point
    pub zp: Vec<i32>,
    /// per-row Σ codes (epilogue correction term)
    pub code_sum: Vec<i64>,
}

fn quantize_rows_into(x: &[f32], rows: usize, cols: usize,
                      grid_of: impl Fn(&[f32]) -> (f32, f32), qmax: f32,
                      out: &mut QuantActs) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert!(qmax <= 255.0, "u8 codes need qmax <= 255, got {qmax}");
    crate::obs::registry::engine::ACT_ROWS_QUANTIZED.add(rows as u64);
    out.rows = rows;
    out.cols = cols;
    out.codes.clear();
    out.codes.resize(rows * cols, 0);
    out.scale.clear();
    out.zp.clear();
    out.code_sum.clear();
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let (s, z) = grid_of(row);
        // the epilogue correction is integer arithmetic, so the zero-point
        // must be an integral code — round (never truncate) and use the same
        // rounded value for the codes, keeping both sides consistent
        debug_assert!(z.fract() == 0.0 && (0.0..=qmax).contains(&z),
                      "zero-point {z} is not an integral code in [0, {qmax}]");
        let zi = z.round();
        let crow = &mut out.codes[r * cols..(r + 1) * cols];
        let mut sum = 0i64;
        for (o, &v) in crow.iter_mut().zip(row) {
            let q = crate::quant::act::quantize_code(v, s, zi, qmax) as u8;
            sum += q as i64;
            *o = q;
        }
        out.scale.push(s);
        out.zp.push(zi as i32);
        out.code_sum.push(sum);
    }
}

/// Per-token asymmetric quantization over the trailing dim — the integer
/// twin of [`crate::quant::act::per_token_quant`], sharing its grid math
/// via [`crate::quant::act::row_grid`]. Refills `out` in place (the
/// scratch-arena path: steady-state decode steps reuse one holder).
pub fn quantize_acts_per_token_into(x: &[f32], rows: usize, cols: usize,
                                    qmax: f32, out: &mut QuantActs) {
    quantize_rows_into(x, rows, cols,
                       |row| crate::quant::act::row_grid(row, qmax), qmax,
                       out);
}

/// Allocating convenience wrapper over [`quantize_acts_per_token_into`].
pub fn quantize_acts_per_token(x: &[f32], rows: usize, cols: usize,
                               qmax: f32) -> QuantActs {
    let mut out = QuantActs::default();
    quantize_acts_per_token_into(x, rows, cols, qmax, &mut out);
    out
}

/// Per-tensor static quantization with a calibrated `(scale, zp)` — the
/// integer twin of [`crate::quant::act::per_tensor_quant`]. Refills `out`
/// in place.
pub fn quantize_acts_static_into(x: &[f32], rows: usize, cols: usize,
                                 scale: f32, zp: f32, qmax: f32,
                                 out: &mut QuantActs) {
    quantize_rows_into(x, rows, cols, |_| (scale, zp), qmax, out);
}

/// Allocating convenience wrapper over [`quantize_acts_static_into`].
pub fn quantize_acts_static(x: &[f32], rows: usize, cols: usize, scale: f32,
                            zp: f32, qmax: f32) -> QuantActs {
    let mut out = QuantActs::default();
    quantize_acts_static_into(x, rows, cols, scale, zp, qmax, &mut out);
    out
}

/// Unrolled u8×u8 dot product with i32 accumulation. Caller guarantees
/// `a.len() == b.len() <= MAX_DOT_K` (checked at `QuantLinear` build).
#[inline]
pub fn dot_u8(a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let chunks = k / 4;
    let mut acc0 = 0i32;
    let mut acc1 = 0i32;
    let mut acc2 = 0i32;
    let mut acc3 = 0i32;
    for c in 0..chunks {
        let p = c * 4;
        acc0 += a[p] as i32 * b[p] as i32;
        acc1 += a[p + 1] as i32 * b[p + 1] as i32;
        acc2 += a[p + 2] as i32 * b[p + 2] as i32;
        acc3 += a[p + 3] as i32 * b[p + 3] as i32;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for p in chunks * 4..k {
        acc += a[p] as i32 * b[p] as i32;
    }
    acc
}

/// f32×u8 dot product (weight-only path: FP activations, integer weights).
///
/// Accumulation is **sequential** over the inner dim — one accumulator, in
/// index order — because this is the `ExecMode::Reference` twin of the
/// register-blocked [`dot_block_f32_u8_scalar`], whose per-output-element
/// accumulation is also one sequential chain. Same per-element f32 op order
/// ⇒ the planned and reference weight-only paths are bit-identical, not
/// merely close.
///
/// This order is a **contract**: the weight-only GEMM is never vectorized
/// (no SIMD dispatch arm exists for it in [`crate::infer::simd`]) because
/// any lane split would reassociate f32 adds and break the planned ==
/// reference bit-equality. `sequential_f32_accumulation_is_load_bearing`
/// below fails if anyone reorders it.
#[inline]
pub fn dot_f32_u8(x: &[f32], q: &[u8]) -> f32 {
    debug_assert_eq!(x.len(), q.len());
    let mut acc = 0.0f32;
    for (&xv, &qv) in x.iter().zip(q) {
        acc += xv * qv as f32;
    }
    acc
}

/// Register-blocked integer micro-kernel (the **scalar oracle** of
/// [`crate::infer::simd::dot_block_u8`]): one `tn × rn` output block
/// (`tn <= 4` token rows × `rn <= 4` weight rows, [`super::plan::MR`]) per
/// call, with 16 independent i32 accumulators so the autovectorizer can
/// keep the whole block in registers.
///
/// * `a` — `tn` contiguous token-code rows (`tn * k` bytes, row-major);
/// * `wt` — one lane-padded row-major weight tile (the
///   [`super::plan::TilePlan`] layout): weight row `r` is
///   `wt[r*stride .. r*stride + k]`, `stride >= k` a multiple of
///   [`crate::infer::simd::LANE`] so vector loads land on lane boundaries;
/// * `acc[t * 4 + r]` — dot of token row `t` against weight row `r`.
///
/// Integer accumulation is exact, so any tiling of the same codes produces
/// identical results; the i32 bound is the same [`MAX_DOT_K`] contract as
/// [`dot_u8`].
#[inline]
pub fn dot_block_u8_scalar(a: &[u8], k: usize, tn: usize, wt: &[u8],
                           stride: usize, rn: usize, acc: &mut [i32; 16]) {
    debug_assert!((1..=4).contains(&tn) && (1..=4).contains(&rn));
    debug_assert!(stride >= k);
    debug_assert!(a.len() >= tn * k);
    debug_assert!(wt.len() >= (rn - 1) * stride + k);
    acc.fill(0);
    if tn == 4 && rn == 4 {
        let (a0, rest) = a.split_at(k);
        let (a1, rest) = rest.split_at(k);
        let (a2, a3) = rest.split_at(k);
        let w0 = &wt[..k];
        let w1 = &wt[stride..stride + k];
        let w2 = &wt[2 * stride..2 * stride + k];
        let w3 = &wt[3 * stride..3 * stride + k];
        for c in 0..k {
            let w0c = w0[c] as i32;
            let w1c = w1[c] as i32;
            let w2c = w2[c] as i32;
            let w3c = w3[c] as i32;
            let x0 = a0[c] as i32;
            acc[0] += x0 * w0c;
            acc[1] += x0 * w1c;
            acc[2] += x0 * w2c;
            acc[3] += x0 * w3c;
            let x1 = a1[c] as i32;
            acc[4] += x1 * w0c;
            acc[5] += x1 * w1c;
            acc[6] += x1 * w2c;
            acc[7] += x1 * w3c;
            let x2 = a2[c] as i32;
            acc[8] += x2 * w0c;
            acc[9] += x2 * w1c;
            acc[10] += x2 * w2c;
            acc[11] += x2 * w3c;
            let x3 = a3[c] as i32;
            acc[12] += x3 * w0c;
            acc[13] += x3 * w1c;
            acc[14] += x3 * w2c;
            acc[15] += x3 * w3c;
        }
    } else if tn == 1 && rn == 4 {
        // single-token fast path: the shape of every decode step
        let w0 = &wt[..k];
        let w1 = &wt[stride..stride + k];
        let w2 = &wt[2 * stride..2 * stride + k];
        let w3 = &wt[3 * stride..3 * stride + k];
        for c in 0..k {
            let x0 = a[c] as i32;
            acc[0] += x0 * w0[c] as i32;
            acc[1] += x0 * w1[c] as i32;
            acc[2] += x0 * w2[c] as i32;
            acc[3] += x0 * w3[c] as i32;
        }
    } else {
        // ragged edge (tail tile rows / tail token rows); integer dots are
        // exact, so delegating per (t, r) keeps the same results
        for t in 0..tn {
            let arow = &a[t * k..(t + 1) * k];
            for r in 0..rn {
                acc[t * 4 + r] =
                    dot_u8(arow, &wt[r * stride..r * stride + k]);
            }
        }
    }
}

/// Weight-only twin of [`dot_block_u8_scalar`]: FP token rows ×
/// lane-padded row-major integer weight tile, 16 independent f32
/// accumulators. Each output element is one **sequential** accumulation
/// chain over the inner dim — the exact per-element op order of
/// [`dot_f32_u8`], keeping planned and reference weight-only outputs
/// bit-identical. Like [`dot_f32_u8`], this kernel is deliberately never
/// vectorized (see the reassociation contract there).
#[inline]
pub fn dot_block_f32_u8_scalar(x: &[f32], k: usize, tn: usize, wt: &[u8],
                               stride: usize, rn: usize,
                               acc: &mut [f32; 16]) {
    debug_assert!((1..=4).contains(&tn) && (1..=4).contains(&rn));
    debug_assert!(stride >= k);
    debug_assert!(x.len() >= tn * k);
    debug_assert!(wt.len() >= (rn - 1) * stride + k);
    acc.fill(0.0);
    if tn == 4 && rn == 4 {
        let (x0, rest) = x.split_at(k);
        let (x1, rest) = rest.split_at(k);
        let (x2, x3) = rest.split_at(k);
        let w0 = &wt[..k];
        let w1 = &wt[stride..stride + k];
        let w2 = &wt[2 * stride..2 * stride + k];
        let w3 = &wt[3 * stride..3 * stride + k];
        for c in 0..k {
            let w0c = w0[c] as f32;
            let w1c = w1[c] as f32;
            let w2c = w2[c] as f32;
            let w3c = w3[c] as f32;
            let v0 = x0[c];
            acc[0] += v0 * w0c;
            acc[1] += v0 * w1c;
            acc[2] += v0 * w2c;
            acc[3] += v0 * w3c;
            let v1 = x1[c];
            acc[4] += v1 * w0c;
            acc[5] += v1 * w1c;
            acc[6] += v1 * w2c;
            acc[7] += v1 * w3c;
            let v2 = x2[c];
            acc[8] += v2 * w0c;
            acc[9] += v2 * w1c;
            acc[10] += v2 * w2c;
            acc[11] += v2 * w3c;
            let v3 = x3[c];
            acc[12] += v3 * w0c;
            acc[13] += v3 * w1c;
            acc[14] += v3 * w2c;
            acc[15] += v3 * w3c;
        }
    } else if tn == 1 && rn == 4 {
        // single-token fast path: the shape of every decode step
        let w0 = &wt[..k];
        let w1 = &wt[stride..stride + k];
        let w2 = &wt[2 * stride..2 * stride + k];
        let w3 = &wt[3 * stride..3 * stride + k];
        for c in 0..k {
            let v0 = x[c];
            acc[0] += v0 * w0[c] as f32;
            acc[1] += v0 * w1[c] as f32;
            acc[2] += v0 * w2[c] as f32;
            acc[3] += v0 * w3[c] as f32;
        }
    } else {
        // ragged edge: per-(t, r) sequential chains — the dot_f32_u8 order
        for t in 0..tn {
            let xrow = &x[t * k..(t + 1) * k];
            for r in 0..rn {
                acc[t * 4 + r] =
                    dot_f32_u8(xrow, &wt[r * stride..r * stride + k]);
            }
        }
    }
}

/// Fused unpack of weight rows `[r0, r0+n)` from an LSB-first packed
/// bitstream into `out[0..n*cols]` (u8 codes). This is the "unpack tile,
/// then matmul against it" half of the fused 3/4-bit kernels: tiles stay
/// small enough to live in L1 while every token row streams past them.
///
/// The stream layout is validated at [`crate::quant::PackedMatrix`]
/// construction; this only debug-checks.
pub fn unpack_rows(packed: &[u8], bits: u32, cols: usize, r0: usize, n: usize,
                   out: &mut [u8]) {
    debug_assert!(out.len() >= n * cols);
    debug_assert!(packed.len() >= packed_len((r0 + n) * cols, bits));
    crate::obs::registry::engine::BYTES_UNPACKED.add((n * cols) as u64);
    match bits {
        8 => {
            out[..n * cols]
                .copy_from_slice(&packed[r0 * cols..(r0 + n) * cols]);
        }
        4 if cols % 2 == 0 => {
            // rows are byte-aligned: expand two nibbles per byte
            let src = &packed[r0 * cols / 2..(r0 + n) * cols / 2];
            for (i, &b) in src.iter().enumerate() {
                out[2 * i] = b & 0x0F;
                out[2 * i + 1] = b >> 4;
            }
        }
        _ => {
            // generic bit cursor (3-bit rows start mid-byte)
            let mask = (1u32 << bits) - 1;
            let mut bitpos = r0 * cols * bits as usize;
            for o in out[..n * cols].iter_mut() {
                let byte = bitpos / 8;
                let off = (bitpos % 8) as u32;
                // splice up to 16 bits so any <=8-bit code is covered
                let lo = packed[byte] as u32;
                let hi = *packed.get(byte + 1).unwrap_or(&0) as u32;
                *o = (((lo | (hi << 8)) >> off) & mask) as u8;
                bitpos += bits as usize;
            }
        }
    }
}

/// Contiguous shard ranges splitting `n` rows across `shards` workers.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, n.max(1));
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for i in 0..shards {
        let len = base + usize::from(i < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Validate an inner dimension against the i32 accumulator bound.
pub fn check_dot_k(k: usize) -> Result<()> {
    if k > MAX_DOT_K {
        bail!("inner dim {k} exceeds i32-safe u8 GEMM bound {MAX_DOT_K}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::act::per_token_quant;
    use crate::quant::pack::pack_bits;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn per_token_codes_dequant_to_oracle() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[6, 40], 1.3);
        for qmax in [255.0f32, 15.0] {
            let qa = quantize_acts_per_token(&x.data, 6, 40, qmax);
            let oracle = per_token_quant(&x, qmax);
            for r in 0..6 {
                for c in 0..40 {
                    let deq = (qa.codes[r * 40 + c] as f32 - qa.zp[r] as f32)
                        * qa.scale[r];
                    let want = oracle.data[r * 40 + c];
                    assert!((deq - want).abs() < 1e-6,
                            "qmax {qmax} r{r} c{c}: {deq} vs {want}");
                }
            }
        }
    }

    #[test]
    fn static_codes_dequant_to_oracle() {
        use crate::quant::act::{per_tensor_quant, ActRange};
        let mut rng = Rng::new(7);
        let x = Tensor::randn(&mut rng, &[4, 24], 1.1);
        let mut r = ActRange::default();
        r.update(x.min(), x.max());
        let (s, z) = r.grid(255.0);
        let qa = quantize_acts_static(&x.data, 4, 24, s, z, 255.0);
        let oracle = per_tensor_quant(&x, s, z, 255.0);
        for (i, &want) in oracle.data.iter().enumerate() {
            let row = i / 24;
            let deq = (qa.codes[i] as f32 - qa.zp[row] as f32)
                * qa.scale[row];
            assert!((deq - want).abs() < 1e-6, "i{i}: {deq} vs {want}");
        }
    }

    #[test]
    fn code_sums_consistent() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[3, 17], 0.7);
        let qa = quantize_acts_per_token(&x.data, 3, 17, 255.0);
        for r in 0..3 {
            let s: i64 = qa.codes[r * 17..(r + 1) * 17]
                .iter()
                .map(|&c| c as i64)
                .sum();
            assert_eq!(s, qa.code_sum[r]);
        }
    }

    #[test]
    fn dots_match_naive() {
        let mut rng = Rng::new(3);
        for k in [1usize, 3, 4, 7, 64, 129] {
            let a: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let want: i32 = a.iter().zip(&b)
                .map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_u8(&a, &b), want);
            let xf: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            let wantf: f32 = xf.iter().zip(&b)
                .map(|(&x, &y)| x * y as f32).sum();
            let tol = wantf.abs() * 1e-5 + 1e-2;
            assert!((dot_f32_u8(&xf, &b) - wantf).abs() < tol);
        }
    }

    #[test]
    fn block_dots_match_scalar_dots() {
        let mut rng = Rng::new(8);
        for k in [1usize, 3, 4, 17, 64, 130] {
            // 4 token rows of codes + FP rows, one lane-padded 4-row tile;
            // exercise both a tight stride (== k) and a padded one
            let a: Vec<u8> =
                (0..4 * k).map(|_| rng.below(256) as u8).collect();
            let xf: Vec<f32> = (0..4 * k).map(|_| rng.normal()).collect();
            let wrows: Vec<Vec<u8>> = (0..4)
                .map(|_| (0..k).map(|_| rng.below(256) as u8).collect())
                .collect();
            for stride in [k, k.div_ceil(16) * 16] {
                for rn in 1..=4usize {
                    // row-major rows at r*stride, zero-padded tails
                    let mut wt = vec![0u8; rn * stride];
                    for (r, wr) in wrows.iter().take(rn).enumerate() {
                        wt[r * stride..r * stride + k]
                            .copy_from_slice(wr);
                    }
                    for tn in 1..=4usize {
                        let mut acc = [0i32; 16];
                        dot_block_u8_scalar(&a[..tn * k], k, tn, &wt,
                                            stride, rn, &mut acc);
                        let mut facc = [0.0f32; 16];
                        dot_block_f32_u8_scalar(&xf[..tn * k], k, tn, &wt,
                                                stride, rn, &mut facc);
                        for t in 0..tn {
                            for (r, wr) in
                                wrows.iter().take(rn).enumerate()
                            {
                                let want =
                                    dot_u8(&a[t * k..(t + 1) * k], wr);
                                assert_eq!(
                                    acc[t * 4 + r], want,
                                    "k {k} s {stride} tn {tn} rn {rn}");
                                // identical sequential op order ->
                                // bit-equal
                                let wantf =
                                    dot_f32_u8(&xf[t * k..(t + 1) * k],
                                               wr);
                                assert_eq!(
                                    facc[t * 4 + r], wantf,
                                    "fp k {k} s {stride} tn {tn} rn {rn}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sequential_f32_accumulation_is_load_bearing() {
        // Reassociation canary for the weight-only contract: summed left to
        // right, 1e8 + 1 rounds back to 1e8 (f32 ulp at 1e8 is 8), so the
        // sequential chain yields exactly 1.0. A pairwise/lane split that
        // groups (1e8 - 1e8) + (1 + 1) yields 2.0 — this test fails the
        // moment anyone vectorizes dot_f32_u8 or changes its order.
        let x = [1.0e8f32, 1.0, -1.0e8, 1.0];
        let q = [1u8, 1, 1, 1];
        assert_eq!(dot_f32_u8(&x, &q), 1.0);
        let mut acc = [0.0f32; 16];
        for stride in [4usize, 16] {
            let mut wt = vec![0u8; stride];
            wt[..4].copy_from_slice(&q);
            dot_block_f32_u8_scalar(&x, 4, 1, &wt, stride, 1, &mut acc);
            assert_eq!(acc[0], 1.0, "stride {stride}");
        }
        // the reassociated grouping really is different — the canary bites
        let pairwise = (x[0] + x[2]) + (x[1] + x[3]);
        assert_eq!(pairwise, 2.0);
    }

    #[test]
    fn unpack_rows_matches_bitstream() {
        let mut rng = Rng::new(4);
        for bits in [3u32, 4, 8] {
            for cols in [5usize, 8, 33] {
                let rows = 9;
                let codes: Vec<u32> = (0..rows * cols)
                    .map(|_| rng.below(1 << bits) as u32)
                    .collect();
                let packed = pack_bits(&codes, bits);
                let mut tile = vec![0u8; 4 * cols];
                for r0 in [0usize, 1, 5] {
                    let n = 4.min(rows - r0);
                    unpack_rows(&packed, bits, cols, r0, n, &mut tile);
                    for i in 0..n * cols {
                        assert_eq!(tile[i] as u32, codes[r0 * cols + i],
                                   "bits {bits} cols {cols} r0 {r0} i {i}");
                    }
                }
            }
        }
    }

    #[test]
    fn shard_ranges_cover() {
        for (n, s) in [(10usize, 3usize), (7, 7), (5, 9), (352, 4), (1, 1)] {
            let r = shard_ranges(n, s);
            assert_eq!(r.first().unwrap().0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    #[test]
    fn shard_ranges_empty_input_is_single_empty_range() {
        // n = 0 must not panic or emit shards < 1 — the empty-batch guard
        // upstream never executes, but the primitive stays total
        for s in [1usize, 4, 9] {
            assert_eq!(shard_ranges(0, s), vec![(0, 0)]);
        }
    }
}
