//! [`QuantLinear`]: one packed linear layer executed natively.
//!
//! Two execution paths, both cache-blocked over weight-row tiles that are
//! unpacked on the fly (the fused unpack-then-matmul of the 3/4-bit formats;
//! 8-bit tiles are a straight copy):
//!
//! * **integer path** (`forward_q`): quantized activations × quantized
//!   weights with an exact-integer inner product and a per-channel dequant
//!   epilogue. With `x ≈ (a - z_a)·s_a` per token and `w = (q - z_w)·s_w`
//!   per output channel,
//!   `y[t,j] = s_a[t]·s_w[j]·(Σ a·q − z_a[t]·Σq_j − z_w[j]·Σa_t + K·z_a[t]·z_w[j])`
//!   — everything inside the parentheses is integer arithmetic, so the only
//!   difference from the fake-quant reference is f32 summation order.
//! * **weight-only path** (`forward_fp`): FP activations × integer weights,
//!   `y[t,j] = s_w[j]·(Σ x·q − z_w[j]·Σx_t)`.
//!
//! Row-sharded parallelism: output channels split into contiguous shards,
//! one scoped worker thread per shard (the engine is `Send`, unlike PJRT).

use anyhow::{bail, Result};

use crate::quant::PackedMatrix;
use crate::tensor::Tensor;

use super::kernels::{check_dot_k, dot_f32_u8, dot_u8, shard_ranges,
                     unpack_rows, QuantActs};

/// Weight rows unpacked per tile: 16 rows × Cin bytes stays L1-resident for
/// every model dimension this repo ships.
const ROW_TILE: usize = 16;

/// A packed linear layer ready for native execution (`y = x @ W.T`).
#[derive(Clone, Debug)]
pub struct QuantLinear {
    pub cout: usize,
    pub cin: usize,
    pub bits: u32,
    packed: Vec<u8>,
    pub scale: Vec<f32>,
    zp: Vec<i32>,
    /// per-output-row Σ codes (dequant epilogue correction)
    code_sum: Vec<i64>,
}

impl QuantLinear {
    /// Build from a packed checkpoint matrix (any quantization method).
    pub fn from_packed(pm: &PackedMatrix) -> Result<Self> {
        check_dot_k(pm.cols)?;
        let codes = pm.unpack();
        let mut zp = Vec::with_capacity(pm.rows);
        for (r, &z) in pm.zp.iter().enumerate() {
            if z < 0.0 || z > 255.0 || z.fract() != 0.0 {
                bail!("row {r}: zero-point {z} is not an integer in [0, 255]");
            }
            zp.push(z as i32);
        }
        let mut code_sum = vec![0i64; pm.rows];
        for r in 0..pm.rows {
            code_sum[r] = codes[r * pm.cols..(r + 1) * pm.cols]
                .iter()
                .map(|&c| c as i64)
                .sum();
        }
        Ok(QuantLinear {
            cout: pm.rows,
            cin: pm.cols,
            bits: pm.bits,
            packed: pm.packed.clone(),
            scale: pm.scale.clone(),
            zp,
            code_sum,
        })
    }

    /// Packed weight bytes (model-size accounting).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * 4 + self.zp.len() * 4
    }

    /// Integer path: quantized activations -> `[acts.rows, cout]`.
    pub fn forward_q(&self, acts: &QuantActs, shards: usize) -> Result<Tensor> {
        if acts.cols != self.cin {
            bail!("forward_q: act dim {} != Cin {}", acts.cols, self.cin);
        }
        self.run_sharded(acts.rows, shards, |j0, j1, chunk| {
            self.gemm_q_chunk(acts, j0, j1, chunk);
        })
    }

    /// Weight-only path: FP activations `[rows, cin]` -> `[rows, cout]`.
    pub fn forward_fp(&self, x: &[f32], rows: usize, shards: usize)
                      -> Result<Tensor> {
        if x.len() != rows * self.cin {
            bail!("forward_fp: x len {} != {rows}x{}", x.len(), self.cin);
        }
        let xsum: Vec<f32> = (0..rows)
            .map(|t| x[t * self.cin..(t + 1) * self.cin].iter().sum())
            .collect();
        self.run_sharded(rows, shards, |j0, j1, chunk| {
            self.gemm_fp_chunk(x, rows, &xsum, j0, j1, chunk);
        })
    }

    /// Split output channels into shards, run `body(j0, j1, chunk)` per
    /// shard (scoped worker threads when `shards > 1`), stitch `[rows, cout]`.
    fn run_sharded<F>(&self, rows: usize, shards: usize, body: F)
                      -> Result<Tensor>
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        let ranges = shard_ranges(self.cout, shards);
        if ranges.len() == 1 {
            let mut out = vec![0.0f32; rows * self.cout];
            body(0, self.cout, &mut out);
            return Ok(Tensor::new(vec![rows, self.cout], out));
        }
        let chunks: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    let body = &body;
                    s.spawn(move || {
                        let mut chunk = vec![0.0f32; rows * (j1 - j0)];
                        body(j0, j1, &mut chunk);
                        chunk
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // stitch column blocks back into row-major [rows, cout]
        let mut out = vec![0.0f32; rows * self.cout];
        for (&(j0, j1), chunk) in ranges.iter().zip(&chunks) {
            let jw = j1 - j0;
            for t in 0..rows {
                out[t * self.cout + j0..t * self.cout + j1]
                    .copy_from_slice(&chunk[t * jw..(t + 1) * jw]);
            }
        }
        Ok(Tensor::new(vec![rows, self.cout], out))
    }

    /// Integer GEMM over output channels `[j0, j1)` into a `[rows, j1-j0]`
    /// chunk.
    fn gemm_q_chunk(&self, acts: &QuantActs, j0: usize, j1: usize,
                    chunk: &mut [f32]) {
        let k = self.cin;
        let jw = j1 - j0;
        let kk = k as i64;
        let mut tile = vec![0u8; ROW_TILE * k];
        let mut jt = j0;
        while jt < j1 {
            let jn = ROW_TILE.min(j1 - jt);
            unpack_rows(&self.packed, self.bits, k, jt, jn, &mut tile);
            for t in 0..acts.rows {
                let arow = &acts.codes[t * k..(t + 1) * k];
                let sa = acts.scale[t];
                let za = acts.zp[t] as i64;
                let asum = acts.code_sum[t];
                let orow = &mut chunk[t * jw..(t + 1) * jw];
                for jj in 0..jn {
                    let j = jt + jj;
                    let q = &tile[jj * k..(jj + 1) * k];
                    let dot = dot_u8(arow, q) as i64;
                    let zw = self.zp[j] as i64;
                    let corr =
                        dot - za * self.code_sum[j] - zw * asum + kk * za * zw;
                    orow[j - j0] = sa * self.scale[j] * corr as f32;
                }
            }
            jt += jn;
        }
    }

    /// Weight-only GEMM over output channels `[j0, j1)`.
    fn gemm_fp_chunk(&self, x: &[f32], rows: usize, xsum: &[f32], j0: usize,
                     j1: usize, chunk: &mut [f32]) {
        let k = self.cin;
        let jw = j1 - j0;
        let mut tile = vec![0u8; ROW_TILE * k];
        let mut jt = j0;
        while jt < j1 {
            let jn = ROW_TILE.min(j1 - jt);
            unpack_rows(&self.packed, self.bits, k, jt, jn, &mut tile);
            for t in 0..rows {
                let xrow = &x[t * k..(t + 1) * k];
                let orow = &mut chunk[t * jw..(t + 1) * jw];
                for jj in 0..jn {
                    let j = jt + jj;
                    let q = &tile[jj * k..(jj + 1) * k];
                    let acc = dot_f32_u8(xrow, q);
                    orow[j - j0] =
                        self.scale[j] * (acc - self.zp[j] as f32 * xsum[t]);
                }
            }
            jt += jn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::kernels::quantize_acts_per_token;
    use crate::quant::{self, grid::rtn_grid, lrq::quantize_int_codes};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn packed(rng: &mut Rng, cout: usize, cin: usize, bits: u32)
              -> (Tensor, PackedMatrix) {
        let w = Tensor::randn(rng, &[cout, cin], 0.08);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quantize_int_codes(&w, &g, None);
        let pm =
            PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits).unwrap();
        (w, pm)
    }

    fn rel_rmse(a: &Tensor, b: &Tensor) -> f64 {
        a.rmse(b) / (b.frob() / (b.len() as f64).sqrt()).max(1e-12)
    }

    #[test]
    fn integer_path_matches_dequant_reference() {
        let mut rng = Rng::new(11);
        for bits in [3u32, 4, 8] {
            let (_, pm) = packed(&mut rng, 23, 36, bits);
            let ql = QuantLinear::from_packed(&pm).unwrap();
            let x = Tensor::randn(&mut rng, &[9, 36], 1.0);
            let qa = quantize_acts_per_token(&x.data, 9, 36, 255.0);
            let got = ql.forward_q(&qa, 1).unwrap();
            // reference: fake-quant acts (dequantized codes) × dequant W
            let mut xq = vec![0.0f32; 9 * 36];
            for t in 0..9 {
                for c in 0..36 {
                    xq[t * 36 + c] = (qa.codes[t * 36 + c] as f32
                        - qa.zp[t] as f32) * qa.scale[t];
                }
            }
            let want =
                Tensor::new(vec![9, 36], xq).matmul_bt(&pm.dequant());
            assert!(rel_rmse(&got, &want) < 1e-5,
                    "bits {bits}: {}", rel_rmse(&got, &want));
        }
    }

    #[test]
    fn weight_only_path_matches_dequant_reference() {
        let mut rng = Rng::new(12);
        for bits in [3u32, 4, 8] {
            let (_, pm) = packed(&mut rng, 17, 29, bits);
            let ql = QuantLinear::from_packed(&pm).unwrap();
            let x = Tensor::randn(&mut rng, &[7, 29], 1.0);
            let got = ql.forward_fp(&x.data, 7, 1).unwrap();
            let want = x.matmul_bt(&pm.dequant());
            assert!(rel_rmse(&got, &want) < 1e-4,
                    "bits {bits}: {}", rel_rmse(&got, &want));
        }
    }

    #[test]
    fn sharding_is_invariant() {
        let mut rng = Rng::new(13);
        let (_, pm) = packed(&mut rng, 40, 24, 4);
        let ql = QuantLinear::from_packed(&pm).unwrap();
        let x = Tensor::randn(&mut rng, &[5, 24], 1.0);
        let qa = quantize_acts_per_token(&x.data, 5, 24, 255.0);
        let one = ql.forward_q(&qa, 1).unwrap();
        for shards in [2usize, 3, 7, 64] {
            let many = ql.forward_q(&qa, shards).unwrap();
            // same per-element arithmetic, only the thread changes
            assert_eq!(one, many, "shards {shards}");
        }
        let fone = ql.forward_fp(&x.data, 5, 1).unwrap();
        let fmany = ql.forward_fp(&x.data, 5, 3).unwrap();
        assert_eq!(fone, fmany);
    }

    #[test]
    fn rejects_mismatched_dims() {
        let mut rng = Rng::new(14);
        let (_, pm) = packed(&mut rng, 8, 16, 8);
        let ql = QuantLinear::from_packed(&pm).unwrap();
        let x = Tensor::randn(&mut rng, &[2, 12], 1.0);
        assert!(ql.forward_fp(&x.data, 2, 1).is_err());
        let qa = quantize_acts_per_token(&x.data, 2, 12, 255.0);
        assert!(ql.forward_q(&qa, 1).is_err());
    }
}
