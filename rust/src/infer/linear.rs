//! [`QuantLinear`]: one packed linear layer executed natively.
//!
//! Two execution paths, both computing `y = x @ W.T` with identical
//! per-element arithmetic (proved bit-exact in `tests/native.rs`):
//!
//! * **planned** ([`ExecMode::Planned`], the serving path) — weights were
//!   repacked once at load into a lane-padded [`TilePlan`]; the integer
//!   GEMM streams those tiles through the runtime-dispatched micro-kernels
//!   ([`crate::infer::simd`]: AVX2/SSE2/scalar, chosen per engine via
//!   `Exec::backend`) with **zero per-call unpack**, sharded over weight
//!   tiles across the persistent [`WorkerPool`], every shard writing its
//!   output columns straight into the final `[rows, cout]` buffer (no
//!   stitch copy). The weight-only GEMM always runs the scalar kernel —
//!   its sequential f32 order is a bit-exactness contract.
//! * **reference** ([`ExecMode::Reference`], the pre-plan engine) — single
//!   threaded, unpacks `ROW_TILE` weight rows from the packed bitstream per
//!   tile per call, scalar dots. Kept as the bit-exact oracle and the
//!   baseline of the bench's speedup comparison.
//!
//! Dequant epilogues (identical formulas in both paths):
//!
//! * **integer path** (`forward_q`): with `x ≈ (a - z_a)·s_a` per token and
//!   `w = (q - z_w)·s_w` per output channel,
//!   `y[t,j] = s_a[t]·s_w[j]·(Σ a·q − z_a[t]·Σq_j − z_w[j]·Σa_t + K·z_a[t]·z_w[j])`
//!   — everything inside the parentheses is integer arithmetic.
//! * **weight-only path** (`forward_fp`): FP activations × integer weights,
//!   `y[t,j] = s_w[j]·(Σ x·q − z_w[j]·Σx_t)`, with `Σx_t` computed once per
//!   call into the scratch arena.

use anyhow::{bail, Result};

use crate::obs::registry::engine;
use crate::obs::{trace, KernelKind};
use crate::quant::PackedMatrix;
use crate::tensor::Tensor;

use super::kernels::{check_dot_k, dot_block_f32_u8_scalar, dot_f32_u8,
                     dot_u8, shard_ranges, unpack_rows, QuantActs};
use super::plan::{Exec, ExecMode, TilePlan, MR};
use super::pool::{JobPanicked, OutSlice, WorkerPool};
use super::simd::{self, Backend};

/// Reference-path tile height: 16 rows × Cin bytes stays L1-resident for
/// every model dimension this repo ships.
const ROW_TILE: usize = 16;

/// A packed linear layer ready for native execution (`y = x @ W.T`).
#[derive(Clone, Debug)]
pub struct QuantLinear {
    pub cout: usize,
    pub cin: usize,
    pub bits: u32,
    /// original packed bitstream (checkpoint bytes; reference path input)
    packed: Vec<u8>,
    /// load-time interleaved repack (planned path input)
    plan: TilePlan,
    pub scale: Vec<f32>,
    zp: Vec<i32>,
    /// per-output-row Σ codes (dequant epilogue correction), computed
    /// streaming during the plan repack
    code_sum: Vec<i64>,
}

impl QuantLinear {
    /// Build from a packed checkpoint matrix (any quantization method).
    /// Unpacks the bitstream exactly once — into the interleaved tile plan,
    /// accumulating the epilogue code sums in the same streaming pass.
    pub fn from_packed(pm: &PackedMatrix) -> Result<Self> {
        check_dot_k(pm.cols)?;
        let mut zp = Vec::with_capacity(pm.rows);
        for (r, &z) in pm.zp.iter().enumerate() {
            if z < 0.0 || z > 255.0 || z.fract() != 0.0 {
                bail!("row {r}: zero-point {z} is not an integer in [0, 255]");
            }
            zp.push(z as i32);
        }
        let (plan, code_sum) = TilePlan::from_packed(pm);
        Ok(QuantLinear {
            cout: pm.rows,
            cin: pm.cols,
            bits: pm.bits,
            packed: pm.packed.clone(),
            plan,
            scale: pm.scale.clone(),
            zp,
            code_sum,
        })
    }

    /// Packed weight bytes (model-size accounting — the checkpoint
    /// representation, not the in-memory execution plan).
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * 4 + self.zp.len() * 4
    }

    /// In-memory bytes of the load-time execution plan (one u8 per code).
    pub fn plan_bytes(&self) -> usize {
        self.plan.plan_bytes()
    }

    /// Integer path: quantized activations -> `[acts.rows, cout]`.
    pub fn forward_q(&self, acts: &QuantActs, exec: &mut Exec)
                     -> Result<Tensor> {
        if acts.cols != self.cin {
            bail!("forward_q: act dim {} != Cin {}", acts.cols, self.cin);
        }
        let rows = acts.rows;
        let mut out = exec.scratch.zeroed(rows * self.cout);
        let (p0, s0) = (exec.prof.t0(), trace::begin());
        let backend = exec.backend;
        let mut pool_err: Option<JobPanicked> = None;
        match exec.mode {
            ExecMode::Planned => {
                pool_err = self.run_planned(exec.pool, &mut out, &|t0, t1, o| {
                    self.gemm_q_tiles(backend, acts, t0, t1, o);
                }).err();
            }
            ExecMode::Reference => self.gemm_q_ref(acts, &mut out),
        }
        self.tally_gemm(exec, rows, p0);
        trace::complete(s0, || {
            (format!("gemm{}x{}", self.cout, self.cin),
             Some(format!("{{\"rows\":{rows}}}")))
        });
        if let Some(e) = pool_err {
            // supervision (DESIGN.md §13): a panicked GEMM shard fails this
            // batch with an error the serving layer turns into per-request
            // rejections — it never unwinds through the engine
            bail!("gemm {}x{}: {e}; batch discarded", self.cout, self.cin);
        }
        Ok(Tensor::new(vec![rows, self.cout], out))
    }

    /// GEMM accounting shared by both forward flavors: wall time at the
    /// call site (includes the pool barrier — the true GEMM cost the caller
    /// pays), tile×block passes, and plan bytes streamed.
    fn tally_gemm(&self, exec: &mut Exec, rows: usize,
                  p0: Option<std::time::Instant>) {
        let passes = (self.plan.n_tiles() * rows.div_ceil(MR)) as u64;
        let bytes = (self.plan.plan_bytes() * rows.div_ceil(MR)) as u64;
        if exec.mode == ExecMode::Planned {
            engine::TILES_EXECUTED.add(passes);
            engine::PLAN_BYTES_STREAMED.add(bytes);
        }
        exec.prof.rec(exec.layer, KernelKind::Gemm, p0, passes, bytes);
    }

    /// Weight-only path: FP activations `[rows, cin]` -> `[rows, cout]`.
    pub fn forward_fp(&self, x: &[f32], rows: usize, exec: &mut Exec)
                      -> Result<Tensor> {
        if x.len() != rows * self.cin {
            bail!("forward_fp: x len {} != {rows}x{}", x.len(), self.cin);
        }
        // per-token Σx in the scratch arena: single-row decode steps
        // allocate nothing here in steady state
        let mut xsum = exec.scratch.zeroed(rows);
        for (t, o) in xsum.iter_mut().enumerate() {
            *o = x[t * self.cin..(t + 1) * self.cin].iter().sum();
        }
        let mut out = exec.scratch.zeroed(rows * self.cout);
        let (p0, s0) = (exec.prof.t0(), trace::begin());
        let mut pool_err: Option<JobPanicked> = None;
        match exec.mode {
            ExecMode::Planned => {
                pool_err = self.run_planned(exec.pool, &mut out, &|t0, t1, o| {
                    self.gemm_fp_tiles(x, rows, &xsum, t0, t1, o);
                }).err();
            }
            ExecMode::Reference => self.gemm_fp_ref(x, rows, &xsum, &mut out),
        }
        self.tally_gemm(exec, rows, p0);
        trace::complete(s0, || {
            (format!("gemm_fp{}x{}", self.cout, self.cin),
             Some(format!("{{\"rows\":{rows}}}")))
        });
        exec.scratch.put(xsum);
        if let Some(e) = pool_err {
            // see forward_q: fail the batch, keep the engine thread alive
            bail!("gemm_fp {}x{}: {e}; batch discarded", self.cout, self.cin);
        }
        Ok(Tensor::new(vec![rows, self.cout], out))
    }

    /// Shard the tile range across the persistent pool; every shard writes
    /// its (disjoint) output columns directly into `out`. A panicking shard
    /// (pooled or inline) is reported as `Err` — the engine thread never
    /// unwinds through a GEMM.
    fn run_planned(&self, pool: &WorkerPool, out: &mut [f32],
                   body: &(dyn Fn(usize, usize, OutSlice) + Sync))
                   -> Result<(), JobPanicked> {
        let tiles = self.plan.n_tiles();
        let o = OutSlice::new(out);
        let shards = pool.threads().min(tiles).max(1);
        if shards <= 1 {
            return match std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| body(0, tiles, o))) {
                Ok(()) => Ok(()),
                Err(_) => Err(JobPanicked),
            };
        }
        let ranges = shard_ranges(tiles, shards);
        pool.run(ranges.len(), |i| {
            let (t0, t1) = ranges[i];
            // worker-thread shard spans cost a probe per job, so they are
            // compiled in only under the `obs-trace` feature
            #[cfg(feature = "obs-trace")]
            let sp = trace::begin();
            body(t0, t1, o);
            #[cfg(feature = "obs-trace")]
            trace::complete(sp, || (format!("shard[{t0},{t1})"), None));
        })
    }

    /// Planned integer GEMM over weight tiles `[t0, t1)`: streams
    /// lane-padded tile rows through the runtime-dispatched micro-kernel
    /// (`backend` — AVX2/SSE2/scalar oracle, all bit-equal since integer
    /// accumulation is exact) — zero unpack, 16 live accumulators — and
    /// applies the dequant epilogue into the shard's output columns.
    fn gemm_q_tiles(&self, backend: Backend, acts: &QuantActs, t0: usize,
                    t1: usize, out: OutSlice) {
        let k = self.cin;
        let kk = k as i64;
        let rows = acts.rows;
        let stride = self.plan.stride();
        let mut acc = [0i32; 16];
        for t in t0..t1 {
            let (wt, rn) = self.plan.tile(t);
            let j0 = t * MR;
            let wsc = &self.scale[j0..j0 + rn];
            let wzp = &self.zp[j0..j0 + rn];
            let wsum = &self.code_sum[j0..j0 + rn];
            let mut tb = 0usize;
            while tb < rows {
                let tn = MR.min(rows - tb);
                simd::dot_block_u8(backend,
                                   &acts.codes[tb * k..(tb + tn) * k], k,
                                   tn, wt, stride, rn, &mut acc);
                for tt in 0..tn {
                    let row = tb + tt;
                    let sa = acts.scale[row];
                    let za = acts.zp[row] as i64;
                    let asum = acts.code_sum[row];
                    // SAFETY: this shard owns output columns [j0, j0+rn) —
                    // tile ranges are disjoint across shards — and
                    // row*cout + j0 + rn <= rows*cout.
                    let orow =
                        unsafe { out.slice(row * self.cout + j0, rn) };
                    for rr in 0..rn {
                        let zw = wzp[rr] as i64;
                        let corr = acc[tt * 4 + rr] as i64 - za * wsum[rr]
                            - zw * asum
                            + kk * za * zw;
                        orow[rr] = sa * wsc[rr] * corr as f32;
                    }
                }
                tb += tn;
            }
        }
    }

    /// Planned weight-only GEMM over weight tiles `[t0, t1)`. Stays on the
    /// scalar kernel on every backend: its sequential f32 accumulation
    /// order is the bit-exactness contract with `ExecMode::Reference`
    /// (see `dot_f32_u8`), and SIMD would reassociate it.
    fn gemm_fp_tiles(&self, x: &[f32], rows: usize, xsum: &[f32], t0: usize,
                     t1: usize, out: OutSlice) {
        let k = self.cin;
        let stride = self.plan.stride();
        let mut acc = [0.0f32; 16];
        for t in t0..t1 {
            let (wt, rn) = self.plan.tile(t);
            let j0 = t * MR;
            let wsc = &self.scale[j0..j0 + rn];
            let wzp = &self.zp[j0..j0 + rn];
            let mut tb = 0usize;
            while tb < rows {
                let tn = MR.min(rows - tb);
                dot_block_f32_u8_scalar(&x[tb * k..(tb + tn) * k], k, tn,
                                        wt, stride, rn, &mut acc);
                for tt in 0..tn {
                    let row = tb + tt;
                    // SAFETY: disjoint columns per shard, in bounds (as in
                    // `gemm_q_tiles`).
                    let orow =
                        unsafe { out.slice(row * self.cout + j0, rn) };
                    for rr in 0..rn {
                        orow[rr] = wsc[rr]
                            * (acc[tt * 4 + rr]
                               - wzp[rr] as f32 * xsum[row]);
                    }
                }
                tb += tn;
            }
        }
    }

    /// Reference integer GEMM (the pre-plan engine): unpack `ROW_TILE`
    /// weight rows from the packed bitstream per tile **per call**, scalar
    /// dots, single thread. Identical per-element arithmetic to
    /// [`QuantLinear::gemm_q_tiles`].
    fn gemm_q_ref(&self, acts: &QuantActs, out: &mut [f32]) {
        let k = self.cin;
        let kk = k as i64;
        let mut tile = vec![0u8; ROW_TILE * k];
        let mut jt = 0usize;
        while jt < self.cout {
            let jn = ROW_TILE.min(self.cout - jt);
            unpack_rows(&self.packed, self.bits, k, jt, jn, &mut tile);
            for t in 0..acts.rows {
                let arow = &acts.codes[t * k..(t + 1) * k];
                let sa = acts.scale[t];
                let za = acts.zp[t] as i64;
                let asum = acts.code_sum[t];
                let orow = &mut out[t * self.cout + jt..t * self.cout + jt
                                    + jn];
                for jj in 0..jn {
                    let j = jt + jj;
                    let q = &tile[jj * k..(jj + 1) * k];
                    let dot = dot_u8(arow, q) as i64;
                    let zw = self.zp[j] as i64;
                    let corr =
                        dot - za * self.code_sum[j] - zw * asum + kk * za * zw;
                    orow[jj] = sa * self.scale[j] * corr as f32;
                }
            }
            jt += jn;
        }
    }

    /// Reference weight-only GEMM (the pre-plan engine).
    fn gemm_fp_ref(&self, x: &[f32], rows: usize, xsum: &[f32],
                   out: &mut [f32]) {
        let k = self.cin;
        let mut tile = vec![0u8; ROW_TILE * k];
        let mut jt = 0usize;
        while jt < self.cout {
            let jn = ROW_TILE.min(self.cout - jt);
            unpack_rows(&self.packed, self.bits, k, jt, jn, &mut tile);
            for t in 0..rows {
                let xrow = &x[t * k..(t + 1) * k];
                let orow = &mut out[t * self.cout + jt..t * self.cout + jt
                                    + jn];
                for jj in 0..jn {
                    let j = jt + jj;
                    let q = &tile[jj * k..(jj + 1) * k];
                    let acc = dot_f32_u8(xrow, q);
                    orow[jj] =
                        self.scale[j] * (acc - self.zp[j] as f32 * xsum[t]);
                }
            }
            jt += jn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::kernels::quantize_acts_per_token;
    use crate::infer::plan::ExecState;
    use crate::quant::{self, grid::rtn_grid, lrq::quantize_int_codes};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    fn packed(rng: &mut Rng, cout: usize, cin: usize, bits: u32)
              -> (Tensor, PackedMatrix) {
        let w = Tensor::randn(rng, &[cout, cin], 0.08);
        let g = rtn_grid(&w, quant::qmax(bits));
        let codes = quantize_int_codes(&w, &g, None);
        let pm =
            PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits).unwrap();
        (w, pm)
    }

    fn rel_rmse(a: &Tensor, b: &Tensor) -> f64 {
        a.rmse(b) / (b.frob() / (b.len() as f64).sqrt()).max(1e-12)
    }

    #[test]
    fn integer_path_matches_dequant_reference() {
        let mut rng = Rng::new(11);
        let mut ex = ExecState::new(1);
        for bits in [3u32, 4, 8] {
            let (_, pm) = packed(&mut rng, 23, 36, bits);
            let ql = QuantLinear::from_packed(&pm).unwrap();
            let x = Tensor::randn(&mut rng, &[9, 36], 1.0);
            let qa = quantize_acts_per_token(&x.data, 9, 36, 255.0);
            let got = ql.forward_q(&qa, &mut ex.exec()).unwrap();
            // reference: fake-quant acts (dequantized codes) × dequant W
            let mut xq = vec![0.0f32; 9 * 36];
            for t in 0..9 {
                for c in 0..36 {
                    xq[t * 36 + c] = (qa.codes[t * 36 + c] as f32
                        - qa.zp[t] as f32) * qa.scale[t];
                }
            }
            let want =
                Tensor::new(vec![9, 36], xq).matmul_bt(&pm.dequant());
            assert!(rel_rmse(&got, &want) < 1e-5,
                    "bits {bits}: {}", rel_rmse(&got, &want));
        }
    }

    #[test]
    fn weight_only_path_matches_dequant_reference() {
        let mut rng = Rng::new(12);
        let mut ex = ExecState::new(1);
        for bits in [3u32, 4, 8] {
            let (_, pm) = packed(&mut rng, 17, 29, bits);
            let ql = QuantLinear::from_packed(&pm).unwrap();
            let x = Tensor::randn(&mut rng, &[7, 29], 1.0);
            let got = ql.forward_fp(&x.data, 7, &mut ex.exec()).unwrap();
            let want = x.matmul_bt(&pm.dequant());
            assert!(rel_rmse(&got, &want) < 1e-4,
                    "bits {bits}: {}", rel_rmse(&got, &want));
        }
    }

    #[test]
    fn planned_path_is_bit_exact_vs_preplan_reference() {
        // same per-element arithmetic, only layout/threading changes: the
        // planned micro-kernel path must equal the per-call-unpack engine
        // bit for bit, for ragged tails included
        let mut rng = Rng::new(15);
        for bits in [3u32, 4, 8] {
            for (cout, cin) in [(23usize, 36usize), (4, 8), (3, 5),
                                (40, 24)] {
                let (_, pm) = packed(&mut rng, cout, cin, bits);
                let ql = QuantLinear::from_packed(&pm).unwrap();
                for rows in [1usize, 3, 5] {
                    let x = Tensor::randn(&mut rng, &[rows, cin], 1.0);
                    let qa =
                        quantize_acts_per_token(&x.data, rows, cin, 255.0);
                    let mut pl = ExecState::new(1);
                    let mut rf =
                        ExecState::new(1).with_mode(ExecMode::Reference);
                    let got = ql.forward_q(&qa, &mut pl.exec()).unwrap();
                    let want = ql.forward_q(&qa, &mut rf.exec()).unwrap();
                    assert_eq!(got, want,
                               "q bits {bits} {cout}x{cin} rows {rows}");
                    let gotf =
                        ql.forward_fp(&x.data, rows, &mut pl.exec()).unwrap();
                    let wantf =
                        ql.forward_fp(&x.data, rows, &mut rf.exec()).unwrap();
                    assert_eq!(gotf, wantf,
                               "fp bits {bits} {cout}x{cin} rows {rows}");
                }
            }
        }
    }

    #[test]
    fn sharding_is_invariant() {
        // pool-vs-single-thread bit-exactness across shard counts: sharding
        // only moves tiles across threads; per-element arithmetic (and the
        // column each shard writes) is identical
        let mut rng = Rng::new(13);
        let (_, pm) = packed(&mut rng, 40, 24, 4);
        let ql = QuantLinear::from_packed(&pm).unwrap();
        let x = Tensor::randn(&mut rng, &[5, 24], 1.0);
        let qa = quantize_acts_per_token(&x.data, 5, 24, 255.0);
        let mut one = ExecState::new(1);
        let q1 = ql.forward_q(&qa, &mut one.exec()).unwrap();
        let f1 = ql.forward_fp(&x.data, 5, &mut one.exec()).unwrap();
        for threads in [2usize, 3, 7, 16] {
            let mut many = ExecState::new(threads);
            let qn = ql.forward_q(&qa, &mut many.exec()).unwrap();
            assert_eq!(q1, qn, "threads {threads}");
            let fn_ = ql.forward_fp(&x.data, 5, &mut many.exec()).unwrap();
            assert_eq!(f1, fn_, "threads {threads}");
        }
    }

    #[test]
    fn rejects_mismatched_dims() {
        let mut rng = Rng::new(14);
        let mut ex = ExecState::new(1);
        let (_, pm) = packed(&mut rng, 8, 16, 8);
        let ql = QuantLinear::from_packed(&pm).unwrap();
        let x = Tensor::randn(&mut rng, &[2, 12], 1.0);
        assert!(ql.forward_fp(&x.data, 2, &mut ex.exec()).is_err());
        let qa = quantize_acts_per_token(&x.data, 2, 12, 255.0);
        assert!(ql.forward_q(&qa, &mut ex.exec()).is_err());
    }

    #[test]
    fn plan_bytes_accounting() {
        let mut rng = Rng::new(16);
        let (_, pm) = packed(&mut rng, 12, 20, 4);
        let ql = QuantLinear::from_packed(&pm).unwrap();
        // plan holds one byte per code per lane-padded row; storage stays
        // the packed stream
        let stride = 20usize.div_ceil(simd::LANE) * simd::LANE;
        assert_eq!(ql.plan_bytes(), 12 * stride);
        assert_eq!(ql.storage_bytes(), pm.storage_bytes());
    }

    #[test]
    fn forced_backends_are_bit_exact_at_the_linear_level() {
        // the per-instance kernel override: a scalar-pinned engine and a
        // vector-pinned engine produce identical bytes for both GEMM
        // flavors (integer accumulation is exact; the weight-only path is
        // scalar on every backend by contract)
        let mut rng = Rng::new(17);
        for bits in [3u32, 4, 8] {
            let (_, pm) = packed(&mut rng, 21, 37, bits);
            let ql = QuantLinear::from_packed(&pm).unwrap();
            let x = Tensor::randn(&mut rng, &[5, 37], 1.0);
            let qa = quantize_acts_per_token(&x.data, 5, 37, 255.0);
            let mut sc =
                ExecState::new(2).with_kernel(simd::Backend::Scalar);
            let qs = ql.forward_q(&qa, &mut sc.exec()).unwrap();
            let fs = ql.forward_fp(&x.data, 5, &mut sc.exec()).unwrap();
            for be in simd::backends() {
                let mut ex = ExecState::new(2).with_kernel(be);
                assert_eq!(ex.kernel(), be);
                let q = ql.forward_q(&qa, &mut ex.exec()).unwrap();
                assert_eq!(q, qs, "q bits {bits} {}", be.name());
                let f = ql.forward_fp(&x.data, 5, &mut ex.exec()).unwrap();
                assert_eq!(f, fs, "fp bits {bits} {}", be.name());
            }
        }
    }
}
