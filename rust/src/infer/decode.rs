//! Incremental decode: the per-sequence quantized KV cache.
//!
//! The serving payoff of a finalized `(s1, z, codes)` checkpoint (paper
//! Fig. 5, App. G/H) is that token-by-token generation only touches the new
//! token — everything already seen lives in a **quantized KV cache**. Each
//! appended K/V row is quantized post-RoPE with exactly the grid math of
//! [`crate::quant::act::per_token_quant`] (same `(hi-lo)/qmax` scale floor,
//! same rounded zero-point), so a cached row dequantizes bit-for-bit to the
//! value the full-context forward would have used, and
//! [`crate::infer::NativeModel::decode_step`] reproduces the full forward
//! token-for-token (proved in `tests/native.rs`).
//!
//! Storage per token per layer: `2·d` u8 codes + two `(scale, zp)` pairs —
//! the App. H memory story. Attention dequantizes head-slices on the fly
//! ("dequant-in-tile"): codes stay packed in the cache, only one `[head_dim]`
//! scratch row is materialized at a time. Sampling lives in
//! [`crate::rng::sample_top_k`], shared with the engine-agnostic batcher.

/// How K/V rows are stored for one sequence.
#[derive(Clone, Copy, Debug, PartialEq)]
enum KvMode {
    /// FP rows (scheme has `kv_quant: false`).
    Fp,
    /// u8 codes + per-token asymmetric grid (`kv_bits <= 8`).
    Codes(f32),
    /// Fake-quantized FP rows (`kv_bits > 8` cannot fit u8 codes; semantics
    /// stay identical to the reference path).
    FakeFp(f32),
}

/// One cached K or V stream: `[len, d]` rows in appended order.
#[derive(Clone, Debug, Default)]
struct KvTrack {
    /// `[len * d]` u8 codes (`Codes` mode)
    codes: Vec<u8>,
    /// per-token scale (`Codes` mode)
    scale: Vec<f32>,
    /// per-token zero-point, integral by construction (`Codes` mode)
    zp: Vec<f32>,
    /// `[len * d]` FP rows (`Fp` / `FakeFp` modes)
    fp: Vec<f32>,
}

impl KvTrack {
    fn push(&mut self, row: &[f32], mode: KvMode) {
        match mode {
            KvMode::Fp => self.fp.extend_from_slice(row),
            KvMode::Codes(qmax) => {
                let (scale, zp) = crate::quant::act::row_grid(row, qmax);
                self.scale.push(scale);
                self.zp.push(zp);
                for &v in row {
                    let q = crate::quant::act::quantize_code(v, scale, zp,
                                                             qmax);
                    self.codes.push(q as u8);
                }
            }
            KvMode::FakeFp(qmax) => {
                let (scale, zp) = crate::quant::act::row_grid(row, qmax);
                for &v in row {
                    let q = crate::quant::act::quantize_code(v, scale, zp,
                                                             qmax);
                    self.fp.push((q - zp) * scale);
                }
            }
        }
    }

    /// Dequantize `out.len()` features of token `t` starting at feature
    /// `off` (one head slice at a time — the cache itself stays packed).
    /// The `Codes` branch is the dequant epilogue of cached attention; it
    /// runs the vectorized [`crate::infer::simd::dequant`] (elementwise,
    /// bit-equal to the scalar form on every backend).
    fn read(&self, t: usize, off: usize, d: usize, mode: KvMode,
            backend: crate::infer::simd::Backend, out: &mut [f32]) {
        match mode {
            KvMode::Fp | KvMode::FakeFp(_) => {
                out.copy_from_slice(&self.fp[t * d + off..t * d + off
                                             + out.len()]);
            }
            KvMode::Codes(_) => {
                let (s, z) = (self.scale[t], self.zp[t]);
                let src = &self.codes[t * d + off..t * d + off + out.len()];
                crate::infer::simd::dequant_with(backend, src, s, z, out);
            }
        }
    }

    /// Grow capacity for `tokens` more rows in one reallocation (prefill
    /// knows the prompt length up front).
    fn reserve(&mut self, tokens: usize, d: usize, mode: KvMode) {
        match mode {
            KvMode::Fp | KvMode::FakeFp(_) => self.fp.reserve(tokens * d),
            KvMode::Codes(_) => {
                self.codes.reserve(tokens * d);
                self.scale.reserve(tokens);
                self.zp.reserve(tokens);
            }
        }
    }

    fn storage_bytes(&self) -> usize {
        self.codes.len() + (self.scale.len() + self.zp.len()) * 4
            + self.fp.len() * 4
    }
}

#[derive(Clone, Debug)]
struct LayerKv {
    len: usize,
    k: KvTrack,
    v: KvTrack,
}

/// Per-sequence KV cache: one `(K, V)` stream per layer, quantized per token
/// post-RoPE. Layers advance independently within one decode step (layer `l`
/// appends before layer `l+1` runs), so a token is "cached" once the last
/// layer has pushed it.
#[derive(Clone, Debug)]
pub struct KvCache {
    d: usize,
    mode: KvMode,
    layers: Vec<LayerKv>,
}

impl KvCache {
    /// `kv_quant: false` stores FP rows; otherwise u8 codes when
    /// `kv_bits <= 8`, fake-quantized FP rows above that (identical
    /// semantics, no packed win).
    pub fn new(layers: usize, d: usize, kv_quant: bool, kv_bits: u32)
               -> KvCache {
        let mode = if !kv_quant {
            KvMode::Fp
        } else if kv_bits <= 8 {
            KvMode::Codes(crate::quant::qmax(kv_bits))
        } else {
            KvMode::FakeFp(crate::quant::qmax(kv_bits))
        };
        KvCache {
            d,
            mode,
            layers: (0..layers)
                .map(|_| LayerKv { len: 0, k: KvTrack::default(),
                                   v: KvTrack::default() })
                .collect(),
        }
    }

    /// Feature dim of cached rows (`h * hd`).
    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Tokens fully appended (i.e. pushed through the *last* layer).
    pub fn len(&self) -> usize {
        self.layers.last().map(|l| l.len).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tokens appended at one layer — the next token's position there.
    pub fn layer_len(&self, layer: usize) -> usize {
        self.layers[layer].len
    }

    /// Whether rows are stored as u8 codes (vs FP).
    pub fn is_quantized(&self) -> bool {
        matches!(self.mode, KvMode::Codes(_))
    }

    /// Cache footprint in bytes (the App. H axis: u8 codes + grids vs 4-byte
    /// FP rows).
    pub fn storage_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.k.storage_bytes() + l.v.storage_bytes())
            .sum()
    }

    /// Pre-reserve capacity for `tokens` more cached tokens at every layer
    /// (the vectorized prefill calls this with the prompt length, so the
    /// cache grows with one reallocation per track instead of
    /// per-push doublings).
    pub fn reserve(&mut self, tokens: usize) {
        for lk in &mut self.layers {
            lk.k.reserve(tokens, self.d, self.mode);
            lk.v.reserve(tokens, self.d, self.mode);
        }
    }

    /// Append one post-RoPE `(k, v)` row pair (`[d]` each) at `layer`.
    pub fn push(&mut self, layer: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert_eq!(krow.len(), self.d);
        debug_assert_eq!(vrow.len(), self.d);
        let lk = &mut self.layers[layer];
        lk.k.push(krow, self.mode);
        lk.v.push(vrow, self.mode);
        lk.len += 1;
        crate::obs::registry::engine::KV_TOKENS_APPENDED.inc();
    }

    /// Softmax attention of one query row `q [d]` against every cached token
    /// of `layer`, writing `out [d]` (heads re-interleaved). Mirrors
    /// [`crate::infer::ops::causal_attention`]'s accumulation order exactly,
    /// so a decode step is bit-identical to the full-context row.
    ///
    /// `scratch` is caller-owned scoring/dequant workspace (resized here),
    /// so the per-layer-per-sequence hot path does no heap allocation.
    pub fn attend(&self, layer: usize, q: &[f32], h: usize, hd: usize,
                  out: &mut [f32], scratch: &mut Vec<f32>) {
        debug_assert_eq!(h * hd, self.d);
        debug_assert_eq!(q.len(), self.d);
        debug_assert_eq!(out.len(), self.d);
        let lk = &self.layers[layer];
        let len = lk.len;
        debug_assert!(len > 0, "attend on empty cache layer {layer}");
        crate::obs::registry::engine::KV_ROWS_ATTENDED.add(len as u64);
        let scale = 1.0 / (hd as f32).sqrt();
        // scratch = [len score slots | hd-wide dequant row]
        scratch.clear();
        scratch.resize(len + hd, 0.0);
        let (scores, row) = scratch.split_at_mut(len);
        out.fill(0.0);
        let be = crate::infer::simd::active();
        for hi in 0..h {
            let qrow = &q[hi * hd..(hi + 1) * hd];
            // scores over the cached prefix (the causal set by construction)
            for (tj, sc) in scores.iter_mut().enumerate() {
                lk.k.read(tj, hi * hd, self.d, self.mode, be, row);
                *sc = crate::infer::simd::dot_f32_with(be, qrow, row) * scale;
            }
            let mx = crate::infer::simd::max_f32_with(be, scores);
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let inv = 1.0 / denom;
            let orow = &mut out[hi * hd..(hi + 1) * hd];
            for tj in 0..len {
                let w = scores[tj] * inv;
                lk.v.read(tj, hi * hd, self.d, self.mode, be, row);
                crate::infer::simd::axpy_with(be, w, row, orow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::ops::causal_attention;
    use crate::quant::act::per_token_quant;
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    #[test]
    fn cached_attention_matches_causal_reference() {
        let mut rng = Rng::new(41);
        let (s, h, hd) = (6usize, 2usize, 8usize);
        let d = h * hd;
        let q = Tensor::randn(&mut rng, &[s, d], 1.0);
        let k = Tensor::randn(&mut rng, &[s, d], 1.0);
        let v = Tensor::randn(&mut rng, &[s, d], 1.0);
        for (kv_quant, bits) in [(false, 16u32), (true, 8), (true, 16)] {
            // reference: (fake-)quantized K/V through full causal attention
            let (kr, vr) = if kv_quant {
                let qm = crate::quant::qmax(bits);
                (per_token_quant(&k, qm), per_token_quant(&v, qm))
            } else {
                (k.clone(), v.clone())
            };
            let want =
                causal_attention(&q.data, &kr.data, &vr.data, 1, s, h, hd);
            // incremental: push each row, attend the newest query
            let mut cache = KvCache::new(1, d, kv_quant, bits);
            let mut out = vec![0.0f32; d];
            let mut scratch = Vec::new();
            for t in 0..s {
                cache.push(0, k.row(t), v.row(t));
                cache.attend(0, q.row(t), h, hd, &mut out, &mut scratch);
                for (c, i) in out.iter().zip(0..d) {
                    let w = want[t * d + i];
                    assert!(
                        (c - w).abs() < 1e-6,
                        "kv_quant {kv_quant} bits {bits} t{t} i{i}: {c} vs {w}"
                    );
                }
            }
            assert_eq!(cache.len(), s);
            assert_eq!(cache.is_quantized(), kv_quant && bits <= 8);
        }
    }

    #[test]
    fn quantized_cache_is_smaller_than_fp() {
        let mut rng = Rng::new(42);
        let d = 32;
        let mut qc = KvCache::new(2, d, true, 8);
        let mut fc = KvCache::new(2, d, false, 16);
        for l in 0..2 {
            for _ in 0..5 {
                let k: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
                qc.push(l, &k, &v);
                fc.push(l, &k, &v);
            }
        }
        assert_eq!(qc.len(), 5);
        assert_eq!(qc.layer_len(1), 5);
        assert!(qc.storage_bytes() < fc.storage_bytes() / 2,
                "u8 cache {} vs fp cache {}", qc.storage_bytes(),
                fc.storage_bytes());
    }
}
