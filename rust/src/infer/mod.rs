//! Native quantized inference engine: executes packed LRQ checkpoints
//! ([`crate::quant::PackedMatrix`]) directly with pure-Rust integer kernels —
//! no PJRT, no AOT artifacts (DESIGN.md §6).
//!
//! This is the Appendix G serving contract made executable: after
//! reconstruction a checkpoint is only `(s1, z, codes)` per linear, and this
//! module runs W8A8 / W4A8 / weight-only configurations end-to-end from that
//! representation, for weights produced by **any** method in
//! [`crate::methods`] (RTN / GPTQ / AWQ / FlexRound / LRQ — they all finalize
//! into the same packed format).
//!
//! Layer map:
//! * [`kernels`] — primitives: per-token/static activation quantization to u8
//!   codes (bit-exact with [`crate::quant::act`]'s grid math), unrolled
//!   u8×u8→i32 dot products, and fused row-tile unpacking of 3/4/8-bit
//!   packed streams.
//! * [`linear`] — [`QuantLinear`]: cache-blocked integer GEMM with the
//!   per-channel dequant epilogue, an FP-activation weight-only path, and
//!   row-sharded multi-threaded execution.
//! * [`ops`] — the FP glue of a block: RMSNorm, RoPE, causal attention,
//!   SiLU, and the scoring head (log-prob extraction).
//! * [`block`] — [`QuantBlock`] / [`NativeModel`]: the Transformer forward
//!   assembled from `model::layout` order, plus embedding and head — and the
//!   incremental decode entry points (`decode_step` / `prefill` /
//!   `generate`).
//! * [`decode`] — [`KvCache`]: per-sequence quantized KV cache (u8 codes +
//!   per-token grids, post-RoPE, same grid math as `quant::act`) with
//!   cached attention dequantizing on the fly; greedy/top-k sampling lives
//!   in [`crate::rng::sample_top_k`], shared with the batcher.
//! * [`reference`] — the fake-quant oracle (dequantize-then-matmul, the exact
//!   semantics of the `block_fwd_q` artifact) used by the correctness
//!   harness, and native FP calibration of activation ranges.
//! * [`quantize`] — artifact-free PTQ: RTN / grid-searched grids straight to
//!   a packed [`crate::model::QuantizedModel`].
//! * [`scorer`] — [`NativeScorer`]: a [`crate::serve::BatchScorer`] so the
//!   dynamic batcher serves the native engine for both score and generate
//!   workloads (engine-owned KV caches, decode-step batching across active
//!   sequences). Unlike the PJRT runtime the engine is `Send`, so it can be
//!   built outside the engine thread and row-shard across worker threads.

pub mod block;
pub mod decode;
pub mod kernels;
pub mod linear;
pub mod ops;
pub mod quantize;
pub mod reference;
pub mod scorer;

pub use block::{NativeModel, QuantBlock};
pub use decode::KvCache;
pub use kernels::QuantActs;
pub use linear::QuantLinear;
pub use quantize::{calibrate_stats, prepare_native, quantize_weights,
                   ScaleInit};
pub use scorer::{start_native_server, NativeScorer};
