//! Native quantized inference engine: executes packed LRQ checkpoints
//! ([`crate::quant::PackedMatrix`]) directly with pure-Rust integer kernels —
//! no PJRT, no AOT artifacts (DESIGN.md §6).
//!
//! This is the Appendix G serving contract made executable: after
//! reconstruction a checkpoint is only `(s1, z, codes)` per linear, and this
//! module runs W8A8 / W4A8 / weight-only configurations end-to-end from that
//! representation, for weights produced by **any** method in
//! [`crate::methods`] (RTN / GPTQ / AWQ / FlexRound / LRQ — they all finalize
//! into the same packed format).
//!
//! Execution is **planned**: model load repacks every linear's packed
//! bitstream once into a lane-padded row-major tile layout
//! ([`plan::TilePlan`]), spawns the persistent worker pool
//! ([`pool::WorkerPool`]) once, and every forward call after that streams
//! pre-unpacked tiles through register-blocked micro-kernels with
//! scratch-arena buffers — zero per-call unpack, zero thread spawns, no
//! steady-state allocation inside the model (DESIGN.md §8). The pre-plan
//! engine survives as [`plan::ExecMode::Reference`], the bit-exact oracle
//! of the planned path. The integer hot path is additionally vectorized
//! with runtime-dispatched SIMD ([`simd`], DESIGN.md §11); the scalar
//! kernels stay on as the oracle every vector backend must match
//! bit-for-bit.
//!
//! Layer map:
//! * [`kernels`] — primitives: per-token/static activation quantization to u8
//!   codes (bit-exact with [`crate::quant::act`]'s grid math), the **scalar
//!   oracle** 4×4 register-blocked micro-kernels of the planned path, scalar
//!   dots and fused row-tile unpacking for the reference path.
//! * [`simd`] — runtime-dispatched vector backends (AVX2 / SSE2 / scalar)
//!   for the integer micro-kernels and the FP glue helpers, plus the
//!   dispatch policy ([`simd::KernelChoice`]: `--kernel` /
//!   `LRQ_FORCE_SCALAR=1`). Integer kernels are bit-exact vs the scalar
//!   oracle by construction; the f32 helpers keep bit-equal mirrored
//!   accumulation structures (DESIGN.md §11).
//! * [`plan`] — load-time tile repacking ([`TilePlan`]), the [`Scratch`]
//!   buffer arena, and the execution context ([`Exec`] / [`ExecState`] /
//!   [`ExecMode`]) threaded through every forward.
//! * [`pool`] — [`WorkerPool`]: persistent job-queue + barrier worker
//!   threads (spawned once at model load), with shards writing their output
//!   columns straight into the final buffer via [`pool::OutSlice`].
//! * [`linear`] — [`QuantLinear`]: planned tile-streaming integer GEMM with
//!   the per-channel dequant epilogue, an FP-activation weight-only path,
//!   and the pre-plan reference GEMMs.
//! * [`ops`] — the FP glue of a block: RMSNorm, RoPE, causal attention,
//!   SiLU, and the scoring head (log-prob extraction).
//! * [`block`] — [`QuantBlock`] / [`NativeModel`]: the Transformer forward
//!   assembled from `model::layout` order, plus embedding and head — and the
//!   incremental decode entry points (`decode_step` / `prefill` /
//!   `generate`).
//! * [`decode`] — [`KvCache`]: per-sequence quantized KV cache (u8 codes +
//!   per-token grids, post-RoPE, same grid math as `quant::act`) with
//!   cached attention dequantizing on the fly; greedy/top-k sampling lives
//!   in [`crate::rng::sample_top_k`], shared with the batcher.
//! * [`reference`] — the fake-quant oracle (dequantize-then-matmul, the exact
//!   semantics of the `block_fwd_q` artifact) used by the correctness
//!   harness, and native FP calibration of activation ranges.
//! * [`quantize`] — artifact-free PTQ: RTN / grid-searched grids straight to
//!   a packed [`crate::model::QuantizedModel`].
//! * [`scorer`] — [`NativeScorer`]: a [`crate::serve::BatchScorer`] so the
//!   dynamic batcher serves the native engine for both score and generate
//!   workloads (engine-owned KV caches, decode-step batching across active
//!   sequences). Unlike the PJRT runtime the engine is `Send`, so it can be
//!   built outside the engine thread and tile-shard its GEMMs across the
//!   persistent worker pool it spawned at load.
//!
//! The whole engine is instrumented through [`crate::obs`] (DESIGN.md §9):
//! every kernel records ns/items/bytes into the model's per-layer
//! [`crate::obs::Profiler`] (one relaxed atomic load when disabled),
//! layer/GEMM/prefill spans go to the chrome trace when `--trace` is
//! active, and engine-global counters (bytes unpacked, tiles executed,
//! pool jobs, KV rows attended) live in
//! [`crate::obs::registry::engine`].

pub mod block;
pub mod decode;
pub mod kernels;
pub mod linear;
pub mod ops;
pub mod plan;
pub mod pool;
pub mod quantize;
pub mod reference;
pub mod scorer;
pub mod simd;

pub use block::{NativeModel, QuantBlock};
pub use decode::KvCache;
pub use kernels::QuantActs;
pub use linear::QuantLinear;
pub use plan::{Exec, ExecMode, ExecState, Scratch, TilePlan, MR};
pub use simd::{Backend, KernelChoice};
pub use pool::WorkerPool;
pub use quantize::{calibrate_stats, prepare_native, prepare_native_from,
                   quantize_weights, ScaleInit};
pub use scorer::{start_native_server, start_native_server_with,
                 NativeScorer};
