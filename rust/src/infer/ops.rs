//! FP glue ops of the native forward pass — the pure-Rust mirror of
//! `python/compile/model.py` (RMSNorm, half-split RoPE, causal softmax
//! attention, SiLU, and the scoring head). Numerics follow the L2 model
//! exactly: same eps, same base-10000 rotary angles, same masking constant,
//! so the native engine and the AOT artifacts disagree only by f32
//! accumulation order.
//!
//! The reductions here (RMSNorm mean-square, attention score dots, softmax
//! max, weighted-V accumulation) dispatch through [`super::simd`]: the
//! vector paths are bit-equal to their scalar mirrors (same lane
//! structure), `exp` stays scalar libm, and — since both [`ExecMode`]s
//! share these functions — planned vs reference equality is untouched by
//! the dispatch decision.
//!
//! [`ExecMode`]: super::plan::ExecMode

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::simd;

/// RMSNorm over the trailing dim into a caller-provided buffer
/// (`out.len() == x.len()`) — the scratch-arena path of the decode loop.
pub fn rmsnorm_into(x: &Tensor, g: &Tensor, out: &mut [f32]) {
    let (rows, d) = x.as_2d();
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(out.len(), x.len());
    let be = simd::active();
    for r in 0..rows {
        let row = &x.data[r * d..(r + 1) * d];
        let ms: f32 = simd::sum_sq_with(be, row) / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for ((o, &v), &gv) in out[r * d..(r + 1) * d]
            .iter_mut()
            .zip(row)
            .zip(&g.data)
        {
            *o = v * inv * gv;
        }
    }
}

/// RMSNorm over the trailing dim: `x · rsqrt(mean(x²) + 1e-5) · g`.
pub fn rmsnorm(x: &Tensor, g: &Tensor) -> Tensor {
    let mut out = vec![0.0f32; x.len()];
    rmsnorm_into(x, g, &mut out);
    Tensor::new(x.dims.clone(), out)
}

/// In-place half-split rotary embedding over `x[b, s, h, hd]` (row-major
/// `[b*s, h*hd]` layout, position = sequence index).
pub fn rope(x: &mut [f32], b: usize, s: usize, h: usize, hd: usize) {
    debug_assert_eq!(x.len(), b * s * h * hd);
    let half = hd / 2;
    // angle table [s, half]
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    for p in 0..s {
        for i in 0..half {
            let inv = 1.0 / 10000f32.powf(i as f32 / half as f32);
            let ang = p as f32 * inv;
            cos[p * half + i] = ang.cos();
            sin[p * half + i] = ang.sin();
        }
    }
    for bi in 0..b {
        for p in 0..s {
            let base = (bi * s + p) * h * hd;
            for hi in 0..h {
                let off = base + hi * hd;
                for i in 0..half {
                    let (c, sn) = (cos[p * half + i], sin[p * half + i]);
                    let x1 = x[off + i];
                    let x2 = x[off + half + i];
                    x[off + i] = x1 * c - x2 * sn;
                    x[off + half + i] = x1 * sn + x2 * c;
                }
            }
        }
    }
}

/// Half-split rotary embedding of a single token row `x [h*hd]` at absolute
/// position `pos` — the incremental-decode twin of [`rope`]. The angle math
/// is kept identical (same base-10000 formula, same f32 op order), so a K
/// row rotated here matches the full-context path bit-for-bit.
pub fn rope_row(x: &mut [f32], pos: usize, h: usize, hd: usize) {
    debug_assert_eq!(x.len(), h * hd);
    let half = hd / 2;
    // angles depend only on (pos, i): compute each once, apply to all heads
    for i in 0..half {
        let inv = 1.0 / 10000f32.powf(i as f32 / half as f32);
        let ang = pos as f32 * inv;
        let (c, sn) = (ang.cos(), ang.sin());
        for hi in 0..h {
            let off = hi * hd;
            let x1 = x[off + i];
            let x2 = x[off + half + i];
            x[off + i] = x1 * c - x2 * sn;
            x[off + half + i] = x1 * sn + x2 * c;
        }
    }
}

/// Causal softmax attention: `q, k, v` are `[b*s, h*hd]` row-major; returns
/// `attn [b*s, h*hd]` (heads re-interleaved, ready for the `wo` projection).
///
/// Accumulation structure (score dots, max-then-exp softmax, weighted-V
/// `axpy`) is kept in lockstep with [`crate::infer::KvCache::attend`] —
/// the cached-attention twin is tested against this function, so any
/// change here must land there too.
pub fn causal_attention(q: &[f32], k: &[f32], v: &[f32], b: usize, s: usize,
                        h: usize, hd: usize) -> Vec<f32> {
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let be = simd::active();
    let mut out = vec![0.0f32; b * s * d];
    let mut scores = vec![0.0f32; s];
    for bi in 0..b {
        for hi in 0..h {
            for ti in 0..s {
                let qoff = (bi * s + ti) * d + hi * hd;
                let qrow = &q[qoff..qoff + hd];
                // scores over the causal prefix
                for tj in 0..=ti {
                    let koff = (bi * s + tj) * d + hi * hd;
                    scores[tj] =
                        simd::dot_f32_with(be, qrow, &k[koff..koff + hd])
                        * scale;
                }
                let mx = simd::max_f32_with(be, &scores[..=ti]);
                // softmax over the prefix (exp stays scalar libm)
                let mut denom = 0.0f32;
                for sc in scores[..=ti].iter_mut() {
                    *sc = (*sc - mx).exp();
                    denom += *sc;
                }
                let inv = 1.0 / denom;
                // weighted sum of v
                let ooff = (bi * s + ti) * d + hi * hd;
                let orow = &mut out[ooff..ooff + hd];
                for tj in 0..=ti {
                    let w = scores[tj] * inv;
                    let voff = (bi * s + tj) * d + hi * hd;
                    simd::axpy_with(be, w, &v[voff..voff + hd], orow);
                }
            }
        }
    }
    out
}

/// SiLU (x·sigmoid(x)), elementwise.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Embedding gather into a caller-provided buffer (cleared first; capacity
/// is recycled across decode steps by the scratch arena).
pub fn embed_into(emb: &Tensor, ids: &[i32], out: &mut Vec<f32>)
                  -> Result<()> {
    let (vocab, d) = emb.rc();
    crate::obs::registry::engine::TOKENS_EMBEDDED.add(ids.len() as u64);
    out.clear();
    out.reserve(ids.len() * d);
    for &id in ids {
        let idx = id as usize;
        if id < 0 || idx >= vocab {
            bail!("token id {id} outside vocab {vocab}");
        }
        out.extend_from_slice(emb.row(idx));
    }
    Ok(())
}

/// Embedding gather: `ids[b*s]` -> `[b*s, d]`.
pub fn embed(emb: &Tensor, ids: &[i32]) -> Result<Tensor> {
    let d = emb.rc().1;
    let mut out = Vec::new();
    embed_into(emb, ids, &mut out)?;
    Ok(Tensor::new(vec![ids.len(), d], out))
}

/// Final norm + head projection: hidden `[rows, d]` -> logits
/// `[rows, vocab]`. Shared by scoring ([`head_logprobs`]) and the
/// incremental decode path (next-token distribution).
pub fn head_logits(x: &Tensor, final_norm: &Tensor, head: &Tensor)
                   -> Tensor {
    rmsnorm(x, final_norm).matmul_bt(head)
}

/// Final norm + head: returns `(mean NLL, per-position logprob of targets)`,
/// logprobs shaped `[rows]` in the same order as `targets` — the native twin
/// of `head_logprobs` in `model.py`.
pub fn head_logprobs(x: &Tensor, final_norm: &Tensor, head: &Tensor,
                     targets: &[i32]) -> Result<(f32, Vec<f32>)> {
    let (rows, _d) = x.as_2d();
    if targets.len() != rows {
        bail!("head: {} targets for {rows} positions", targets.len());
    }
    let (vocab, _) = head.rc();
    let logits = head_logits(x, final_norm, head); // [rows, vocab]
    let mut logp = Vec::with_capacity(rows);
    let mut nll = 0.0f64;
    for r in 0..rows {
        let row = logits.row(r);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
        let logz = mx + sum.ln();
        let t = targets[r] as usize;
        if targets[r] < 0 || t >= vocab {
            bail!("target id {} outside vocab {vocab}", targets[r]);
        }
        let lp = row[t] - logz;
        logp.push(lp);
        nll -= lp as f64;
    }
    Ok(((nll / rows as f64) as f32, logp))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn rmsnorm_unit_rows() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[4, 32], 2.0);
        let g = Tensor::ones(&[32]);
        let y = rmsnorm(&x, &g);
        for r in 0..4 {
            let ms: f32 = y.row(r).iter().map(|&v| v * v).sum::<f32>() / 32.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {r} ms {ms}");
        }
    }

    #[test]
    fn rope_preserves_norm_and_fixes_origin() {
        let mut rng = Rng::new(2);
        let (b, s, h, hd) = (2usize, 5, 2, 8);
        let x0 = Tensor::randn(&mut rng, &[b * s, h * hd], 1.0);
        let mut x = x0.data.clone();
        rope(&mut x, b, s, h, hd);
        // position 0 is unrotated
        for bi in 0..b {
            let off = bi * s * h * hd;
            for i in 0..h * hd {
                assert!((x[off + i] - x0.data[off + i]).abs() < 1e-6);
            }
        }
        // rotation preserves per-pair norms
        for (r, chunk) in x.chunks(hd).enumerate() {
            let orig = &x0.data[r * hd..(r + 1) * hd];
            let n0: f32 = orig.iter().map(|v| v * v).sum();
            let n1: f32 = chunk.iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-3, "chunk {r}");
        }
    }

    #[test]
    fn rope_row_matches_full_rope() {
        let mut rng = Rng::new(6);
        let (s, h, hd) = (7usize, 2usize, 8usize);
        let x0 = Tensor::randn(&mut rng, &[s, h * hd], 1.0);
        let mut full = x0.data.clone();
        rope(&mut full, 1, s, h, hd);
        for p in 0..s {
            let mut row = x0.row(p).to_vec();
            rope_row(&mut row, p, h, hd);
            // identical angle math -> bit-identical rotation
            assert_eq!(row.as_slice(), &full[p * h * hd..(p + 1) * h * hd],
                       "pos {p}");
        }
    }

    #[test]
    fn attention_first_token_is_v() {
        // causal: position 0 attends only to itself -> output == v[0]
        let mut rng = Rng::new(3);
        let (b, s, h, hd) = (1usize, 4, 2, 6);
        let d = h * hd;
        let q = Tensor::randn(&mut rng, &[s, d], 1.0);
        let k = Tensor::randn(&mut rng, &[s, d], 1.0);
        let v = Tensor::randn(&mut rng, &[s, d], 1.0);
        let out = causal_attention(&q.data, &k.data, &v.data, b, s, h, hd);
        for i in 0..d {
            assert!((out[i] - v.data[i]).abs() < 1e-6);
        }
        // every output row is a convex combination -> bounded by v extremes
        let vmax = v.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let vmin = v.data.iter().cloned().fold(f32::INFINITY, f32::min);
        for &o in &out {
            assert!(o <= vmax + 1e-5 && o >= vmin - 1e-5);
        }
    }

    #[test]
    fn head_logprobs_normalized() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&mut rng, &[6, 16], 1.0);
        let fnorm = Tensor::ones(&[16]);
        let head = Tensor::randn(&mut rng, &[40, 16], 0.3);
        let targets: Vec<i32> = (0..6).map(|_| rng.below(40) as i32).collect();
        let (loss, logp) = head_logprobs(&x, &fnorm, &head, &targets).unwrap();
        assert_eq!(logp.len(), 6);
        assert!(logp.iter().all(|&p| p < 0.0));
        let mean = -logp.iter().map(|&p| p as f64).sum::<f64>() / 6.0;
        assert!((loss as f64 - mean).abs() < 1e-6);
        // exhaustive check on row 0: exp(logp) sums to 1 across all targets
        let mut total = 0.0f64;
        for t in 0..40 {
            let (_, lp) =
                head_logprobs(&x, &fnorm, &head,
                              &[t, targets[1], targets[2], targets[3],
                                targets[4], targets[5]]).unwrap();
            if t == 0 {
                total = 0.0;
            }
            total += (lp[0] as f64).exp();
        }
        assert!((total - 1.0).abs() < 1e-4, "Σp = {total}");
    }

    #[test]
    fn embed_gathers_and_validates() {
        let emb = Tensor::new(vec![3, 2], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let x = embed(&emb, &[2, 0]).unwrap();
        assert_eq!(x.data, vec![4.0, 5.0, 0.0, 1.0]);
        assert!(embed(&emb, &[3]).is_err());
        assert!(embed(&emb, &[-1]).is_err());
    }
}
