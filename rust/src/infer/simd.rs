//! Runtime-dispatched SIMD kernels with a scalar oracle (DESIGN.md §11).
//!
//! The u8×u8→i32 dot is the hottest loop of every prefill and decode step
//! (PR 6 profiles), so it gets explicit vector code here: an AVX2 kernel
//! widening 16 u8 codes to i16 lanes per step (`vpmovzxbw` + `vpmaddwd` —
//! exact, unlike `maddubs` whose u8×i8 products saturate in i16), an SSE2
//! fallback (`punpcklbw` + `pmaddwd`, baseline on every x86_64), and the
//! scalar micro-kernels of [`super::kernels`] kept as the bit-exact
//! **oracle** every vector path is differentially tested against
//! (`tests/properties.rs`).
//!
//! Dispatch is decided once per process from `is_x86_feature_detected!`,
//! overridable by `LRQ_FORCE_SCALAR=1` or `--kernel scalar|simd|auto`
//! ([`set_choice`]); the integer GEMM additionally carries a per-engine
//! [`Backend`] (`ExecState::with_kernel`) so two engines in one process can
//! pin different paths — that is how the end-to-end forced-scalar vs
//! forced-SIMD equality tests run without racing on the global.
//!
//! Exactness contract, per kernel family:
//!
//! * **integer dots** — i32 accumulation is associative, so any lane split
//!   is bit-equal to the scalar oracle by construction; the per-lane i32
//!   bound under [`kernels::MAX_DOT_K`] is re-derived in the kernel docs.
//! * **f32 helpers** (`sum_sq`, `dot_f32`, `axpy`, `dequant`, `max_f32`) —
//!   f32 adds do NOT reassociate, so each vector helper has a scalar
//!   mirror here with the *same* 8-lane accumulator structure and the same
//!   horizontal-reduce order; the pair is bit-equal and both live behind
//!   the dispatch. The weight-only GEMM (`dot_f32_u8` and friends) is
//!   deliberately **not** vectorized: its documented sequential
//!   accumulation order is a bit-exactness contract with
//!   `ExecMode::Reference` (see `kernels.rs` and the reassociation
//!   regression test).
//! * **`exp`** — stays scalar libm everywhere; softmax vectorizes only the
//!   score dots, the running max, and the weighted-V accumulation.
//!
//! Adding a vector backend (NEON, AVX-512) = a new [`Backend`] variant, a
//! guarded arm per dispatch function, and nothing else: the property
//! battery iterates [`backends`], so a new variant is tested against the
//! oracle automatically.

use std::sync::atomic::{AtomicU8, Ordering};

use super::kernels;

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// u8 codes consumed per vector step (one 128-bit load widened to 16×i16).
/// [`super::plan::TilePlan`] pads weight-row strides to this, so every row
/// of a tile starts on a lane boundary and tails are shared per tile.
pub const LANE: usize = 16;

/// f32 lanes per vector step of the f32 helpers (one 256-bit register).
pub const F32_LANE: usize = 8;

// ------------------------------------------------------------ dispatch ----

/// A code-generation path for the hot kernels. `Avx2`/`Sse2` arms only
/// execute vector code after an `is_x86_feature_detected!` re-check, so a
/// mis-constructed value degrades to the scalar oracle instead of UB.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// 256-bit integer path (`vpmaddwd`), f32 helpers vectorized too.
    Avx2,
    /// 128-bit integer path (baseline on x86_64); f32 helpers stay on the
    /// scalar mirrors (SSE f32 reductions would need their own mirror
    /// structure for marginal gain).
    Sse2,
    /// The oracle: the scalar micro-kernels in [`super::kernels`].
    Scalar,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Avx2 => "avx2",
            Backend::Sse2 => "sse2",
            Backend::Scalar => "scalar",
        }
    }

    pub fn is_simd(self) -> bool {
        self != Backend::Scalar
    }
}

/// User-facing kernel override (`--kernel`, `LRQ_FORCE_SCALAR`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// Best detected path (the default).
    Auto,
    /// Pin the scalar oracle.
    Scalar,
    /// Ask for vector code; degrades to scalar when nothing is detected.
    Simd,
}

impl KernelChoice {
    pub fn name(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Simd => "simd",
        }
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelChoice::Auto),
            "scalar" => Ok(KernelChoice::Scalar),
            "simd" => Ok(KernelChoice::Simd),
            other => Err(format!(
                "unknown kernel choice '{other}' (auto|scalar|simd)")),
        }
    }
}

/// Best vector path this machine supports (`Scalar` off x86_64).
pub fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
        if std::arch::is_x86_feature_detected!("sse2") {
            return Backend::Sse2;
        }
    }
    Backend::Scalar
}

/// Every backend runnable on this machine, scalar first — the property
/// battery iterates this so each vector path is tested where it can run.
pub fn backends() -> Vec<Backend> {
    let mut v = vec![Backend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("sse2") {
            v.push(Backend::Sse2);
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(Backend::Avx2);
        }
    }
    v
}

const CHOICE_UNSET: u8 = u8::MAX;
static CHOICE: AtomicU8 = AtomicU8::new(CHOICE_UNSET);

/// Install a process-wide kernel choice (the `--kernel` flag). Engines
/// built afterwards default to the matching backend; the FP glue helpers
/// re-resolve on every call.
pub fn set_choice(c: KernelChoice) {
    // Relaxed: the choice byte is a standalone policy latch — no other
    // memory is published through it, readers re-resolve per call.
    CHOICE.store(c as u8, Ordering::Relaxed);
}

/// The process-wide choice; first call latches `LRQ_FORCE_SCALAR` from the
/// environment (accepted truthy spellings: `1`, `true`, `yes`).
pub fn choice() -> KernelChoice {
    // Relaxed: reads the standalone policy byte; a racing first-call
    // latch at worst repeats the idempotent env lookup below.
    match CHOICE.load(Ordering::Relaxed) {
        x if x == KernelChoice::Auto as u8 => KernelChoice::Auto,
        x if x == KernelChoice::Scalar as u8 => KernelChoice::Scalar,
        x if x == KernelChoice::Simd as u8 => KernelChoice::Simd,
        _ => {
            let forced = std::env::var("LRQ_FORCE_SCALAR")
                .map(|v| {
                    let v = v.trim().to_ascii_lowercase();
                    v == "1" || v == "true" || v == "yes"
                })
                .unwrap_or(false);
            let c = if forced {
                KernelChoice::Scalar
            } else {
                KernelChoice::Auto
            };
            // Relaxed: same standalone latch — every racer stores the
            // same value computed from the same environment.
            CHOICE.store(c as u8, Ordering::Relaxed);
            c
        }
    }
}

/// The backend the process-wide choice resolves to right now.
pub fn active() -> Backend {
    match choice() {
        KernelChoice::Scalar => Backend::Scalar,
        KernelChoice::Auto | KernelChoice::Simd => detect(),
    }
}

/// One-line dispatch description for load-time logs and `lrq stats`.
pub fn describe() -> String {
    format!("{} (choice {}, detected {})",
            active().name(), choice().name(), detect().name())
}

// -------------------------------------------------------- integer dots ----

/// Vectorized u8×u8→i32 dot. Bit-equal to [`kernels::dot_u8`] on every
/// backend (integer accumulation is exact); same [`kernels::MAX_DOT_K`]
/// caller contract.
pub fn dot_u8(backend: Backend, a: &[u8], b: &[u8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { dot_u8_avx2(a, b) }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 if std::arch::is_x86_feature_detected!("sse2") => {
            // SAFETY: SSE2 presence just re-checked.
            unsafe { dot_u8_sse2(a, b) }
        }
        _ => kernels::dot_u8(a, b),
    }
}

/// Vectorized register-blocked integer micro-kernel: `tn` token-code rows
/// (contiguous, `k` bytes each) × `rn` weight rows living at `r·stride`
/// inside a lane-padded [`super::plan::TilePlan`] tile. Widened form of
/// the scalar oracle [`kernels::dot_block_u8_scalar`]: each 16-byte
/// activation load is shared across all `rn` weight rows (the decode-shape
/// `tn = 1` case runs 4 accumulator registers off one load), bit-equal to
/// the oracle on every backend.
#[allow(clippy::too_many_arguments)] // mirrors the oracle + backend
pub fn dot_block_u8(backend: Backend, a: &[u8], k: usize, tn: usize,
                    wt: &[u8], stride: usize, rn: usize,
                    acc: &mut [i32; 16]) {
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { dot_block_u8_avx2(a, k, tn, wt, stride, rn, acc) }
        }
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 if std::arch::is_x86_feature_detected!("sse2") => {
            // SAFETY: SSE2 presence just re-checked.
            unsafe { dot_block_u8_sse2(a, k, tn, wt, stride, rn, acc) }
        }
        _ => kernels::dot_block_u8_scalar(a, k, tn, wt, stride, rn, acc),
    }
}

/// i32-safety of the vector accumulators, re-derived: one `vpmaddwd` lane
/// holds `2·255·255 = 130_050` max; with `k <= MAX_DOT_K = 33_000` the
/// AVX2 path runs at most `⌈33_000/16⌉ = 2_063` steps per lane
/// (`≈ 2.7e8 < 2^31`) and the SSE2 path two madds per step (`≈ 5.4e8`).
/// The scalar total `255·255·33_000 ≈ 2.15e9` stays below `i32::MAX` too.
///
/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_u8_avx2(a: &[u8], b: &[u8]) -> i32 {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); every 16-byte
    // load sits inside `a`/`b` because the loop requires `p + LANE <= k`
    // and the store targets a local 32-byte array.
    unsafe {
        let k = a.len();
        let mut vacc = _mm256_setzero_si256();
        let mut p = 0usize;
        while p + LANE <= k {
            let va = _mm256_cvtepu8_epi16(
                _mm_loadu_si128(a.as_ptr().add(p) as *const __m128i));
            let vb = _mm256_cvtepu8_epi16(
                _mm_loadu_si128(b.as_ptr().add(p) as *const __m128i));
            vacc = _mm256_add_epi32(vacc, _mm256_madd_epi16(va, vb));
            p += LANE;
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, vacc);
        let mut acc: i32 = lanes.iter().sum();
        for i in p..k {
            acc += a[i] as i32 * b[i] as i32;
        }
        acc
    }
}

/// # Safety
/// Caller must guarantee SSE2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot_u8_sse2(a: &[u8], b: &[u8]) -> i32 {
    // SAFETY: SSE2 is the caller's contract (`# Safety`); every 16-byte
    // load sits inside `a`/`b` because the loop requires `p + LANE <= k`
    // and the store targets a local 16-byte array.
    unsafe {
        let k = a.len();
        let zero = _mm_setzero_si128();
        let mut vacc = _mm_setzero_si128();
        let mut p = 0usize;
        while p + LANE <= k {
            let va = _mm_loadu_si128(a.as_ptr().add(p) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(p) as *const __m128i);
            let lo = _mm_madd_epi16(_mm_unpacklo_epi8(va, zero),
                                    _mm_unpacklo_epi8(vb, zero));
            let hi = _mm_madd_epi16(_mm_unpackhi_epi8(va, zero),
                                    _mm_unpackhi_epi8(vb, zero));
            vacc = _mm_add_epi32(vacc, _mm_add_epi32(lo, hi));
            p += LANE;
        }
        let mut lanes = [0i32; 4];
        _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, vacc);
        let mut acc: i32 = lanes.iter().sum();
        for i in p..k {
            acc += a[i] as i32 * b[i] as i32;
        }
        acc
    }
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_block_u8_avx2(a: &[u8], k: usize, tn: usize, wt: &[u8],
                            stride: usize, rn: usize, acc: &mut [i32; 16]) {
    debug_assert!((1..=4).contains(&tn) && (1..=4).contains(&rn));
    debug_assert!(stride >= k);
    debug_assert!(a.len() >= tn * k);
    debug_assert!(wt.len() >= (rn - 1) * stride + k);
    acc.fill(0);
    // SAFETY: AVX2 is the caller's contract (`# Safety`). Activation loads
    // reach at most `(tn-1)·k + p + 16 <= tn·k <= a.len()` and weight
    // loads at most `(rn-1)·stride + p + 16 <= (rn-1)·stride + k <=
    // wt.len()` (asserted above); stores hit local arrays only.
    unsafe {
        for t in 0..tn {
            let arow = a.as_ptr().add(t * k);
            let mut vacc = [_mm256_setzero_si256(); 4];
            let mut p = 0usize;
            while p + LANE <= k {
                // one widened activation load feeds all rn weight rows
                let xv = _mm256_cvtepu8_epi16(
                    _mm_loadu_si128(arow.add(p) as *const __m128i));
                for (r, vr) in vacc.iter_mut().take(rn).enumerate() {
                    let wv = _mm256_cvtepu8_epi16(_mm_loadu_si128(
                        wt.as_ptr().add(r * stride + p) as *const __m128i));
                    *vr = _mm256_add_epi32(*vr, _mm256_madd_epi16(xv, wv));
                }
                p += LANE;
            }
            for (r, vr) in vacc.iter().take(rn).enumerate() {
                let mut lanes = [0i32; 8];
                _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, *vr);
                let mut s: i32 = lanes.iter().sum();
                for i in p..k {
                    s += a[t * k + i] as i32 * wt[r * stride + i] as i32;
                }
                acc[t * 4 + r] = s;
            }
        }
    }
}

/// # Safety
/// Caller must guarantee SSE2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot_block_u8_sse2(a: &[u8], k: usize, tn: usize, wt: &[u8],
                            stride: usize, rn: usize, acc: &mut [i32; 16]) {
    debug_assert!((1..=4).contains(&tn) && (1..=4).contains(&rn));
    debug_assert!(stride >= k);
    debug_assert!(a.len() >= tn * k);
    debug_assert!(wt.len() >= (rn - 1) * stride + k);
    acc.fill(0);
    // SAFETY: SSE2 is the caller's contract (`# Safety`). The same bounds
    // argument as `dot_block_u8_avx2`: activation loads stay below
    // `tn·k <= a.len()`, weight loads below `(rn-1)·stride + k <=
    // wt.len()` (asserted above); stores hit local arrays only.
    unsafe {
        let zero = _mm_setzero_si128();
        for t in 0..tn {
            let arow = a.as_ptr().add(t * k);
            let mut vacc = [_mm_setzero_si128(); 4];
            let mut p = 0usize;
            while p + LANE <= k {
                let xv = _mm_loadu_si128(arow.add(p) as *const __m128i);
                let xlo = _mm_unpacklo_epi8(xv, zero);
                let xhi = _mm_unpackhi_epi8(xv, zero);
                for (r, vr) in vacc.iter_mut().take(rn).enumerate() {
                    let wv = _mm_loadu_si128(
                        wt.as_ptr().add(r * stride + p) as *const __m128i);
                    let lo = _mm_madd_epi16(xlo, _mm_unpacklo_epi8(wv, zero));
                    let hi = _mm_madd_epi16(xhi, _mm_unpackhi_epi8(wv, zero));
                    *vr = _mm_add_epi32(*vr, _mm_add_epi32(lo, hi));
                }
                p += LANE;
            }
            for (r, vr) in vacc.iter().take(rn).enumerate() {
                let mut lanes = [0i32; 4];
                _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, *vr);
                let mut s: i32 = lanes.iter().sum();
                for i in p..k {
                    s += a[t * k + i] as i32 * wt[r * stride + i] as i32;
                }
                acc[t * 4 + r] = s;
            }
        }
    }
}

// --------------------------------------------------------- f32 helpers ----
//
// Every vector helper below has a scalar mirror with the SAME 8-lane
// accumulator structure and the SAME horizontal-reduce order, so the pair
// is bit-equal (f32 ops in identical order; Rust never contracts mul+add
// into fma, and the intrinsics used are explicit mul/add). The SSE2 tier
// runs the mirrors: integer dots dominate the profile there and an SSE
// mirror pair would double the surface for marginal gain.

/// Σ x², 8-lane blocked. Dispatches on the process-wide [`active`] choice.
#[inline]
pub fn sum_sq(x: &[f32]) -> f32 {
    sum_sq_with(active(), x)
}

pub fn sum_sq_with(backend: Backend, x: &[f32]) -> f32 {
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { sum_sq_avx2(x) }
        }
        _ => sum_sq_scalar(x),
    }
}

/// The oracle mirror of the vector `sum_sq`: identical lane structure.
pub fn sum_sq_scalar(x: &[f32]) -> f32 {
    let k = x.len();
    let mut lanes = [0.0f32; F32_LANE];
    let mut p = 0usize;
    while p + F32_LANE <= k {
        for (j, l) in lanes.iter_mut().enumerate() {
            *l += x[p + j] * x[p + j];
        }
        p += F32_LANE;
    }
    let mut acc = 0.0f32;
    for &l in &lanes {
        acc += l;
    }
    for &v in &x[p..] {
        acc += v * v;
    }
    acc
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sum_sq_avx2(x: &[f32]) -> f32 {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); each 8-float
    // load stays in bounds via `p + F32_LANE <= k`, the store targets a
    // local array.
    unsafe {
        let k = x.len();
        let mut vacc = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + F32_LANE <= k {
            let v = _mm256_loadu_ps(x.as_ptr().add(p));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(v, v));
            p += F32_LANE;
        }
        let mut lanes = [0.0f32; F32_LANE];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        let mut acc = 0.0f32;
        for &l in &lanes {
            acc += l;
        }
        for &v in &x[p..] {
            acc += v * v;
        }
        acc
    }
}

/// f32 dot, 8-lane blocked (attention scores).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    dot_f32_with(active(), a, b)
}

pub fn dot_f32_with(backend: Backend, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { dot_f32_avx2(a, b) }
        }
        _ => dot_f32_scalar(a, b),
    }
}

/// The oracle mirror of the vector `dot_f32`: identical lane structure.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    let k = a.len();
    let mut lanes = [0.0f32; F32_LANE];
    let mut p = 0usize;
    while p + F32_LANE <= k {
        for (j, l) in lanes.iter_mut().enumerate() {
            *l += a[p + j] * b[p + j];
        }
        p += F32_LANE;
    }
    let mut acc = 0.0f32;
    for &l in &lanes {
        acc += l;
    }
    for i in p..k {
        acc += a[i] * b[i];
    }
    acc
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); each 8-float
    // load stays inside `a`/`b` (same length, asserted by the dispatch
    // wrapper) via `p + F32_LANE <= k`, the store targets a local array.
    unsafe {
        let k = a.len();
        let mut vacc = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + F32_LANE <= k {
            let va = _mm256_loadu_ps(a.as_ptr().add(p));
            let vb = _mm256_loadu_ps(b.as_ptr().add(p));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(va, vb));
            p += F32_LANE;
        }
        let mut lanes = [0.0f32; F32_LANE];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        let mut acc = 0.0f32;
        for &l in &lanes {
            acc += l;
        }
        for i in p..k {
            acc += a[i] * b[i];
        }
        acc
    }
}

/// Max over a non-empty slice of non-NaN values (softmax running max).
/// f32 max is order-insensitive for non-NaN inputs, so vector and scalar
/// agree bit-for-bit without a mirrored structure.
#[inline]
pub fn max_f32(x: &[f32]) -> f32 {
    max_f32_with(active(), x)
}

pub fn max_f32_with(backend: Backend, x: &[f32]) -> f32 {
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { max_f32_avx2(x) }
        }
        _ => max_f32_scalar(x),
    }
}

pub fn max_f32_scalar(x: &[f32]) -> f32 {
    x.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_f32_avx2(x: &[f32]) -> f32 {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); each 8-float
    // load stays in bounds via `p + F32_LANE <= k`, the store targets a
    // local array.
    unsafe {
        let k = x.len();
        let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut p = 0usize;
        while p + F32_LANE <= k {
            vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(x.as_ptr().add(p)));
            p += F32_LANE;
        }
        let mut lanes = [0.0f32; F32_LANE];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vmax);
        let mut mx = f32::NEG_INFINITY;
        for &l in &lanes {
            mx = mx.max(l);
        }
        for &v in &x[p..] {
            mx = mx.max(v);
        }
        mx
    }
}

/// `out[i] += w·v[i]` (attention weighted-V). Purely elementwise — one
/// mul + one add per element in both paths — so vector and scalar are
/// bit-equal with no mirrored reduction needed.
#[inline]
pub fn axpy(w: f32, v: &[f32], out: &mut [f32]) {
    axpy_with(active(), w, v, out)
}

pub fn axpy_with(backend: Backend, w: f32, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { axpy_avx2(w, v, out) }
        }
        _ => axpy_scalar(w, v, out),
    }
}

pub fn axpy_scalar(w: f32, v: &[f32], out: &mut [f32]) {
    for (o, &vv) in out.iter_mut().zip(v) {
        *o += w * vv;
    }
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(w: f32, v: &[f32], out: &mut [f32]) {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); loads and the
    // store stay inside `v`/`out` (same length, asserted by the dispatch
    // wrapper) via `p + F32_LANE <= k`.
    unsafe {
        let k = v.len();
        let vw = _mm256_set1_ps(w);
        let mut p = 0usize;
        while p + F32_LANE <= k {
            let vo = _mm256_loadu_ps(out.as_ptr().add(p));
            let vv = _mm256_loadu_ps(v.as_ptr().add(p));
            _mm256_storeu_ps(out.as_mut_ptr().add(p),
                             _mm256_add_ps(vo, _mm256_mul_ps(vw, vv)));
            p += F32_LANE;
        }
        for i in p..k {
            out[i] += w * v[i];
        }
    }
}

/// Dequantize u8 codes: `out[i] = (codes[i] - z)·s` (KV-cache reads, the
/// dequant epilogue of cached attention). u8→f32 conversion is exact and
/// the sub/mul pair is elementwise, so vector and scalar are bit-equal.
#[inline]
pub fn dequant(codes: &[u8], s: f32, z: f32, out: &mut [f32]) {
    dequant_with(active(), codes, s, z, out)
}

pub fn dequant_with(backend: Backend, codes: &[u8], s: f32, z: f32,
                    out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if std::arch::is_x86_feature_detected!("avx2") => {
            // SAFETY: AVX2 presence just re-checked.
            unsafe { dequant_avx2(codes, s, z, out) }
        }
        _ => dequant_scalar(codes, s, z, out),
    }
}

pub fn dequant_scalar(codes: &[u8], s: f32, z: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = (c as f32 - z) * s;
    }
}

/// # Safety
/// Caller must guarantee AVX2 is available (the dispatch match re-checks
/// with `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequant_avx2(codes: &[u8], s: f32, z: f32, out: &mut [f32]) {
    // SAFETY: AVX2 is the caller's contract (`# Safety`); the 8-byte
    // load and 8-float store stay inside `codes`/`out` (same length,
    // asserted by the dispatch wrapper) via `p + F32_LANE <= k`.
    unsafe {
        let k = codes.len();
        let vs = _mm256_set1_ps(s);
        let vz = _mm256_set1_ps(z);
        let mut p = 0usize;
        while p + F32_LANE <= k {
            // 8 codes zero-extended to i32, converted exactly to f32
            let c = _mm256_cvtepu8_epi32(
                _mm_loadl_epi64(codes.as_ptr().add(p) as *const __m128i));
            let f = _mm256_cvtepi32_ps(c);
            _mm256_storeu_ps(out.as_mut_ptr().add(p),
                             _mm256_mul_ps(_mm256_sub_ps(f, vz), vs));
            p += F32_LANE;
        }
        for i in p..k {
            out[i] = (codes[i] as f32 - z) * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn kernel_choice_parses() {
        assert_eq!("auto".parse::<KernelChoice>(), Ok(KernelChoice::Auto));
        assert_eq!("SCALAR".parse::<KernelChoice>(),
                   Ok(KernelChoice::Scalar));
        assert_eq!(" simd ".parse::<KernelChoice>(),
                   Ok(KernelChoice::Simd));
        assert!("avx9".parse::<KernelChoice>().is_err());
    }

    #[test]
    fn backends_start_with_the_oracle() {
        let bs = backends();
        assert_eq!(bs[0], Backend::Scalar);
        assert!(bs.contains(&detect()));
        // the resolved active backend is always runnable here
        assert!(bs.contains(&active()));
        assert!(!Backend::Scalar.is_simd());
    }

    #[test]
    fn vector_dots_match_oracle_smoke() {
        // quick in-module sanity; the full battery (alignment offsets,
        // saturation inputs, all tails) lives in tests/properties.rs
        let mut rng = Rng::new(61);
        for k in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 100] {
            let a: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..k).map(|_| rng.below(256) as u8).collect();
            let want = kernels::dot_u8(&a, &b);
            for be in backends() {
                assert_eq!(dot_u8(be, &a, &b), want,
                           "{} k {k}", be.name());
            }
        }
    }

    #[test]
    fn f32_helpers_match_mirrors_smoke() {
        let mut rng = Rng::new(62);
        for k in [0usize, 1, 5, 8, 9, 24, 65] {
            let a: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
            let codes: Vec<u8> =
                (0..k).map(|_| rng.below(256) as u8).collect();
            for be in backends() {
                assert_eq!(sum_sq_with(be, &a), sum_sq_scalar(&a),
                           "sum_sq {} k {k}", be.name());
                assert_eq!(dot_f32_with(be, &a, &b), dot_f32_scalar(&a, &b),
                           "dot {} k {k}", be.name());
                if k > 0 {
                    assert_eq!(max_f32_with(be, &a), max_f32_scalar(&a),
                               "max {} k {k}", be.name());
                }
                let mut o1: Vec<f32> = a.clone();
                let mut o2: Vec<f32> = a.clone();
                axpy_with(be, 0.37, &b, &mut o1);
                axpy_scalar(0.37, &b, &mut o2);
                assert_eq!(o1, o2, "axpy {} k {k}", be.name());
                let mut d1 = vec![0.0f32; k];
                let mut d2 = vec![0.0f32; k];
                dequant_with(be, &codes, 3.0, 0.1, &mut d1);
                dequant_scalar(&codes, 3.0, 0.1, &mut d2);
                assert_eq!(d1, d2, "dequant {} k {k}", be.name());
            }
        }
    }
}
