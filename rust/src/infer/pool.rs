//! [`WorkerPool`]: persistent GEMM worker threads, spawned once at model
//! load and reused by every forward call.
//!
//! The pre-plan engine spawned fresh `std::thread::scope` workers per
//! matmul, so a decode step paid thread spawn/join for every linear — fixed
//! overhead that dominated the actual integer math at small batch sizes.
//! This pool replaces that with a **job queue + barrier**: `run(jobs, body)`
//! publishes a job count and a borrowed body under one mutex, wakes the
//! workers, lets the *calling thread claim jobs too* (so a 1-thread pool is
//! just an inline loop with zero synchronization), and returns only when
//! every job has finished — the barrier that makes lending stack-borrowed
//! closures to long-lived threads sound.
//!
//! Shard outputs are written straight into the final `[rows, cout]` buffer
//! through [`OutSlice`] (each shard owns a disjoint set of output columns),
//! which deletes the per-shard chunk allocation *and* the stitch copy the
//! scoped-thread design needed.
//!
//! The pool is kernel-agnostic: each shard body captures the `Exec` it was
//! handed, including its pinned [`crate::infer::simd::Backend`], so every
//! worker of one forward runs the same (SIMD or scalar) micro-kernel tier
//! and sharded outputs stay bit-identical to the single-thread result.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// The caller's job body with its borrow lifetime erased. Sound because
/// [`WorkerPool::run`] blocks until every claimed job has completed, and
/// workers can only claim while `next < jobs` — state that is reset before
/// `run` returns.
#[derive(Clone, Copy)]
struct Body(&'static (dyn Fn(usize) + Sync));

struct State {
    /// jobs published for the current `run` (claims allowed while
    /// `next < jobs`)
    jobs: usize,
    /// next unclaimed job index
    next: usize,
    /// claimed-or-unclaimed jobs not yet finished (the barrier count)
    active: usize,
    body: Option<Body>,
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here between runs
    go: Condvar,
    /// the submitting thread parks here until `active == 0`
    done: Condvar,
}

/// Persistent worker pool (see module docs). One per engine instance,
/// shared by clones through an `Arc`.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// serializes concurrent `run` calls (model clones share the pool)
    submit: Mutex<()>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads - 1` persistent workers (the submitting thread is the
    /// remaining executor). `threads <= 1` spawns nothing: `run` degrades to
    /// an inline loop.
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                jobs: 0,
                next: 0,
                active: 0,
                body: None,
                panicked: false,
                shutdown: false,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lrq-gemm-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), workers }
    }

    /// Total executor count: spawned workers + the submitting thread.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Execute `body(0)`, `body(1)`, ..., `body(jobs - 1)` across the pool
    /// and the calling thread; returns after **all** jobs completed (the
    /// barrier). Jobs may run in any order and must not call `run`
    /// re-entrantly. Panics in any job are re-raised here after the barrier.
    pub fn run<F: Fn(usize) + Sync>(&self, jobs: usize, body: F) {
        if jobs == 0 {
            return;
        }
        crate::obs::registry::engine::POOL_JOBS.add(jobs as u64);
        if jobs == 1 || self.workers.is_empty() {
            // inline fast path: no locks, no wakeups
            for i in 0..jobs {
                body(i);
            }
            return;
        }
        let wide: &(dyn Fn(usize) + Sync) = &body;
        // SAFETY: lifetime erasure only — the barrier below guarantees no
        // worker touches `body` after `run` returns (claims require
        // `next < jobs`, and we wait for `active == 0` before resetting).
        #[allow(clippy::useless_transmute, clippy::transmute_ptr_to_ptr)]
        let eternal: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                  &'static (dyn Fn(usize) + Sync)>(wide)
        };
        // a panicking job unwinds through `run` with this guard held,
        // poisoning the mutex — recover the lock rather than bricking the
        // pool for every model clone (pool state is reset by the barrier
        // logic itself, not protected by this guard)
        let _epoch =
            self.submit.lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.active, 0, "pool run while a run is active");
            st.jobs = jobs;
            st.next = 0;
            st.active = jobs;
            st.body = Some(Body(eternal));
            self.shared.go.notify_all();
        }
        // the submitting thread claims jobs like any worker, then becomes
        // the barrier waiter once everything is claimed
        let panicked = loop {
            let mut st = self.shared.state.lock().unwrap();
            if st.next < st.jobs {
                let i = st.next;
                st.next += 1;
                drop(st);
                let ok =
                    catch_unwind(AssertUnwindSafe(|| body(i))).is_ok();
                let mut st = self.shared.state.lock().unwrap();
                if !ok {
                    st.panicked = true;
                }
                st.active -= 1;
                if st.active == 0 {
                    self.shared.done.notify_all();
                }
            } else {
                while st.active > 0 {
                    st = self.shared.done.wait(st).unwrap();
                }
                st.body = None;
                st.jobs = 0;
                st.next = 0;
                let p = st.panicked;
                st.panicked = false;
                break p;
            }
        };
        if panicked {
            panic!("worker pool job panicked");
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads())
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.go.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if st.next < st.jobs {
            let i = st.next;
            st.next += 1;
            let body = st.body.expect("job body published while claims remain");
            drop(st);
            let ok = catch_unwind(AssertUnwindSafe(|| (body.0)(i))).is_ok();
            st = shared.state.lock().unwrap();
            if !ok {
                st.panicked = true;
            }
            st.active -= 1;
            if st.active == 0 {
                shared.done.notify_all();
            }
        } else {
            st = shared.go.wait(st).unwrap();
        }
    }
}

/// An unchecked window into a shared output buffer: shards write their
/// (disjoint) output columns straight into the final `[rows, cout]` tensor,
/// so there is no per-shard chunk and no stitch copy.
#[derive(Clone, Copy)]
pub struct OutSlice {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: raw access is gated behind `OutSlice::slice`, whose contract
// requires callers to hold disjoint ranges; moving the pointer to another
// of the pool's threads adds no aliasing that contract doesn't already
// police.
unsafe impl Send for OutSlice {}
// SAFETY: same argument for shared references — `slice` hands out
// pairwise-disjoint `&mut` windows, so concurrent use never aliases.
unsafe impl Sync for OutSlice {}

impl OutSlice {
    pub fn new(out: &mut [f32]) -> OutSlice {
        OutSlice { ptr: out.as_mut_ptr(), len: out.len() }
    }

    /// Reborrow `n` elements starting at `off`.
    ///
    /// # Safety
    /// Concurrent holders must use pairwise-disjoint `[off, off + n)`
    /// ranges, every range in bounds of the buffer `new` wrapped, and no
    /// slice may outlive the `run` call that received the `OutSlice`.
    pub unsafe fn slice<'a>(self, off: usize, n: usize) -> &'a mut [f32] {
        debug_assert!(off + n <= self.len);
        // SAFETY: in-bounds range, pairwise disjointness, and the
        // lifetime cap are the caller's contract (`# Safety` above).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), n) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_exactly_once_and_is_reusable() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        for jobs in [1usize, 3, 4, 17] {
            let hits: Vec<AtomicUsize> =
                (0..jobs).map(|_| AtomicUsize::new(0)).collect();
            pool.run(jobs, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "jobs {jobs} i {i}");
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(5, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn out_slice_shards_write_disjoint_ranges() {
        let pool = WorkerPool::new(3);
        let mut buf = vec![0.0f32; 24];
        let out = OutSlice::new(&mut buf);
        pool.run(4, |i| {
            // SAFETY: job i owns [6i, 6i + 6) — disjoint and in bounds
            let s = unsafe { out.slice(i * 6, 6) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (i * 6 + k) as f32;
            }
        });
        for (k, &v) in buf.iter().enumerate() {
            assert_eq!(v, k as f32);
        }
    }

    #[test]
    #[should_panic(expected = "worker pool job panicked")]
    fn job_panic_propagates_after_barrier() {
        let pool = WorkerPool::new(2);
        pool.run(4, |i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run(0, |_| panic!("must not run"));
    }

    #[test]
    fn pool_lifecycle_stress() {
        // repeated spawn → exec → drop cycles at every width: the TSan
        // lane runs this to prove worker startup, the go/done barriers,
        // and Drop's shutdown handshake race-free
        for round in 0..8usize {
            for width in 1..=4usize {
                let pool = WorkerPool::new(width);
                for jobs in [1usize, 2, 7, 16] {
                    let hits: Vec<AtomicUsize> =
                        (0..jobs).map(|_| AtomicUsize::new(0)).collect();
                    pool.run(jobs, |i| {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    });
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::SeqCst), 1,
                                   "round {round} width {width} jobs \
                                    {jobs} i {i}");
                    }
                }
                // `pool` drops here: joins every worker
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // model clones share one Arc<WorkerPool>; `run` serializes epochs
        // on the submit lock. Hammer it from several threads and count
        // every job exactly once.
        let pool = std::sync::Arc::new(WorkerPool::new(3));
        let total = std::sync::Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    pool.run(5, |_| {
                        total.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 5);
    }

    #[test]
    fn pool_survives_a_job_panic() {
        // a panicking job must not brick the pool (shared by model clones):
        // the barrier drains the epoch, the submit lock recovers from
        // poisoning, and the next run proceeds normally
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(3, |i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        let hits = AtomicUsize::new(0);
        pool.run(3, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }
}
