//! [`WorkerPool`]: persistent GEMM worker threads, spawned once at model
//! load and reused by every forward call.
//!
//! The pre-plan engine spawned fresh `std::thread::scope` workers per
//! matmul, so a decode step paid thread spawn/join for every linear — fixed
//! overhead that dominated the actual integer math at small batch sizes.
//! This pool replaces that with a **job queue + barrier**: `run(jobs, body)`
//! publishes a job count and a borrowed body under one mutex, wakes the
//! workers, lets the *calling thread claim jobs too* (so a 1-thread pool is
//! just an inline loop with zero synchronization), and returns only when
//! every job has finished — the barrier that makes lending stack-borrowed
//! closures to long-lived threads sound.
//!
//! Supervision (DESIGN.md §13): a panicking job is caught at the job
//! boundary and reported as [`JobPanicked`] from `run` — it never unwinds
//! through the pool, never poisons a later epoch, and never aborts the
//! process. Workers that exit for any reason are respawned lazily at the
//! next `run`, so a pool survives arbitrary job failures with its full
//! width restored.
//!
//! Shard outputs are written straight into the final `[rows, cout]` buffer
//! through [`OutSlice`] (each shard owns a disjoint set of output columns),
//! which deletes the per-shard chunk allocation *and* the stitch copy the
//! scoped-thread design needed.
//!
//! The pool is kernel-agnostic: each shard body captures the `Exec` it was
//! handed, including its pinned [`crate::infer::simd::Backend`], so every
//! worker of one forward runs the same (SIMD or scalar) micro-kernel tier
//! and sharded outputs stay bit-identical to the single-thread result.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// At least one job of a [`WorkerPool::run`] epoch panicked. The epoch
/// still completed its barrier (every job was claimed and either finished
/// or unwound), so the pool stays usable — but the panicked jobs' outputs
/// are unspecified and the caller must discard the whole batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JobPanicked;

impl std::fmt::Display for JobPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker pool job panicked")
    }
}

impl std::error::Error for JobPanicked {}

/// The caller's job body with its borrow lifetime erased. Sound because
/// [`WorkerPool::run`] blocks until every claimed job has completed, and
/// workers can only claim while `next < jobs` — state that is reset before
/// `run` returns.
#[derive(Clone, Copy)]
struct Body(&'static (dyn Fn(usize) + Sync));

struct State {
    /// jobs published for the current `run` (claims allowed while
    /// `next < jobs`)
    jobs: usize,
    /// next unclaimed job index
    next: usize,
    /// claimed-or-unclaimed jobs not yet finished (the barrier count)
    active: usize,
    body: Option<Body>,
    panicked: bool,
    shutdown: bool,
    /// chaos hook: idle workers consume one unit each and exit
    /// ([`WorkerPool::chaos_kill_worker`])
    die: usize,
}

struct Shared {
    state: Mutex<State>,
    /// workers park here between runs
    go: Condvar,
    /// the submitting thread parks here until `active == 0`
    done: Condvar,
}

/// Persistent worker pool (see module docs). One per engine instance,
/// shared by clones through an `Arc`.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// serializes concurrent `run` calls (model clones share the pool)
    submit: Mutex<()>,
    /// live worker handles; dead entries are respawned at the next `run`
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl WorkerPool {
    /// Spawn `threads - 1` persistent workers (the submitting thread is the
    /// remaining executor). `threads <= 1` spawns nothing: `run` degrades to
    /// an inline loop.
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                jobs: 0,
                next: 0,
                active: 0,
                body: None,
                panicked: false,
                shutdown: false,
                die: 0,
            }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("lrq-gemm-{i}"))
                    .spawn(move || worker_loop(&sh))
                    // PANIC: startup-only — spawning the initial pool at
                    // model load; nothing is serving yet
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            shared,
            submit: Mutex::new(()),
            workers: Mutex::new(workers),
        }
    }

    /// Poison-tolerant state lock. Jobs execute with the lock released, so
    /// a poisoned state mutex carries no torn invariants — recover it.
    fn state(&self) -> MutexGuard<'_, State> {
        self.shared.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Total executor count: spawned workers + the submitting thread.
    pub fn threads(&self) -> usize {
        let ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        ws.len() + 1
    }

    /// Workers whose threads have exited (candidates for respawn). A
    /// healthy pool reports 0.
    pub fn dead_workers(&self) -> usize {
        let ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        ws.iter().filter(|h| h.is_finished()).count()
    }

    /// Chaos hook: ask one idle worker to exit (it consumes the marker the
    /// next time it reaches the dispatch loop). Used by the chaos tests to
    /// prove the respawn path; never called in production serving.
    pub fn chaos_kill_worker(&self) {
        let mut st = self.state();
        st.die += 1;
        self.shared.go.notify_all();
    }

    /// Replace any worker thread that has exited (job-induced death, chaos
    /// kill). Best-effort: if the OS refuses a spawn the pool still makes
    /// progress because the submitting thread claims unclaimed jobs itself.
    fn respawn_dead(&self) {
        let mut ws = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        for slot in ws.iter_mut() {
            if !slot.is_finished() {
                continue;
            }
            let sh = self.shared.clone();
            let spawned = std::thread::Builder::new()
                .name("lrq-gemm-respawn".to_string())
                .spawn(move || worker_loop(&sh));
            if let Ok(h) = spawned {
                let dead = std::mem::replace(slot, h);
                let _ = dead.join();
            }
        }
    }

    /// Execute `body(0)`, `body(1)`, ..., `body(jobs - 1)` across the pool
    /// and the calling thread; returns after **all** jobs completed (the
    /// barrier). Jobs may run in any order and must not call `run`
    /// re-entrantly. A panic in any job is caught at the job boundary and
    /// reported as `Err(JobPanicked)` after the barrier — the pool itself
    /// stays healthy and the caller decides what to fail (DESIGN.md §13).
    pub fn run<F: Fn(usize) + Sync>(&self, jobs: usize, body: F)
        -> Result<(), JobPanicked> {
        if jobs == 0 {
            return Ok(());
        }
        crate::obs::registry::engine::POOL_JOBS.add(jobs as u64);
        let no_workers = {
            let ws =
                self.workers.lock().unwrap_or_else(|e| e.into_inner());
            ws.is_empty()
        };
        if jobs == 1 || no_workers {
            // inline fast path: no locks, no wakeups — but the same
            // no-unwind contract as the pooled path
            let mut panicked = false;
            for i in 0..jobs {
                if catch_unwind(AssertUnwindSafe(|| body(i))).is_err() {
                    panicked = true;
                }
            }
            return if panicked { Err(JobPanicked) } else { Ok(()) };
        }
        let wide: &(dyn Fn(usize) + Sync) = &body;
        // SAFETY: lifetime erasure only — the barrier below guarantees no
        // worker touches `body` after `run` returns (claims require
        // `next < jobs`, and we wait for `active == 0` before resetting).
        #[allow(clippy::useless_transmute, clippy::transmute_ptr_to_ptr)]
        let eternal: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                  &'static (dyn Fn(usize) + Sync)>(wide)
        };
        // historical note: `run` used to re-raise job panics and could
        // unwind through this guard, poisoning the mutex — recovery is kept
        // so a pool shared by model clones never bricks on a stale poison
        let _epoch =
            self.submit.lock().unwrap_or_else(|e| e.into_inner());
        // restore full width before publishing the epoch (workers may have
        // died to a chaos kill or an earlier failure)
        self.respawn_dead();
        {
            let mut st = self.state();
            debug_assert_eq!(st.active, 0, "pool run while a run is active");
            st.jobs = jobs;
            st.next = 0;
            st.active = jobs;
            st.body = Some(Body(eternal));
            self.shared.go.notify_all();
        }
        // the submitting thread claims jobs like any worker, then becomes
        // the barrier waiter once everything is claimed
        let panicked = loop {
            let mut st = self.state();
            if st.next < st.jobs {
                let i = st.next;
                st.next += 1;
                drop(st);
                let ok =
                    catch_unwind(AssertUnwindSafe(|| body(i))).is_ok();
                let mut st = self.state();
                if !ok {
                    st.panicked = true;
                }
                st.active -= 1;
                if st.active == 0 {
                    self.shared.done.notify_all();
                }
            } else {
                while st.active > 0 {
                    st = self
                        .shared
                        .done
                        .wait(st)
                        .unwrap_or_else(|e| e.into_inner());
                }
                st.body = None;
                st.jobs = 0;
                st.next = 0;
                let p = st.panicked;
                st.panicked = false;
                break p;
            }
        };
        if panicked { Err(JobPanicked) } else { Ok(()) }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads())
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.state();
            st.shutdown = true;
            self.shared.go.notify_all();
        }
        let ws = self.workers.get_mut().unwrap_or_else(|e| e.into_inner());
        for h in ws.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut st =
        shared.state.lock().unwrap_or_else(|e| e.into_inner());
    loop {
        if st.shutdown {
            return;
        }
        if st.die > 0 {
            // chaos kill: exit between jobs, never mid-barrier — the
            // submitting thread claims whatever this worker would have
            st.die -= 1;
            return;
        }
        if st.next < st.jobs {
            let i = st.next;
            st.next += 1;
            // PANIC: invariant — `body` is published before `jobs` under
            // the same lock and cleared only after the barrier drains
            let body = st.body.expect("job body published while claims remain");
            drop(st);
            let ok = catch_unwind(AssertUnwindSafe(|| (body.0)(i))).is_ok();
            st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            if !ok {
                st.panicked = true;
            }
            st.active -= 1;
            if st.active == 0 {
                shared.done.notify_all();
            }
        } else {
            st = shared.go.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// An unchecked window into a shared output buffer: shards write their
/// (disjoint) output columns straight into the final `[rows, cout]` tensor,
/// so there is no per-shard chunk and no stitch copy.
#[derive(Clone, Copy)]
pub struct OutSlice {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: raw access is gated behind `OutSlice::slice`, whose contract
// requires callers to hold disjoint ranges; moving the pointer to another
// of the pool's threads adds no aliasing that contract doesn't already
// police.
unsafe impl Send for OutSlice {}
// SAFETY: same argument for shared references — `slice` hands out
// pairwise-disjoint `&mut` windows, so concurrent use never aliases.
unsafe impl Sync for OutSlice {}

impl OutSlice {
    pub fn new(out: &mut [f32]) -> OutSlice {
        OutSlice { ptr: out.as_mut_ptr(), len: out.len() }
    }

    /// Reborrow `n` elements starting at `off`.
    ///
    /// # Safety
    /// Concurrent holders must use pairwise-disjoint `[off, off + n)`
    /// ranges, every range in bounds of the buffer `new` wrapped, and no
    /// slice may outlive the `run` call that received the `OutSlice`.
    pub unsafe fn slice<'a>(self, off: usize, n: usize) -> &'a mut [f32] {
        debug_assert!(off + n <= self.len);
        // SAFETY: in-bounds range, pairwise disjointness, and the
        // lifetime cap are the caller's contract (`# Safety` above).
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), n) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_exactly_once_and_is_reusable() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        for jobs in [1usize, 3, 4, 17] {
            let hits: Vec<AtomicUsize> =
                (0..jobs).map(|_| AtomicUsize::new(0)).collect();
            pool.run(jobs, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }).unwrap();
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "jobs {jobs} i {i}");
            }
        }
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(5, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        }).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn out_slice_shards_write_disjoint_ranges() {
        let pool = WorkerPool::new(3);
        let mut buf = vec![0.0f32; 24];
        let out = OutSlice::new(&mut buf);
        pool.run(4, |i| {
            // SAFETY: job i owns [6i, 6i + 6) — disjoint and in bounds
            let s = unsafe { out.slice(i * 6, 6) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (i * 6 + k) as f32;
            }
        }).unwrap();
        for (k, &v) in buf.iter().enumerate() {
            assert_eq!(v, k as f32);
        }
    }

    #[test]
    fn job_panic_reported_after_barrier_not_raised() {
        // the supervision contract: a panicking job surfaces as an Err
        // return after the barrier — `run` itself never unwinds
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        let r = pool.run(4, |i| {
            if i == 2 {
                panic!("boom");
            }
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(r, Err(JobPanicked));
        // the barrier still ran every other job to completion
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn inline_path_reports_panics_too() {
        // jobs == 1 and width-1 pools take the lock-free inline path; the
        // no-unwind contract must hold there as well
        let pool = WorkerPool::new(1);
        assert_eq!(pool.run(3, |i| {
            if i == 0 {
                panic!("boom");
            }
        }), Err(JobPanicked));
        let wide = WorkerPool::new(4);
        assert_eq!(wide.run(1, |_| panic!("boom")), Err(JobPanicked));
        // both pools remain usable
        let hits = AtomicUsize::new(0);
        pool.run(2, |_| { hits.fetch_add(1, Ordering::SeqCst); }).unwrap();
        wide.run(2, |_| { hits.fetch_add(1, Ordering::SeqCst); }).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run(0, |_| panic!("must not run")).unwrap();
    }

    #[test]
    fn pool_lifecycle_stress() {
        // repeated spawn → exec → drop cycles at every width: the TSan
        // lane runs this to prove worker startup, the go/done barriers,
        // and Drop's shutdown handshake race-free
        for round in 0..8usize {
            for width in 1..=4usize {
                let pool = WorkerPool::new(width);
                for jobs in [1usize, 2, 7, 16] {
                    let hits: Vec<AtomicUsize> =
                        (0..jobs).map(|_| AtomicUsize::new(0)).collect();
                    pool.run(jobs, |i| {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    }).unwrap();
                    for (i, h) in hits.iter().enumerate() {
                        assert_eq!(h.load(Ordering::SeqCst), 1,
                                   "round {round} width {width} jobs \
                                    {jobs} i {i}");
                    }
                }
                // `pool` drops here: joins every worker
            }
        }
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // model clones share one Arc<WorkerPool>; `run` serializes epochs
        // on the submit lock. Hammer it from several threads and count
        // every job exactly once.
        let pool = std::sync::Arc::new(WorkerPool::new(3));
        let total = std::sync::Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    pool.run(5, |_| {
                        total.fetch_add(1, Ordering::SeqCst);
                    }).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 25 * 5);
    }

    #[test]
    fn pool_survives_a_job_panic() {
        // regression (the old `run` re-panicked and could poison the submit
        // lock): after a panicked epoch the very next run must produce
        // bit-correct results — checked by value, not just by count
        let pool = WorkerPool::new(2);
        assert_eq!(pool.run(3, |i| {
            if i == 1 {
                panic!("boom");
            }
        }), Err(JobPanicked));
        let mut buf = vec![0.0f32; 12];
        let out = OutSlice::new(&mut buf);
        pool.run(4, |i| {
            // SAFETY: job i owns [3i, 3i + 3) — disjoint and in bounds
            let s = unsafe { out.slice(i * 3, 3) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (i * 3 + k) as f32;
            }
        }).unwrap();
        for (k, &v) in buf.iter().enumerate() {
            assert_eq!(v, k as f32, "wrong value after panicked epoch");
        }
        assert_eq!(pool.dead_workers(), 0);
    }

    #[test]
    fn chaos_killed_workers_are_respawned() {
        let pool = WorkerPool::new(3);
        pool.chaos_kill_worker();
        // the marked worker exits the next time it reaches its dispatch
        // loop; give it a bounded moment to actually die
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(5);
        while pool.dead_workers() == 0
            && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.dead_workers(), 1, "worker did not exit");
        // the next run respawns to full width and completes every job
        let hits: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        pool.run(16, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        }).unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "job {i}");
        }
        assert_eq!(pool.dead_workers(), 0, "respawn did not happen");
        assert_eq!(pool.threads(), 3);
    }
}
