//! Load-time execution planning: repack weights once, allocate never.
//!
//! The pre-plan engine re-unpacked the same 3/4-bit weight tiles from the
//! packed bitstream on **every** forward call and allocated fresh scratch
//! everywhere. This module moves all of that to model-load time:
//!
//! * [`TilePlan`] — each [`PackedMatrix`] is unpacked **exactly once**
//!   (bit-identical codes, streamed tile-by-tile) into a lane-padded
//!   row-major tile layout of one `u8` per code: weight row `j` occupies
//!   `data[j·stride .. j·stride + cin]` with `stride = cin` rounded up to
//!   [`crate::infer::simd::LANE`] and zero-filled tails. Tile `t` is the
//!   `MR` consecutive rows `[t·MR, t·MR + rn)` (`rn < MR` only for the
//!   ragged tail), so both the scalar-oracle micro-kernel
//!   ([`crate::infer::kernels::dot_block_u8_scalar`]) and the vector
//!   kernels ([`crate::infer::simd::dot_block_u8`]) stream rows whose
//!   vector steps never cross a row boundary — zero per-call unpack, one
//!   layout for every backend.
//! * [`Scratch`] — a buffer arena recycled across forward calls: activation
//!   code buffers, GEMM outputs, attention workspaces. In steady state a
//!   decode step allocates nothing inside the model — the only escaping
//!   allocation is the logits tensor handed back to the caller.
//! * [`Exec`] / [`ExecState`] — the per-engine execution context bundling
//!   the persistent [`WorkerPool`], the [`ExecMode`], and the arena; every
//!   forward entry point borrows one `Exec` and threads it down to the
//!   kernels.

use std::sync::Arc;

use crate::obs::Profiler;
use crate::quant::PackedMatrix;
use crate::tensor::Tensor;

use super::kernels::{unpack_rows, QuantActs};
use super::pool::WorkerPool;
use super::simd::{self, Backend, LANE};

/// Micro-kernel register block: output rows per weight tile and token rows
/// per activation block (4×4 = 16 independent accumulators).
pub const MR: usize = 4;

/// A weight matrix repacked for planned execution (see module docs).
#[derive(Clone, Debug)]
pub struct TilePlan {
    pub cout: usize,
    pub cin: usize,
    /// row length in `data`: `cin` rounded up to [`LANE`] (zero-padded
    /// tail), so every row starts on a vector-lane boundary
    stride: usize,
    /// lane-padded row-major codes: weight row `j` occupies
    /// `data[j·stride .. j·stride + cin]`
    data: Vec<u8>,
}

impl TilePlan {
    /// Unpack `pm` once (streaming, `MR` rows at a time — never the full
    /// `rows × cols` temporary the pre-plan loader materialized) into the
    /// lane-padded row-major layout, computing the per-row code sums of
    /// the dequant epilogue in the same pass.
    pub fn from_packed(pm: &PackedMatrix) -> (TilePlan, Vec<i64>) {
        let (rows, cols) = (pm.rows, pm.cols);
        let stride = cols.div_ceil(LANE) * LANE;
        let mut data = vec![0u8; rows * stride];
        let mut code_sum = vec![0i64; rows];
        let mut rowbuf = vec![0u8; MR * cols];
        let mut r0 = 0usize;
        while r0 < rows {
            let rn = MR.min(rows - r0);
            unpack_rows(&pm.packed, pm.bits, cols, r0, rn, &mut rowbuf);
            for r in 0..rn {
                let src = &rowbuf[r * cols..(r + 1) * cols];
                let dst = (r0 + r) * stride;
                data[dst..dst + cols].copy_from_slice(src);
                code_sum[r0 + r] =
                    src.iter().map(|&c| c as i64).sum::<i64>();
            }
            r0 += rn;
        }
        (TilePlan { cout: rows, cin: cols, stride, data }, code_sum)
    }

    /// Number of row tiles (the last may be ragged).
    pub fn n_tiles(&self) -> usize {
        self.cout.div_ceil(MR)
    }

    /// Row stride in bytes inside [`TilePlan::tile`] slices (`>= cin`, a
    /// [`LANE`] multiple).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Tile `t`'s lane-padded row-major bytes and its row count `rn`:
    /// weight row `r` of the tile is `bytes[r·stride .. r·stride + cin]`.
    pub fn tile(&self, t: usize) -> (&[u8], usize) {
        let r0 = t * MR;
        let rn = MR.min(self.cout - r0);
        (&self.data[r0 * self.stride..(r0 + rn) * self.stride], rn)
    }

    /// Gather output row `j` back to row-major codes (round-trip proofs;
    /// `out.len() == cin`).
    pub fn row_codes(&self, j: usize, out: &mut [u8]) {
        debug_assert_eq!(out.len(), self.cin);
        out.copy_from_slice(
            &self.data[j * self.stride..j * self.stride + self.cin]);
    }

    /// Repacked bytes held by the plan (capacity accounting; includes the
    /// lane padding).
    pub fn plan_bytes(&self) -> usize {
        self.data.len()
    }
}

/// How a linear executes its GEMMs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The planned engine: interleaved tiles + register-blocked micro-kernel
    /// on the persistent pool.
    Planned,
    /// The pre-plan engine (single-threaded, per-call tile unpack) — the
    /// bit-exact oracle the planned path is tested against, and the
    /// baseline of the bench's speedup comparison.
    Reference,
}

/// Recyclable buffer arena (see module docs). Buffers keep their capacity
/// across calls, so steady-state forward/decode steps stop allocating once
/// the working-set sizes have been seen once.
#[derive(Clone, Debug, Default)]
pub struct Scratch {
    f32s: Vec<Vec<f32>>,
    acts: Vec<QuantActs>,
}

impl Scratch {
    /// A zero-filled `f32` buffer of exactly `len` elements.
    pub fn zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// An empty `f32` buffer (capacity recycled; caller fills it).
    pub fn take(&mut self) -> Vec<f32> {
        let mut v = self.f32s.pop().unwrap_or_default();
        v.clear();
        v
    }

    pub fn put(&mut self, v: Vec<f32>) {
        self.f32s.push(v);
    }

    /// A zero-filled `[rows, cols]` tensor backed by a recycled buffer.
    pub fn tensor(&mut self, rows: usize, cols: usize) -> Tensor {
        Tensor::new(vec![rows, cols], self.zeroed(rows * cols))
    }

    /// Recycle a tensor's backing buffer.
    pub fn put_tensor(&mut self, t: Tensor) {
        self.f32s.push(t.data);
    }

    /// A recycled activation-code holder (filled by
    /// [`crate::infer::kernels::quantize_acts_per_token_into`] /
    /// [`crate::infer::kernels::quantize_acts_static_into`]).
    pub fn take_acts(&mut self) -> QuantActs {
        self.acts.pop().unwrap_or_default()
    }

    pub fn put_acts(&mut self, a: QuantActs) {
        self.acts.push(a);
    }

    /// Buffers currently parked in the arena (tests / introspection).
    pub fn pooled(&self) -> usize {
        self.f32s.len() + self.acts.len()
    }
}

/// Borrowed execution context threaded through one forward call.
pub struct Exec<'a> {
    pub pool: &'a WorkerPool,
    pub mode: ExecMode,
    /// integer-GEMM kernel backend of this engine instance (the planned
    /// path dispatches on it; `ExecMode::Reference` is always scalar)
    pub backend: Backend,
    pub scratch: &'a mut Scratch,
    /// the owning model's profiler; every hook is a no-op relaxed load
    /// until [`Profiler::set_enabled`] flips it on
    pub prof: &'a Profiler,
    /// layer the profiling hooks attribute work to — set by the model's
    /// block loop, [`crate::obs::MODEL_SLOT`] outside the layer stack
    pub layer: usize,
}

/// Owned execution state of one engine instance: the shared persistent pool
/// plus this instance's private arena. Clones share the pool (threads are
/// spawned once) but get their own arena.
#[derive(Clone, Debug)]
pub struct ExecState {
    pool: Arc<WorkerPool>,
    mode: ExecMode,
    /// integer-GEMM backend; defaults to the process-wide
    /// [`simd::active`] resolution at construction, overridable per
    /// instance ([`ExecState::with_kernel`]) so equivalence tests can run
    /// forced-scalar and forced-SIMD engines side by side
    backend: Backend,
    scratch: Scratch,
    /// shared with every clone of the owning model, so profiles aggregate
    /// across server shards
    prof: Arc<Profiler>,
}

impl ExecState {
    /// Fresh state with its own `threads`-wide pool, planned mode.
    pub fn new(threads: usize) -> ExecState {
        ExecState::shared(Arc::new(WorkerPool::new(threads)))
    }

    /// State over an existing pool (model clones, multi-model hosts).
    pub fn shared(pool: Arc<WorkerPool>) -> ExecState {
        ExecState {
            pool,
            mode: ExecMode::Planned,
            backend: simd::active(),
            scratch: Scratch::default(),
            prof: Arc::new(Profiler::disabled()),
        }
    }

    /// Install the model-sized profiler (called once at model load).
    pub fn set_profiler(&mut self, prof: Arc<Profiler>) {
        self.prof = prof;
    }

    pub fn profiler(&self) -> &Arc<Profiler> {
        &self.prof
    }

    pub fn with_mode(mut self, mode: ExecMode) -> ExecState {
        self.mode = mode;
        self
    }

    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn with_kernel(mut self, backend: Backend) -> ExecState {
        self.backend = backend;
        self
    }

    pub fn set_kernel(&mut self, backend: Backend) {
        self.backend = backend;
    }

    pub fn kernel(&self) -> Backend {
        self.backend
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Borrow the context for one forward call.
    pub fn exec(&mut self) -> Exec<'_> {
        Exec {
            pool: self.pool.as_ref(),
            mode: self.mode,
            backend: self.backend,
            scratch: &mut self.scratch,
            prof: self.prof.as_ref(),
            layer: crate::obs::MODEL_SLOT,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_bits;
    use crate::rng::Rng;

    fn random_pm(rng: &mut Rng, rows: usize, cols: usize, bits: u32)
                 -> (Vec<u32>, PackedMatrix) {
        let codes: Vec<u32> =
            (0..rows * cols).map(|_| rng.below(1 << bits) as u32).collect();
        let packed = pack_bits(&codes, bits);
        let pm = PackedMatrix::new(rows, cols, bits, vec![1.0; rows],
                                   vec![0.0; rows], packed)
            .unwrap();
        (codes, pm)
    }

    #[test]
    fn tile_plan_roundtrips_codes_and_sums() {
        let mut rng = Rng::new(51);
        for bits in [3u32, 4, 8] {
            // ragged tails: cout % MR covers 0..=3 across these shapes
            for (rows, cols) in [(1usize, 5usize), (3, 8), (4, 7), (9, 33),
                                 (10, 6), (16, 16)] {
                let (codes, pm) = random_pm(&mut rng, rows, cols, bits);
                let (plan, sums) = TilePlan::from_packed(&pm);
                assert_eq!(plan.n_tiles(), rows.div_ceil(MR));
                assert_eq!(plan.stride(), cols.div_ceil(LANE) * LANE);
                assert_eq!(plan.plan_bytes(), rows * plan.stride());
                let mut row = vec![0u8; cols];
                for j in 0..rows {
                    plan.row_codes(j, &mut row);
                    let mut want_sum = 0i64;
                    for c in 0..cols {
                        let want = codes[j * cols + c];
                        want_sum += want as i64;
                        assert_eq!(row[c] as u32, want,
                                   "bits {bits} {rows}x{cols} j{j} c{c}");
                    }
                    assert_eq!(sums[j], want_sum, "bits {bits} row {j}");
                }
            }
        }
    }

    #[test]
    fn tile_layout_is_lane_padded_row_major() {
        let mut rng = Rng::new(52);
        let (codes, pm) = random_pm(&mut rng, 8, 10, 4);
        let (plan, _) = TilePlan::from_packed(&pm);
        let (tile, rn) = plan.tile(1); // rows 4..8
        assert_eq!(rn, MR);
        let stride = plan.stride();
        assert_eq!(stride, LANE); // 10 rounds up to one 16-byte lane
        assert_eq!(tile.len(), rn * stride);
        for r in 0..rn {
            for c in 0..10 {
                assert_eq!(tile[r * stride + c] as u32,
                           codes[(MR + r) * 10 + c], "c{c} r{r}");
            }
            // padding past cin is zero, so vector loads that stop at the
            // scalar tail never see garbage even if widened later
            for c in 10..stride {
                assert_eq!(tile[r * stride + c], 0, "pad r{r} c{c}");
            }
        }
    }

    #[test]
    fn scratch_recycles_capacity() {
        let mut s = Scratch::default();
        let v = s.zeroed(64);
        assert_eq!(v.len(), 64);
        let p = v.as_ptr();
        s.put(v);
        assert_eq!(s.pooled(), 1);
        let v2 = s.zeroed(32);
        // same backing allocation comes back (shrunk in place)
        assert_eq!(v2.as_ptr(), p);
        assert!(v2.iter().all(|&x| x == 0.0));
        s.put(v2);
        let t = s.tensor(4, 8);
        assert_eq!(t.as_2d(), (4, 8));
        s.put_tensor(t);
        let qa = s.take_acts();
        s.put_acts(qa);
        assert_eq!(s.pooled(), 2);
    }

    #[test]
    fn exec_state_modes_and_threads() {
        let mut st = ExecState::new(2).with_mode(ExecMode::Reference);
        assert_eq!(st.mode(), ExecMode::Reference);
        assert_eq!(st.threads(), 2);
        assert_eq!(st.kernel(), simd::active());
        st.set_mode(ExecMode::Planned);
        st.set_kernel(Backend::Scalar);
        let e = st.exec();
        assert_eq!(e.mode, ExecMode::Planned);
        assert_eq!(e.backend, Backend::Scalar);
        // clones share the pool but not the arena
        let st2 = st.clone().with_kernel(simd::detect());
        assert_eq!(st2.threads(), 2);
        assert_eq!(st2.kernel(), simd::detect());
    }
}
