//! Artifact-free post-training quantization: turn an FP [`Weights`] into a
//! packed [`QuantizedModel`] (RTN or grid-searched per-channel scales) and
//! calibrate static activation grids with the native FP forward — no PJRT,
//! no AOT artifacts. Checkpoints produced by the full pipeline
//! ([`crate::coordinator::quantize_model`], any method) serve through the
//! same [`NativeModel`]; this module exists so `lrq serve-native` and the
//! tests can run from a bare weights file.

use anyhow::Result;

use crate::config::{ActScheme, Scheme};
use crate::coordinator::engine::BlockStats;
use crate::data::Corpus;
use crate::model::{QuantizedBlock, QuantizedModel, Weights};
use crate::quant::{grid_search_scales, lrq::quantize_int_codes, qmax,
                   rtn_grid, PackedMatrix};
use crate::rng::Rng;

use super::block::NativeModel;
use super::ops::embed;
use super::reference::fp_block_forward;

/// Per-channel scale initializer for artifact-free quantization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleInit {
    /// min/max RTN grid
    Rtn,
    /// RTN refined by the FlexRound/LRQ `argmin ||W - Ŵ||²` grid search
    GridSearch,
}

impl std::str::FromStr for ScaleInit {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rtn" => ScaleInit::Rtn,
            "grid" | "gridsearch" | "grid-search" => ScaleInit::GridSearch,
            other => anyhow::bail!("unknown scale init {other} \
                                    (rtn | grid)"),
        })
    }
}

/// Quantize every block linear of `weights` to packed `w_bits` codes.
/// Embeddings, norms, and the head stay FP (paper scheme).
pub fn quantize_weights(weights: &Weights, w_bits: u32, init: ScaleInit)
                        -> Result<QuantizedModel> {
    let qm_val = qmax(w_bits);
    let mut blocks = Vec::with_capacity(weights.blocks.len());
    for bw in &weights.blocks {
        let mut ws = Vec::with_capacity(7);
        for w in &bw.ws {
            let grid = match init {
                ScaleInit::Rtn => rtn_grid(w, qm_val),
                ScaleInit::GridSearch => grid_search_scales(w, qm_val, 40),
            };
            let codes = quantize_int_codes(w, &grid, None);
            ws.push(PackedMatrix::from_codes(&codes, &grid.scale, &grid.zp,
                                             w_bits)?);
        }
        blocks.push(QuantizedBlock {
            ws,
            norm_attn: bw.norm_attn.clone(),
            norm_ffn: bw.norm_ffn.clone(),
        });
    }
    Ok(QuantizedModel {
        dim: weights.dim.clone(),
        bits: w_bits,
        emb: weights.emb.clone(),
        blocks,
        final_norm: weights.final_norm.clone(),
        head: weights.head.clone(),
    })
}

/// Calibrate static activation grids by streaming `batches` calibration
/// batches through the native FP forward, merging (min, max, amax) at the
/// four quant points of every block.
pub fn calibrate_stats(weights: &Weights, corpus: &Corpus, batches: usize,
                       seed: u64) -> Result<Vec<BlockStats>> {
    let dim = &weights.dim;
    let mut stats: Vec<BlockStats> =
        (0..weights.blocks.len()).map(|_| Default::default()).collect();
    let mut rng = Rng::new(seed ^ 0xCA11B);
    for _ in 0..batches.max(1) {
        let ids = corpus.calib_batch(dim.calib_batch, dim.seq, &mut rng);
        let mut x = embed(&weights.emb, &ids)?;
        for (bw, st) in weights.blocks.iter().zip(stats.iter_mut()) {
            x = fp_block_forward(&x, bw, dim, st)?;
        }
    }
    Ok(stats)
}

/// One-call setup for artifact-free native serving: quantize, calibrate (if
/// the scheme needs static grids), and assemble a [`NativeModel`].
pub fn prepare_native(weights: &Weights, scheme: Scheme, init: ScaleInit,
                      corpus: &Corpus, calib_batches: usize, seed: u64,
                      shards: usize) -> Result<NativeModel> {
    let qm = quantize_weights(weights, scheme.w_bits, init)?;
    prepare_native_from(&qm, weights, scheme, corpus, calib_batches, seed,
                        shards)
}

/// Like [`prepare_native`] but serving an already-quantized checkpoint (an
/// `LRQQ` file from `lrq quantize --out`, loaded via
/// [`QuantizedModel::load`]): skips weight quantization entirely. `weights`
/// is still consulted when the scheme needs static activation grids — the
/// calibration forward runs on FP weights by design.
pub fn prepare_native_from(qm: &QuantizedModel, weights: &Weights,
                           scheme: Scheme, corpus: &Corpus,
                           calib_batches: usize, seed: u64, shards: usize)
                           -> Result<NativeModel> {
    anyhow::ensure!(
        scheme.w_bits == qm.bits,
        "scheme says W{} but the checkpoint is packed at W{}",
        scheme.w_bits, qm.bits
    );
    let stats = if matches!(scheme.act, ActScheme::PerTensorStatic) {
        calibrate_stats(weights, corpus, calib_batches, seed)?
    } else {
        Vec::new()
    };
    NativeModel::from_quantized(qm, &stats, scheme, shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::CorpusConfig;
    use crate::model::ModelDim;

    fn micro_dim() -> ModelDim {
        ModelDim::builtin("micro").expect("micro builtin")
    }

    #[test]
    fn quantize_produces_valid_packed_model() {
        let dim = micro_dim();
        let w = Weights::init(&dim, &mut Rng::new(1));
        for bits in [3u32, 4, 8] {
            let qm = quantize_weights(&w, bits, ScaleInit::GridSearch)
                .unwrap();
            assert_eq!(qm.blocks.len(), dim.layers);
            assert_eq!(qm.bits, bits);
            assert!(qm.storage_bytes() < qm.fp_equivalent_bytes());
            // every matrix dequantizes close to the FP weight
            let dq = qm.blocks[0].ws[0].dequant();
            let rel = dq.rmse(&w.blocks[0].ws[0])
                / (w.blocks[0].ws[0].frob()
                   / (dq.len() as f64).sqrt()).max(1e-12);
            assert!(rel < 0.5, "bits {bits} rel {rel}");
        }
    }

    #[test]
    fn calibration_populates_ranges() {
        let dim = micro_dim();
        let w = Weights::init(&dim, &mut Rng::new(2));
        let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 7));
        let stats = calibrate_stats(&w, &corpus, 2, 3).unwrap();
        assert_eq!(stats.len(), dim.layers);
        for st in &stats {
            for p in st.iter() {
                assert!(p.range.max > 0.0);
                assert!(!p.amax.is_empty());
            }
        }
        // point dims match the layout contract
        assert_eq!(stats[0][0].amax.len(), dim.d);
        assert_eq!(stats[0][3].amax.len(), dim.ff);
    }
}
