//! [`NativeScorer`]: the native engine behind the existing dynamic batcher.
//!
//! Implements [`crate::serve::BatchScorer`] over a [`NativeModel`], so
//! [`crate::serve::Server`] serves packed checkpoints unchanged. Unlike the
//! PJRT engine the native model is `Send`: it can be quantized/calibrated on
//! the caller's thread and *moved* into the engine thread
//! ([`start_native_server`]), and its GEMMs row-shard across
//! `model.shards` scoped worker threads.

use anyhow::Result;

use crate::serve::{BatchScorer, Server, ServerConfig};

use super::block::NativeModel;

pub struct NativeScorer {
    pub model: NativeModel,
    batch: usize,
}

impl NativeScorer {
    /// Default batch capacity: the config's calibration batch (parity with
    /// the PJRT `EngineScorer`).
    pub fn new(model: NativeModel) -> Self {
        let batch = model.dim.calib_batch.max(1);
        NativeScorer { model, batch }
    }

    /// Override the rows-per-execution capacity (the native engine has no
    /// fixed-shape artifacts, so any batch works).
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }
}

impl BatchScorer for NativeScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.model.dim.seq
    }

    /// The native engine has no fixed-shape artifacts: partially filled
    /// batches are executed at their true occupancy, not padded to capacity.
    fn variable_batch(&self) -> bool {
        true
    }

    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        let (_, logp) = self.model.forward(ids, targets)?;
        Ok(logp.data)
    }
}

/// Start the dynamic batcher over a native model. The model is built here,
/// on the caller's thread, and moved into the engine thread — legal because
/// the native engine is `Send` (the PJRT path must construct inside).
/// Scorer capacity follows `cfg.max_batch` (the native engine has no
/// fixed-shape artifacts, so the batching knob is fully honored).
pub fn start_native_server(model: NativeModel, cfg: ServerConfig)
                           -> Result<Server> {
    let scorer = NativeScorer::new(model).with_batch(cfg.max_batch);
    Server::start(cfg, move || Ok(Box::new(scorer) as Box<dyn BatchScorer>))
}
