//! [`NativeScorer`]: the native engine behind the dynamic batcher.
//!
//! Implements [`crate::serve::BatchScorer`] over a [`NativeModel`] for both
//! workload kinds: **score** (full-sequence log-probs, as before) and
//! **generate** (incremental decode). Each generation owns an engine-side
//! [`KvCache`]; the serve loop batches decode steps across active
//! sequences, and [`NativeScorer::decode_step`] executes them as one
//! `[n, d]` model step so every linear's unpack/GEMM work is shared.
//!
//! Unlike the PJRT engine the native model is `Send`: it can be
//! quantized/calibrated on the caller's thread and *moved* into the engine
//! thread ([`start_native_server`]), and its GEMMs tile-shard across the
//! persistent `model.shards`-wide worker pool spawned once at model load
//! (no per-call thread spawns — see `infer::pool`).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::obs::trace;
use crate::serve::{BatchScorer, SeqId, Server, ServerConfig};

use super::block::NativeModel;
use super::decode::KvCache;

pub struct NativeScorer {
    pub model: NativeModel,
    batch: usize,
    /// engine-owned KV caches of active decode sequences
    seqs: HashMap<SeqId, KvCache>,
    next_seq: SeqId,
}

impl NativeScorer {
    /// Default batch capacity: the config's calibration batch (parity with
    /// the PJRT `EngineScorer`).
    pub fn new(model: NativeModel) -> Self {
        let batch = model.dim.calib_batch.max(1);
        NativeScorer { model, batch, seqs: HashMap::new(), next_seq: 0 }
    }

    /// Override the rows-per-execution capacity (the native engine has no
    /// fixed-shape artifacts, so any batch works).
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Active decode sequences currently holding a KV cache.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

impl BatchScorer for NativeScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.model.dim.seq
    }

    /// The native engine has no fixed-shape artifacts: partially filled
    /// batches are executed at their true occupancy, not padded to capacity.
    fn variable_batch(&self) -> bool {
        true
    }

    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        let (_, logp) = self.model.forward(ids, targets)?;
        Ok(logp.data)
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn begin_decode(&mut self, prompt: &[i32]) -> Result<(SeqId, Vec<f32>)> {
        let sp = trace::begin();
        let mut cache = self.model.new_cache();
        let logits = self.model.prefill(prompt, &mut cache)?;
        let sid = self.next_seq;
        self.next_seq += 1;
        self.seqs.insert(sid, cache);
        trace::complete(sp, || {
            ("prefill".to_string(),
             Some(format!("{{\"seq\":{sid},\"prompt_len\":{}}}",
                          prompt.len())))
        });
        Ok((sid, logits))
    }

    fn decode_step(&mut self, batch: &[(SeqId, i32)])
                   -> Result<Vec<Vec<f32>>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        // take the caches out of the map so the whole step runs as one
        // batched [n, d] model execution, then put them back. Removal also
        // catches duplicate handles in one batch (the second take fails),
        // which a contains_key pre-check would miss.
        let mut sids = Vec::with_capacity(batch.len());
        let mut toks = Vec::with_capacity(batch.len());
        let mut caches = Vec::with_capacity(batch.len());
        for &(sid, tok) in batch {
            match self.seqs.remove(&sid) {
                Some(c) => {
                    sids.push(sid);
                    toks.push(tok);
                    caches.push(c);
                }
                None => {
                    for (s, c) in sids.into_iter().zip(caches) {
                        self.seqs.insert(s, c);
                    }
                    bail!("decode_step: unknown or duplicate sequence \
                           {sid}");
                }
            }
        }
        let stepped = self.model.decode_step(&toks, &mut caches);
        for (sid, cache) in sids.into_iter().zip(caches) {
            self.seqs.insert(sid, cache);
        }
        let logits = stepped?;
        let (n, vocab) = logits.as_2d();
        debug_assert_eq!(n, batch.len());
        Ok(logits.data.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn end_decode(&mut self, sid: SeqId) {
        self.seqs.remove(&sid);
    }
}

/// Start the dynamic batcher over a native model. The model is built here,
/// on the caller's thread, and moved into the engine thread — legal because
/// the native engine is `Send` (the PJRT path must construct inside).
/// Scorer capacity follows `cfg.max_batch` (the native engine has no
/// fixed-shape artifacts, so the batching knob is fully honored).
pub fn start_native_server(model: NativeModel, cfg: ServerConfig)
                           -> Result<Server> {
    let scorer = NativeScorer::new(model).with_batch(cfg.max_batch);
    Server::start(cfg, move || Ok(Box::new(scorer) as Box<dyn BatchScorer>))
}
