//! [`NativeScorer`]: the native engine behind the dynamic batcher.
//!
//! Implements [`crate::serve::BatchScorer`] over a [`NativeModel`] for both
//! workload kinds: **score** (full-sequence log-probs, as before) and
//! **generate** (incremental decode). Each generation owns an engine-side
//! [`KvCache`]; the serve loop batches decode steps across active
//! sequences, and [`NativeScorer::decode_step`] executes them as one
//! `[n, d]` model step so every linear's unpack/GEMM work is shared.
//!
//! Unlike the PJRT engine the native model is `Send`: it can be
//! quantized/calibrated on the caller's thread and *moved* into the engine
//! thread ([`start_native_server`]), and its GEMMs tile-shard across the
//! persistent `model.shards`-wide worker pool spawned once at model load
//! (no per-call thread spawns — see `infer::pool`).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::obs::trace;
use crate::serve::{BatchScorer, ChaosScorer, FaultPlan, SeqId, Server,
                   ServerConfig};

use super::block::NativeModel;
use super::decode::KvCache;

pub struct NativeScorer {
    pub model: NativeModel,
    /// cheaper pre-built plan for load-shed downshifts (e.g. the same
    /// checkpoint packed at W4A8 next to a W8A8 primary); `None` disables
    /// degraded mode
    degraded_model: Option<NativeModel>,
    /// whether work is currently routed through the degraded plan
    use_degraded: bool,
    batch: usize,
    /// engine-owned KV caches of active decode sequences
    seqs: HashMap<SeqId, KvCache>,
    next_seq: SeqId,
}

impl NativeScorer {
    /// Default batch capacity: the config's calibration batch (parity with
    /// the PJRT `EngineScorer`).
    pub fn new(model: NativeModel) -> Self {
        let batch = model.dim.calib_batch.max(1);
        NativeScorer { model, degraded_model: None, use_degraded: false,
                       batch, seqs: HashMap::new(), next_seq: 0 }
    }

    /// Override the rows-per-execution capacity (the native engine has no
    /// fixed-shape artifacts, so any batch works).
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Attach a cheaper pre-built plan the serve loop may downshift to
    /// under load (DESIGN.md §13). The two models must share dimensions and
    /// KV-cache scheme: live caches keep decoding across a switch, so a
    /// cache written by one plan must be readable by the other (the KV grid
    /// math depends only on `kv_quant`/`kv_bits`, not the weight bits).
    pub fn with_degraded(mut self, degraded: NativeModel) -> Result<Self> {
        if degraded.dim != self.model.dim {
            bail!("degraded plan dims {:?} differ from primary {:?}",
                  degraded.dim.name, self.model.dim.name);
        }
        if degraded.scheme.kv_quant != self.model.scheme.kv_quant
            || degraded.scheme.kv_bits != self.model.scheme.kv_bits
            || degraded.blocks.len() != self.model.blocks.len() {
            bail!("degraded plan KV scheme (kv_quant={} kv_bits={} layers={})\
                   is incompatible with primary (kv_quant={} kv_bits={} \
                   layers={}): live caches could not survive a downshift",
                  degraded.scheme.kv_quant, degraded.scheme.kv_bits,
                  degraded.blocks.len(), self.model.scheme.kv_quant,
                  self.model.scheme.kv_bits, self.model.blocks.len());
        }
        self.degraded_model = Some(degraded);
        Ok(self)
    }

    /// The plan current work routes through.
    fn active(&self) -> &NativeModel {
        match (&self.degraded_model, self.use_degraded) {
            (Some(m), true) => m,
            _ => &self.model,
        }
    }

    /// Active decode sequences currently holding a KV cache.
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
}

impl BatchScorer for NativeScorer {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.model.dim.seq
    }

    /// The native engine has no fixed-shape artifacts: partially filled
    /// batches are executed at their true occupancy, not padded to capacity.
    fn variable_batch(&self) -> bool {
        true
    }

    fn score(&mut self, ids: &[i32], targets: &[i32]) -> Result<Vec<f32>> {
        let (_, logp) = self.active().forward(ids, targets)?;
        Ok(logp.data)
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn begin_decode(&mut self, prompt: &[i32]) -> Result<(SeqId, Vec<f32>)> {
        let sp = trace::begin();
        let mut cache = self.active().new_cache();
        let logits = self.active().prefill(prompt, &mut cache)?;
        let sid = self.next_seq;
        self.next_seq += 1;
        self.seqs.insert(sid, cache);
        trace::complete(sp, || {
            ("prefill".to_string(),
             Some(format!("{{\"seq\":{sid},\"prompt_len\":{}}}",
                          prompt.len())))
        });
        Ok((sid, logits))
    }

    fn decode_step(&mut self, batch: &[(SeqId, i32)])
                   -> Result<Vec<Vec<f32>>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        // take the caches out of the map so the whole step runs as one
        // batched [n, d] model execution, then put them back. Removal also
        // catches duplicate handles in one batch (the second take fails),
        // which a contains_key pre-check would miss.
        let mut sids = Vec::with_capacity(batch.len());
        let mut toks = Vec::with_capacity(batch.len());
        let mut caches = Vec::with_capacity(batch.len());
        for &(sid, tok) in batch {
            match self.seqs.remove(&sid) {
                Some(c) => {
                    sids.push(sid);
                    toks.push(tok);
                    caches.push(c);
                }
                None => {
                    for (s, c) in sids.into_iter().zip(caches) {
                        self.seqs.insert(s, c);
                    }
                    bail!("decode_step: unknown or duplicate sequence \
                           {sid}");
                }
            }
        }
        let stepped = self.active().decode_step(&toks, &mut caches);
        for (sid, cache) in sids.into_iter().zip(caches) {
            self.seqs.insert(sid, cache);
        }
        let logits = stepped?;
        let (n, vocab) = logits.as_2d();
        debug_assert_eq!(n, batch.len());
        Ok(logits.data.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    fn end_decode(&mut self, sid: SeqId) {
        self.seqs.remove(&sid);
    }

    fn supports_degrade(&self) -> bool {
        self.degraded_model.is_some()
    }

    /// Route subsequent work through the degraded plan. Live KV caches stay
    /// valid: `with_degraded` enforced an identical cache scheme, so active
    /// sequences keep decoding through the cheaper weights.
    fn set_degraded(&mut self, on: bool) {
        self.use_degraded = on && self.degraded_model.is_some();
    }

    fn degraded(&self) -> bool {
        self.use_degraded
    }
}

/// Start the dynamic batcher over a native model. The model is built here,
/// on the caller's thread, and moved into the engine thread — legal because
/// the native engine is `Send` (the PJRT path must construct inside).
/// Scorer capacity follows `cfg.max_batch` (the native engine has no
/// fixed-shape artifacts, so the batching knob is fully honored).
pub fn start_native_server(model: NativeModel, cfg: ServerConfig)
                           -> Result<Server> {
    start_native_server_with(model, None, cfg, None)
}

/// [`start_native_server`] with the overload-and-failure extras wired in:
/// an optional pre-built `degraded` plan (enables `cfg.degrade` downshifts)
/// and an optional fault-injection plan (`lrq soak --chaos` wraps the
/// scorer in a [`ChaosScorer`] so injected faults travel the production
/// failure paths).
pub fn start_native_server_with(model: NativeModel,
                                degraded: Option<NativeModel>,
                                cfg: ServerConfig,
                                fault: Option<Arc<FaultPlan>>)
                                -> Result<Server> {
    let mut scorer = NativeScorer::new(model).with_batch(cfg.max_batch);
    if let Some(d) = degraded {
        scorer = scorer.with_degraded(d)?;
    }
    let chaos = fault.clone();
    Server::start_with(cfg, fault, move || {
        let mut inner = Box::new(scorer) as Box<dyn BatchScorer>;
        if let Some(plan) = chaos {
            inner = Box::new(ChaosScorer::new(inner, plan));
        }
        Ok(inner)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ActScheme, Scheme};
    use crate::data::{Corpus, CorpusConfig};
    use crate::infer::quantize::{prepare_native, ScaleInit};
    use crate::model::{ModelDim, Weights};
    use crate::rng::Rng;

    fn micro_model(w_bits: u32, kv_bits: u32) -> NativeModel {
        let dim = ModelDim::builtin("micro").expect("micro builtin");
        // per-token activations: no calibration pass needed
        let scheme = Scheme { w_bits, act: ActScheme::PerToken, a_bits: 8,
                              kv_quant: true, kv_bits };
        let w = Weights::init(&dim, &mut Rng::new(7));
        let corpus = Corpus::new(CorpusConfig::with_seed(dim.vocab, 7));
        prepare_native(&w, scheme, ScaleInit::Rtn, &corpus, 1, 7, 1)
            .expect("prepare micro model")
    }

    #[test]
    fn degraded_plan_routes_and_keeps_live_caches_decoding() {
        // W8A8 primary + W4A8 degraded built from the same weights: the
        // LRQ serving premise behind the downshift (low-bit configs retain
        // near-full accuracy, so shedding quality beats shedding requests)
        let mut sc = NativeScorer::new(micro_model(8, 8))
            .with_batch(2)
            .with_degraded(micro_model(4, 8))
            .expect("compatible degraded plan");
        assert!(sc.supports_degrade());
        assert!(!sc.degraded());

        // begin a sequence on the primary plan...
        let (sid, logits) = sc.begin_decode(&[1, 2, 3]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));

        // ...downshift, and keep decoding the same live cache
        sc.set_degraded(true);
        assert!(sc.degraded());
        let next = sc.decode_step(&[(sid, 4)]).unwrap();
        assert_eq!(next.len(), 1);
        assert!(next[0].iter().all(|v| v.is_finite()));

        // restore and take one more step — still the same sequence
        sc.set_degraded(false);
        assert!(!sc.degraded());
        let last = sc.decode_step(&[(sid, 5)]).unwrap();
        assert!(last[0].iter().all(|v| v.is_finite()));
        sc.end_decode(sid);
        assert_eq!(sc.active_seqs(), 0);
    }

    #[test]
    fn incompatible_kv_scheme_is_rejected() {
        // a degraded plan whose KV grid differs would corrupt live caches
        // on downshift — with_degraded must refuse it up front
        let err = NativeScorer::new(micro_model(8, 8))
            .with_degraded(micro_model(4, 4))
            .unwrap_err();
        assert!(format!("{err}").contains("incompatible"), "{err}");
    }

    #[test]
    fn set_degraded_without_plan_is_inert() {
        let mut sc = NativeScorer::new(micro_model(8, 8));
        assert!(!sc.supports_degrade());
        sc.set_degraded(true);
        assert!(!sc.degraded());
    }
}
