//! [`QuantBlock`] / [`NativeModel`]: the Transformer forward pass assembled
//! from packed linears, mirroring `python/compile/model.py::block_fwd` — the
//! same four activation-quant points (attn_in, o_in, ffn_in, down_in; Fig. 8),
//! the same per-token KV-cache quantization post-RoPE, the same FP softmax.
//!
//! Activation handling per [`crate::config::ActScheme`]:
//! * `None` — weight-only: FP activations into the fused unpack-matmul path.
//! * `PerTensorStatic` — calibrated `(scale, zp)` from [`BlockStats`]; one
//!   integer grid per quant point.
//! * `PerToken` — dynamic asymmetric grid per token row.
//!
//! q/k/v (and gate/up) share one quantization of their common input, exactly
//! like the `ActQuant` dispatch in the L2 model.

use anyhow::{bail, Result};

use crate::config::{ActScheme, Scheme};
use crate::coordinator::engine::BlockStats;
use crate::model::{ModelDim, QuantizedBlock, QuantizedModel};
use crate::quant::{act::per_token_quant, qmax};
use crate::tensor::Tensor;

use super::kernels::{quantize_acts_per_token, quantize_acts_static,
                     QuantActs};
use super::linear::QuantLinear;
use super::ops::{causal_attention, embed, head_logprobs, rmsnorm, rope,
                 silu};

/// One block's packed linears + FP norms, ready for native execution.
#[derive(Clone, Debug)]
pub struct QuantBlock {
    /// canonical order: wq wk wv wo wg wu wd
    pub ws: Vec<QuantLinear>,
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

/// How activations enter a linear at one quant point.
enum ActInput<'a> {
    Fp(&'a Tensor),
    Quant(QuantActs),
}

impl<'a> ActInput<'a> {
    fn matmul(&self, lin: &QuantLinear, shards: usize) -> Result<Tensor> {
        match self {
            ActInput::Fp(x) => {
                let (rows, _) = x.as_2d();
                lin.forward_fp(&x.data, rows, shards)
            }
            ActInput::Quant(qa) => lin.forward_q(qa, shards),
        }
    }
}

impl QuantBlock {
    pub fn from_quantized(qb: &QuantizedBlock) -> Result<Self> {
        if qb.ws.len() != 7 {
            bail!("quantized block has {} linears, want 7", qb.ws.len());
        }
        let ws: Result<Vec<QuantLinear>> =
            qb.ws.iter().map(QuantLinear::from_packed).collect();
        Ok(QuantBlock {
            ws: ws?,
            norm_attn: qb.norm_attn.clone(),
            norm_ffn: qb.norm_ffn.clone(),
        })
    }

    pub fn storage_bytes(&self) -> usize {
        self.ws.iter().map(|w| w.storage_bytes()).sum::<usize>()
            + (self.norm_attn.len() + self.norm_ffn.len()) * 4
    }

    /// Quantize (or pass through) the activations at one quant point.
    fn act_input<'a>(&self, x: &'a Tensor, point: usize, stats: &BlockStats,
                     scheme: &Scheme) -> ActInput<'a> {
        let (rows, cols) = x.as_2d();
        let qa = qmax(scheme.a_bits);
        match scheme.act {
            ActScheme::None => ActInput::Fp(x),
            ActScheme::PerToken => ActInput::Quant(
                quantize_acts_per_token(&x.data, rows, cols, qa)),
            ActScheme::PerTensorStatic => {
                let (s, z) = stats[point].range.grid(qa);
                ActInput::Quant(
                    quantize_acts_static(&x.data, rows, cols, s, z, qa))
            }
        }
    }

    /// One block forward: `x [b*s, d]` -> `[b*s, d]`.
    pub fn forward(&self, x: &Tensor, dim: &ModelDim, stats: &BlockStats,
                   scheme: &Scheme, shards: usize) -> Result<Tensor> {
        let (t, d) = x.as_2d();
        if d != dim.d || t % dim.seq != 0 {
            bail!("block forward: input [{t}, {d}] vs dim d={} seq={}",
                  dim.d, dim.seq);
        }
        let b = t / dim.seq;
        let (s, h, hd) = (dim.seq, dim.heads, dim.head_dim());

        // ---- attention ----
        let xa = rmsnorm(x, &self.norm_attn);
        let ain = self.act_input(&xa, 0, stats, scheme); // attn_in
        let mut q = ain.matmul(&self.ws[0], shards)?;
        let mut k = ain.matmul(&self.ws[1], shards)?;
        let v = ain.matmul(&self.ws[2], shards)?;
        rope(&mut q.data, b, s, h, hd);
        rope(&mut k.data, b, s, h, hd);
        // per-token KV quantization (post-RoPE, over the flattened d)
        let (k, v) = if scheme.kv_quant {
            let qkv = qmax(scheme.kv_bits);
            (per_token_quant(&k, qkv), per_token_quant(&v, qkv))
        } else {
            (k, v)
        };
        let attn = Tensor::new(
            vec![t, d],
            causal_attention(&q.data, &k.data, &v.data, b, s, h, hd),
        );
        let oin = self.act_input(&attn, 1, stats, scheme); // o_in
        let o = oin.matmul(&self.ws[3], shards)?;
        let hidd = x.add(&o);

        // ---- gated FFN ----
        let xf = rmsnorm(&hidd, &self.norm_ffn);
        let fin = self.act_input(&xf, 2, stats, scheme); // ffn_in
        let g = fin.matmul(&self.ws[4], shards)?;
        let u = fin.matmul(&self.ws[5], shards)?;
        let gate = g.zip(&u, |gv, uv| silu(gv) * uv);
        let din = self.act_input(&gate, 3, stats, scheme); // down_in
        let down = din.matmul(&self.ws[6], shards)?;
        Ok(hidd.add(&down))
    }
}

/// A full model executing natively from a packed checkpoint: FP embeddings /
/// norms / head (as in the paper — only block linears are quantized),
/// integer block linears.
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub dim: ModelDim,
    pub scheme: Scheme,
    /// engine worker threads for row-sharded GEMMs (1 = single-threaded)
    pub shards: usize,
    pub emb: Tensor,
    pub blocks: Vec<QuantBlock>,
    pub final_norm: Tensor,
    pub head: Tensor,
    pub stats: Vec<BlockStats>,
}

impl NativeModel {
    /// Build from any quantized checkpoint + calibrated stats. `stats` may be
    /// empty for weight-only / per-token schemes (no static grids needed).
    pub fn from_quantized(qm: &QuantizedModel, stats: &[BlockStats],
                          scheme: Scheme, shards: usize) -> Result<Self> {
        if matches!(scheme.act, ActScheme::PerTensorStatic)
            && stats.len() != qm.blocks.len() {
            bail!("static act scheme needs {} block stats, got {}",
                  qm.blocks.len(), stats.len());
        }
        // the integer path carries activation codes in u8
        if !matches!(scheme.act, ActScheme::None) && scheme.a_bits > 8 {
            bail!("native engine quantizes activations to u8 codes; \
                   a_bits {} > 8 unsupported", scheme.a_bits);
        }
        let blocks: Result<Vec<QuantBlock>> =
            qm.blocks.iter().map(QuantBlock::from_quantized).collect();
        let stats: Vec<BlockStats> = if stats.is_empty() {
            (0..qm.blocks.len()).map(|_| Default::default()).collect()
        } else {
            stats.to_vec()
        };
        Ok(NativeModel {
            dim: qm.dim.clone(),
            scheme,
            shards: shards.max(1),
            emb: qm.emb.clone(),
            blocks: blocks?,
            final_norm: qm.final_norm.clone(),
            head: qm.head.clone(),
            stats,
        })
    }

    /// Full forward over padded rows: `ids`/`targets` are `[b * seq]` with
    /// any `b >= 1`. Returns `(mean NLL, per-position target logprob [b*seq])`.
    pub fn forward(&self, ids: &[i32], targets: &[i32])
                   -> Result<(f32, Tensor)> {
        let seq = self.dim.seq;
        if ids.is_empty() || ids.len() % seq != 0 {
            bail!("forward: ids len {} not a multiple of seq {seq}",
                  ids.len());
        }
        if targets.len() != ids.len() {
            bail!("forward: {} targets for {} ids", targets.len(), ids.len());
        }
        let b = ids.len() / seq;
        let mut x = embed(&self.emb, ids)?;
        for (blk, st) in self.blocks.iter().zip(&self.stats) {
            x = blk.forward(&x, &self.dim, st, &self.scheme, self.shards)?;
        }
        let (loss, logp) =
            head_logprobs(&x, &self.final_norm, &self.head, targets)?;
        Ok((loss, Tensor::new(vec![b, seq], logp)))
    }

    /// Packed storage bytes (the Fig. 5 size axis, native layout).
    pub fn storage_bytes(&self) -> usize {
        let fp =
            (self.emb.len() + self.final_norm.len() + self.head.len()) * 4;
        fp + self.blocks.iter().map(|b| b.storage_bytes()).sum::<usize>()
    }
}
