//! [`QuantBlock`] / [`NativeModel`]: the Transformer forward pass assembled
//! from packed linears, mirroring `python/compile/model.py::block_fwd` — the
//! same four activation-quant points (attn_in, o_in, ffn_in, down_in; Fig. 8),
//! the same per-token KV-cache quantization post-RoPE, the same FP softmax.
//!
//! Activation handling per [`crate::config::ActScheme`]:
//! * `None` — weight-only: FP activations into the planned weight-only path.
//! * `PerTensorStatic` — calibrated `(scale, zp)` from [`BlockStats`]; one
//!   integer grid per quant point.
//! * `PerToken` — dynamic asymmetric grid per token row.
//!
//! q/k/v (and gate/up) share one quantization of their common input, exactly
//! like the `ActQuant` dispatch in the L2 model.
//!
//! Every forward flavor borrows one [`Exec`] — the persistent worker pool,
//! the execution mode (planned / pre-plan reference), and the scratch arena.
//! All block-internal buffers (norms, activation codes, GEMM outputs,
//! attention workspace) are taken from and returned to the arena, so a
//! steady-state decode step performs no heap allocation inside the model;
//! the only escaping allocation is the logits tensor handed to the caller.

use std::cell::RefCell;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ActScheme, Scheme};
use crate::coordinator::engine::BlockStats;
use crate::model::{ModelDim, QuantizedBlock, QuantizedModel};
use crate::obs::{trace, KernelKind, Profiler, MODEL_SLOT};
use crate::quant::{act::per_token_quant, qmax};
use crate::rng::{sample_top_k, Rng};
use crate::tensor::Tensor;

use super::decode::KvCache;
use super::kernels::{quantize_acts_per_token_into, quantize_acts_static_into};
use super::linear::QuantLinear;
use super::ops::{causal_attention, embed, embed_into, head_logits,
                 head_logprobs, rmsnorm_into, rope, rope_row, silu};
use super::plan::{Exec, ExecMode, ExecState, Scratch};

/// One block's packed linears + FP norms, ready for native execution.
#[derive(Clone, Debug)]
pub struct QuantBlock {
    /// canonical order: wq wk wv wo wg wu wd
    pub ws: Vec<QuantLinear>,
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
}

/// How activations enter a linear at one quant point.
enum ActInput<'a> {
    Fp(&'a Tensor),
    Quant(super::kernels::QuantActs),
}

impl<'a> ActInput<'a> {
    fn matmul(&self, lin: &QuantLinear, exec: &mut Exec) -> Result<Tensor> {
        match self {
            ActInput::Fp(x) => {
                let (rows, _) = x.as_2d();
                lin.forward_fp(&x.data, rows, exec)
            }
            ActInput::Quant(qa) => lin.forward_q(qa, exec),
        }
    }

    /// Return the quantized-code holder to the arena.
    fn recycle(self, scratch: &mut Scratch) {
        if let ActInput::Quant(qa) = self {
            scratch.put_acts(qa);
        }
    }
}

impl QuantBlock {
    pub fn from_quantized(qb: &QuantizedBlock) -> Result<Self> {
        if qb.ws.len() != 7 {
            bail!("quantized block has {} linears, want 7", qb.ws.len());
        }
        let ws: Result<Vec<QuantLinear>> =
            qb.ws.iter().map(QuantLinear::from_packed).collect();
        Ok(QuantBlock {
            ws: ws?,
            norm_attn: qb.norm_attn.clone(),
            norm_ffn: qb.norm_ffn.clone(),
        })
    }

    pub fn storage_bytes(&self) -> usize {
        self.ws.iter().map(|w| w.storage_bytes()).sum::<usize>()
            + (self.norm_attn.len() + self.norm_ffn.len()) * 4
    }

    /// Quantize (or pass through) the activations at one quant point. The
    /// code holder comes from the arena — `recycle` it after the matmuls.
    fn act_input<'a>(&self, x: &'a Tensor, point: usize, stats: &BlockStats,
                     scheme: &Scheme, exec: &mut Exec) -> ActInput<'a> {
        let (rows, cols) = x.as_2d();
        let qa = qmax(scheme.a_bits);
        match scheme.act {
            ActScheme::None => ActInput::Fp(x),
            ActScheme::PerToken => {
                let t0 = exec.prof.t0();
                let mut acts = exec.scratch.take_acts();
                quantize_acts_per_token_into(&x.data, rows, cols, qa,
                                             &mut acts);
                exec.prof.rec(exec.layer, KernelKind::ActQuant, t0,
                              rows as u64, 0);
                ActInput::Quant(acts)
            }
            ActScheme::PerTensorStatic => {
                let t0 = exec.prof.t0();
                let (s, z) = stats[point].range.grid(qa);
                let mut acts = exec.scratch.take_acts();
                quantize_acts_static_into(&x.data, rows, cols, s, z, qa,
                                          &mut acts);
                exec.prof.rec(exec.layer, KernelKind::ActQuant, t0,
                              rows as u64, 0);
                ActInput::Quant(acts)
            }
        }
    }

    /// Shared tail of every forward flavor: o-projection + residual +
    /// gated FFN (quant points o_in, ffn_in, down_in — all position-
    /// independent). One copy keeps the full-context, decode-step, and
    /// prefill paths bit-identical by construction. Residuals and the gate
    /// accumulate in place into arena buffers (f32 addition is commutative,
    /// so `o += x` is bitwise `x + o`).
    fn attn_ffn_tail(&self, x: &Tensor, attn: &Tensor, stats: &BlockStats,
                     scheme: &Scheme, exec: &mut Exec) -> Result<Tensor> {
        let oin = self.act_input(attn, 1, stats, scheme, exec); // o_in
        let o = oin.matmul(&self.ws[3], exec)?;
        oin.recycle(exec.scratch);
        let mut hidd = o;
        let t0 = exec.prof.t0();
        for (h, &xv) in hidd.data.iter_mut().zip(&x.data) {
            *h += xv;
        }
        let (t, d) = hidd.as_2d();
        exec.prof.rec(exec.layer, KernelKind::Eltwise, t0, t as u64, 0);

        let mut xf = exec.scratch.tensor(t, d);
        let t0 = exec.prof.t0();
        rmsnorm_into(&hidd, &self.norm_ffn, &mut xf.data);
        exec.prof.rec(exec.layer, KernelKind::Norm, t0, t as u64, 0);
        let fin = self.act_input(&xf, 2, stats, scheme, exec); // ffn_in
        let g = fin.matmul(&self.ws[4], exec)?;
        let u = fin.matmul(&self.ws[5], exec)?;
        fin.recycle(exec.scratch);
        exec.scratch.put_tensor(xf);
        let mut gate = g;
        let t0 = exec.prof.t0();
        for (gv, &uv) in gate.data.iter_mut().zip(&u.data) {
            *gv = silu(*gv) * uv;
        }
        exec.prof.rec(exec.layer, KernelKind::Eltwise, t0, t as u64, 0);
        exec.scratch.put_tensor(u);
        let din = self.act_input(&gate, 3, stats, scheme, exec); // down_in
        let down = din.matmul(&self.ws[6], exec)?;
        din.recycle(exec.scratch);
        exec.scratch.put_tensor(gate);
        let mut out = down;
        let t0 = exec.prof.t0();
        for (ov, &hv) in out.data.iter_mut().zip(&hidd.data) {
            *ov += hv;
        }
        exec.prof.rec(exec.layer, KernelKind::Eltwise, t0, t as u64, 0);
        exec.scratch.put_tensor(hidd);
        Ok(out)
    }

    /// One block forward: `x [b*s, d]` -> `[b*s, d]`.
    pub fn forward(&self, x: &Tensor, dim: &ModelDim, stats: &BlockStats,
                   scheme: &Scheme, exec: &mut Exec) -> Result<Tensor> {
        let (t, d) = x.as_2d();
        if d != dim.d || t % dim.seq != 0 {
            bail!("block forward: input [{t}, {d}] vs dim d={} seq={}",
                  dim.d, dim.seq);
        }
        let b = t / dim.seq;
        let (s, h, hd) = (dim.seq, dim.heads, dim.head_dim());

        // ---- attention ----
        let mut xa = exec.scratch.tensor(t, d);
        let t0 = exec.prof.t0();
        rmsnorm_into(x, &self.norm_attn, &mut xa.data);
        exec.prof.rec(exec.layer, KernelKind::Norm, t0, t as u64, 0);
        let ain = self.act_input(&xa, 0, stats, scheme, exec); // attn_in
        let mut q = ain.matmul(&self.ws[0], exec)?;
        let mut k = ain.matmul(&self.ws[1], exec)?;
        let v = ain.matmul(&self.ws[2], exec)?;
        ain.recycle(exec.scratch);
        exec.scratch.put_tensor(xa);
        let t0 = exec.prof.t0();
        rope(&mut q.data, b, s, h, hd);
        rope(&mut k.data, b, s, h, hd);
        exec.prof.rec(exec.layer, KernelKind::Rope, t0, t as u64, 0);
        // per-token KV quantization (post-RoPE, over the flattened d)
        let t0 = exec.prof.t0();
        let (k, v) = if scheme.kv_quant {
            let qkv = qmax(scheme.kv_bits);
            let kq = per_token_quant(&k, qkv);
            let vq = per_token_quant(&v, qkv);
            exec.scratch.put_tensor(k);
            exec.scratch.put_tensor(v);
            (kq, vq)
        } else {
            (k, v)
        };
        let attn = Tensor::new(
            vec![t, d],
            causal_attention(&q.data, &k.data, &v.data, b, s, h, hd),
        );
        exec.prof.rec(exec.layer, KernelKind::Attn, t0, t as u64, 0);
        exec.scratch.put_tensor(q);
        exec.scratch.put_tensor(k);
        exec.scratch.put_tensor(v);
        let out = self.attn_ffn_tail(x, &attn, stats, scheme, exec)?;
        exec.scratch.put_tensor(attn);
        Ok(out)
    }

    /// One *decode* step: `x [n, d]` holds one new token per sequence (each
    /// sequence owning `caches[i]`), at layer index `layer` of the model.
    /// Appends the post-RoPE quantized K/V row of every sequence to its
    /// cache, attends the new token against the cached prefix, and returns
    /// the block output `[n, d]`.
    ///
    /// Every per-row op (RMSNorm, act quant, integer GEMM, RoPE, KV grid) is
    /// the same arithmetic as [`QuantBlock::forward`] applies to that row in
    /// a full-context pass, so incremental decode reproduces the full
    /// forward token-for-token (see `tests/native.rs`). All intermediates
    /// live in the arena: zero heap allocation here in steady state.
    pub fn forward_step(&self, x: &Tensor, dim: &ModelDim, stats: &BlockStats,
                        scheme: &Scheme, exec: &mut Exec, layer: usize,
                        caches: &mut [KvCache]) -> Result<Tensor> {
        let (n, d) = x.as_2d();
        if d != dim.d || n != caches.len() {
            bail!("forward_step: input [{n}, {d}] vs d={} / {} caches",
                  dim.d, caches.len());
        }
        let (h, hd) = (dim.heads, dim.head_dim());

        // ---- attention (incremental) ----
        let mut xa = exec.scratch.tensor(n, d);
        let t0 = exec.prof.t0();
        rmsnorm_into(x, &self.norm_attn, &mut xa.data);
        exec.prof.rec(exec.layer, KernelKind::Norm, t0, n as u64, 0);
        let ain = self.act_input(&xa, 0, stats, scheme, exec); // attn_in
        let mut q = ain.matmul(&self.ws[0], exec)?;
        let mut k = ain.matmul(&self.ws[1], exec)?;
        let v = ain.matmul(&self.ws[2], exec)?;
        ain.recycle(exec.scratch);
        exec.scratch.put_tensor(xa);
        // per-row RoPE at each sequence's next position
        let t0 = exec.prof.t0();
        for (i, cache) in caches.iter().enumerate() {
            let pos = cache.layer_len(layer);
            rope_row(&mut q.data[i * d..(i + 1) * d], pos, h, hd);
            rope_row(&mut k.data[i * d..(i + 1) * d], pos, h, hd);
        }
        exec.prof.rec(exec.layer, KernelKind::Rope, t0, n as u64, 0);
        // append quantized K/V (post-RoPE, the cache applies the per-token
        // grid), then attend the new token against its full cached prefix
        let t0 = exec.prof.t0();
        for (i, cache) in caches.iter_mut().enumerate() {
            cache.push(layer, &k.data[i * d..(i + 1) * d],
                       &v.data[i * d..(i + 1) * d]);
        }
        exec.prof.rec(exec.layer, KernelKind::KvAppend, t0, n as u64, 0);
        let mut attn = exec.scratch.tensor(n, d);
        let mut att_ws = exec.scratch.take();
        let t0 = exec.prof.t0();
        let mut kv_rows = 0u64;
        for (i, cache) in caches.iter_mut().enumerate() {
            kv_rows += cache.layer_len(layer) as u64;
            cache.attend(layer, &q.data[i * d..(i + 1) * d], h, hd,
                         &mut attn.data[i * d..(i + 1) * d], &mut att_ws);
        }
        exec.prof.rec(exec.layer, KernelKind::Attn, t0, n as u64, kv_rows);
        exec.scratch.put(att_ws);
        exec.scratch.put_tensor(q);
        exec.scratch.put_tensor(k);
        exec.scratch.put_tensor(v);
        let out = self.attn_ffn_tail(x, &attn, stats, scheme, exec)?;
        exec.scratch.put_tensor(attn);
        Ok(out)
    }

    /// Vectorized prefill of one sequence: `x [p, d]` holds the prompt rows
    /// at positions `0..p`; `cache` must be empty at `layer`. Pushes every
    /// post-RoPE K/V row to the cache and attends over the in-batch causal
    /// prefix — one multi-row pass, so each weight tile streams once per
    /// tile instead of once per prompt token ([`QuantBlock::forward_step`]
    /// would pay that `p` times).
    pub fn forward_prefill(&self, x: &Tensor, dim: &ModelDim,
                           stats: &BlockStats, scheme: &Scheme,
                           exec: &mut Exec, layer: usize, cache: &mut KvCache)
                           -> Result<Tensor> {
        let (p, d) = x.as_2d();
        if d != dim.d {
            bail!("forward_prefill: input [{p}, {d}] vs d={}", dim.d);
        }
        if cache.layer_len(layer) != 0 {
            bail!("forward_prefill: cache layer {layer} already holds {} \
                   tokens", cache.layer_len(layer));
        }
        let (h, hd) = (dim.heads, dim.head_dim());

        // ---- attention (positions 0..p, cache == in-batch prefix) ----
        let mut xa = exec.scratch.tensor(p, d);
        let t0 = exec.prof.t0();
        rmsnorm_into(x, &self.norm_attn, &mut xa.data);
        exec.prof.rec(exec.layer, KernelKind::Norm, t0, p as u64, 0);
        let ain = self.act_input(&xa, 0, stats, scheme, exec); // attn_in
        let mut q = ain.matmul(&self.ws[0], exec)?;
        let mut k = ain.matmul(&self.ws[1], exec)?;
        let v = ain.matmul(&self.ws[2], exec)?;
        ain.recycle(exec.scratch);
        exec.scratch.put_tensor(xa);
        let t0 = exec.prof.t0();
        rope(&mut q.data, 1, p, h, hd);
        rope(&mut k.data, 1, p, h, hd);
        exec.prof.rec(exec.layer, KernelKind::Rope, t0, p as u64, 0);
        // the cache applies the same per-token grid the fake-quant below
        // uses, so cached rows dequantize to exactly what we attend over
        let t0 = exec.prof.t0();
        for t in 0..p {
            cache.push(layer, k.row(t), v.row(t));
        }
        exec.prof.rec(exec.layer, KernelKind::KvAppend, t0, p as u64, 0);
        let t0 = exec.prof.t0();
        let (k, v) = if scheme.kv_quant {
            let qkv = qmax(scheme.kv_bits);
            let kq = per_token_quant(&k, qkv);
            let vq = per_token_quant(&v, qkv);
            exec.scratch.put_tensor(k);
            exec.scratch.put_tensor(v);
            (kq, vq)
        } else {
            (k, v)
        };
        let attn = Tensor::new(
            vec![p, d],
            causal_attention(&q.data, &k.data, &v.data, 1, p, h, hd),
        );
        exec.prof.rec(exec.layer, KernelKind::Attn, t0, p as u64, 0);
        exec.scratch.put_tensor(q);
        exec.scratch.put_tensor(k);
        exec.scratch.put_tensor(v);
        let out = self.attn_ffn_tail(x, &attn, stats, scheme, exec)?;
        exec.scratch.put_tensor(attn);
        Ok(out)
    }
}

/// A full model executing natively from a packed checkpoint: FP embeddings /
/// norms / head (as in the paper — only block linears are quantized),
/// integer block linears. Owns the planned-execution state: the persistent
/// worker pool (spawned once here, shared by clones) and the scratch arena
/// (private per clone).
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub dim: ModelDim,
    pub scheme: Scheme,
    /// engine worker threads for tile-sharded GEMMs (1 = single-threaded)
    pub shards: usize,
    /// pool + mode + arena (interior mutability: forward calls recycle
    /// buffers through `&self`)
    exec: RefCell<ExecState>,
    pub emb: Tensor,
    pub blocks: Vec<QuantBlock>,
    pub final_norm: Tensor,
    pub head: Tensor,
    pub stats: Vec<BlockStats>,
}

impl NativeModel {
    /// Build from any quantized checkpoint + calibrated stats. `stats` may be
    /// empty for weight-only / per-token schemes (no static grids needed).
    /// Spawns the persistent worker pool (`shards` threads) and repacks
    /// every linear into its execution plan — both exactly once, here.
    pub fn from_quantized(qm: &QuantizedModel, stats: &[BlockStats],
                          scheme: Scheme, shards: usize) -> Result<Self> {
        if matches!(scheme.act, ActScheme::PerTensorStatic)
            && stats.len() != qm.blocks.len() {
            bail!("static act scheme needs {} block stats, got {}",
                  qm.blocks.len(), stats.len());
        }
        // the integer path carries activation codes in u8
        if !matches!(scheme.act, ActScheme::None) && scheme.a_bits > 8 {
            bail!("native engine quantizes activations to u8 codes; \
                   a_bits {} > 8 unsupported", scheme.a_bits);
        }
        let blocks: Result<Vec<QuantBlock>> =
            qm.blocks.iter().map(QuantBlock::from_quantized).collect();
        let blocks = blocks?;
        let stats: Vec<BlockStats> = if stats.is_empty() {
            (0..qm.blocks.len()).map(|_| Default::default()).collect()
        } else {
            stats.to_vec()
        };
        let shards = shards.max(1);
        let mut state = ExecState::new(shards);
        state.set_profiler(Arc::new(Profiler::new(blocks.len())));
        Ok(NativeModel {
            dim: qm.dim.clone(),
            scheme,
            shards,
            exec: RefCell::new(state),
            emb: qm.emb.clone(),
            blocks,
            final_norm: qm.final_norm.clone(),
            head: qm.head.clone(),
            stats,
        })
    }

    /// This model's profiler — shared by clones (server shards aggregate
    /// into one profile). Disabled until [`Profiler::set_enabled`].
    pub fn profiler(&self) -> Arc<Profiler> {
        self.exec.borrow().profiler().clone()
    }

    /// Switch execution mode: [`ExecMode::Planned`] (default) or
    /// [`ExecMode::Reference`] (the pre-plan engine — the bit-exact oracle
    /// and the bench's speedup baseline).
    pub fn with_mode(self, mode: ExecMode) -> Self {
        self.exec.borrow_mut().set_mode(mode);
        self
    }

    pub fn mode(&self) -> ExecMode {
        self.exec.borrow().mode()
    }

    /// Pin the integer-GEMM kernel backend for this instance (the planned
    /// path's SIMD dispatch). Instances default to [`simd::active()`], so
    /// this is only needed to force a slower tier — e.g. the scalar oracle
    /// in differential tests, or `--kernel scalar` at the CLI.
    ///
    /// [`simd::active()`]: crate::infer::simd::active
    pub fn with_kernel(self, backend: crate::infer::simd::Backend) -> Self {
        self.exec.borrow_mut().set_kernel(backend);
        self
    }

    pub fn kernel(&self) -> crate::infer::simd::Backend {
        self.exec.borrow().kernel()
    }

    /// Worker threads in the persistent pool (shared across clones).
    pub fn threads(&self) -> usize {
        self.exec.borrow().threads()
    }

    /// Full-context forward to final hidden states: `ids` is `[b * seq]`
    /// with any `b >= 1`; returns `[b*seq, d]` (pre final-norm/head).
    pub fn forward_hidden(&self, ids: &[i32]) -> Result<Tensor> {
        let seq = self.dim.seq;
        if ids.is_empty() || ids.len() % seq != 0 {
            bail!("forward: ids len {} not a multiple of seq {seq}",
                  ids.len());
        }
        let mut state = self.exec.borrow_mut();
        let mut exec = state.exec();
        let t0 = exec.prof.t0();
        let mut x = embed(&self.emb, ids)?;
        exec.prof.rec(MODEL_SLOT, KernelKind::Embed, t0, ids.len() as u64, 0);
        for (l, (blk, st)) in
            self.blocks.iter().zip(&self.stats).enumerate()
        {
            exec.layer = l;
            let sp = trace::begin();
            let nx = blk.forward(&x, &self.dim, st, &self.scheme,
                                 &mut exec)?;
            trace::complete(sp, || (format!("layer{l}"), None));
            exec.scratch.put_tensor(std::mem::replace(&mut x, nx));
        }
        exec.layer = MODEL_SLOT;
        Ok(x)
    }

    /// Full forward over padded rows: `ids`/`targets` are `[b * seq]` with
    /// any `b >= 1`. Returns `(mean NLL, per-position target logprob [b*seq])`.
    pub fn forward(&self, ids: &[i32], targets: &[i32])
                   -> Result<(f32, Tensor)> {
        if targets.len() != ids.len() {
            bail!("forward: {} targets for {} ids", targets.len(), ids.len());
        }
        let x = self.forward_hidden(ids)?;
        let b = ids.len() / self.dim.seq;
        let (loss, logp) =
            head_logprobs(&x, &self.final_norm, &self.head, targets)?;
        Ok((loss, Tensor::new(vec![b, self.dim.seq], logp)))
    }

    /// Fresh per-sequence KV cache matching this model's layer count, width,
    /// and KV-quant scheme.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.blocks.len(), self.dim.d, self.scheme.kv_quant,
                     self.scheme.kv_bits)
    }

    /// One incremental decode step: `ids[i]` is the next token of the
    /// sequence owning `caches[i]` (sequences may be at different lengths).
    /// Appends each token's quantized K/V to its cache and returns the
    /// next-token logits `[n, vocab]` — the only heap allocation of a
    /// steady-state step (it escapes to the sampler).
    pub fn decode_step(&self, ids: &[i32], caches: &mut [KvCache])
                       -> Result<Tensor> {
        if ids.is_empty() || ids.len() != caches.len() {
            bail!("decode_step: {} ids vs {} caches", ids.len(),
                  caches.len());
        }
        for (i, c) in caches.iter().enumerate() {
            if c.layer_count() != self.blocks.len() || c.dim() != self.dim.d {
                bail!("decode_step: cache {i} is [{} layers, d {}], model \
                       is [{} layers, d {}]",
                      c.layer_count(), c.dim(), self.blocks.len(),
                      self.dim.d);
            }
            // same limit the serving path enforces: positions beyond the
            // trained context would silently produce garbage
            if c.len() >= self.dim.seq {
                bail!("decode_step: cache {i} is at the {}-token context \
                       limit", self.dim.seq);
            }
        }
        let mut state = self.exec.borrow_mut();
        let mut exec = state.exec();
        let t0 = exec.prof.t0();
        let mut x = {
            let mut buf = exec.scratch.take();
            embed_into(&self.emb, ids, &mut buf)?;
            Tensor::new(vec![ids.len(), self.dim.d], buf)
        };
        exec.prof.rec(MODEL_SLOT, KernelKind::Embed, t0, ids.len() as u64, 0);
        for (l, (blk, st)) in
            self.blocks.iter().zip(&self.stats).enumerate()
        {
            exec.layer = l;
            let sp = trace::begin();
            let nx = blk.forward_step(&x, &self.dim, st, &self.scheme,
                                      &mut exec, l, caches)?;
            trace::complete(sp, || (format!("layer{l}"), None));
            exec.prof.add_step_tokens(l, ids.len() as u64);
            exec.scratch.put_tensor(std::mem::replace(&mut x, nx));
        }
        exec.layer = MODEL_SLOT;
        let t0 = exec.prof.t0();
        let logits = head_logits(&x, &self.final_norm, &self.head);
        exec.prof.rec(MODEL_SLOT, KernelKind::Head, t0, ids.len() as u64, 0);
        exec.scratch.put_tensor(x);
        Ok(logits)
    }

    /// Fill a fresh `cache` with a prompt in one vectorized multi-row pass
    /// (each weight tile streamed once, not once per token); returns the
    /// next-token logits after the last prompt token (`[vocab]`).
    pub fn prefill(&self, ids: &[i32], cache: &mut KvCache)
                   -> Result<Vec<f32>> {
        if ids.is_empty() {
            bail!("prefill: empty prompt");
        }
        if ids.len() > self.dim.seq {
            bail!("prefill: prompt {} exceeds the {}-token context",
                  ids.len(), self.dim.seq);
        }
        if cache.layer_count() != self.blocks.len()
            || cache.dim() != self.dim.d {
            bail!("prefill: cache is [{} layers, d {}], model is \
                   [{} layers, d {}]", cache.layer_count(), cache.dim(),
                  self.blocks.len(), self.dim.d);
        }
        if !cache.is_empty() {
            bail!("prefill: cache already holds {} tokens (needs a fresh \
                   cache)", cache.len());
        }
        cache.reserve(ids.len());
        let mut state = self.exec.borrow_mut();
        let mut exec = state.exec();
        let t0 = exec.prof.t0();
        let mut x = embed(&self.emb, ids)?;
        exec.prof.rec(MODEL_SLOT, KernelKind::Embed, t0, ids.len() as u64, 0);
        for (l, (blk, st)) in
            self.blocks.iter().zip(&self.stats).enumerate()
        {
            exec.layer = l;
            let sp = trace::begin();
            let nx = blk.forward_prefill(&x, &self.dim, st, &self.scheme,
                                         &mut exec, l, cache)?;
            trace::complete(sp, || (format!("layer{l}"), None));
            exec.scratch.put_tensor(std::mem::replace(&mut x, nx));
        }
        exec.layer = MODEL_SLOT;
        // only the last prompt position feeds the next-token distribution
        let last =
            Tensor::new(vec![1, self.dim.d], x.row(ids.len() - 1).to_vec());
        exec.scratch.put_tensor(x);
        let t0 = exec.prof.t0();
        let logits = head_logits(&last, &self.final_norm, &self.head).data;
        exec.prof.rec(MODEL_SLOT, KernelKind::Head, t0, 1, 0);
        Ok(logits)
    }

    /// Generate `max_new` tokens after `prompt` with a fresh KV cache —
    /// greedy when `top_k <= 1`, top-k sampling otherwise. The single-
    /// sequence twin of the batched serve path (`lrq generate-native`), and
    /// the direct oracle its tests compare against. Enforces the same
    /// context budget as the serving path.
    pub fn generate(&self, prompt: &[i32], max_new: usize, top_k: usize,
                    seed: u64) -> Result<Vec<i32>> {
        if prompt.len() + max_new > self.dim.seq {
            bail!("generate: prompt {} + max_new {max_new} exceeds the \
                   {}-token context", prompt.len(), self.dim.seq);
        }
        let mut cache = self.new_cache();
        let mut logits = self.prefill(prompt, &mut cache)?;
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity(max_new);
        let prof = self.profiler();
        for step in 0..max_new {
            let t0 = prof.t0();
            let t = sample_top_k(&logits, top_k, &mut rng) as i32;
            prof.rec(MODEL_SLOT, KernelKind::Sample, t0, 1, 0);
            out.push(t);
            if step + 1 < max_new {
                logits = self
                    .decode_step(&[t], std::slice::from_mut(&mut cache))?
                    .data;
            }
        }
        Ok(out)
    }

    /// Packed storage bytes (the Fig. 5 size axis, native layout).
    pub fn storage_bytes(&self) -> usize {
        let fp =
            (self.emb.len() + self.final_norm.len() + self.head.len()) * 4;
        fp + self.blocks.iter().map(|b| b.storage_bytes()).sum::<usize>()
    }
}
