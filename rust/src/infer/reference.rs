//! Reference paths for the native engine:
//!
//! * [`ref_block_forward`] — the **fake-quant oracle**: dequantize packed
//!   weights to f32 and run the block with fake-quantized activations,
//!   reproducing the semantics of the `block_fwd_q` AOT artifact in pure
//!   Rust. The correctness harness (`tests/native.rs`) asserts the integer
//!   engine matches this within f32-accumulation tolerance.
//! * [`fp_block_forward`] — the FP block with activation-statistics capture
//!   at the four quant points, powering artifact-free calibration of static
//!   activation grids ([`super::quantize::calibrate_stats`]).

use anyhow::{bail, Result};

use crate::config::{ActScheme, Scheme};
use crate::coordinator::engine::BlockStats;
use crate::model::{BlockWeights, ModelDim, QuantizedModel};
use crate::quant::act::{per_tensor_quant, per_token_quant};
use crate::quant::qmax;
use crate::tensor::Tensor;

use super::ops::{causal_attention, embed, head_logprobs, rmsnorm, rope,
                 silu};

/// Fake-quantize activations at one quant point (the `ActQuant` dispatch of
/// `model.py`, in f32).
fn fq_act(x: &Tensor, point: usize, stats: &BlockStats, scheme: &Scheme)
          -> Tensor {
    let qa = qmax(scheme.a_bits);
    match scheme.act {
        ActScheme::None => x.clone(),
        ActScheme::PerToken => per_token_quant(x, qa),
        ActScheme::PerTensorStatic => {
            let (s, z) = stats[point].range.grid(qa);
            per_tensor_quant(x, s, z, qa)
        }
    }
}

/// Reference quantized block forward over dequantized (Ŵ) weights — the
/// fake-quant semantics every PTQ method in this repo evaluates under.
pub fn ref_block_forward(x: &Tensor, whats: &[Tensor], norm_attn: &Tensor,
                         norm_ffn: &Tensor, dim: &ModelDim,
                         stats: &BlockStats, scheme: &Scheme)
                         -> Result<Tensor> {
    if whats.len() != 7 {
        bail!("reference block needs 7 weight tensors, got {}", whats.len());
    }
    let (t, d) = x.as_2d();
    if d != dim.d || t % dim.seq != 0 {
        bail!("reference block: input [{t}, {d}] vs dim");
    }
    let b = t / dim.seq;
    let (s, h, hd) = (dim.seq, dim.heads, dim.head_dim());

    let xa = fq_act(&rmsnorm(x, norm_attn), 0, stats, scheme);
    let mut q = xa.matmul_bt(&whats[0]);
    let mut k = xa.matmul_bt(&whats[1]);
    let v = xa.matmul_bt(&whats[2]);
    rope(&mut q.data, b, s, h, hd);
    rope(&mut k.data, b, s, h, hd);
    let (k, v) = if scheme.kv_quant {
        let qkv = qmax(scheme.kv_bits);
        (per_token_quant(&k, qkv), per_token_quant(&v, qkv))
    } else {
        (k, v)
    };
    let attn = Tensor::new(
        vec![t, d],
        causal_attention(&q.data, &k.data, &v.data, b, s, h, hd),
    );
    let o = fq_act(&attn, 1, stats, scheme).matmul_bt(&whats[3]);
    let hidd = x.add(&o);

    let xf = fq_act(&rmsnorm(&hidd, norm_ffn), 2, stats, scheme);
    let g = xf.matmul_bt(&whats[4]);
    let u = xf.matmul_bt(&whats[5]);
    let gate = g.zip(&u, |gv, uv| silu(gv) * uv);
    let down = fq_act(&gate, 3, stats, scheme).matmul_bt(&whats[6]);
    Ok(hidd.add(&down))
}

/// Full reference forward over a packed checkpoint (dequantized weights,
/// fake-quant activations): the oracle for [`super::NativeModel::forward`].
pub fn ref_forward(qm: &QuantizedModel, stats: &[BlockStats],
                   scheme: &Scheme, ids: &[i32], targets: &[i32])
                   -> Result<(f32, Tensor)> {
    let seq = qm.dim.seq;
    if ids.is_empty() || ids.len() % seq != 0 || targets.len() != ids.len() {
        bail!("ref_forward: bad ids/targets shapes");
    }
    let b = ids.len() / seq;
    let default_stats: BlockStats = Default::default();
    let mut x = embed(&qm.emb, ids)?;
    for (i, qb) in qm.blocks.iter().enumerate() {
        let whats = qb.dequant_ws();
        let st = stats.get(i).unwrap_or(&default_stats);
        x = ref_block_forward(&x, &whats, &qb.norm_attn, &qb.norm_ffn,
                              &qm.dim, st, scheme)?;
    }
    let (loss, logp) = head_logprobs(&x, &qm.final_norm, &qm.head, targets)?;
    Ok((loss, Tensor::new(vec![b, seq], logp)))
}

/// Record per-tensor (min, max) and per-channel amax of a 2-D activation
/// into one quant point's stats.
fn capture(stats: &mut BlockStats, point: usize, x: &Tensor) {
    let mn = x.min().min(0.0);
    let mx = x.max().max(0.0);
    let amax = x.col_amax();
    stats[point].merge(mn, mx, &amax);
}

/// FP block forward with stats capture at the four quant points — the native
/// twin of the `block_fwd` artifact's calibration outputs.
pub fn fp_block_forward(x: &Tensor, bw: &BlockWeights, dim: &ModelDim,
                        stats: &mut BlockStats) -> Result<Tensor> {
    let (t, d) = x.as_2d();
    if d != dim.d || t % dim.seq != 0 {
        bail!("fp block: input [{t}, {d}] vs dim");
    }
    let b = t / dim.seq;
    let (s, h, hd) = (dim.seq, dim.heads, dim.head_dim());

    let xa = rmsnorm(x, &bw.norm_attn);
    capture(stats, 0, &xa);
    let mut q = xa.matmul_bt(&bw.ws[0]);
    let mut k = xa.matmul_bt(&bw.ws[1]);
    let v = xa.matmul_bt(&bw.ws[2]);
    rope(&mut q.data, b, s, h, hd);
    rope(&mut k.data, b, s, h, hd);
    let attn = Tensor::new(
        vec![t, d],
        causal_attention(&q.data, &k.data, &v.data, b, s, h, hd),
    );
    capture(stats, 1, &attn);
    let o = attn.matmul_bt(&bw.ws[3]);
    let hidd = x.add(&o);

    let xf = rmsnorm(&hidd, &bw.norm_ffn);
    capture(stats, 2, &xf);
    let g = xf.matmul_bt(&bw.ws[4]);
    let u = xf.matmul_bt(&bw.ws[5]);
    let gate = g.zip(&u, |gv, uv| silu(gv) * uv);
    capture(stats, 3, &gate);
    let down = gate.matmul_bt(&bw.ws[6]);
    Ok(hidd.add(&down))
}
