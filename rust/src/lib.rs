//! # LRQ — Low-Rank Quantization for LLMs (NAACL 2025) in Rust + JAX/Pallas
//!
//! Reproduction of *"LRQ: Optimizing Post-Training Quantization for Large
//! Language Models by Learning Low-Rank Weight-Scaling Matrices"* as a
//! three-layer stack:
//!
//! * **L3 (this crate)** — the coordinator: block-wise PTQ pipeline,
//!   calibration, method drivers (RTN / SmoothQuant / GPTQ / AWQ / FlexRound /
//!   LRQ), evaluation harness, batch-scoring server, benchmark tables.
//!   Python never runs on this path.
//! * **L2 (python/compile, build-time)** — JAX model / reconstruction /
//!   training steps, AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels (fused LRQ
//!   fake-quant, per-token quant, dequant-matmul) lowered into the same HLO.
//!
//! The [`runtime`] module loads the artifacts through the PJRT C API (`xla`
//! crate) and exposes typed executables the coordinator drives. The
//! [`infer`] module is the artifact-free counterpart: a native integer
//! inference engine that executes packed checkpoints (`quant::pack`)
//! directly and serves them through the same dynamic batcher
//! (`lrq serve-native`).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a module and bench target.

// Every unsafe operation inside an `unsafe fn` must sit in its own
// `unsafe {}` block with a SAFETY comment — `lrq lint` and clippy's
// `undocumented_unsafe_blocks` enforce the comments, this makes the
// blocks themselves non-optional (DESIGN.md §12).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod infer;
pub mod lint;
pub mod loadgen;
pub mod methods;
pub mod model;
pub mod obs;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tables;
pub mod tensor;
pub mod testutil;

pub use anyhow::{anyhow, bail, Context, Result};
