//! `lrq` — coordinator CLI.
//!
//! ```text
//! lrq info                              # artifacts + configs
//! lrq train     --cfg tiny --steps 600 --lr 1e-3 --out weights.bin
//! lrq quantize  --cfg tiny --weights weights.bin --method lrq --wbits 8 \
//!               --act static --steps 200 --calib 64
//! lrq eval      --cfg tiny --weights weights.bin [--method ...]
//! lrq serve     --cfg tiny --weights weights.bin [--method lrq]
//! lrq serve-native --cfg tiny --wbits 4 --act token --shards 4   # no PJRT
//! lrq bench-table <id>                  # regenerate a paper table/figure
//! lrq report                            # regenerate everything
//! ```

use std::path::Path;
use std::process::ExitCode;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use lrq::config::{ActScheme, Args, Method, ReconConfig, Scheme};
use lrq::coordinator::{pretrain, quantize_model, Engine};
use lrq::data::{Corpus, CorpusConfig, TaskKind, TaskSet};
use lrq::eval::{evaluate, ModelView};
use lrq::infer::{prepare_native, prepare_native_from, simd,
                 start_native_server, start_native_server_with,
                 KernelChoice, NativeModel, ScaleInit};
use lrq::loadgen::{self, LoadMode, LoadSpec, ServeBenchRow, SloSpec};
use lrq::model::{ModelDim, QuantizedModel, Weights};
use lrq::obs::{export, trace, HttpExporter};
use lrq::rng::Rng;
use lrq::runtime::{Manifest, Runtime};
use lrq::serve::{FaultPlan, ServerConfig, Watermarks, EXPIRED_PREFIX,
                 SHED_PREFIX};
use lrq::tables;

fn main() -> ExitCode {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(args),
        "train" => train(args),
        "quantize" => quantize(args),
        "eval" => eval_cmd(args),
        "serve" => serve(args),
        "serve-native" => serve_native(args),
        "generate-native" => generate_native(args),
        "soak" => soak(args),
        "stats" => stats(args),
        "bench-table" => {
            let id = args
                .positional
                .get(1)
                .context("bench-table needs an id (e.g. t1, fig3)")?;
            tables::run_table(id, args)
        }
        "report" => tables::run_all(args),
        "lint" => lint_cmd(args),
        "debug-loss" => debug_loss(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
lrq — LRQ (NAACL 2025) reproduction: Rust coordinator + JAX/Pallas AOT compute

commands:
  info                               show artifact manifest + configs
  train    --cfg C --steps N --lr F --out PATH [--seed S]
  quantize --cfg C --weights PATH --method M --wbits B
           [--act none|static|token] [--abits B] [--no-kv] [--steps N]
           [--calib N] [--rank R] [--lr F] [--out CKPT.lrqq]
           (--out saves the packed model as a checksummed LRQQ checkpoint
            servable by serve-native/generate-native --checkpoint)
  eval     --cfg C --weights PATH [--method M ...quantize flags]
  serve    --cfg C --weights PATH [--method M] [--requests N] [--wbits B]
  serve-native --cfg C [--weights PATH] [--wbits B] [--act none|static|token]
           [--abits B] [--no-kv] [--init rtn|grid] [--shards N]
           [--requests N] [--max-batch B] [--clients N]
           [--calib-batches N] [--seed S] [--checkpoint CKPT.lrqq]
           [--kernel auto|scalar|simd]
           pure-Rust integer engine over packed codes; needs no artifacts
           (dims fall back to built-ins micro|tiny|small, missing weights
           are random-init); --checkpoint serves a saved LRQQ file instead
           of quantizing at load; --kernel pins the micro-kernel dispatch
           (also LRQ_FORCE_SCALAR=1; default auto-detects AVX2/SSE2)
  generate-native --cfg C [--prompt-len N] [--max-new N] [--top-k K]
           [--requests N] [--clients N] [--max-batch B]
           [...same engine flags as serve-native]
           token-by-token generation through the dynamic batcher with a
           quantized KV cache (decode steps batched across sequences)
  soak     [--smoke] [--chaos] [--cfg C] [--bits 3,4,8]
           [--mode closed|open] [--clients N] [--requests N] [--rate R]
           [--max-new N] [--oversized F] [--disconnect F] [--straggler F]
           [--slo-p50-ms MS] [--slo-p99-ms MS] [--slo-ttft-ms MS]
           [--slo-queue-ms MS] [--slo-err F] [--slo-expire F]
           [--slo-shed F] [--out BENCH_serve.json]
           [--events-out soak_events.jsonl] [--compare BASELINE.json]
           sustained mixed score/generate load against serve-native per
           bit-width, asserting latency/TTFT/queue/error SLOs and zero
           stuck sequences; emits BENCH_serve.json + a request-lifecycle
           JSONL (--smoke: the fast CI configuration on the micro model);
           --chaos additionally injects a worker-pool job panic, an
           engine-thread panic, a kernel stall, and a dropped response
           through the live server, then forces an overload burst — the
           run must come back with zero stuck/lost, every injected fault
           surfaced as a terminal event, shed-then-recover, and (for
           w_bits > 4) a degraded-plan downshift-then-restore
  stats    --cfg C [--requests N] [--prompt-len N] [--max-new N]
           [...same engine flags as serve-native]
           run a profiled generate workload on the native engine and print
           the per-layer / per-kernel model profile + the SIMD kernel
           dispatch decision
  bench-table ID                     regenerate one paper table/figure
                                     (fig1 fig2 fig3 fig4a fig4b fig5
                                      t1 t3 t5 t7 t9 t13 t29 t30 t31 kvq)
  report                             regenerate all tables/figures
  lint     [--root DIR] [--config lint.toml] [--json LINT.json]
           run the repo invariant linter (DESIGN.md §12) over --root
           (default src, relative to the rust/ crate dir); prints findings,
           writes the JSON report, exits nonzero on any violation

common flags: --artifacts DIR (default ./artifacts), --seed S
overload policy (serve-native / generate-native / soak; DESIGN.md §13):
  --deadline-ms MS    per-request deadline measured from submission;
                      enforced wherever the request is when it passes —
                      queued, awaiting admission, or mid-decode
  --shed-at H[,L]     admission control: shed new work with a fast
                      retriable error while queue depth or KV pressure is
                      at/above H, re-admit once back at/below L
                      (L defaults to H/2)
  --degrade H[,L]     downshift decode to a cheaper pre-built plan at
                      queue depth H, restore at/below L (soak builds the
                      same checkpoint at W4 as the degraded plan when
                      w_bits > 4)
  --drain-ms MS       shutdown bound on draining in-flight decodes;
                      stragglers past it are expired (default 5000)
observability (serve-native / generate-native / stats):
  --trace PATH        record a chrome://tracing JSON trace of the run
  --profile           enable the per-layer/per-kernel profiler, print report
  --metrics-out PATH  write a Prometheus text snapshot after the run
  --metrics-addr A    serve live metrics over HTTP during the run
                      (e.g. 127.0.0.1:9184; serve-native/generate-native)";

fn scheme_from(args: &Args) -> Result<Scheme> {
    let w_bits: u32 = args.parse_as("wbits", 8)?;
    let act: ActScheme = args.parse_as("act", ActScheme::PerTensorStatic)?;
    let a_bits: u32 = args.parse_as("abits", 8)?;
    let kv = !args.flag("no-kv") && !matches!(act, ActScheme::None);
    Ok(Scheme { w_bits, act, a_bits, kv_quant: kv, kv_bits: 8 })
}

fn recon_from(args: &Args) -> Result<ReconConfig> {
    Ok(ReconConfig {
        steps: args.parse_as("steps", 200)?,
        lr: args.parse_as("lr", 3e-4)?,
        calib_samples: args.parse_as("calib", 64)?,
        rank: args.parse_as("rank", 0)?,
        seed: args.parse_as("seed", 1234)?,
    })
}

fn load_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts");
    Runtime::load(Path::new(&dir))
}

fn info(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    println!("platform: {} ({} devices)", rt.client.platform_name(),
             rt.client.device_count());
    println!("configs:");
    let mut cfgs: Vec<_> = rt.manifest.configs.values().collect();
    cfgs.sort_by(|a, b| a.name.cmp(&b.name));
    for c in cfgs {
        println!("  {}: vocab={} d={} heads={} layers={} ff={} seq={} \
                  rank={} (~{:.1}M params)",
                 c.name, c.vocab, c.d, c.heads, c.layers, c.ff, c.seq, c.rank,
                 c.param_count() as f64 / 1e6);
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    let mut names: Vec<_> = rt.manifest.artifacts.keys().collect();
    names.sort();
    for n in names {
        let a = &rt.manifest.artifacts[n];
        println!("  {n}: {} in / {} out", a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let cfg = args.get_or("cfg", "tiny");
    let steps: usize = args.parse_as("steps", 600)?;
    let lr: f32 = args.parse_as("lr", 1e-3)?;
    let seed: u64 = args.parse_as("seed", 7)?;
    let out = args.get_or("out", &format!("weights_{cfg}.bin"));
    let dim = rt.dim(&cfg)?;
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));

    println!("pre-training {cfg} ({:.1}M params) for {steps} steps…",
             dim.param_count() as f64 / 1e6);
    let outcome = pretrain(&rt, &cfg, &corpus, steps, lr, seed, 20)?;
    for (s, l) in &outcome.losses {
        println!("  step {s:>5}  loss {l:.4}");
    }
    println!("trained in {:.1}s", outcome.wall_secs);
    outcome.weights.save(Path::new(&out))?;
    println!("saved {out}");
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let cfg = args.get_or("cfg", "tiny");
    let method: Method = args.parse_as("method", Method::Lrq)?;
    let scheme = scheme_from(args)?;
    let recon = recon_from(args)?;
    let dim = rt.dim(&cfg)?;
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let weights = Weights::load(&dim, Path::new(&wpath))?;
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let engine = Engine::new(&rt, &cfg)?;

    println!("quantizing {cfg} with {} (W/A/KV {})…", method.paper_name(),
             scheme.label());
    let out = quantize_model(&rt, &engine, &weights, &corpus, method, scheme,
                             recon)?;
    println!("done in {:.1}s; model {:.2} MB (fp {:.2} MB, {:.2}x)",
             out.wall.as_secs_f64(),
             out.model.storage_bytes() as f64 / 1e6,
             out.model.fp_equivalent_bytes() as f64 / 1e6,
             out.model.fp_equivalent_bytes() as f64
                 / out.model.storage_bytes() as f64);
    for (b, trace) in out.loss_traces.iter().enumerate() {
        if let (Some(first), Some(last)) = (trace.first(), trace.last()) {
            println!("  block {b}: recon loss {first:.5} -> {last:.5}");
        }
    }
    if let Some(ckpt) = args.get("out") {
        out.model.save(Path::new(ckpt))?;
        println!("saved LRQQ checkpoint {ckpt} ({:.2} MB); serve it with \
                  `lrq serve-native --cfg {cfg} --checkpoint {ckpt}`",
                 out.model.storage_bytes() as f64 / 1e6);
    }

    // quick eval
    let mut rng = Rng::new(recon.seed ^ 0x5EED);
    let csr = TaskSet::generate(&corpus, TaskKind::Csr, 100, dim.seq / 2,
                                8, 4, &mut rng);
    let mmlu = TaskSet::generate(&corpus, TaskKind::Mmlu, 100, dim.seq / 2,
                                 8, 4, &mut rng);
    let view = ModelView::Quant {
        model: &out.model,
        stats: &out.stats,
        scheme,
    };
    let s = evaluate(&engine, &view, &corpus, &csr, &mmlu, 8, recon.seed)?;
    println!("CSR {:.2}%  MMLU {:.2}%  PPL {:.3}", s.csr_acc * 100.0,
             s.mmlu_acc * 100.0, s.ppl);
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let rt = load_runtime(args)?;
    let cfg = args.get_or("cfg", "tiny");
    let dim = rt.dim(&cfg)?;
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let weights = Weights::load(&dim, Path::new(&wpath))?;
    let seed: u64 = args.parse_as("seed", 1234)?;
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let engine = Engine::new(&rt, &cfg)?;
    let mut rng = Rng::new(seed ^ 0x5EED);
    let csr = TaskSet::generate(&corpus, TaskKind::Csr, 200, dim.seq / 2, 8,
                                4, &mut rng);
    let mmlu = TaskSet::generate(&corpus, TaskKind::Mmlu, 200, dim.seq / 2, 8,
                                 4, &mut rng);

    if let Some(m) = args.get("method") {
        let method: Method = m.parse()?;
        let scheme = scheme_from(args)?;
        let recon = recon_from(args)?;
        let out = quantize_model(&rt, &engine, &weights, &corpus, method,
                                 scheme, recon)?;
        let view = ModelView::Quant {
            model: &out.model,
            stats: &out.stats,
            scheme,
        };
        let s = evaluate(&engine, &view, &corpus, &csr, &mmlu, 8, seed)?;
        println!("{} ({}): CSR {:.2}%  MMLU {:.2}%  PPL {:.3}",
                 method.paper_name(), scheme.label(), s.csr_acc * 100.0,
                 s.mmlu_acc * 100.0, s.ppl);
    } else {
        let view = ModelView::Fp(&weights);
        let s = evaluate(&engine, &view, &corpus, &csr, &mmlu, 8, seed)?;
        println!("FP16: CSR {:.2}%  MMLU {:.2}%  PPL {:.3}",
                 s.csr_acc * 100.0, s.mmlu_acc * 100.0, s.ppl);
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let cfg = args.get_or("cfg", "tiny");
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let artifacts = args.get_or("artifacts", "artifacts");
    let method = args.get("method").map(|s| s.to_string());
    let requests: usize = args.parse_as("requests", 200)?;
    let seed: u64 = args.parse_as("seed", 1234)?;
    let w_bits: u32 = args.parse_as("wbits", 4)?;
    tables::serving_run(&artifacts, &cfg, &wpath, method.as_deref(), w_bits,
                        requests, seed)
}

/// Build the artifact-free native engine from CLI flags (shared by
/// `serve-native` and `generate-native`).
fn native_model_from_args(args: &Args) -> Result<(ModelDim, NativeModel)> {
    native_model_with_scheme(args, scheme_from(args)?, "tiny")
}

/// Like [`native_model_from_args`] but with the quantization scheme decided
/// by the caller — `soak` sweeps bit-widths within one invocation.
fn native_model_with_scheme(args: &Args, mut scheme: Scheme,
                            default_cfg: &str)
                            -> Result<(ModelDim, NativeModel)> {
    let cfg = args.get_or("cfg", default_cfg);
    let init: ScaleInit = args.parse_as("init", ScaleInit::GridSearch)?;
    let shards: usize = args.parse_as("shards", 1)?;
    let seed: u64 = args.parse_as("seed", 1234)?;
    let calib: usize = args.parse_as("calib-batches", 4)?;

    // kernel dispatch override, installed before any engine is built so the
    // pinned backend is what every ExecState latches (LRQ_FORCE_SCALAR=1 is
    // the flag-free spelling, latched on first dispatch query)
    if let Some(k) = args.get("kernel") {
        let choice: KernelChoice =
            k.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        simd::set_choice(choice);
    }

    // dims: manifest entry if present (authoritative), else built-ins —
    // `micro` is native-only and never appears in a manifest
    let adir = args.get_or("artifacts", "artifacts");
    let dim = Manifest::load(Path::new(&adir))
        .ok()
        .and_then(|m| m.configs.get(cfg.as_str()).cloned())
        .or_else(|| ModelDim::builtin(&cfg))
        .with_context(|| {
            format!("config {cfg}: neither in {adir}/manifest.txt nor a \
                     built-in (micro|tiny|small)")
        })?;

    // weights: load the trained checkpoint, or random-init for a dry run
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let weights = if Path::new(&wpath).exists() {
        Weights::load(&dim, Path::new(&wpath))?
    } else {
        println!("({wpath} missing; serving random-init weights)");
        Weights::init(&dim, &mut Rng::new(seed ^ 0x1217))
    };

    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let t0 = Instant::now();
    // --checkpoint serves a saved LRQQ file (its packed bit-width wins over
    // --wbits); otherwise quantize the FP weights at load as before
    let model = match args.get("checkpoint") {
        Some(ckpt) => {
            let qm = QuantizedModel::load(&dim, Path::new(ckpt))?;
            println!("loaded LRQQ checkpoint {ckpt} (W{})", qm.bits);
            scheme = Scheme { w_bits: qm.bits, ..scheme };
            prepare_native_from(&qm, &weights, scheme, &corpus, calib, seed,
                                shards)?
        }
        None => prepare_native(&weights, scheme, init, &corpus, calib, seed,
                               shards)?,
    };
    println!(
        "native engine ready in {:.2}s: {cfg} W/A/KV {} ({:?} init), \
         {:.2} MB packed ({:.2}x vs fp32), {shards} shard thread(s), \
         kernels {}",
        t0.elapsed().as_secs_f64(),
        scheme.label(),
        init,
        model.storage_bytes() as f64 / 1e6,
        (dim.param_count() * 4) as f64 / model.storage_bytes() as f64,
        simd::describe(),
    );
    Ok((dim, model))
}

/// Start tracing when `--trace PATH` was given; returns whether a trace is
/// active so the caller knows to [`trace::shutdown`] at the end of the run.
fn trace_from(args: &Args) -> Result<bool> {
    match args.get("trace") {
        Some(path) => {
            trace::init(Path::new(path))
                .with_context(|| format!("starting trace {path}"))?;
            println!("tracing to {path}");
            Ok(true)
        }
        None => Ok(false),
    }
}

/// Shared end-of-run observability outputs: close the trace file and write
/// the `--metrics-out` Prometheus snapshot (serving registries + the
/// engine-global kernel counters).
fn obs_finish(args: &Args, trace_on: bool, regs: &[&lrq::obs::Registry])
              -> Result<()> {
    if trace_on {
        let n = trace::shutdown().context("closing trace file")?;
        println!("trace closed ({n} events)");
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(Path::new(path), export::snapshot(regs))
            .with_context(|| format!("writing metrics snapshot {path}"))?;
        println!("metrics snapshot written to {path}");
    }
    Ok(())
}

/// Start the live HTTP metrics endpoint when `--metrics-addr` was given.
fn exporter_from(args: &Args, reg: std::sync::Arc<lrq::obs::Registry>)
                 -> Result<Option<HttpExporter>> {
    match args.get("metrics-addr") {
        Some(addr) => {
            let ex = HttpExporter::start(addr, vec![reg])
                .with_context(|| format!("binding metrics on {addr}"))?;
            println!("serving metrics on http://{}/metrics", ex.addr());
            Ok(Some(ex))
        }
        None => Ok(None),
    }
}

/// Print the per-layer / per-kernel model profile plus its coverage of the
/// run's wall clock.
fn print_profile(prof: &lrq::obs::Profiler, wall: Duration) {
    let report = prof.report();
    println!("{}", report.render());
    println!(
        "profiled kernel time {:.2}s = {:.1}% of the {:.2}s wall clock",
        report.total().as_secs_f64(),
        report.coverage(wall) * 100.0,
        wall.as_secs_f64(),
    );
}

/// Parse a `HIGH[,LOW]` hysteresis watermark flag; `LOW` defaults to
/// `HIGH/2` so a bare `--shed-at 64` still gets a real recovery band.
fn watermarks_from(args: &Args, key: &str) -> Result<Option<Watermarks>> {
    let Some(spec) = args.get(key) else {
        return Ok(None);
    };
    let mut parts = spec.splitn(2, ',');
    let high: usize = parts.next().unwrap_or("").trim().parse()
        .map_err(|e| anyhow::anyhow!("bad --{key} {spec:?}: {e}"))?;
    let low = match parts.next() {
        Some(s) => s.trim().parse()
            .map_err(|e| anyhow::anyhow!("bad --{key} {spec:?}: {e}"))?,
        None => high / 2,
    };
    Ok(Some(Watermarks::new(high, low)))
}

/// The overload-policy server configuration shared by the serving
/// commands (DESIGN.md §13): `--deadline-ms`, `--shed-at` (applied to both
/// the queue-depth and KV-pressure signals), `--degrade`, `--drain-ms`.
fn server_config_from(args: &Args, max_batch: usize)
                      -> Result<ServerConfig> {
    let shed = watermarks_from(args, "shed-at")?;
    Ok(ServerConfig {
        max_batch,
        max_wait: Duration::from_millis(2),
        default_deadline: match args.get("deadline-ms") {
            Some(_) => Some(Duration::from_millis(
                args.parse_as("deadline-ms", 0u64)?)),
            None => None,
        },
        shed_queue: shed,
        shed_kv: shed,
        degrade: watermarks_from(args, "degrade")?,
        drain_deadline: Duration::from_millis(
            args.parse_as("drain-ms", 5_000u64)?),
    })
}

/// `serve-native`: serve a packed checkpoint through the dynamic batcher
/// with the pure-Rust integer engine — no PJRT, no AOT artifacts.
fn serve_native(args: &Args) -> Result<()> {
    let requests: usize = args.parse_as("requests", 200)?;
    let clients: usize = args.parse_as("clients", 4)?;
    let max_batch: usize = args.parse_as("max-batch", 8)?;

    let (dim, model) = native_model_from_args(args)?;
    let tokens_per_req = dim.seq; // each scored row is one seq-length batch row
    let prof = model.profiler();
    if args.flag("profile") {
        prof.set_enabled(true);
    }
    let trace_on = trace_from(args)?;
    let server =
        start_native_server(model, server_config_from(args, max_batch)?)?;
    let exporter =
        exporter_from(args, server.metrics.lock().unwrap().registry())?;
    let t1 = Instant::now();
    let mut handles = Vec::new();
    let n_clients = clients.max(1);
    for k in 0..n_clients as u64 {
        let client = server.client();
        // distribute the remainder so exactly `requests` are served
        let per = requests / n_clients
            + usize::from((k as usize) < requests % n_clients);
        let vocab = dim.vocab;
        let seq = dim.seq;
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut rng = Rng::new(0xD00D ^ k);
            for _ in 0..per {
                let len = rng.range(2, seq.min(48) + 1);
                let ids: Vec<i32> =
                    (0..len).map(|_| rng.below(vocab) as i32).collect();
                client.score(ids)?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join()
            .map_err(|_| anyhow::anyhow!("client thread panicked"))??;
    }
    let wall = t1.elapsed();
    let m = server.metrics.lock().unwrap().clone();
    println!("{}", m.summary(wall));
    println!(
        "wall {:.2}s, {:.0} tokens/s at seq {}",
        wall.as_secs_f64(),
        m.throughput(wall) * tokens_per_req as f64,
        tokens_per_req,
    );
    if args.flag("profile") {
        print_profile(&prof, wall);
    }
    if let Some(ex) = exporter {
        ex.shutdown();
    }
    let reg = m.registry();
    obs_finish(args, trace_on, &[reg.as_ref()])
}

/// `generate-native`: token-by-token generation through the dynamic batcher
/// with the quantized KV cache — concurrent clients' decode steps are
/// batched into shared model executions.
fn generate_native(args: &Args) -> Result<()> {
    let requests: usize = args.parse_as("requests", 32)?;
    let clients: usize = args.parse_as("clients", 4)?;
    let max_batch: usize = args.parse_as("max-batch", 8)?;
    let prompt_len: usize = args.parse_as("prompt-len", 8)?;
    let max_new: usize = args.parse_as("max-new", 16)?;
    let top_k: usize = args.parse_as("top-k", 1)?;
    let seed: u64 = args.parse_as("seed", 1234)?;

    let (dim, model) = native_model_from_args(args)?;
    if prompt_len == 0 || prompt_len + max_new > dim.seq {
        anyhow::bail!(
            "prompt-len {prompt_len} + max-new {max_new} must fit the \
             {}-token context (and prompt-len must be >= 1)",
            dim.seq
        );
    }

    let prof = model.profiler();
    if args.flag("profile") {
        prof.set_enabled(true);
    }
    let trace_on = trace_from(args)?;
    let server =
        start_native_server(model, server_config_from(args, max_batch)?)?;
    let exporter =
        exporter_from(args, server.metrics.lock().unwrap().registry())?;
    let t1 = Instant::now();
    let mut handles = Vec::new();
    let n_clients = clients.max(1);
    for k in 0..n_clients as u64 {
        let client = server.client();
        // distribute the remainder so exactly `requests` are generated
        let per = requests / n_clients
            + usize::from((k as usize) < requests % n_clients);
        let vocab = dim.vocab;
        handles.push(std::thread::spawn(
            move || -> Result<Option<(Vec<i32>, Vec<i32>)>> {
                let mut rng = Rng::new(0x6E47 ^ k);
                let mut sample = None;
                for i in 0..per {
                    let prompt: Vec<i32> = (0..prompt_len)
                        .map(|_| rng.below(vocab) as i32)
                        .collect();
                    let resp = client.generate(prompt.clone(), max_new,
                                               top_k, seed ^ (k << 8) ^ i as u64)?;
                    if sample.is_none() {
                        sample = Some((prompt, resp.tokens));
                    }
                }
                Ok(sample)
            },
        ));
    }
    let mut sample = None;
    for h in handles {
        let s = h.join()
            .map_err(|_| anyhow::anyhow!("client thread panicked"))??;
        if sample.is_none() {
            sample = s;
        }
    }
    let wall = t1.elapsed();
    let m = server.metrics.lock().unwrap().clone();
    if let Some((prompt, tokens)) = sample {
        println!("sample: prompt {prompt:?} -> {tokens:?}");
    }
    println!("{}", m.summary(wall));
    println!(
        "wall {:.2}s, {:.0} generated tokens/s end-to-end \
         (prompt {prompt_len} + {max_new} new, top-k {top_k})",
        wall.as_secs_f64(),
        m.gen_tokens() as f64 / wall.as_secs_f64(),
    );
    if args.flag("profile") {
        print_profile(&prof, wall);
    }
    if let Some(ex) = exporter {
        ex.shutdown();
    }
    let reg = m.registry();
    obs_finish(args, trace_on, &[reg.as_ref()])
}

/// `lrq soak`: the production-path soak harness (DESIGN.md §10). Per
/// bit-width: build the native engine, drive it with seeded mixed
/// score/generate load ([`lrq::loadgen`]), evaluate the declared SLOs
/// against the server's request-lifecycle event log, and emit
/// `BENCH_serve.json` (+ the event JSONL). Fails loudly — nonzero exit —
/// on any SLO violation, stuck sequence, or lost response.
fn soak(args: &Args) -> Result<()> {
    let smoke = args.flag("smoke");
    let chaos = args.flag("chaos");
    // --smoke is the CI configuration: micro model, few requests, seconds
    // of wall clock; defaults below scale up for a real soak
    let bits_str = args.get_or("bits", if smoke { "4,8" } else { "3,4,8" });
    let bits: Vec<u32> = bits_str
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse::<u32>()
             .map_err(|e| anyhow::anyhow!("bad --bits entry {s:?}: {e}")))
        .collect::<Result<_>>()?;
    if bits.is_empty() {
        anyhow::bail!("--bits named no bit-widths");
    }
    let clients: usize = args.parse_as("clients", if smoke { 3 } else { 8 })?;
    let requests: usize =
        args.parse_as("requests", if smoke { 8 } else { 64 })?;
    let max_batch: usize = args.parse_as("max-batch", 8)?;
    let max_new: usize = args.parse_as("max-new", 4)?;
    let rate: f64 = args.parse_as("rate", 200.0)?;
    let mode = match args.get_or("mode", "closed").as_str() {
        "closed" => LoadMode::Closed,
        "open" => LoadMode::Open,
        m => anyhow::bail!("--mode {m:?} is not closed|open"),
    };
    let oversized: f32 = args.parse_as("oversized", 0.1)?;
    let disconnect: f32 = args.parse_as("disconnect", 0.05)?;
    let straggler: f32 = args.parse_as("straggler", 0.1)?;
    let seed: u64 = args.parse_as("seed", 1234)?;
    // SLO ceilings: CI-safe defaults (micro model on shared runners), all
    // overridable; the error budget covers the injected oversized traffic.
    // --chaos widens it further because each injected pool/engine panic
    // rejects its whole batch by design — the chaos lane's hard gates are
    // zero stuck/lost and the fault-to-terminal-event audit, not the
    // error budget
    let err_budget =
        if chaos { 0.9 } else { (oversized as f64) * 2.0 + 0.05 };
    let slo = SloSpec {
        p50_ms: Some(args.parse_as("slo-p50-ms", 2_000.0)?),
        p99_ms: Some(args.parse_as("slo-p99-ms", 10_000.0)?),
        ttft_p99_ms: Some(args.parse_as("slo-ttft-ms", 10_000.0)?),
        queue_p99_ms: Some(args.parse_as("slo-queue-ms", 10_000.0)?),
        max_error_rate: Some(args.parse_as("slo-err", err_budget)?),
        max_expire_rate: match args.get("slo-expire") {
            Some(_) => Some(args.parse_as("slo-expire", 0.0)?),
            None => None,
        },
        max_shed_rate: match args.get("slo-shed") {
            Some(_) => Some(args.parse_as("slo-shed", 0.0)?),
            None => None,
        },
        max_stuck: 0,
    };
    // per-request deadline attached to every loadgen submission (the
    // engine-side enforcement path is exercised wherever the request is
    // when it passes: queued, awaiting admission, or mid-decode)
    let deadline_ms: Option<u64> = match args.get("deadline-ms") {
        Some(_) => Some(args.parse_as("deadline-ms", 0u64)?),
        None => None,
    };

    let mut rows: Vec<ServeBenchRow> = Vec::new();
    let mut events_jsonl = String::new();
    let mut failures: Vec<String> = Vec::new();
    let mut cfg_name = String::new();
    for &w_bits in &bits {
        let scheme = Scheme { w_bits, ..scheme_from(args)? };
        let default_cfg = if smoke { "micro" } else { "tiny" };
        let (dim, model) =
            native_model_with_scheme(args, scheme, default_cfg)?;
        cfg_name = dim.name.clone();

        // degraded plan: the same checkpoint packed at W4 next to the
        // primary — the LRQ premise that low-bit configs retain near-full
        // accuracy makes shedding quality cheaper than shedding requests
        let want_degrade = chaos || args.get("degrade").is_some();
        let degraded = if want_degrade && w_bits > 4 {
            let (_, d) = native_model_with_scheme(
                args, Scheme { w_bits: 4, ..scheme }, default_cfg)?;
            Some(d)
        } else {
            None
        };
        let has_degraded = degraded.is_some();

        let mut cfg = server_config_from(args, max_batch)?;
        if chaos {
            // chaos defaults (explicit flags win): watermarks low enough
            // that the forced burst below must trip both controllers
            if cfg.shed_queue.is_none() {
                cfg.shed_queue = Some(Watermarks::new(4, 1));
                cfg.shed_kv = Some(Watermarks::new(4, 1));
            }
            if cfg.degrade.is_none() {
                cfg.degrade = Some(Watermarks::new(2, 0));
            }
        }

        // the chaos fault plan: one of each injected failure, at call /
        // response indices the warm-up traffic is guaranteed to reach
        let plan = if chaos {
            let mut p = FaultPlan::new();
            p.pool_panic_call = Some(2);
            p.engine_panic_call = Some(5);
            p.stall_call = Some(8);
            p.stall = Duration::from_millis(400);
            p.drop_response = Some(3);
            Some(std::sync::Arc::new(p))
        } else {
            None
        };

        let mut server =
            start_native_server_with(model, degraded, cfg, plan.clone())?;
        let spec = LoadSpec {
            mode,
            clients,
            requests,
            rate_per_sec: rate,
            score_frac: 0.5,
            oversized_frac: oversized,
            disconnect_frac: disconnect,
            straggler_frac: straggler,
            score_len: (2, dim.seq.min(24)),
            prompt_len: (1, (dim.seq.saturating_sub(max_new)).clamp(1, 8)),
            max_new,
            top_k: 1,
            vocab: dim.vocab,
            seq: dim.seq,
            seed: seed ^ w_bits as u64,
            drain_timeout: Duration::from_secs(60),
            deadline_ms,
        };
        println!("\n== soak W{w_bits} ({}, {:?}, {clients} clients x \
                  {requests} reqs) ==", dim.name, mode);
        let out = loadgen::run(&server, &spec);
        if let Some(plan) = &plan {
            chaos_audit(&server, plan, &out, dim.vocab, w_bits,
                        has_degraded, &mut failures)?;
        }
        let m = server.metrics.lock().unwrap().clone();
        let ev = server.events();
        server.shutdown();
        let stuck = ev.stuck();
        let agg = ev.agg();
        let report = slo.evaluate(&agg, stuck.len() as u64);
        println!("{}", m.summary(out.wall));
        println!("submitted {} ok {} rejected {} expired {} shed {} \
                  disconnected {} lost {} in {:.2}s ({:.1} req/s)",
                 out.submitted, out.ok, out.rejected, out.expired,
                 out.shed, out.disconnected, out.lost,
                 out.wall.as_secs_f64(), out.req_per_sec());
        print!("{}", report.render());
        if !stuck.is_empty() {
            failures.push(format!(
                "W{w_bits}: {} stuck sequence(s): {stuck:?}", stuck.len()));
        }
        // under --chaos a lost response is legitimate exactly when the
        // fault plan dropped it; chaos_audit holds that equality
        if plan.is_none() && out.lost > 0 {
            failures.push(format!(
                "W{w_bits}: {} response(s) lost", out.lost));
        }
        if !report.passed() {
            failures.push(format!("W{w_bits}: SLO violation"));
        }
        events_jsonl.push_str(&ev.jsonl(&format!("w{w_bits}")));
        let ms = |us: u64| us as f64 / 1e3;
        rows.push(ServeBenchRow {
            w_bits,
            req_s: out.req_per_sec(),
            decode_tok_s: m.decode_tokens_per_sec(),
            p50_ms: ms(lrq::obs::events::percentile_us(&agg.total_us, 0.50)),
            p99_ms: ms(lrq::obs::events::percentile_us(&agg.total_us, 0.99)),
            ttft_p99_ms:
                ms(lrq::obs::events::percentile_us(&agg.ttft_us, 0.99)),
            queue_p99_ms:
                ms(lrq::obs::events::percentile_us(&agg.queue_us, 0.99)),
            error_rate: agg.error_rate(),
            expire_rate: agg.expire_rate(),
            shed_rate: agg.shed_rate(),
            degrade_shifts: m.degrade_shifts() as u64,
            stuck: stuck.len() as u64,
        });
    }

    // artifacts are written even when the run failed, so CI uploads always
    // carry the evidence
    let out_path = args.get_or("out", "BENCH_serve.json");
    let json = loadgen::render_bench_serve(smoke, &cfg_name, &rows);
    std::fs::write(&out_path, &json)
        .with_context(|| format!("writing {out_path}"))?;
    println!("\nwrote {out_path} ({} bytes)", json.len());
    let ev_path = args.get_or("events-out", "soak_events.jsonl");
    std::fs::write(&ev_path, &events_jsonl)
        .with_context(|| format!("writing {ev_path}"))?;
    println!("wrote {ev_path} ({} events)", events_jsonl.lines().count());

    // regression gate: same semantics as the native bench's --compare
    // (zero-valued baseline entries are provisional and skipped)
    if let Some(bpath) = args.get("compare") {
        let baseline = std::fs::read_to_string(bpath)
            .with_context(|| format!("reading baseline {bpath}"))?;
        for key in ["req_s", "decode_tok_s"] {
            for r in lrq::bench::regressions(&baseline, &json, key, 0.30) {
                failures.push(format!("regression vs {bpath}: {r}"));
            }
        }
        if failures.is_empty() {
            println!("soak compare vs {bpath}: ok");
        }
    }

    if !failures.is_empty() {
        for f in &failures {
            eprintln!("soak FAIL: {f}");
        }
        anyhow::bail!("{} soak failure(s)", failures.len());
    }
    println!("soak: all SLOs passed, zero stuck sequences{}",
             if chaos { ", chaos faults contained" } else { "" });
    Ok(())
}

/// The chaos lane's in-vivo audit (DESIGN.md §13), run against the live
/// server after the warm-up soak traffic: every injected fault must have
/// fired exactly once and be accounted for by a terminal outcome, a forced
/// overload burst must trip shed-then-recover, zero-deadline probes must
/// all expire, and — when a degraded plan is attached — the burst must
/// drive a downshift-then-restore of the decode plan.
fn chaos_audit(server: &lrq::serve::Server, plan: &FaultPlan,
               out: &loadgen::LoadOutcome, vocab: usize, w_bits: u32,
               has_degraded: bool, failures: &mut Vec<String>)
               -> Result<()> {
    let fired = plan.fired();
    for (what, got) in [("pool-job panic", fired.pool_panics),
                        ("engine panic", fired.engine_panics),
                        ("kernel stall", fired.stalls),
                        ("response drop", fired.drops)] {
        if got != 1 {
            failures.push(format!(
                "W{w_bits} chaos: injected {what} fired {got}x, want 1 \
                 (warm-up traffic too small for the fault plan?)"));
        }
    }
    // zero-lost: a response may vanish only because the plan dropped it
    if out.lost != plan.drops_fired() {
        failures.push(format!(
            "W{w_bits} chaos: {} lost response(s) vs {} injected drop(s)",
            out.lost, plan.drops_fired()));
    }

    // forced overload burst: submit far past the shed watermark before
    // reading any response, so admission control must arm (and the
    // degrade controller downshift) while the backlog drains
    let c = server.client();
    let mut pending = Vec::new();
    for i in 0..64u64 {
        let ids: Vec<i32> = (0..6)
            .map(|t| ((i * 7 + t) % vocab.min(61) as u64) as i32)
            .collect();
        pending.push(c.submit(ids)?);
    }
    let (mut served, mut shed, mut other) = (0u64, 0u64, 0u64);
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => served += 1,
            Ok(Err(msg)) if msg.starts_with(SHED_PREFIX) => shed += 1,
            _ => other += 1,
        }
    }
    if shed == 0 {
        failures.push(format!(
            "W{w_bits} chaos: overload burst tripped no shedding"));
    }
    if served == 0 {
        failures.push(format!(
            "W{w_bits} chaos: overload burst starved every request"));
    }
    if other != 0 {
        failures.push(format!(
            "W{w_bits} chaos: {other} burst request(s) ended neither \
             served nor shed"));
    }

    // zero-deadline probes, submitted after the backlog cleared so they
    // reach the queue (instead of being shed) and must all expire
    let zc = c.clone().with_deadline(Duration::ZERO);
    let probes: Vec<_> = (0..4)
        .map(|_| zc.submit(vec![1, 2, 3]))
        .collect::<Result<_>>()?;
    let expired = probes
        .into_iter()
        .filter(|rx| matches!(rx.recv(),
                              Ok(Err(msg)) if msg.starts_with(EXPIRED_PREFIX)))
        .count();
    if expired != 4 {
        failures.push(format!(
            "W{w_bits} chaos: {expired}/4 zero-deadline probes expired"));
    }

    // recovery: shedding must have disarmed once the burst drained — a
    // fresh request is served normally
    if let Err(e) = c.score(vec![1, 2, 3, 4]) {
        failures.push(format!(
            "W{w_bits} chaos: no recovery after the burst: {e}"));
    }

    // downshift-then-restore: the burst pushed the queue past the degrade
    // watermark, and once idle the controller must restore the primary
    // plan (the restore lands on an idle controller pass, so poll)
    if has_degraded {
        let poll_deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let (shifts, on) = {
                let m = server.metrics.lock().unwrap();
                (m.degrade_shifts(), m.is_degraded())
            };
            if shifts >= 2 && !on {
                println!("chaos: degrade downshift-then-restore observed \
                          ({shifts} transitions)");
                break;
            }
            if Instant::now() >= poll_deadline {
                failures.push(format!(
                    "W{w_bits} chaos: no downshift-then-restore \
                     ({shifts} transition(s), degraded={on})"));
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
    println!("chaos burst: {served} served, {shed} shed, {expired}/4 \
              probes expired; faults fired {fired:?}");
    Ok(())
}

/// `stats`: run a profiled generate workload directly on the native engine
/// (no batcher) and print the per-layer / per-kernel model profile — the
/// observability twin of `generate-native` for answering "where does a
/// decode step's time go?".
fn stats(args: &Args) -> Result<()> {
    let requests: usize = args.parse_as("requests", 8)?;
    let prompt_len: usize = args.parse_as("prompt-len", 8)?;
    let max_new: usize = args.parse_as("max-new", 32)?;
    let top_k: usize = args.parse_as("top-k", 1)?;
    let seed: u64 = args.parse_as("seed", 1234)?;

    let (dim, model) = native_model_from_args(args)?;
    if prompt_len == 0 || prompt_len + max_new > dim.seq {
        anyhow::bail!(
            "prompt-len {prompt_len} + max-new {max_new} must fit the \
             {}-token context (and prompt-len must be >= 1)",
            dim.seq
        );
    }
    let prof = model.profiler();
    prof.set_enabled(true);
    let trace_on = trace_from(args)?;

    let mut rng = Rng::new(seed ^ 0x57A7);
    let t0 = Instant::now();
    let mut generated = 0usize;
    for _ in 0..requests.max(1) {
        let prompt: Vec<i32> = (0..prompt_len)
            .map(|_| rng.below(dim.vocab) as i32)
            .collect();
        let tokens = model.generate(&prompt, max_new, top_k, seed)?;
        generated += tokens.len();
    }
    let wall = t0.elapsed();
    println!(
        "{} generations x (prompt {prompt_len} + {max_new} new) = {} tokens \
         in {:.2}s",
        requests.max(1),
        generated,
        wall.as_secs_f64(),
    );
    print_profile(&prof, wall);
    println!("kernel dispatch: {}", simd::describe());
    obs_finish(args, trace_on, &[])
}

/// Consistency probe: loss reported by the train_step artifact (lr=0) vs the
/// chained embed→block→head engine on the same weights and batch.
/// `lrq lint`: run the invariant linter (DESIGN.md §12) and fail the
/// process on any violation — the blocking CI step.
fn lint_cmd(args: &Args) -> Result<()> {
    let root = args.get_or("root", "src");
    let config = args.get_or("config", "lint.toml");
    let json_out = args.get_or("json", "LINT.json");
    let cfg_text = std::fs::read_to_string(&config)
        .with_context(|| format!("reading lint config {config} (run from \
                                  the rust/ crate dir, or pass --config)"))?;
    let cfg = lrq::lint::LintConfig::parse(&cfg_text)?;
    let report = lrq::lint::run(Path::new(&root), &cfg)?;
    print!("{}", report.render_text());
    std::fs::write(&json_out, report.render_json())
        .with_context(|| format!("writing {json_out}"))?;
    println!("wrote {json_out}");
    if !report.violations.is_empty() {
        anyhow::bail!("{} lint violation(s)", report.violations.len());
    }
    Ok(())
}

fn debug_loss(args: &Args) -> Result<()> {
    use lrq::runtime::{ids_lit, scalar_from_lit, scalar_lit, to_lit};
    let rt = load_runtime(args)?;
    let cfg = args.get_or("cfg", "tiny");
    let dim = rt.dim(&cfg)?;
    let wpath = args.get_or("weights", &format!("weights_{cfg}.bin"));
    let weights = Weights::load(&dim, Path::new(&wpath))?;
    let corpus = Corpus::new(CorpusConfig::for_vocab(dim.vocab));
    let mut rng = Rng::new(42);
    let (ids, tgt) = corpus.train_batch(dim.train_batch, dim.seq, &mut rng);

    // (a) loss via train_step with lr = 0
    let exec = rt.exec(&format!("train_step_{cfg}"))?;
    let flat = weights.flat();
    let mut inputs: Vec<xla::Literal> = Vec::new();
    for t in &flat {
        inputs.push(to_lit(t)?);
    }
    for t in &flat {
        inputs.push(to_lit(&lrq::tensor::Tensor::zeros(&t.dims))?);
    }
    for t in &flat {
        inputs.push(to_lit(&lrq::tensor::Tensor::zeros(&t.dims))?);
    }
    inputs.push(ids_lit(&ids, &[dim.train_batch, dim.seq])?);
    inputs.push(ids_lit(&tgt, &[dim.train_batch, dim.seq])?);
    inputs.push(scalar_lit(0.0));
    inputs.push(scalar_lit(0.0));
    let outs = exec.run(&inputs)?;
    println!("train_step loss: {:.4}", scalar_from_lit(&outs[0])?);

    // (b) loss via the chained engine on the first calib_batch rows
    let engine = Engine::new(&rt, &cfg)?;
    let rows = dim.calib_batch * dim.seq;
    let (loss, _) = engine.fp_forward(&weights, &ids[..rows], &tgt[..rows])?;
    println!("engine chain loss: {loss:.4}");
    Ok(())
}
