//! Bit-packing of integer weight codes (8/4/3-bit) — the storage format of
//! quantized checkpoints and the model-size numbers of Fig. 5 / Table 15.
//!
//! Codes are packed LSB-first into a contiguous bitstream per matrix; 4-bit
//! packs two codes per byte, 3-bit packs 8 codes per 3 bytes (true bit-level
//! packing, matching the 4.55× / 3.58× compression ratios in Appendix G).
//!
//! Every decode path is length-checked: [`unpack_bits`] refuses truncated
//! bitstreams and [`PackedMatrix::new`] validates the packed buffer against
//! `(rows·cols·bits)/8` at construction, so the serving-side kernels in
//! [`crate::infer`] can index the stream without per-element bounds anxiety.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// A per-channel-quantized matrix in packed storage: integer codes + grid.
///
/// The packed stream is crate-private so the length invariant established by
/// [`PackedMatrix::new`] cannot be bypassed by struct-literal construction;
/// decode paths ([`PackedMatrix::unpack`], the `infer` GEMM tiles) rely on
/// it.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
    pub(crate) packed: Vec<u8>,
}

/// Exact byte length of `n` codes packed at `bits` bits each.
pub fn packed_len(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Pack `codes` (each < 2^bits) into an LSB-first bitstream.
pub fn pack_bits(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=16).contains(&bits), "pack_bits: bits {bits} out of range");
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c < (1 << bits));
        let mut v = c;
        let mut left = bits;
        while left > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(left as usize) as u32;
            out[byte] |= ((v & ((1 << take) - 1)) as u8) << off;
            v >>= take;
            left -= take;
            bitpos += take as usize;
        }
    }
    out
}

/// Inverse of [`pack_bits`]. Fails on a truncated/short bitstream instead of
/// indexing out of bounds.
pub fn unpack_bits(packed: &[u8], bits: u32, n: usize) -> Result<Vec<u32>> {
    if !(1..=16).contains(&bits) {
        bail!("unpack_bits: bits {bits} out of range [1, 16]");
    }
    let need = packed_len(n, bits);
    if packed.len() < need {
        bail!("unpack_bits: truncated bitstream ({} bytes, need {need} for \
               {n} codes at {bits} bits)", packed.len());
    }
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut v = 0u32;
        let mut got = 0u32;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min((bits - got) as usize) as u32;
            let part = ((packed[byte] >> off) as u32) & ((1 << take) - 1);
            v |= part << got;
            got += take;
            bitpos += take as usize;
        }
        out.push(v);
    }
    Ok(out)
}

impl PackedMatrix {
    /// Validated constructor: grid and packed-stream lengths must match the
    /// matrix shape exactly. All decode paths rely on this invariant.
    pub fn new(rows: usize, cols: usize, bits: u32, scale: Vec<f32>,
               zp: Vec<f32>, packed: Vec<u8>) -> Result<Self> {
        if !(1..=8).contains(&bits) {
            bail!("PackedMatrix: bits {bits} out of range [1, 8]");
        }
        if scale.len() != rows || zp.len() != rows {
            bail!("PackedMatrix: grid size mismatch (rows {rows}, scale {}, \
                   zp {})", scale.len(), zp.len());
        }
        let expect = packed_len(rows * cols, bits);
        if packed.len() != expect {
            bail!("PackedMatrix: packed stream is {} bytes, expected {expect} \
                   for {rows}x{cols} at {bits} bits", packed.len());
        }
        Ok(PackedMatrix { rows, cols, bits, scale, zp, packed })
    }

    /// Pack integer codes (f32-carried, as produced by quantization) with
    /// their grid.
    pub fn from_codes(
        codes: &Tensor,
        scale: &[f32],
        zp: &[f32],
        bits: u32,
    ) -> Result<Self> {
        let (rows, cols) = codes.rc();
        let max = (1u32 << bits) - 1;
        let ints: Vec<u32> = codes
            .data
            .iter()
            .map(|&c| (c.round() as i64).clamp(0, max as i64) as u32)
            .collect();
        PackedMatrix::new(rows, cols, bits, scale.to_vec(), zp.to_vec(),
                          pack_bits(&ints, bits))
    }

    /// Unpack to integer codes carried in f32 (the kernel_qmm input format).
    pub fn codes(&self) -> Tensor {
        let ints = self.unpack();
        Tensor::new(
            vec![self.rows, self.cols],
            ints.into_iter().map(|v| v as f32).collect(),
        )
    }

    /// Raw integer codes, row-major.
    pub fn unpack(&self) -> Vec<u32> {
        unpack_bits(&self.packed, self.bits, self.rows * self.cols)
            .expect("PackedMatrix invariant: lengths validated at construction")
    }

    /// Dequantize to dense f32 (`(q - z)·s` per row).
    pub fn dequant(&self) -> Tensor {
        let ints = self.unpack();
        let mut data = Vec::with_capacity(ints.len());
        for r in 0..self.rows {
            let s = self.scale[r];
            let z = self.zp[r];
            for c in 0..self.cols {
                data.push((ints[r * self.cols + c] as f32 - z) * s);
            }
        }
        Tensor::new(vec![self.rows, self.cols], data)
    }

    /// Storage bytes (packed codes + f32 grid) — the Fig. 5 model-size number.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zp.len()) * 4
    }

    /// FP32 storage for comparison.
    pub fn fp_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{grid::rtn_grid, lrq::quantize_int_codes};
    use crate::rng::Rng;

    #[test]
    fn pack_roundtrip_all_bits() {
        let mut rng = Rng::new(1);
        for bits in [3u32, 4, 8] {
            let n = 1000;
            let codes: Vec<u32> =
                (0..n).map(|_| rng.below(1 << bits) as u32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(unpack_bits(&packed, bits, n).unwrap(), codes);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn unpack_rejects_truncated_stream() {
        let codes: Vec<u32> = (0..64).map(|i| i % 8).collect();
        for bits in [3u32, 4, 8] {
            let packed = pack_bits(&codes, bits);
            // full stream decodes
            assert!(unpack_bits(&packed, bits, 64).is_ok());
            // one byte short: refused, not out-of-bounds
            let short = &packed[..packed.len() - 1];
            let err = unpack_bits(short, bits, 64).unwrap_err();
            assert!(format!("{err}").contains("truncated"), "{err}");
            // asking for more codes than the stream holds: refused
            assert!(unpack_bits(&packed, bits, 100).is_err());
        }
        // bad bit-widths
        assert!(unpack_bits(&[0u8; 4], 0, 1).is_err());
        assert!(unpack_bits(&[0u8; 4], 17, 1).is_err());
    }

    #[test]
    fn constructor_validates_lengths() {
        let ok = PackedMatrix::new(2, 8, 4, vec![1.0; 2], vec![0.0; 2],
                                   vec![0u8; 8]);
        assert!(ok.is_ok());
        // short packed stream
        assert!(PackedMatrix::new(2, 8, 4, vec![1.0; 2], vec![0.0; 2],
                                  vec![0u8; 7]).is_err());
        // over-long packed stream
        assert!(PackedMatrix::new(2, 8, 4, vec![1.0; 2], vec![0.0; 2],
                                  vec![0u8; 9]).is_err());
        // grid mismatch
        assert!(PackedMatrix::new(2, 8, 4, vec![1.0; 3], vec![0.0; 2],
                                  vec![0u8; 8]).is_err());
        // unsupported bits
        assert!(PackedMatrix::new(2, 8, 9, vec![1.0; 2], vec![0.0; 2],
                                  vec![0u8; 18]).is_err());
    }

    #[test]
    fn packed_matrix_roundtrip() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[12, 40], 0.1);
        for bits in [3u32, 4, 8] {
            let qmax = crate::quant::qmax(bits);
            let g = rtn_grid(&w, qmax);
            let codes = quantize_int_codes(&w, &g, None);
            let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
                .unwrap();
            assert_eq!(pm.codes(), codes);
            // dequant error bounded by scale/2 per element
            let dq = pm.dequant();
            for r in 0..12 {
                for c in 0..40 {
                    let d = (dq.data[r * 40 + c] - w.data[r * 40 + c]).abs();
                    assert!(d <= g.scale[r] * 0.5 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn compression_ratios_match_appendix_g() {
        // Appendix G: 3-bit ≈ 4.55×, 4-bit ≈ 3.58× on Llama-2-7B (weights +
        // grids). Pure packing upper bounds: 32/3 = 10.7, 32/4 = 8 — the
        // measured ratios include FP pieces; here we check the matrix-level
        // ratio is between 32/(bits+1) and 32/bits.
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[128, 352], 0.1);
        for bits in [3u32, 4] {
            let qmax = crate::quant::qmax(bits);
            let g = rtn_grid(&w, qmax);
            let codes = quantize_int_codes(&w, &g, None);
            let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
                .unwrap();
            let ratio = pm.fp_bytes() as f64 / pm.storage_bytes() as f64;
            assert!(ratio > 32.0 / (bits as f64 + 1.0), "ratio {ratio}");
            assert!(ratio <= 32.0 / bits as f64 + 1e-9);
        }
    }
}
