//! Bit-packing of integer weight codes (8/4/3-bit) — the storage format of
//! quantized checkpoints and the model-size numbers of Fig. 5 / Table 15.
//!
//! Codes are packed LSB-first into a contiguous bitstream per matrix; 4-bit
//! packs two codes per byte, 3-bit packs 8 codes per 3 bytes (true bit-level
//! packing, matching the 4.55× / 3.58× compression ratios in Appendix G).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// A per-channel-quantized matrix in packed storage: integer codes + grid.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
    pub packed: Vec<u8>,
}

/// Pack `codes` (each < 2^bits) into an LSB-first bitstream.
pub fn pack_bits(codes: &[u32], bits: u32) -> Vec<u8> {
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c < (1 << bits));
        let mut v = c;
        let mut left = bits;
        while left > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(left as usize) as u32;
            out[byte] |= ((v & ((1 << take) - 1)) as u8) << off;
            v >>= take;
            left -= take;
            bitpos += take as usize;
        }
    }
    out
}

/// Inverse of [`pack_bits`].
pub fn unpack_bits(packed: &[u8], bits: u32, n: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut v = 0u32;
        let mut got = 0u32;
        while got < bits {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min((bits - got) as usize) as u32;
            let part = ((packed[byte] >> off) as u32) & ((1 << take) - 1);
            v |= part << got;
            got += take;
            bitpos += take as usize;
        }
        out.push(v);
    }
    out
}

impl PackedMatrix {
    /// Pack integer codes (f32-carried, as produced by quantization) with
    /// their grid.
    pub fn from_codes(
        codes: &Tensor,
        scale: &[f32],
        zp: &[f32],
        bits: u32,
    ) -> Result<Self> {
        let (rows, cols) = codes.rc();
        if scale.len() != rows || zp.len() != rows {
            bail!("grid size mismatch");
        }
        let max = (1u32 << bits) - 1;
        let ints: Vec<u32> = codes
            .data
            .iter()
            .map(|&c| (c.round() as i64).clamp(0, max as i64) as u32)
            .collect();
        Ok(PackedMatrix {
            rows,
            cols,
            bits,
            scale: scale.to_vec(),
            zp: zp.to_vec(),
            packed: pack_bits(&ints, bits),
        })
    }

    /// Unpack to integer codes carried in f32 (the kernel_qmm input format).
    pub fn codes(&self) -> Tensor {
        let ints = unpack_bits(&self.packed, self.bits, self.rows * self.cols);
        Tensor::new(
            vec![self.rows, self.cols],
            ints.into_iter().map(|v| v as f32).collect(),
        )
    }

    /// Dequantize to dense f32 (`(q - z)·s` per row).
    pub fn dequant(&self) -> Tensor {
        let ints = unpack_bits(&self.packed, self.bits, self.rows * self.cols);
        let mut data = Vec::with_capacity(ints.len());
        for r in 0..self.rows {
            let s = self.scale[r];
            let z = self.zp[r];
            for c in 0..self.cols {
                data.push((ints[r * self.cols + c] as f32 - z) * s);
            }
        }
        Tensor::new(vec![self.rows, self.cols], data)
    }

    /// Storage bytes (packed codes + f32 grid) — the Fig. 5 model-size number.
    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zp.len()) * 4
    }

    /// FP32 storage for comparison.
    pub fn fp_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{grid::rtn_grid, lrq::quantize_int_codes};
    use crate::rng::Rng;

    #[test]
    fn pack_roundtrip_all_bits() {
        let mut rng = Rng::new(1);
        for bits in [3u32, 4, 8] {
            let n = 1000;
            let codes: Vec<u32> =
                (0..n).map(|_| rng.below(1 << bits) as u32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(unpack_bits(&packed, bits, n), codes);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn packed_matrix_roundtrip() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[12, 40], 0.1);
        for bits in [3u32, 4, 8] {
            let qmax = crate::quant::qmax(bits);
            let g = rtn_grid(&w, qmax);
            let codes = quantize_int_codes(&w, &g, None);
            let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
                .unwrap();
            assert_eq!(pm.codes(), codes);
            // dequant error bounded by scale/2 per element
            let dq = pm.dequant();
            for r in 0..12 {
                for c in 0..40 {
                    let d = (dq.data[r * 40 + c] - w.data[r * 40 + c]).abs();
                    assert!(d <= g.scale[r] * 0.5 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn compression_ratios_match_appendix_g() {
        // Appendix G: 3-bit ≈ 4.55×, 4-bit ≈ 3.58× on Llama-2-7B (weights +
        // grids). Pure packing upper bounds: 32/3 = 10.7, 32/4 = 8 — the
        // measured ratios include FP pieces; here we check the matrix-level
        // ratio is between 32/(bits+1) and 32/bits.
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[128, 352], 0.1);
        for bits in [3u32, 4] {
            let qmax = crate::quant::qmax(bits);
            let g = rtn_grid(&w, qmax);
            let codes = quantize_int_codes(&w, &g, None);
            let pm = PackedMatrix::from_codes(&codes, &g.scale, &g.zp, bits)
                .unwrap();
            let ratio = pm.fp_bytes() as f64 / pm.storage_bytes() as f64;
            assert!(ratio > 32.0 / (bits as f64 + 1.0), "ratio {ratio}");
            assert!(ratio <= 32.0 / bits as f64 + 1e-9);
        }
    }
}
