//! Per-channel asymmetric quantization grids (the weight-side scheme used in
//! every experiment of the paper) and the RTN / grid-searched initializers.

use crate::tensor::Tensor;

/// Per-output-channel asymmetric grid: `q = clip(round(w/s + z), 0, qmax)`,
/// `ŵ = (q - z)·s`. One (s, z) pair per row of `W[Cout, Cin]`.
#[derive(Clone, Debug)]
pub struct ChannelGrid {
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
    pub qmax: f32,
}

impl ChannelGrid {
    pub fn rows(&self) -> usize {
        self.scale.len()
    }

    /// Fake-quant one row with this grid (no weight-scaling exponent).
    pub fn fq_row(&self, r: usize, w: &[f32], out: &mut [f32]) {
        let s = self.scale[r];
        let z = self.zp[r];
        for (o, &x) in out.iter_mut().zip(w) {
            let q = (x / s + z).round().clamp(0.0, self.qmax);
            *o = (q - z) * s;
        }
    }
}

/// RTN init: per-row min/max range (zero always included, as in the paper's
/// asymmetric scheme).
pub fn rtn_grid(w: &Tensor, qmax: f32) -> ChannelGrid {
    let (rows, _cols) = w.rc();
    let mut scale = Vec::with_capacity(rows);
    let mut zp = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = w.row(r);
        let mut lo = 0.0f32;
        let mut hi = 0.0f32;
        for &x in row {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let s = ((hi - lo) / qmax).max(1e-9);
        let z = (-lo / s).round().clamp(0.0, qmax);
        scale.push(s);
        zp.push(z);
    }
    ChannelGrid { scale, zp, qmax }
}

/// FlexRound/LRQ initializer: refine each row's scale by grid search,
/// `s1 = argmin_s ||w - fq(w; s)||²` over multiplicative candidates around the
/// RTN scale (the paper's `arg min_{s1} ||W - Ŵ||²` init).
pub fn grid_search_scales(w: &Tensor, qmax: f32, candidates: usize) -> ChannelGrid {
    let mut g = rtn_grid(w, qmax);
    let (rows, cols) = w.rc();
    let mut buf = vec![0.0f32; cols];
    for r in 0..rows {
        let row = w.row(r);
        let s0 = g.scale[r];
        let mut best = (f64::INFINITY, s0, g.zp[r]);
        for i in 0..candidates {
            // sweep 0.6 .. 1.15 × RTN scale
            let f = 0.6 + 0.55 * (i as f32) / (candidates.max(2) - 1) as f32;
            let s = s0 * f;
            // re-derive zero point for the candidate scale
            let lo = row.iter().cloned().fold(0.0f32, f32::min);
            let z = (-lo / s).round().clamp(0.0, qmax);
            let mut err = 0.0f64;
            for (o, &x) in buf.iter_mut().zip(row) {
                let q = (x / s + z).round().clamp(0.0, qmax);
                *o = (q - z) * s;
                let d = (*o - x) as f64;
                err += d * d;
            }
            if err < best.0 {
                best = (err, s, z);
            }
        }
        g.scale[r] = best.1;
        g.zp[r] = best.2;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn rtn_range_covers_zero() {
        let w = Tensor::new(vec![1, 4], vec![0.5, 1.0, 2.0, 3.0]);
        let g = rtn_grid(&w, 255.0);
        // all-positive row: lo clamps to 0, zp = 0
        assert_eq!(g.zp[0], 0.0);
        assert!((g.scale[0] - 3.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn rtn_roundtrip_error_bound() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[16, 64], 1.0);
        let g = rtn_grid(&w, 255.0);
        let mut out = vec![0.0f32; 64];
        for r in 0..16 {
            g.fq_row(r, w.row(r), &mut out);
            for (o, &x) in out.iter().zip(w.row(r)) {
                assert!((o - x).abs() <= g.scale[r] * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn grid_search_not_worse_than_rtn() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[8, 128], 0.05);
        for qmax in [7.0, 15.0, 255.0] {
            let rtn = rtn_grid(&w, qmax);
            let gs = grid_search_scales(&w, qmax, 40);
            let mut buf = vec![0.0f32; 128];
            let err = |g: &ChannelGrid| {
                let mut e = 0.0f64;
                let mut buf = buf.clone();
                for r in 0..8 {
                    g.fq_row(r, w.row(r), &mut buf);
                    for (o, &x) in buf.iter().zip(w.row(r)) {
                        let d = (o - x) as f64;
                        e += d * d;
                    }
                }
                e
            };
            let e_gs = err(&gs);
            let e_rtn = err(&rtn);
            assert!(e_gs <= e_rtn * 1.0001, "{e_gs} vs {e_rtn} @ qmax {qmax}");
            buf.clear();
        }
    }

    #[test]
    fn lower_bits_more_error() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[4, 256], 1.0);
        let mut errs = Vec::new();
        for bits in [8u32, 4, 3] {
            let g = rtn_grid(&w, super::super::qmax(bits));
            let mut e = 0.0f64;
            let mut buf = vec![0.0f32; 256];
            for r in 0..4 {
                g.fq_row(r, w.row(r), &mut buf);
                for (o, &x) in buf.iter().zip(w.row(r)) {
                    e += ((o - x) as f64).powi(2);
                }
            }
            errs.push(e);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }
}
