//! Activation-side quantization: per-tensor static ranges (calibrated by the
//! L3 pass from `block_fwd` stats) and per-token fake-quant (the Rust oracle
//! for the L1 per-token kernel; also used by SmoothQuant's statistics).

use crate::tensor::Tensor;

/// A calibrated per-tensor asymmetric range.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActRange {
    pub min: f32,
    pub max: f32,
}

impl ActRange {
    pub fn update(&mut self, mn: f32, mx: f32) {
        self.min = self.min.min(mn.min(0.0));
        self.max = self.max.max(mx.max(0.0));
    }

    /// (scale, zero-point) for a given qmax.
    pub fn grid(&self, qmax: f32) -> (f32, f32) {
        let scale = ((self.max - self.min) / qmax).max(1e-9);
        let zp = (-self.min / scale).round().clamp(0.0, qmax);
        (scale, zp)
    }
}

/// Map one value onto the integer grid: `round(v/scale + zp)` clamped to
/// `[0, qmax]`. The single source of the code mapping — paired with
/// [`row_grid`] so every quant site (fake-quant oracles here, the integer
/// activation kernels, the KV cache) stays bit-identical.
#[inline]
pub fn quantize_code(v: f32, scale: f32, zp: f32, qmax: f32) -> f32 {
    (v / scale + zp).round().clamp(0.0, qmax)
}

/// Per-tensor static asymmetric fake-quant.
pub fn per_tensor_quant(x: &Tensor, scale: f32, zp: f32, qmax: f32) -> Tensor {
    x.map(|v| (quantize_code(v, scale, zp, qmax) - zp) * scale)
}

/// The per-token asymmetric grid of one activation row: `(scale, zp)` with
/// the `(hi-lo)/qmax` scale floor and zero-anchored range (`min(0)` /
/// `max(0)`). The **single source of the per-token grid math** — shared by
/// [`per_token_quant`], the integer activation-quant kernel
/// (`infer::kernels`), and the KV cache (`infer::decode`), whose
/// token-for-token decode equivalence depends on all three staying
/// bit-identical.
pub fn row_grid(row: &[f32], qmax: f32) -> (f32, f32) {
    let mut lo = 0.0f32;
    let mut hi = 0.0f32;
    for &v in row {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = ((hi - lo) / qmax).max(1e-9);
    let zp = (-lo / scale).round().clamp(0.0, qmax);
    (scale, zp)
}

/// Per-token asymmetric fake-quant over the trailing dim (oracle for the
/// Pallas per-token kernel).
pub fn per_token_quant(x: &Tensor, qmax: f32) -> Tensor {
    let (t, d) = x.as_2d();
    let mut out = vec![0.0f32; x.len()];
    for i in 0..t {
        let row = &x.data[i * d..(i + 1) * d];
        let (scale, zp) = row_grid(row, qmax);
        for (o, &v) in out[i * d..(i + 1) * d].iter_mut().zip(row) {
            *o = (quantize_code(v, scale, zp, qmax) - zp) * scale;
        }
    }
    Tensor::new(x.dims.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn range_grid_covers() {
        let mut r = ActRange::default();
        r.update(-2.0, 6.0);
        let (scale, zp) = r.grid(255.0);
        // dequant endpoints land near the true range
        let lo = (0.0 - zp) * scale;
        let hi = (255.0 - zp) * scale;
        assert!((lo - -2.0).abs() < scale);
        assert!((hi - 6.0).abs() < scale);
    }

    #[test]
    fn per_tensor_error_bound() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[8, 32], 1.0);
        let mut r = ActRange::default();
        r.update(x.min(), x.max());
        let (s, z) = r.grid(255.0);
        let q = per_tensor_quant(&x, s, z, 255.0);
        for (a, b) in q.data.iter().zip(&x.data) {
            assert!((a - b).abs() <= s * 0.5 + 1e-6);
        }
    }

    #[test]
    fn per_token_tighter_than_per_tensor_on_outliers() {
        // one token with huge dynamic range should not hurt the others under
        // per-token quant — the SmoothQuant/per-token motivation.
        let mut rng = Rng::new(2);
        let mut x = Tensor::randn(&mut rng, &[4, 64], 0.1);
        for v in x.row_mut(0) {
            *v *= 100.0;
        }
        let qmax = 255.0;
        let per_tok = per_token_quant(&x, qmax);
        let mut r = ActRange::default();
        r.update(x.min(), x.max());
        let (s, z) = r.grid(qmax);
        let per_ten = per_tensor_quant(&x, s, z, qmax);
        // compare error on the *normal* tokens only
        let sl = |t: &Tensor| Tensor::new(vec![3, 64], t.data[64..].to_vec());
        let base = sl(&x);
        assert!(sl(&per_tok).rmse(&base) < sl(&per_ten).rmse(&base));
    }

    #[test]
    fn per_token_idempotent() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[6, 16], 1.0);
        let q1 = per_token_quant(&x, 255.0);
        let q2 = per_token_quant(&q1, 255.0);
        assert!(q1.rmse(&q2) < 2e-2 * q1.frob() / (q1.len() as f64).sqrt());
    }
}
