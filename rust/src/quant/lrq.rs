//! Rust-side LRQ math: the exponent matrix `S = L2·U2 + r2 + c2`, fake-quant
//! with a learned exponent, integer-code extraction, and the Table 29
//! learnable-parameter accounting.
//!
//! This mirrors the L1 Pallas kernel exactly (cross-checked by the
//! `kernel_fakequant_*` integration test) and is used at *finalize* time:
//! after reconstruction, `L2, U2, r2, c2` are folded into integer codes and
//! discarded — inference needs only `(s1, z, codes)` (Appendix G).

use crate::tensor::Tensor;

use super::grid::ChannelGrid;

/// Learned LRQ parameters for one linear layer.
#[derive(Clone, Debug)]
pub struct LrqParams {
    /// multiplicative offset on the init scale: `s1 = s1_init · exp(ds1)`
    pub ds1: Vec<f32>,
    pub l2: Tensor,
    pub u2: Tensor,
    pub r2: Vec<f32>,
    pub c2: Vec<f32>,
}

impl LrqParams {
    /// RTN start: ds1 = 0, L2 = 0, U2 ~ N(0, 0.01), r2 = c2 = 0 (paper §2.3).
    pub fn init(rng: &mut crate::rng::Rng, cout: usize, cin: usize,
                rank: usize) -> Self {
        LrqParams {
            ds1: vec![0.0; cout],
            l2: Tensor::zeros(&[cout, rank]),
            u2: Tensor::randn(rng, &[rank, cin], 0.01),
            r2: vec![0.0; cout],
            c2: vec![0.0; cin],
        }
    }

    /// The exponent matrix `S = L2U2 + r2 + c2` (Appendix M broadcasting).
    pub fn exponent(&self) -> Tensor {
        let mut s = self.l2.matmul(&self.u2);
        let (rows, _cols) = s.rc();
        for r in 0..rows {
            let rb = self.r2[r];
            let row = s.row_mut(r);
            for (x, &cb) in row.iter_mut().zip(&self.c2) {
                *x += rb + cb;
            }
        }
        s
    }

    /// Effective per-channel scales `s1 = s1_init · exp(ds1)`.
    pub fn effective_scale(&self, s1_init: &[f32]) -> Vec<f32> {
        s1_init
            .iter()
            .zip(&self.ds1)
            .map(|(&s, &d)| s * d.exp())
            .collect()
    }

    pub fn param_count(&self) -> usize {
        self.ds1.len() + self.l2.len() + self.u2.len() + self.r2.len()
            + self.c2.len()
    }
}

/// Fake-quant `W` with grid `(s1, z)` and exponent matrix `S`:
/// `ŵ = (clip(round(w / (s1·exp(S)) + z), 0, qmax) - z) · s1`.
pub fn fakequant_with_exponent(w: &Tensor, grid: &ChannelGrid,
                               s_exp: &Tensor) -> Tensor {
    let (rows, cols) = w.rc();
    assert_eq!(s_exp.rc(), (rows, cols));
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let s1 = grid.scale[r];
        let z = grid.zp[r];
        let wrow = w.row(r);
        let srow = s_exp.row(r);
        let orow = &mut out[r * cols..(r + 1) * cols];
        for ((o, &x), &e) in orow.iter_mut().zip(wrow).zip(srow) {
            let div = s1 * e.exp();
            let q = (x / div + z).round().clamp(0.0, grid.qmax);
            *o = (q - z) * s1;
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// Full LRQ fake-quant from learned params (Eq. 2 with the ds1 re-param).
pub fn fakequant_lrq(w: &Tensor, grid_init: &ChannelGrid,
                     params: &LrqParams) -> Tensor {
    let grid = ChannelGrid {
        scale: params.effective_scale(&grid_init.scale),
        zp: grid_init.zp.clone(),
        qmax: grid_init.qmax,
    };
    let s_exp = params.exponent();
    fakequant_with_exponent(w, &grid, &s_exp)
}

/// Integer codes `q = clip(round(w/(s1·exp(S)) + z), 0, qmax)`; `s_exp = None`
/// is plain RTN. Codes are carried in f32 (the packing/serving format).
pub fn quantize_int_codes(w: &Tensor, grid: &ChannelGrid,
                          s_exp: Option<&Tensor>) -> Tensor {
    let (rows, cols) = w.rc();
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let s1 = grid.scale[r];
        let z = grid.zp[r];
        let wrow = w.row(r);
        let orow = &mut out[r * cols..(r + 1) * cols];
        for (c, (o, &x)) in orow.iter_mut().zip(wrow).enumerate() {
            let div = match s_exp {
                Some(s) => s1 * s.data[r * cols + c].exp(),
                None => s1,
            };
            *o = (x / div + z).round().clamp(0.0, grid.qmax);
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// Table 29 accounting: (#learnable LRQ params, #weights) for one linear.
pub fn lrq_param_counts(cout: usize, cin: usize, rank: usize) -> (usize, usize) {
    // ds1 excluded as in the paper (s1 exists for FlexRound too); the table
    // counts L2, U2, r2, c2 against Cout×Cin.
    let learn = cout * rank + rank * cin + cout + cin;
    (learn, cout * cin)
}

/// The Table 29 ratio for a full block: 4 attention (d×d) + gate/up (f×d) +
/// down (d×f) projections.
pub fn block_param_ratio(d: usize, f: usize, rank: usize) -> f64 {
    let mut learn = 0usize;
    let mut weights = 0usize;
    for (co, ci) in [(d, d), (d, d), (d, d), (d, d), (f, d), (f, d), (d, f)] {
        let (l, w) = lrq_param_counts(co, ci, rank);
        learn += l;
        weights += w;
    }
    learn as f64 / weights as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grid::rtn_grid;
    use crate::rng::Rng;

    #[test]
    fn zero_params_is_rtn() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[16, 24], 0.1);
        let grid = rtn_grid(&w, 255.0);
        let mut p = LrqParams::init(&mut rng, 16, 24, 4);
        p.u2 = Tensor::zeros(&[4, 24]); // L2U2 = 0 exactly
        let out = fakequant_lrq(&w, &grid, &p);
        let mut rtn = vec![0.0f32; 24];
        for r in 0..16 {
            grid.fq_row(r, w.row(r), &mut rtn);
            for (a, b) in out.row(r).iter().zip(&rtn) {
                assert!((a - b).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn exponent_broadcasting_appendix_m() {
        let p = LrqParams {
            ds1: vec![0.0; 2],
            l2: Tensor::new(vec![2, 1], vec![1.0, 2.0]),
            u2: Tensor::new(vec![1, 3], vec![1.0, 0.0, -1.0]),
            r2: vec![10.0, 20.0],
            c2: vec![0.1, 0.2, 0.3],
        };
        let s = p.exponent();
        assert_eq!(
            s.data,
            vec![
                1.0 + 10.0 + 0.1, 0.0 + 10.0 + 0.2, -1.0 + 10.0 + 0.3,
                2.0 + 20.0 + 0.1, 0.0 + 20.0 + 0.2, -2.0 + 20.0 + 0.3,
            ]
        );
    }

    #[test]
    fn positive_exponent_shrinks_codes() {
        // larger divisor => codes pulled toward the zero-point
        let w = Tensor::new(vec![1, 2], vec![1.0, -1.0]);
        let grid = rtn_grid(&w, 15.0);
        let s_hi = Tensor::new(vec![1, 2], vec![2.0, 2.0]);
        let codes_rtn = quantize_int_codes(&w, &grid, None);
        let codes_hi = quantize_int_codes(&w, &grid, Some(&s_hi));
        let z = grid.zp[0];
        for c in 0..2 {
            assert!((codes_hi.data[c] - z).abs() <= (codes_rtn.data[c] - z).abs());
        }
    }

    #[test]
    fn table29_ratios() {
        // Llama-7B: d=4096, f=11008, r=1024 -> 39.51 % (Table 29)
        let r = block_param_ratio(4096, 11008, 1024);
        assert!((r - 0.3951).abs() < 0.001, "7B ratio {r}");
        // Llama-13B: d=5120, f=13824, r=1024 -> 31.57 %
        let r = block_param_ratio(5120, 13824, 1024);
        assert!((r - 0.3157).abs() < 0.001, "13B ratio {r}");
        // Llama-33B: d=6656, f=17920, r=2048 -> 48.60 %
        let r = block_param_ratio(6656, 17920, 2048);
        assert!((r - 0.4860).abs() < 0.001, "33B ratio {r}");
        // Llama-65B: d=8192, f=22016, r=2048 -> 39.51 %
        let r = block_param_ratio(8192, 22016, 2048);
        assert!((r - 0.3951).abs() < 0.001, "65B ratio {r}");
    }

    #[test]
    fn effective_scale_multiplicative() {
        let p = LrqParams {
            ds1: vec![0.0, (2.0f32).ln()],
            l2: Tensor::zeros(&[2, 1]),
            u2: Tensor::zeros(&[1, 2]),
            r2: vec![0.0; 2],
            c2: vec![0.0; 2],
        };
        let s = p.effective_scale(&[0.5, 0.5]);
        assert!((s[0] - 0.5).abs() < 1e-7);
        assert!((s[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn finalize_codes_match_fakequant() {
        // dequant(quantize_int_codes with exponent) must equal fakequant
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&mut rng, &[8, 12], 0.2);
        let grid0 = rtn_grid(&w, 15.0);
        let mut p = LrqParams::init(&mut rng, 8, 12, 2);
        p.l2 = Tensor::randn(&mut rng, &[8, 2], 0.05);
        p.r2 = rng.normal_vec(8, 0.05);
        p.c2 = rng.normal_vec(12, 0.05);
        for d in p.ds1.iter_mut() {
            *d = rng.normal() * 0.05;
        }
        let grid = ChannelGrid {
            scale: p.effective_scale(&grid0.scale),
            zp: grid0.zp.clone(),
            qmax: grid0.qmax,
        };
        let s_exp = p.exponent();
        let codes = quantize_int_codes(&w, &grid, Some(&s_exp));
        let what = fakequant_lrq(&w, &grid0, &p);
        for r in 0..8 {
            for c in 0..12 {
                let deq = (codes.data[r * 12 + c] - grid.zp[r]) * grid.scale[r];
                assert!((deq - what.data[r * 12 + c]).abs() < 1e-6);
            }
        }
    }
}
