//! Quantization substrate: grids, rounding, packing, and the Rust-side LRQ
//! fake-quant (used to finalize learned parameters into integer weights and
//! as the cross-layer oracle against the Pallas kernel artifact).

pub mod act;
pub mod grid;
pub mod lrq;
pub mod pack;

pub use act::{per_tensor_quant, per_token_quant, ActRange};
pub use grid::{grid_search_scales, rtn_grid, ChannelGrid};
pub use lrq::{fakequant_lrq, fakequant_with_exponent, lrq_param_counts,
              quantize_int_codes, LrqParams};
pub use pack::PackedMatrix;

/// qmax for a bit-width (unsigned asymmetric grid [0, 2^bits - 1]).
pub fn qmax(bits: u32) -> f32 {
    ((1u64 << bits) - 1) as f32
}

#[cfg(test)]
mod tests {
    #[test]
    fn qmax_values() {
        assert_eq!(super::qmax(8), 255.0);
        assert_eq!(super::qmax(4), 15.0);
        assert_eq!(super::qmax(3), 7.0);
    }
}
