//! Dense f32 tensor substrate for the L3 coordinator.
//!
//! The heavy math runs in the AOT-compiled XLA artifacts; this module covers
//! everything the coordinator does natively: weight finalization (LRQ
//! fake-quant of learned parameters), GPTQ's Hessian algebra, AWQ's grid
//! search, statistics, and the packed-weight serving path. `matmul_bt` is the
//! hot kernel (blocked, both operands traversed row-major) — benched in
//! `rust/benches/kernels.rs`.

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(),
                   "dims {:?} vs len {}", dims, data.len());
        Tensor { dims, data }
    }

    pub fn zeros(dims: &[usize]) -> Self {
        Tensor { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    pub fn ones(dims: &[usize]) -> Self {
        Tensor { dims: dims.to_vec(), data: vec![1.0; dims.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { dims: vec![], data: vec![v] }
    }

    pub fn randn(rng: &mut crate::rng::Rng, dims: &[usize], std: f32) -> Self {
        Tensor {
            dims: dims.to_vec(),
            data: rng.normal_vec(dims.iter().product(), std),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn rc(&self) -> (usize, usize) {
        assert_eq!(self.dims.len(), 2, "rc() on rank-{} tensor", self.dims.len());
        (self.dims[0], self.dims[1])
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.rc();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (_, c) = self.rc();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, dims: &[usize]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims.to_vec();
        self
    }

    /// View the trailing dim as columns: (prod(leading), last).
    pub fn as_2d(&self) -> (usize, usize) {
        let last = *self.dims.last().expect("as_2d on scalar");
        (self.data.len() / last, last)
    }

    // ---- elementwise ----

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            dims: self.dims.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.dims, other.dims);
        Tensor {
            dims: self.dims.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }

    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    // ---- reductions ----

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.data.len() as f64
    }

    pub fn min(&self) -> f32 {
        self.data.iter().cloned().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob(&self) -> f64 {
        self.sq_norm().sqrt()
    }

    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.dims, other.dims);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n as f64
    }

    pub fn rmse(&self, other: &Tensor) -> f64 {
        self.mse(other).sqrt()
    }

    /// Per-column absolute max of a (rows, cols) view over the trailing dim.
    pub fn col_amax(&self) -> Vec<f32> {
        let (r, c) = self.as_2d();
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            let row = &self.data[i * c..(i + 1) * c];
            for (o, &x) in out.iter_mut().zip(row) {
                *o = o.max(x.abs());
            }
        }
        out
    }

    // ---- matmul ----

    /// `self[m,k] @ b[k,n] -> [m,n]` (blocked over k for cache reuse).
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.rc();
        let (k2, n) = b.rc();
        assert_eq!(k, k2, "matmul dim mismatch {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        const BK: usize = 64;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += a * bv;
                    }
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// `self[m,k] @ b[n,k].T -> [m,n]` — both row-major-friendly. This is the
    /// layout every model weight uses (`y = x @ W.T`).
    pub fn matmul_bt(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.rc();
        let (n, k2) = b.rc();
        assert_eq!(k, k2, "matmul_bt dim mismatch {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc0 = 0.0f32;
                let mut acc1 = 0.0f32;
                let mut acc2 = 0.0f32;
                let mut acc3 = 0.0f32;
                let chunks = k / 4;
                for c in 0..chunks {
                    let p = c * 4;
                    acc0 += arow[p] * brow[p];
                    acc1 += arow[p + 1] * brow[p + 1];
                    acc2 += arow[p + 2] * brow[p + 2];
                    acc3 += arow[p + 3] * brow[p + 3];
                }
                let mut acc = acc0 + acc1 + acc2 + acc3;
                for p in chunks * 4..k {
                    acc += arow[p] * brow[p];
                }
                orow[j] = acc;
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// `self[m,k].T @ b[m,n] -> [k,n]` (Gram-style accumulation for GPTQ).
    pub fn matmul_at(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.rc();
        let (m2, n) = b.rc();
        assert_eq!(m, m2);
        let mut out = vec![0.0f32; k * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let brow = &b.data[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += a * bv;
                }
            }
        }
        Tensor::new(vec![k, n], out)
    }

    pub fn transpose(&self) -> Tensor {
        let (r, c) = self.rc();
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], out)
    }

    /// Slice along the outermost dim: rows `lo..hi` of dims[0].
    pub fn slice_outer(&self, lo: usize, hi: usize) -> Tensor {
        assert!(!self.dims.is_empty() && lo <= hi && hi <= self.dims[0]);
        let inner: usize = self.dims[1..].iter().product();
        let mut dims = self.dims.clone();
        dims[0] = hi - lo;
        Tensor::new(dims, self.data[lo * inner..hi * inner].to_vec())
    }

    /// Stack 2-D tensors along rows.
    pub fn vstack(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("vstack of nothing");
        }
        let c = parts[0].rc().1;
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            let (r, c2) = p.rc();
            if c2 != c {
                bail!("vstack col mismatch");
            }
            rows += r;
            data.extend_from_slice(&p.data);
        }
        Ok(Tensor::new(vec![rows, c], data))
    }
}

/// Cholesky decomposition of a symmetric positive-definite matrix (in f64 for
/// stability) — GPTQ's core solve. Returns lower-triangular L with A = L·Lᵀ.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: not positive definite at {i} (sum {sum})");
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Invert a lower-triangular matrix (forward substitution per column).
pub fn tri_inverse_lower(l: &[f64], n: usize) -> Vec<f64> {
    let mut inv = vec![0.0f64; n * n];
    for j in 0..n {
        inv[j * n + j] = 1.0 / l[j * n + j];
        for i in j + 1..n {
            let mut sum = 0.0;
            for k in j..i {
                sum -= l[i * n + k] * inv[k * n + j];
            }
            inv[i * n + j] = sum / l[i * n + i];
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.rc();
        let (_, n) = b.rc();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.data[i * k + kk] * b.data[kk * n + j];
                }
                out[i * n + j] = acc;
            }
        }
        Tensor::new(vec![m, n], out)
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 5, 7), (16, 64, 32), (1, 1, 1), (17, 33, 9)] {
            let a = Tensor::randn(&mut rng, &[m, k], 1.0);
            let b = Tensor::randn(&mut rng, &[k, n], 1.0);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.rmse(&want) < 1e-5);
        }
    }

    #[test]
    fn matmul_bt_matches_transpose() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(4, 6, 8), (13, 31, 7), (32, 128, 96)] {
            let a = Tensor::randn(&mut rng, &[m, k], 1.0);
            let b = Tensor::randn(&mut rng, &[n, k], 1.0);
            let got = a.matmul_bt(&b);
            let want = a.matmul(&b.transpose());
            assert!(got.rmse(&want) < 1e-4);
        }
    }

    #[test]
    fn matmul_at_matches() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&mut rng, &[10, 6], 1.0);
        let b = Tensor::randn(&mut rng, &[10, 4], 1.0);
        let got = a.matmul_at(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.rmse(&want) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&mut rng, &[5, 9], 1.0);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(5);
        let n = 12;
        let x = Tensor::randn(&mut rng, &[24, n], 1.0);
        // A = XᵀX + I (SPD)
        let g = x.matmul_at(&x);
        let mut a: Vec<f64> = g.data.iter().map(|&v| v as f64).collect();
        for i in 0..n {
            a[i * n + i] += 1.0;
        }
        let l = cholesky(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += l[i * n + k] * l[j * n + k];
                }
                assert!((acc - a[i * n + j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn tri_inverse() {
        let l = vec![2.0, 0.0, 0.0, 1.0, 3.0, 0.0, 0.5, -1.0, 4.0];
        let inv = tri_inverse_lower(&l, 3);
        // L * inv == I
        for i in 0..3 {
            for j in 0..3 {
                let mut acc = 0.0;
                for k in 0..3 {
                    acc += l[i * 3 + k] * inv[k * 3 + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((acc - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn stats() {
        let t = Tensor::new(vec![2, 2], vec![1.0, -2.0, 3.0, 0.0]);
        assert_eq!(t.min(), -2.0);
        assert_eq!(t.max(), 3.0);
        assert!((t.mean() - 0.5).abs() < 1e-9);
        let amax = t.col_amax();
        assert_eq!(amax, vec![3.0, 2.0]);
    }

    #[test]
    fn vstack_shapes() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::ones(&[1, 3]);
        let s = Tensor::vstack(&[&a, &b]).unwrap();
        assert_eq!(s.dims, vec![3, 3]);
        assert_eq!(s.data[6..9], [1.0, 1.0, 1.0]);
    }
}
