//! Multiple-choice task generators — the synthetic stand-ins for the paper's
//! benchmarks, scored exactly like lm-eval-harness: the model picks the
//! continuation with the highest summed log-probability given the prefix.
//!
//! * [`TaskKind::Csr`]  — prefix + true continuation from an **in-calibration**
//!   domain; distractors are continuations under *other* domains' laws.
//!   (BoolQ/PIQA/HellaSwag/... analogue: near-calibration distribution.)
//! * [`TaskKind::Mmlu`] — same construction over **held-out** domains (seen in
//!   pre-training, absent from calibration): the generalization axis where
//!   per-weight scale overfitting shows up (paper Fig. 1b).

use crate::rng::Rng;

use super::corpus::Corpus;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Csr,
    Mmlu,
}

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McTask {
    pub prefix: Vec<i32>,
    /// choices\[answer\] is the true continuation
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
    pub domain: usize,
}

#[derive(Clone, Debug)]
pub struct TaskSet {
    pub kind: TaskKind,
    pub tasks: Vec<McTask>,
}

impl TaskSet {
    /// Build `n` items of `kind` with `n_choices` options each.
    ///
    /// Distractor difficulty is graded so the benchmark has real margin
    /// structure (like the paper's benchmarks, where FP16 sits at 60–70 %,
    /// not 100 %): one *cross-domain* continuation (easy to reject) and a
    /// ladder of *corrupted* continuations — the true continuation with 1–2
    /// tokens substituted — whose log-prob margin is a handful of nats and
    /// therefore sensitive to quantization noise.
    pub fn generate(corpus: &Corpus, kind: TaskKind, n: usize,
                    prefix_len: usize, cont_len: usize, n_choices: usize,
                    rng: &mut Rng) -> TaskSet {
        let domains = match kind {
            TaskKind::Csr => corpus.calib_domain_ids(),
            TaskKind::Mmlu => corpus.heldout_domain_ids(),
        };
        let all: Vec<usize> = (0..corpus.n_domains()).collect();
        let vocab = corpus.cfg.vocab;
        let mut tasks = Vec::with_capacity(n);
        for _ in 0..n {
            let dom = domains[rng.below(domains.len())];
            let prefix = corpus.sequence(dom, prefix_len, rng);
            let last = *prefix.last().unwrap() as usize;
            let truth = corpus.continuation(dom, last, cont_len, rng);

            // jitter variants of the FINAL token: same skeleton transition,
            // different jitter offset — the graded-margin distractors.
            let prev_of_last = if cont_len >= 2 {
                truth[cont_len - 2] as usize
            } else {
                last
            };
            let base = corpus.skeleton(dom, prev_of_last);
            let t_true = *truth.last().unwrap() as usize;
            let j_true = (t_true + vocab - base) % vocab;
            let mut variants: Vec<Vec<i32>> = Vec::new();
            for j in 0..3usize {
                if j == j_true || variants.len() >= 2 {
                    continue;
                }
                let mut c = truth.clone();
                *c.last_mut().unwrap() = ((base + j) % vocab) as i32;
                variants.push(c);
            }

            let mut choices = vec![truth.clone()];
            choices.extend(variants);
            while choices.len() < n_choices {
                let other = all[rng.below(all.len())];
                if other == dom {
                    continue;
                }
                choices.push(corpus.continuation(other, last, cont_len, rng));
            }
            choices.truncate(n_choices);
            // shuffle so the answer position is uniform
            let mut order: Vec<usize> = (0..n_choices).collect();
            rng.shuffle(&mut order);
            let answer = order.iter().position(|&i| i == 0).unwrap();
            let choices: Vec<Vec<i32>> =
                order.iter().map(|&i| choices[i].clone()).collect();
            tasks.push(McTask { prefix, choices, answer, domain: dom });
        }
        TaskSet { kind, tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn setup() -> (Corpus, Rng) {
        (Corpus::new(CorpusConfig::with_seed(512, 11)), Rng::new(22))
    }

    #[test]
    fn generates_requested_shape() {
        let (c, mut rng) = setup();
        let ts = TaskSet::generate(&c, TaskKind::Csr, 20, 32, 8, 4, &mut rng);
        assert_eq!(ts.len(), 20);
        for t in &ts.tasks {
            assert_eq!(t.prefix.len(), 32);
            assert_eq!(t.choices.len(), 4);
            assert!(t.answer < 4);
            for ch in &t.choices {
                assert_eq!(ch.len(), 8);
            }
        }
    }

    #[test]
    fn kind_selects_domain_partition() {
        let (c, mut rng) = setup();
        let csr = TaskSet::generate(&c, TaskKind::Csr, 30, 16, 4, 4, &mut rng);
        let mmlu = TaskSet::generate(&c, TaskKind::Mmlu, 30, 16, 4, 4, &mut rng);
        let calib = c.calib_domain_ids();
        assert!(csr.tasks.iter().all(|t| calib.contains(&t.domain)));
        assert!(mmlu.tasks.iter().all(|t| !calib.contains(&t.domain)));
    }

    #[test]
    fn answers_roughly_uniform() {
        let (c, mut rng) = setup();
        let ts = TaskSet::generate(&c, TaskKind::Csr, 400, 8, 4, 4, &mut rng);
        let mut counts = [0usize; 4];
        for t in &ts.tasks {
            counts[t.answer] += 1;
        }
        for &cnt in &counts {
            assert!(cnt > 50, "positions skewed: {counts:?}");
        }
    }

    #[test]
    fn bayes_oracle_beats_chance_but_not_ceiling() {
        // Score each choice with the TRUE generative log-prob (skeleton +
        // jitter weights). The Bayes-optimal scorer should sit well above
        // chance (25 %) but below 100 % — truth is *sampled*, so sometimes a
        // higher-probability jitter variant exists by construction. This is
        // the margin structure that makes the benchmark quantization-
        // sensitive.
        let (c, mut rng) = setup();
        let ts = TaskSet::generate(&c, TaskKind::Csr, 200, 16, 8, 4, &mut rng);
        let v = 512usize;
        let mut correct = 0;
        for t in &ts.tasks {
            let score = |ch: &Vec<i32>| -> f64 {
                let mut prev = *t.prefix.last().unwrap() as usize;
                let mut s = 0.0f64;
                for &nx in ch {
                    let base = c.skeleton(t.domain, prev);
                    let nxu = nx as usize;
                    let j = (nxu + v - base) % v;
                    let p = if j < 3 {
                        0.9 * Corpus::JITTER_W[j] as f64 + 0.1 / v as f64
                    } else {
                        0.1 / 16.0 // rough zipf mass
                    };
                    s += p.ln();
                    prev = nxu;
                }
                s
            };
            let scores: Vec<f64> = t.choices.iter().map(score).collect();
            let best = scores
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == t.answer {
                correct += 1;
            }
        }
        let acc = correct as f64 / 200.0;
        assert!(acc > 0.40, "bayes oracle too weak: {acc}");
        assert!(acc < 0.95, "tasks degenerate (no margin structure): {acc}");
    }
}
