//! Synthetic data substrate — the stand-in for C4 (calibration), common-sense
//! reasoning benchmarks, MMLU, and WikiText-2 (DESIGN.md §2 substitutions).
//!
//! A corpus is a mixture of *domains*, each an affine-map language over the
//! shared vocabulary. Calibration draws from a fixed subset of domains; the
//! "CSR-like" benchmark uses in-calibration domains, the "MMLU-like" benchmark
//! uses domains that were seen at pre-training time but are absent from
//! calibration — reproducing the distribution-shift axis on which FlexRound
//! overfits and LRQ generalizes (paper Figs. 1, 3).

pub mod corpus;
pub mod tasks;

pub use corpus::{Corpus, CorpusConfig};
pub use tasks::{McTask, TaskKind, TaskSet};
