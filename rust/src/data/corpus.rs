//! Multi-domain synthetic corpus.
//!
//! Each domain `d` defines a stochastic affine bigram law over the vocab:
//!
//! ```text
//! next = (a_d · prev + b_d + jitter) mod V      with prob p_struct
//! next ~ Zipf(perm_d)                           otherwise
//! ```
//!
//! `a_d` is odd (a bijection mod V), so each domain is a distinct, learnable
//! deterministic skeleton plus noise. Within a sequence the domain is fixed;
//! a Transformer infers it in-context from the observed bigrams — the synthetic
//! analogue of topical/domain structure in C4 vs. benchmark corpora.

use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    pub n_domains: usize,
    /// first `calib_domains` domains form the calibration distribution
    pub calib_domains: usize,
    /// probability of following the affine skeleton
    pub p_struct: f32,
    pub seed: u64,
}

/// The corpus seed defines the *language itself* (domain laws). Everything —
/// pre-training, calibration, evaluation — must share one world; per-run
/// randomness (init, batch sampling, task draws) comes from separate seeds.
pub const WORLD_SEED: u64 = 0x11A;

impl CorpusConfig {
    /// The standard world for a vocab size.
    pub fn for_vocab(vocab: usize) -> Self {
        Self::with_seed(vocab, WORLD_SEED)
    }

    /// A custom world (tests / ablations only).
    pub fn with_seed(vocab: usize, seed: u64) -> Self {
        CorpusConfig {
            vocab,
            n_domains: 8,
            calib_domains: 4,
            p_struct: 0.9,
            seed,
        }
    }
}

#[derive(Clone, Debug)]
struct Domain {
    a: usize,
    b: usize,
    /// domain-specific token permutation for the noise distribution
    perm: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Corpus {
    pub cfg: CorpusConfig,
    domains: Vec<Domain>,
    /// Zipf weights shared by all domains (over permuted ranks)
    zipf: Vec<f32>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let v = cfg.vocab;
        let mut domains = Vec::with_capacity(cfg.n_domains);
        for _ in 0..cfg.n_domains {
            // odd multiplier co-prime with the power-of-two-ish vocab
            let a = rng.range(1, v / 2) * 2 + 1;
            let b = rng.below(v);
            let mut perm: Vec<usize> = (0..v).collect();
            rng.shuffle(&mut perm);
            domains.push(Domain { a, b, perm });
        }
        let zipf: Vec<f32> = (0..v).map(|i| 1.0 / (i as f32 + 2.0)).collect();
        Corpus { cfg, domains, zipf }
    }

    pub fn n_domains(&self) -> usize {
        self.cfg.n_domains
    }

    /// Domains present in the calibration set ("C4").
    pub fn calib_domain_ids(&self) -> Vec<usize> {
        (0..self.cfg.calib_domains).collect()
    }

    /// Domains held out of calibration (the "MMLU" axis).
    pub fn heldout_domain_ids(&self) -> Vec<usize> {
        (self.cfg.calib_domains..self.cfg.n_domains).collect()
    }

    /// Graded jitter distribution inside the structured branch. The *ratios*
    /// between these are the log-prob margins of the benchmark items
    /// (ln(.6/.3) ≈ 0.7 nats, ln(.6/.1) ≈ 1.8 nats) — small enough that
    /// quantization noise measurably flips decisions, as on the paper's
    /// benchmarks.
    pub const JITTER_W: [f32; 3] = [0.6, 0.3, 0.1];

    /// The deterministic skeleton: the jitter-0 next token of (domain, prev).
    pub fn skeleton(&self, domain: usize, prev: usize) -> usize {
        let d = &self.domains[domain];
        (d.a * prev + d.b) % self.cfg.vocab
    }

    fn next_token(&self, domain: usize, prev: usize, rng: &mut Rng) -> usize {
        let d = &self.domains[domain];
        let v = self.cfg.vocab;
        if rng.coin(self.cfg.p_struct) {
            let jitter = rng.weighted(&Self::JITTER_W);
            (d.a * prev + d.b + jitter) % v
        } else {
            let rank = rng.weighted(&self.zipf);
            d.perm[rank]
        }
    }

    /// One sequence of `len` tokens from `domain`, continuing from a random
    /// start token.
    pub fn sequence(&self, domain: usize, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut prev = rng.below(self.cfg.vocab);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(prev as i32);
            prev = self.next_token(domain, prev, rng);
        }
        out
    }

    /// Continue a prefix for `len` more tokens under `domain`'s law.
    pub fn continuation(&self, domain: usize, prefix_last: usize, len: usize,
                        rng: &mut Rng) -> Vec<i32> {
        let mut prev = self.next_token(domain, prefix_last, rng);
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(prev as i32);
            prev = self.next_token(domain, prev, rng);
        }
        out
    }

    /// A batch of (ids, targets) training pairs: domains sampled uniformly
    /// over all domains (pre-training sees everything).
    pub fn train_batch(&self, batch: usize, seq: usize, rng: &mut Rng)
                       -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(batch * seq);
        let mut tgt = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let dom = rng.below(self.cfg.n_domains);
            let s = self.sequence(dom, seq + 1, rng);
            ids.extend_from_slice(&s[..seq]);
            tgt.extend_from_slice(&s[1..seq + 1]);
        }
        (ids, tgt)
    }

    /// Calibration batch: only calibration domains (the "C4 sample").
    pub fn calib_batch(&self, batch: usize, seq: usize, rng: &mut Rng)
                       -> Vec<i32> {
        let mut ids = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let dom = rng.below(self.cfg.calib_domains);
            ids.extend(self.sequence(dom, seq, rng));
        }
        ids
    }

    /// Held-out LM stream over all domains (the "WikiText-2" PPL stream).
    pub fn eval_stream(&self, batch: usize, seq: usize, rng: &mut Rng)
                       -> (Vec<i32>, Vec<i32>) {
        self.train_batch(batch, seq, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusConfig::with_seed(512, 42))
    }

    #[test]
    fn tokens_in_range() {
        let c = corpus();
        let mut rng = Rng::new(1);
        for dom in 0..c.n_domains() {
            let s = c.sequence(dom, 200, &mut rng);
            assert!(s.iter().all(|&t| (0..512).contains(&(t as usize))));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus();
        let s1 = c.sequence(0, 50, &mut Rng::new(7));
        let s2 = c.sequence(0, 50, &mut Rng::new(7));
        assert_eq!(s1, s2);
    }

    #[test]
    fn domains_have_distinct_laws() {
        let c = corpus();
        let mut rng = Rng::new(3);
        // same start token, same rng stream: different domains should diverge
        let s0 = c.sequence(0, 100, &mut Rng::new(9));
        let s1 = c.sequence(1, 100, &mut Rng::new(9));
        assert_ne!(s0, s1);
        let _ = rng.next_u64();
    }

    #[test]
    fn domain_law_is_mostly_deterministic() {
        // given (domain, prev), the modal next token should dominate
        let c = corpus();
        let mut rng = Rng::new(4);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..500 {
            let n = c.next_token(2, 100, &mut rng);
            *counts.entry(n).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        // p_struct 0.9 split over 3 jitter values -> modal ≈ 0.3
        assert!(*max > 100, "modal count {max}");
    }

    #[test]
    fn calib_heldout_partition() {
        let c = corpus();
        let calib = c.calib_domain_ids();
        let held = c.heldout_domain_ids();
        assert_eq!(calib.len() + held.len(), c.n_domains());
        assert!(calib.iter().all(|d| !held.contains(d)));
    }

    #[test]
    fn train_batch_shapes_and_shift() {
        let c = corpus();
        let mut rng = Rng::new(5);
        let (ids, tgt) = c.train_batch(4, 16, &mut rng);
        assert_eq!(ids.len(), 64);
        assert_eq!(tgt.len(), 64);
        // target is the shifted sequence within each row
        for b in 0..4 {
            for t in 0..15 {
                assert_eq!(tgt[b * 16 + t], ids[b * 16 + t + 1]);
            }
        }
    }
}
