//! Tensor ⇄ PJRT `Literal` conversion helpers.

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::tensor::Tensor;

use super::manifest::{DType, IoSpec};

/// f32 tensor -> literal (rank-0 becomes a true scalar literal).
pub fn to_lit(t: &Tensor) -> Result<Literal> {
    if t.dims.is_empty() {
        return Ok(Literal::scalar(t.data[0]));
    }
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(&t.data).reshape(&dims)?)
}

/// i32 ids -> literal with the given dims.
pub fn ids_lit(ids: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if ids.len() != n {
        bail!("ids len {} != dims {:?}", ids.len(), dims);
    }
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(ids).reshape(&d)?)
}

pub fn scalar_lit(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn flag_lit(on: bool) -> Literal {
    Literal::scalar(if on { 1.0f32 } else { 0.0 })
}

/// literal -> f32 tensor (using the manifest dims, which are authoritative).
pub fn from_lit(lit: &Literal, dims: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>().context("literal to_vec<f32>")?;
    let n: usize = dims.iter().product();
    if data.len() != n {
        bail!("literal len {} != manifest dims {:?}", data.len(), dims);
    }
    Ok(Tensor::new(dims.to_vec(), data))
}

pub fn scalar_from_lit(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Validate a literal batch against the manifest input specs (count + size).
pub fn validate_inputs(specs: &[IoSpec], lits: &[&Literal]) -> Result<()> {
    if specs.len() != lits.len() {
        bail!("input count {} != manifest {}", lits.len(), specs.len());
    }
    for (i, (s, l)) in specs.iter().zip(lits).enumerate() {
        let n = l.element_count();
        if n != s.elems() {
            bail!("input {i} ({}): {} elements, manifest wants {:?}",
                  s.name, n, s.dims);
        }
        let want_f32 = matches!(s.dtype, DType::F32);
        let ty = l.ty()?;
        let is_f32 = matches!(ty, xla::ElementType::F32);
        if want_f32 != is_f32 {
            bail!("input {i} ({}): dtype mismatch", s.name);
        }
    }
    Ok(())
}
