//! L3 runtime: loads the AOT HLO-text artifacts through the PJRT C API
//! (`xla` crate), compiles them once, and exposes validated executables.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO **text** is the interchange format — xla_extension 0.5.1 rejects the
//! 64-bit instruction ids of jax≥0.5 serialized protos.

pub mod literal;
pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

pub use literal::{flag_lit, from_lit, ids_lit, scalar_from_lit, scalar_lit,
                  to_lit};
pub use manifest::{ArtifactSpec, DType, IoSpec, Manifest};

use crate::model::ModelDim;
use crate::tensor::Tensor;

/// A compiled artifact with its manifest spec; all calls validate I/O.
pub struct Exec {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl Exec {
    /// Run with raw literals (owned or borrowed — state-threading loops keep
    /// their literals and pass `&Literal`); returns the decomposed output
    /// tuple.
    pub fn run<L: std::borrow::Borrow<Literal>>(
        &self, inputs: &[L]) -> Result<Vec<Literal>> {
        {
            let borrowed: Vec<&Literal> =
                inputs.iter().map(|l| l.borrow()).collect();
            literal::validate_inputs(&self.spec.inputs, &borrowed)
                .with_context(|| format!("artifact {}", self.spec.name))?;
        }
        let bufs = self.exe.execute::<L>(inputs)
            .with_context(|| format!("execute {}", self.spec.name))?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple_elements(tuple, self.spec.outputs.len())?;
        Ok(outs)
    }

    /// Run and convert every output to a [`Tensor`] using manifest dims.
    pub fn run_tensors<L: std::borrow::Borrow<Literal>>(
        &self, inputs: &[L]) -> Result<Vec<Tensor>> {
        let outs = self.run(inputs)?;
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| literal::from_lit(l, &s.dims))
            .collect()
    }
}

fn tuple_elements(mut tuple: Literal, expect: usize) -> Result<Vec<Literal>> {
    let outs = tuple.decompose_tuple()?;
    if outs.len() != expect {
        anyhow::bail!("artifact returned {} outputs, manifest wants {expect}",
                      outs.len());
    }
    Ok(outs)
}

/// The artifact registry: PJRT client + lazily compiled executables.
pub struct Runtime {
    pub client: PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
    pub verbose: bool,
}

impl Runtime {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            verbose: std::env::var("LRQ_VERBOSE").is_ok(),
        })
    }

    /// Default artifact dir: `$LRQ_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Runtime> {
        let dir = std::env::var("LRQ_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Runtime::load(Path::new(&dir))
    }

    pub fn dim(&self, cfg: &str) -> Result<ModelDim> {
        Ok(self.manifest.dim(cfg)?.clone())
    }

    pub fn ranks(&self, cfg: &str) -> Vec<usize> {
        self.manifest.ranks.get(cfg).cloned().unwrap_or_default()
    }

    /// Get (compiling on first use) an executable by artifact name.
    pub fn exec(&self, name: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parse HLO {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        if self.verbose {
            eprintln!("[runtime] compiled {name} in {:?}", t0.elapsed());
        }
        let exec = Rc::new(Exec { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Number of artifacts compiled so far (metrics / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}
