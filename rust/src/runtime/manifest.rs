//! Parser for `artifacts/manifest.txt` — the contract emitted by
//! `python/compile/aot.py` describing every AOT artifact's I/O (name, dtype,
//! dims, order) and the model configs they were lowered for.

use std::collections::HashMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::ModelDim;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Index of the input with this exact name.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {}: no input {name}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {}: no output {name}", self.name))
    }
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub configs: HashMap<String, ModelDim>,
    pub ranks: HashMap<String, Vec<usize>>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

fn parse_dtype(s: &str) -> Result<DType> {
    match s {
        "f32" => Ok(DType::F32),
        "i32" => Ok(DType::I32),
        other => bail!("unknown dtype {other}"),
    }
}

fn parse_io(line: &str) -> Result<IoSpec> {
    let mut it = line.split_whitespace();
    let _tag = it.next();
    let name = it.next().context("io line missing name")?.to_string();
    let dtype = parse_dtype(it.next().context("io line missing dtype")?)?;
    let dims: Result<Vec<usize>, _> = it.map(|d| d.parse()).collect();
    Ok(IoSpec { name, dtype, dims: dims.context("bad dims")? })
}

fn parse_config(line: &str) -> Result<ModelDim> {
    let toks: Vec<&str> = line.split_whitespace().collect();
    // config <name> k v k v ...
    if toks.len() < 2 || (toks.len() - 2) % 2 != 0 {
        bail!("bad config line: {line}");
    }
    let name = toks[1].to_string();
    let mut kv = HashMap::new();
    for pair in toks[2..].chunks(2) {
        kv.insert(pair[0], pair[1].parse::<usize>()
                  .with_context(|| format!("bad config value {}", pair[1]))?);
    }
    let get = |k: &str| -> Result<usize> {
        kv.get(k).copied().with_context(|| format!("config missing {k}"))
    };
    Ok(ModelDim {
        name,
        vocab: get("vocab")?,
        d: get("d")?,
        heads: get("heads")?,
        layers: get("layers")?,
        ff: get("ff")?,
        seq: get("seq")?,
        train_batch: get("train_batch")?,
        calib_batch: get("calib_batch")?,
        recon_batch: get("recon_batch")?,
        rank: get("rank")?,
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let tag = line.split_whitespace().next().unwrap();
            match tag {
                "version" => {}
                "config" => {
                    let dim = parse_config(line)
                        .with_context(|| format!("line {}", ln + 1))?;
                    m.configs.insert(dim.name.clone(), dim);
                }
                "ranks" => {
                    let toks: Vec<&str> = line.split_whitespace().collect();
                    let name = toks.get(1).context("ranks missing cfg")?;
                    let ranks: Result<Vec<usize>, _> =
                        toks[2..].iter().map(|s| s.parse()).collect();
                    m.ranks.insert(name.to_string(), ranks?);
                }
                "artifact" => {
                    if cur.is_some() {
                        bail!("line {}: artifact without end", ln + 1);
                    }
                    let toks: Vec<&str> = line.split_whitespace().collect();
                    cur = Some(ArtifactSpec {
                        name: toks.get(1).context("artifact missing name")?
                            .to_string(),
                        file: toks.get(2).context("artifact missing file")?
                            .to_string(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                "in" => cur
                    .as_mut()
                    .context("in outside artifact")?
                    .inputs
                    .push(parse_io(line)?),
                "out" => cur
                    .as_mut()
                    .context("out outside artifact")?
                    .outputs
                    .push(parse_io(line)?),
                "end" => {
                    let a = cur.take().context("end without artifact")?;
                    m.artifacts.insert(a.name.clone(), a);
                }
                other => bail!("line {}: unknown tag {other}", ln + 1),
            }
        }
        if cur.is_some() {
            bail!("unterminated artifact block");
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts`)"))?;
        Manifest::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn dim(&self, cfg: &str) -> Result<&ModelDim> {
        self.configs
            .get(cfg)
            .with_context(|| format!("config {cfg} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1
config tiny vocab 512 d 128 heads 4 layers 4 ff 352 seq 64 train_batch 16 calib_batch 8 recon_batch 4 rank 32
ranks tiny 4 8 16
artifact embed_tiny embed_tiny.hlo.txt
in emb f32 512 128
in ids i32 8 64
out x f32 8 64 128
end
artifact head_loss_tiny head_loss_tiny.hlo.txt
in x f32 8 64 128
in final_norm f32 128
in head f32 512 128
in targets i32 8 64
out loss f32
out logp f32 8 64
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.configs.len(), 1);
        let dim = m.dim("tiny").unwrap();
        assert_eq!(dim.d, 128);
        assert_eq!(m.ranks["tiny"], vec![4, 8, 16]);
        let a = m.artifact("embed_tiny").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].dims, vec![8, 64, 128]);
        let h = m.artifact("head_loss_tiny").unwrap();
        assert_eq!(h.outputs[0].dims, Vec::<usize>::new()); // scalar
        assert_eq!(h.input_index("head").unwrap(), 2);
        assert!(h.input_index("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("in x f32 3").is_err());
        assert!(Manifest::parse("artifact a f\nartifact b g\nend").is_err());
        assert!(Manifest::parse("bogus line").is_err());
        assert!(Manifest::parse("artifact a f\nin x f32 2").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.contains_key("block_fwd_tiny"));
            assert!(m.configs.contains_key("tiny"));
            let r = m.artifact("recon_lrq_tiny_r32").unwrap();
            // x_q, y_t, 7 W, 2 norms, 7 s1, 7 z, 3×35 theta/m/v, t, lr,
            // 8 static, 6 flags/qmax = 146
            assert_eq!(r.inputs.len(), 146);
        }
    }
}
