//! Micro-benchmark harness (criterion is unavailable in the offline build
//! image): warmup + timed iterations with mean / p50 / p95 / min reporting,
//! used by every `cargo bench` target (`harness = false`).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// optional throughput unit count per iteration (elements, tokens, ...)
    pub units_per_iter: Option<f64>,
}

impl BenchStats {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            fmt_dur(self.min),
        );
        if let Some(u) = self.units_per_iter {
            let per_sec = u / self.mean.as_secs_f64();
            s.push_str(&format!("  {:>12}/s", fmt_rate(per_sec)));
        }
        s
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

fn fmt_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}k", r / 1e3)
    } else {
        format!("{r:.1}")
    }
}

/// Bench runner: target wall budget per case, auto-scaled iteration count.
pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
    pub results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            max_iters: 2_000,
            results: Vec::new(),
        }
    }

    /// Time `f`, which must do one unit of work per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchStats {
        self.run_units(name, None, &mut f)
    }

    /// Time `f` with a units-per-iteration annotation for throughput.
    pub fn run_units<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        f: &mut F,
    ) -> &BenchStats {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup && warm_iters < self.max_iters {
            f();
            warm_iters += 1;
        }
        // measure
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let iters = samples.len().max(1);
        let total: Duration = samples.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean: total / iters as u32,
            p50: samples.get(iters / 2).copied().unwrap_or_default(),
            p95: samples
                .get((iters as f64 * 0.95) as usize)
                .copied()
                .unwrap_or_else(|| *samples.last().unwrap()),
            min: samples.first().copied().unwrap_or_default(),
            units_per_iter,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }
}

/// Every numeric value of `"key"` in `text`, in order of appearance — a
/// hand-rolled scan (no serde in the offline image) good enough for the flat
/// `BENCH_*.json` files this repo emits. Non-numeric values and keys that
/// merely share a prefix (`"key_x"`) are ignored.
pub fn json_key_numbers(text: &str, key: &str) -> Vec<f64> {
    let pat = format!("\"{key}\"");
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(i) = rest.find(&pat) {
        rest = &rest[i + pat.len()..];
        let after = rest.trim_start();
        let Some(tail) = after.strip_prefix(':') else { continue };
        let tail = tail.trim_start();
        let end = tail
            .find(|c: char| {
                !(c.is_ascii_digit()
                  || matches!(c, '.' | '-' | '+' | 'e' | 'E'))
            })
            .unwrap_or(tail.len());
        if let Ok(v) = tail[..end].parse::<f64>() {
            out.push(v);
        }
    }
    out
}

/// Compare every `key` value between a baseline JSON and the current run;
/// returns one message per regression where `current < baseline * (1 -
/// tolerance)`. Baseline entries `<= 0` are **provisional** (committed
/// before a measurement existed) and are skipped, so a zero-valued seed
/// baseline never fails the gate — it only starts enforcing once a real
/// measurement is committed. An entry-count mismatch is itself reported
/// (the bench matrix changed without updating the baseline).
pub fn regressions(baseline: &str, current: &str, key: &str,
                   tolerance: f64) -> Vec<String> {
    let b = json_key_numbers(baseline, key);
    let c = json_key_numbers(current, key);
    if b.len() != c.len() {
        return vec![format!(
            "{key}: baseline has {} entries but current run has {}",
            b.len(), c.len())];
    }
    let mut out = Vec::new();
    for (i, (bv, cv)) in b.iter().zip(&c).enumerate() {
        if *bv <= 0.0 {
            continue; // provisional baseline entry
        }
        if *cv < bv * (1.0 - tolerance) {
            out.push(format!(
                "{key}[{i}]: {cv:.1} is more than {:.0}% below the \
                 baseline {bv:.1}",
                tolerance * 100.0));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_iters: 1000,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
        });
        let s = &b.results[0];
        assert!(s.iters > 0);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert_eq!(fmt_rate(2_500_000.0), "2.50M");
    }

    const SAMPLE: &str = r#"{
      "bench": "native",
      "per_bit": [
        {"w_bits": 3, "decode_tok_s": 100.0, "decode_tok_s_on": 90.0},
        {"w_bits": 4, "decode_tok_s": 200.5},
        {"w_bits": 8, "decode_tok_s": 300}
      ]
    }"#;

    #[test]
    fn json_key_numbers_scans_exact_keys() {
        let v = json_key_numbers(SAMPLE, "decode_tok_s");
        assert_eq!(v, vec![100.0, 200.5, 300.0]);
        // prefix-sharing key is its own key, not a match of the short one
        assert_eq!(json_key_numbers(SAMPLE, "decode_tok_s_on"), vec![90.0]);
        assert_eq!(json_key_numbers(SAMPLE, "w_bits"), vec![3.0, 4.0, 8.0]);
        // string values and absent keys yield nothing
        assert!(json_key_numbers(SAMPLE, "bench").is_empty());
        assert!(json_key_numbers(SAMPLE, "nope").is_empty());
    }

    #[test]
    fn regressions_flags_only_real_drops() {
        let base = r#"{"per_bit": [{"decode_tok_s": 100.0},
                                   {"decode_tok_s": 200.0}]}"#;
        // within tolerance: 80 >= 100 * (1 - 0.3)
        let ok = r#"{"per_bit": [{"decode_tok_s": 80.0},
                                 {"decode_tok_s": 190.0}]}"#;
        assert!(regressions(base, ok, "decode_tok_s", 0.30).is_empty());
        // 60 < 70: one regression, the healthy entry stays quiet
        let bad = r#"{"per_bit": [{"decode_tok_s": 60.0},
                                  {"decode_tok_s": 210.0}]}"#;
        let r = regressions(base, bad, "decode_tok_s", 0.30);
        assert_eq!(r.len(), 1, "{r:?}");
        assert!(r[0].contains("60.0"), "{r:?}");
    }

    #[test]
    fn regressions_skips_provisional_and_catches_shape_drift() {
        // zero-valued (provisional) baseline entries never fail the gate
        let base = r#"{"per_bit": [{"decode_tok_s": 0.0},
                                   {"decode_tok_s": 0.0}]}"#;
        let cur = r#"{"per_bit": [{"decode_tok_s": 5.0},
                                  {"decode_tok_s": 1.0}]}"#;
        assert!(regressions(base, cur, "decode_tok_s", 0.30).is_empty());
        // entry-count mismatch is reported as its own failure
        let short = r#"{"per_bit": [{"decode_tok_s": 5.0}]}"#;
        let r = regressions(base, short, "decode_tok_s", 0.30);
        assert_eq!(r.len(), 1);
        assert!(r[0].contains("entries"), "{r:?}");
    }
}
