//! Execution engine: chains the AOT artifacts (`embed → block × L →
//! head_loss`) for FP and quantized forward passes. This is the request-path
//! core shared by the PTQ pipeline, the evaluator, and the serving engine.

use std::rc::Rc;

use anyhow::{bail, Result};
use xla::Literal;

use crate::config::{ActScheme, Scheme};
use crate::model::{BlockWeights, ModelDim, QuantizedModel, Weights};
use crate::quant::{qmax, ActRange};
use crate::runtime::{flag_lit, from_lit, ids_lit, scalar_from_lit, scalar_lit,
                     to_lit, Exec, Runtime};
use crate::tensor::Tensor;

/// Calibrated statistics at one activation-quant point.
#[derive(Clone, Debug, Default)]
pub struct PointStats {
    pub range: ActRange,
    pub amax: Vec<f32>,
}

impl PointStats {
    pub fn merge(&mut self, mn: f32, mx: f32, amax: &[f32]) {
        self.range.update(mn, mx);
        if self.amax.is_empty() {
            self.amax = amax.to_vec();
        } else {
            for (a, &b) in self.amax.iter_mut().zip(amax) {
                *a = a.max(b);
            }
        }
    }
}

/// Per-block activation ranges for the 4 quant points (Fig. 8).
pub type BlockStats = [PointStats; 4];

/// Output of an FP block forward: next activations + stats + the raw
/// activations at each quant point (GPTQ/AWQ food).
pub struct BlockFwdOut {
    pub y: Tensor,
    pub stats: BlockStats,
    pub acts: [Tensor; 4],
}

pub struct Engine {
    pub dim: ModelDim,
    embed: Rc<Exec>,
    head: Rc<Exec>,
    block_fwd: Rc<Exec>,
    block_fwd_q: Rc<Exec>,
}

impl Engine {
    pub fn new(rt: &Runtime, cfg: &str) -> Result<Engine> {
        Ok(Engine {
            dim: rt.dim(cfg)?,
            embed: rt.exec(&format!("embed_{cfg}"))?,
            head: rt.exec(&format!("head_loss_{cfg}"))?,
            block_fwd: rt.exec(&format!("block_fwd_{cfg}"))?,
            block_fwd_q: rt.exec(&format!("block_fwd_q_{cfg}"))?,
        })
    }

    /// ids (calib_batch × seq) → embeddings.
    pub fn embed(&self, emb: &Tensor, ids: &[i32]) -> Result<Tensor> {
        let d = &self.dim;
        let lits = vec![to_lit(emb)?,
                        ids_lit(ids, &[d.calib_batch, d.seq])?];
        let out = self.embed.run(&lits)?;
        from_lit(&out[0], &[d.calib_batch, d.seq, d.d])
    }

    /// FP block forward with stats + act capture.
    pub fn block_fp(&self, x: &Tensor, bw: &BlockWeights) -> Result<BlockFwdOut> {
        let mut lits = vec![to_lit(x)?];
        for w in &bw.ws {
            lits.push(to_lit(w)?);
        }
        lits.push(to_lit(&bw.norm_attn)?);
        lits.push(to_lit(&bw.norm_ffn)?);
        let out = self.block_fwd.run(&lits)?;
        let spec = &self.block_fwd.spec.outputs;
        let y = from_lit(&out[0], &spec[0].dims)?;
        let mut stats: BlockStats = Default::default();
        let mut acts: Vec<Tensor> = Vec::with_capacity(4);
        for p in 0..4 {
            let base = 1 + p * 4;
            let mn = scalar_from_lit(&out[base])?;
            let mx = scalar_from_lit(&out[base + 1])?;
            let amax = from_lit(&out[base + 2], &spec[base + 2].dims)?;
            stats[p].merge(mn, mx, &amax.data);
            acts.push(from_lit(&out[base + 3], &spec[base + 3].dims)?);
        }
        let acts: [Tensor; 4] = acts.try_into().map_err(|_| {
            anyhow::anyhow!("act count")
        })?;
        Ok(BlockFwdOut { y, stats, acts })
    }

    /// Literal bundle for the activation-quant tail of block_fwd_q / recon
    /// inputs: 4×(scale, zp) then act_on, per_token, kv_on[, qmax_w], qmax_a,
    /// qmax_kv.
    pub fn act_tail(&self, stats: &BlockStats, scheme: &Scheme,
                    include_qmax_w: bool) -> Result<Vec<Literal>> {
        let qmax_a = qmax(scheme.a_bits);
        let qmax_kv = qmax(scheme.kv_bits);
        let mut lits = Vec::new();
        for p in stats.iter() {
            let (s, z) = p.range.grid(qmax_a);
            lits.push(scalar_lit(s));
            lits.push(scalar_lit(z));
        }
        lits.push(flag_lit(!matches!(scheme.act, ActScheme::None)));
        lits.push(flag_lit(matches!(scheme.act, ActScheme::PerToken)));
        lits.push(flag_lit(scheme.kv_quant));
        if include_qmax_w {
            lits.push(scalar_lit(qmax(scheme.w_bits)));
        }
        lits.push(scalar_lit(qmax_a));
        lits.push(scalar_lit(qmax_kv));
        Ok(lits)
    }

    /// Quantized block forward: `whats` are the dequantized Ŵ tensors.
    pub fn block_q(&self, x: &Tensor, whats: &[Tensor], norm_attn: &Tensor,
                   norm_ffn: &Tensor, stats: &BlockStats, scheme: &Scheme)
                   -> Result<Tensor> {
        if whats.len() != 7 {
            bail!("block_q needs 7 weight tensors");
        }
        let mut lits = vec![to_lit(x)?];
        for w in whats {
            lits.push(to_lit(w)?);
        }
        lits.push(to_lit(norm_attn)?);
        lits.push(to_lit(norm_ffn)?);
        lits.extend(self.act_tail(stats, scheme, false)?);
        let out = self.block_fwd_q.run(&lits)?;
        from_lit(&out[0], &self.block_fwd_q.spec.outputs[0].dims)
    }

    /// Final norm + head: (mean NLL, per-position log-prob of targets).
    pub fn head_logp(&self, x: &Tensor, final_norm: &Tensor, head: &Tensor,
                     targets: &[i32]) -> Result<(f32, Tensor)> {
        let d = &self.dim;
        let lits = vec![
            to_lit(x)?,
            to_lit(final_norm)?,
            to_lit(head)?,
            ids_lit(targets, &[d.calib_batch, d.seq])?,
        ];
        let out = self.head.run(&lits)?;
        let loss = scalar_from_lit(&out[0])?;
        let logp = from_lit(&out[1], &[d.calib_batch, d.seq])?;
        Ok((loss, logp))
    }

    /// Full FP forward: (mean NLL, per-position target log-probs).
    pub fn fp_forward(&self, w: &Weights, ids: &[i32], targets: &[i32])
                      -> Result<(f32, Tensor)> {
        let mut x = self.embed(&w.emb, ids)?;
        for bw in &w.blocks {
            x = self.block_fp(&x, bw)?.y;
        }
        self.head_logp(&x, &w.final_norm, &w.head, targets)
    }

    /// Full quantized forward (per-block dequantized weights + calibrated
    /// ranges + scheme flags).
    pub fn q_forward(&self, qm: &QuantizedModel, ranges: &[BlockStats],
                     scheme: &Scheme, ids: &[i32], targets: &[i32])
                     -> Result<(f32, Tensor)> {
        if ranges.len() != qm.blocks.len() {
            bail!("ranges/blocks mismatch");
        }
        let mut x = self.embed(&qm.emb, ids)?;
        for (qb, st) in qm.blocks.iter().zip(ranges) {
            let whats = qb.dequant_ws();
            x = self.block_q(&x, &whats, &qb.norm_attn, &qb.norm_ffn, st,
                             scheme)?;
        }
        self.head_logp(&x, &qm.final_norm, &qm.head, targets)
    }
}
