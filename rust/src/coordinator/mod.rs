//! The L3 coordinator — the paper's system contribution: the block-wise PTQ
//! pipeline (calibration streaming, reconstruction driving, finalization),
//! the pre-training driver that produces the FP baseline, and the execution
//! engine they share.

pub mod engine;
pub mod pipeline;
pub mod trainer;

pub use engine::{BlockFwdOut, BlockStats, Engine, PointStats};
pub use pipeline::{quantize_model, QuantizeOutcome};
pub use trainer::{pretrain, TrainOutcome};
