//! Pre-training driver: produces the FP baseline that PTQ quantizes, by
//! threading (params, m, v) literals through the `train_step` AOT artifact.
//! This is the e2e requirement's loss-curve run (EXPERIMENTS.md §e2e).

use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::data::Corpus;
use crate::model::Weights;
use crate::rng::Rng;
use crate::runtime::{from_lit, ids_lit, scalar_from_lit, scalar_lit, to_lit,
                     Runtime};

pub struct TrainOutcome {
    pub weights: Weights,
    /// (step, loss) pairs at the logging cadence
    pub losses: Vec<(usize, f32)>,
    pub wall_secs: f64,
}

/// Linear warmup then cosine decay to 10% — computed host-side, fed as a
/// scalar input each step.
pub fn lr_at(step: usize, total: usize, base: f32) -> f32 {
    let warmup = (total / 20).max(1);
    if step < warmup {
        return base * (step + 1) as f32 / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    base * (0.1 + 0.9 * cos)
}

/// Train for `steps` on the synthetic corpus; logs every `log_every` steps.
pub fn pretrain(rt: &Runtime, cfg: &str, corpus: &Corpus, steps: usize,
                base_lr: f32, seed: u64, log_every: usize)
                -> Result<TrainOutcome> {
    let dim = rt.dim(cfg)?;
    let exec = rt.exec(&format!("train_step_{cfg}"))?;
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();

    // initial state as literals
    let init = Weights::init(&dim, &mut rng);
    let flat = init.flat();
    let n = flat.len();
    let mut params: Vec<Literal> =
        flat.iter().map(|t| to_lit(t)).collect::<Result<_>>()?;
    let zeros = |src: &[&crate::tensor::Tensor]| -> Result<Vec<Literal>> {
        src.iter()
            .map(|t| to_lit(&crate::tensor::Tensor::zeros(&t.dims)))
            .collect()
    };
    let mut m = zeros(&flat)?;
    let mut v = zeros(&flat)?;

    let mut losses = Vec::new();
    for step in 0..steps {
        let (ids, tgt) = corpus.train_batch(dim.train_batch, dim.seq, &mut rng);
        let ids_l = ids_lit(&ids, &[dim.train_batch, dim.seq])?;
        let tgt_l = ids_lit(&tgt, &[dim.train_batch, dim.seq])?;
        let t_l = scalar_lit(step as f32);
        let lr_l = scalar_lit(lr_at(step, steps, base_lr));

        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n + 4);
        inputs.extend(params.iter());
        inputs.extend(m.iter());
        inputs.extend(v.iter());
        inputs.push(&ids_l);
        inputs.push(&tgt_l);
        inputs.push(&t_l);
        inputs.push(&lr_l);
        let mut outs = exec.run(&inputs)
            .with_context(|| format!("train step {step}"))?;
        if outs.len() != 1 + 3 * n {
            bail!("train_step output count {}", outs.len());
        }
        let loss = scalar_from_lit(&outs[0])?;
        if !loss.is_finite() {
            bail!("training diverged at step {step} (loss {loss})");
        }
        if step % log_every == 0 || step + 1 == steps {
            losses.push((step, loss));
        }
        let rest = outs.split_off(1);
        let mut it = rest.into_iter();
        params = (&mut it).take(n).collect();
        m = (&mut it).take(n).collect();
        v = (&mut it).take(n).collect();
    }

    // read back final params
    let dims: Vec<Vec<usize>> = flat.iter().map(|t| t.dims.clone()).collect();
    let tensors: Result<Vec<_>> = params
        .iter()
        .zip(&dims)
        .map(|(l, d)| from_lit(l, d))
        .collect();
    let weights = Weights::from_flat(&dim, tensors?)?;
    Ok(TrainOutcome { weights, losses, wall_secs: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let base = 1e-3;
        // warmup rises
        assert!(lr_at(0, 1000, base) < lr_at(20, 1000, base));
        // decays later
        assert!(lr_at(900, 1000, base) < lr_at(100, 1000, base));
        // never exceeds base, never hits 0
        for s in 0..1000 {
            let lr = lr_at(s, 1000, base);
            assert!(lr > 0.0 && lr <= base + 1e-9);
        }
    }
}
