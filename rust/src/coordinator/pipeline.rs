//! The block-wise PTQ pipeline (BRECQ recipe, paper §2.1):
//!
//! 1. stream calibration batches through the FP blocks (`X` stream), caching
//!    stats and the reconstruction targets `Y = block_fp(X)`;
//! 2. maintain the parallel quantized-input stream `X̃` through already-
//!    quantized blocks;
//! 3. per block, hand a [`BlockContext`] to the method driver;
//! 4. re-calibrate activation ranges on the *quantized* block (the ranges the
//!    runtime will actually see), then advance `X̃`.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{Method, ReconConfig, Scheme};
use crate::data::Corpus;
use crate::methods::{needs_acts, quantize_block, BlockContext};
use crate::model::{BlockWeights, ModelDim, QuantizedBlock, QuantizedModel,
                   Weights};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::engine::{BlockStats, Engine};

/// Everything the pipeline produces for one (method, scheme) run.
pub struct QuantizeOutcome {
    pub model: QuantizedModel,
    /// runtime activation ranges per block (calibrated on the quantized net)
    pub stats: Vec<BlockStats>,
    /// reconstruction loss traces per block (empty for learning-free methods)
    pub loss_traces: Vec<Vec<f32>>,
    pub wall: Duration,
    /// rough working-set estimate: bytes of cached activations + weights
    pub mem_bytes: usize,
}

fn merge_stats(dst: &mut BlockStats, src: &BlockStats) {
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        d.range.update(s.range.min, s.range.max);
        if d.amax.is_empty() {
            d.amax = s.amax.clone();
        } else {
            for (a, &b) in d.amax.iter_mut().zip(&s.amax) {
                *a = a.max(b);
            }
        }
    }
}

/// Build calibration id batches: `samples` sequences from the calibration
/// domains, grouped into [calib_batch × seq] rows (paper: 512 C4 samples).
pub fn calib_ids(dim: &ModelDim, corpus: &Corpus, samples: usize, seed: u64)
                 -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    let n_batches = samples.div_ceil(dim.calib_batch);
    (0..n_batches)
        .map(|_| corpus.calib_batch(dim.calib_batch, dim.seq, &mut rng))
        .collect()
}

/// Quantize a full model with `method` under `scheme`.
#[allow(clippy::too_many_arguments)]
pub fn quantize_model(rt: &Runtime, engine: &Engine, weights: &Weights,
                      corpus: &Corpus, method: Method, scheme: Scheme,
                      recon: ReconConfig) -> Result<QuantizeOutcome> {
    if method == Method::Fp16 {
        bail!("FP16 is the baseline, not a quantization method");
    }
    let t0 = Instant::now();
    let dim = &engine.dim;
    let id_batches = calib_ids(dim, corpus, recon.calib_samples, recon.seed);

    // embed calibration batches once; FP and quant streams start equal
    let mut x_fp: Vec<Tensor> = id_batches
        .iter()
        .map(|ids| engine.embed(&weights.emb, ids))
        .collect::<Result<_>>()?;
    let mut x_q: Vec<Tensor> = x_fp.clone();

    let mut mem_bytes = x_fp.iter().map(|t| t.len() * 8).sum::<usize>();
    let mut out_blocks = Vec::with_capacity(dim.layers);
    let mut out_stats = Vec::with_capacity(dim.layers);
    let mut loss_traces = Vec::with_capacity(dim.layers);

    for (bi, bw) in weights.blocks.iter().enumerate() {
        // (1) FP stream: targets + FP-calibrated stats
        let mut stats: BlockStats = Default::default();
        let mut y_t = Vec::with_capacity(x_fp.len());
        for x in &x_fp {
            let o = engine.block_fp(x, bw)?;
            merge_stats(&mut stats, &o.stats);
            y_t.push(o.y);
        }
        // (2) quant-stream activations for Hessian/saliency methods
        let acts_q: Option<Vec<[Tensor; 4]>> = if needs_acts(method) {
            let mut all = Vec::with_capacity(x_q.len());
            for x in &x_q {
                all.push(engine.block_fp(x, bw)?.acts);
            }
            mem_bytes = mem_bytes.max(
                all.iter()
                    .map(|a| a.iter().map(|t| t.len() * 4).sum::<usize>())
                    .sum::<usize>());
            Some(all)
        } else {
            None
        };

        // (3) method driver
        let ctx = BlockContext {
            dim,
            weights: bw,
            x_q: &x_q,
            y_t: &y_t,
            acts_q: acts_q.as_deref(),
            stats: &stats,
            scheme,
            recon,
            block_index: bi,
        };
        let res = quantize_block(rt, engine, method, &ctx)?;
        let whats = res.whats();

        // (4) runtime re-calibration on the quantized block
        let qbw = BlockWeights {
            ws: whats.clone(),
            norm_attn: res.norm_attn.clone(),
            norm_ffn: res.norm_ffn.clone(),
        };
        let mut fstats: BlockStats = Default::default();
        for x in &x_q {
            let o = engine.block_fp(x, &qbw)?;
            merge_stats(&mut fstats, &o.stats);
        }

        // (5) advance the quantized-input stream
        for x in x_q.iter_mut() {
            *x = engine.block_q(x, &whats, &res.norm_attn, &res.norm_ffn,
                                &fstats, &scheme)?;
        }
        x_fp = y_t;

        out_blocks.push(QuantizedBlock {
            ws: res.packed(scheme.w_bits)?,
            norm_attn: res.norm_attn,
            norm_ffn: res.norm_ffn,
        });
        out_stats.push(fstats);
        loss_traces.push(res.loss_trace);
    }

    let model = QuantizedModel {
        dim: dim.clone(),
        bits: scheme.w_bits,
        emb: weights.emb.clone(),
        blocks: out_blocks,
        final_norm: weights.final_norm.clone(),
        head: weights.head.clone(),
    };
    mem_bytes += model.storage_bytes();
    Ok(QuantizeOutcome {
        model,
        stats: out_stats,
        loss_traces,
        wall: t0.elapsed(),
        mem_bytes,
    })
}
