//! Table rendering for the paper-shaped outputs: aligned text to stdout and
//! markdown files under `reports/` (one per regenerated table/figure).

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::Result;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(),
                   "row width {} != header width {}", cells.len(),
                   self.headers.len());
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: &str) -> &mut Self {
        self.notes.push(s.to_string());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &w));
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    /// GitHub-flavoured markdown rendering.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n> {n}");
        }
        out
    }

    /// Print to stdout and persist markdown under `dir/<id>.md`.
    pub fn emit(&self, dir: &Path, id: &str) -> Result<()> {
        print!("{}", self.text());
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{id}.md")), self.markdown())?;
        Ok(())
    }
}

/// f64 -> fixed-point cell.
pub fn fmt(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// accuracy fraction -> percent cell.
pub fn pct(v: f64) -> String {
    format!("{:.2}", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(vec!["RTN".into(), pct(0.5012)]);
        t.row(vec!["LRQ (Ours)".into(), pct(0.7525)]);
        t.note("synthetic");
        t
    }

    #[test]
    fn text_aligned() {
        let s = table().text();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("RTN"));
        assert!(s.contains("75.25"));
    }

    #[test]
    fn markdown_valid() {
        let s = table().markdown();
        assert!(s.contains("| Method | Acc |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("> synthetic"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn emit_writes_file() {
        let dir = std::env::temp_dir().join("lrq_report_test");
        table().emit(&dir, "demo").unwrap();
        let p = dir.join("demo.md");
        assert!(p.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
