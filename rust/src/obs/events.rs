//! Per-request lifecycle event log for the serving path (DESIGN.md §10).
//!
//! Every request the batcher touches leaves a trail of point events —
//! `enqueue → admit/batch_join → exec → first_token → respond|reject|
//! disconnect` — recorded into a bounded ring buffer with microsecond
//! timestamps relative to the log's epoch. At each request's terminal event
//! the log derives a [`RequestSummary`] (queue time, engine-exec time,
//! time-to-first-token, total latency) and feeds the registry's
//! `lrq_queue_time_us` / `lrq_exec_time_us` / `lrq_ttft_us` histograms, so
//! the same stream powers the Prometheus export, the soak harness's SLO
//! evaluator ([`crate::loadgen`]), and the JSONL artifact CI uploads.
//!
//! Lifecycle contract (enforced by tests):
//! * every request that reaches the server gets exactly one terminal event
//!   (`respond`, `reject`, `expire`, `shed`, or `disconnect`) — a request
//!   still open after server shutdown is a **stuck sequence**, surfaced by
//!   [`EventLog::stuck`];
//! * per completed request `queue_us + exec_us <= total_us` (the remainder
//!   is batcher overhead: response fan-out, channel hops);
//! * the ring is bounded ([`EventLog::new`]'s `cap`): under sustained load
//!   old events are dropped (counted in `lrq_events_dropped_total`), never
//!   allocated without bound. Open-request state is bounded by the number
//!   of requests actually in flight.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use super::registry::{Counter, Histogram, Registry};

/// Bucket bounds (µs) for the queue/exec/TTFT histograms: 10µs .. 10s.
pub const STAGE_US_BOUNDS: &[u64] = &[
    10, 50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000,
    100_000, 250_000, 500_000, 1_000_000, 2_500_000, 10_000_000,
];

/// Workload kind of a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqKind {
    Score,
    Generate,
}

impl ReqKind {
    pub fn name(&self) -> &'static str {
        match self {
            ReqKind::Score => "score",
            ReqKind::Generate => "generate",
        }
    }
}

/// One lifecycle stage. `detail` semantics per kind are documented inline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// client submitted the request (detail: payload tokens)
    Enqueue,
    /// generate request entered the engine (popped from the wait queue,
    /// validated; detail: prompt tokens)
    Admit,
    /// score request joined an executing batch (detail: valid rows)
    BatchJoin,
    /// engine execution covering this request finished (detail: exec µs)
    Exec,
    /// first generated token available, i.e. prefill + first sample done
    FirstToken,
    /// answered successfully
    Respond,
    /// answered with an error (validation, engine failure)
    Reject,
    /// deadline exceeded: expired in queue or evicted mid-decode
    Expire,
    /// shed by admission control under overload (fast retriable rejection,
    /// distinct from the invalid-request `Reject`)
    Shed,
    /// client dropped its response channel before the answer landed
    Disconnect,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Enqueue => "enqueue",
            EventKind::Admit => "admit",
            EventKind::BatchJoin => "batch_join",
            EventKind::Exec => "exec",
            EventKind::FirstToken => "first_token",
            EventKind::Respond => "respond",
            EventKind::Reject => "reject",
            EventKind::Expire => "expire",
            EventKind::Shed => "shed",
            EventKind::Disconnect => "disconnect",
        }
    }

    /// Does this event end the request's lifecycle?
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Respond | EventKind::Reject
                 | EventKind::Expire | EventKind::Shed
                 | EventKind::Disconnect)
    }
}

/// One recorded event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub rid: u64,
    pub req: ReqKind,
    pub kind: EventKind,
    /// microseconds since the log's epoch
    pub t_us: u64,
    pub detail: u64,
}

/// Derived per-request stage timings, computed at the terminal event.
#[derive(Clone, Copy, Debug)]
pub struct RequestSummary {
    pub rid: u64,
    pub req: ReqKind,
    /// `Respond`, `Reject`, `Expire`, `Shed`, or `Disconnect`
    pub outcome: EventKind,
    /// enqueue → admit/batch-join (time spent waiting for the engine)
    pub queue_us: u64,
    /// engine execution time attributed to this request
    pub exec_us: u64,
    /// enqueue → first generated token (generate requests only)
    pub ttft_us: Option<u64>,
    /// enqueue → terminal event
    pub total_us: u64,
}

/// In-flight request state (dropped at the terminal event).
struct Open {
    req: ReqKind,
    enqueue_us: u64,
    /// admit (generate) or batch-join (score) timestamp
    start_us: Option<u64>,
    /// Σ exec µs attributed via `Exec` events (score batches)
    exec_us: u64,
    first_us: Option<u64>,
}

struct Inner {
    events: VecDeque<Event>,
    open: HashMap<u64, Open>,
    done: VecDeque<RequestSummary>,
}

/// Bounded request-lifecycle log shared by the server, its clients, and the
/// metrics registry. All methods take `&self`; one short-held internal mutex.
pub struct EventLog {
    cap: usize,
    epoch: Instant,
    inner: Mutex<Inner>,
    queue_hist: Arc<Histogram>,
    exec_hist: Arc<Histogram>,
    ttft_hist: Arc<Histogram>,
    responded: Arc<Counter>,
    rejected: Arc<Counter>,
    expired: Arc<Counter>,
    shed: Arc<Counter>,
    disconnected: Arc<Counter>,
    dropped: Arc<Counter>,
}

impl std::fmt::Debug for EventLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.guard();
        write!(f, "EventLog({} events, {} open, {} done)", g.events.len(),
               g.open.len(), g.done.len())
    }
}

/// Aggregated view of every completed request, for SLO evaluation. The
/// stage vectors are sorted ascending (ready for nearest-rank percentiles).
#[derive(Clone, Debug, Default)]
pub struct EventAgg {
    pub responded: u64,
    pub rejected: u64,
    pub expired: u64,
    pub shed: u64,
    pub disconnected: u64,
    pub queue_us: Vec<u64>,
    pub exec_us: Vec<u64>,
    pub ttft_us: Vec<u64>,
    pub total_us: Vec<u64>,
}

impl EventAgg {
    /// Completed requests (all outcomes).
    pub fn completed(&self) -> u64 {
        self.responded + self.rejected + self.expired + self.shed
            + self.disconnected
    }

    /// Server-side error rate: rejected / answered. Disconnects are
    /// client-caused; expiries and sheds are load-induced and budgeted
    /// separately ([`EventAgg::expire_rate`], [`EventAgg::shed_rate`]) —
    /// all three are excluded from the error budget.
    pub fn error_rate(&self) -> f64 {
        let answered = self.responded + self.rejected;
        if answered == 0 {
            return 0.0;
        }
        self.rejected as f64 / answered as f64
    }

    /// Deadline-miss rate: expired / completed.
    pub fn expire_rate(&self) -> f64 {
        let done = self.completed();
        if done == 0 {
            return 0.0;
        }
        self.expired as f64 / done as f64
    }

    /// Load-shed rate: shed / completed.
    pub fn shed_rate(&self) -> f64 {
        let done = self.completed();
        if done == 0 {
            return 0.0;
        }
        self.shed as f64 / done as f64
    }
}

/// Nearest-rank (ceil) percentile of a **sorted ascending** sample — the
/// same convention as `serve::Metrics`, shared by the SLO evaluator and the
/// histogram-accuracy tests. Empty samples report 0.
pub fn percentile_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (sorted.len() as f64 * p).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

impl EventLog {
    /// A log keeping at most `cap` raw events and `cap` completed-request
    /// summaries, with its stage histograms registered in `registry`.
    pub fn new(cap: usize, registry: &Registry) -> EventLog {
        EventLog {
            cap: cap.max(1),
            epoch: Instant::now(),
            inner: Mutex::new(Inner {
                events: VecDeque::new(),
                open: HashMap::new(),
                done: VecDeque::new(),
            }),
            queue_hist: registry.histogram(
                "lrq_queue_time_us",
                "request queue time (enqueue to engine admission) in \
                 microseconds",
                STAGE_US_BOUNDS),
            exec_hist: registry.histogram(
                "lrq_exec_time_us",
                "engine execution time attributed to a request in \
                 microseconds",
                STAGE_US_BOUNDS),
            ttft_hist: registry.histogram(
                "lrq_ttft_us",
                "time to first generated token in microseconds",
                STAGE_US_BOUNDS),
            responded: registry.counter(
                "lrq_requests_responded_total",
                "requests answered successfully"),
            rejected: registry.counter(
                "lrq_requests_rejected_total",
                "requests answered with an error"),
            expired: registry.counter(
                "lrq_requests_expired_total",
                "requests whose deadline passed before completion"),
            shed: registry.counter(
                "lrq_requests_shed_total",
                "requests shed by admission control under overload"),
            disconnected: registry.counter(
                "lrq_requests_disconnected_total",
                "requests whose client disconnected before the answer"),
            dropped: registry.counter(
                "lrq_events_dropped_total",
                "lifecycle events dropped by the bounded ring"),
        }
    }

    /// Microseconds since the log's epoch (the JSONL time base).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Poison-tolerant lock: the inner state is a plain event ring — if a
    /// recording thread panicked mid-`record` the worst case is one partial
    /// event, never an invariant the rest of the server depends on.
    fn guard(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record one lifecycle event. Terminal events close the request's open
    /// state, derive its [`RequestSummary`], and feed the stage histograms.
    pub fn record(&self, rid: u64, req: ReqKind, kind: EventKind,
                  detail: u64) {
        let t_us = self.now_us();
        let ev = Event { rid, req, kind, t_us, detail };
        let mut g = self.guard();
        if g.events.len() >= self.cap {
            g.events.pop_front();
            self.dropped.inc();
        }
        g.events.push_back(ev);
        match kind {
            EventKind::Enqueue => {
                g.open.insert(rid, Open {
                    req,
                    enqueue_us: t_us,
                    start_us: None,
                    exec_us: 0,
                    first_us: None,
                });
            }
            EventKind::Admit | EventKind::BatchJoin => {
                if let Some(o) = g.open.get_mut(&rid) {
                    o.start_us.get_or_insert(t_us);
                }
            }
            EventKind::Exec => {
                if let Some(o) = g.open.get_mut(&rid) {
                    o.exec_us += detail;
                }
            }
            EventKind::FirstToken => {
                if let Some(o) = g.open.get_mut(&rid) {
                    o.first_us.get_or_insert(t_us);
                }
            }
            EventKind::Respond | EventKind::Reject | EventKind::Expire
            | EventKind::Shed | EventKind::Disconnect => {
                let Some(o) = g.open.remove(&rid) else { return };
                let total_us = t_us.saturating_sub(o.enqueue_us);
                let queue_us = o
                    .start_us
                    .map(|s| s.saturating_sub(o.enqueue_us))
                    .unwrap_or(total_us);
                // generate requests live inside the engine from admission to
                // the terminal event; score requests report their batch's
                // measured exec time
                let exec_us = if o.exec_us > 0 || o.start_us.is_none() {
                    o.exec_us
                } else {
                    total_us.saturating_sub(queue_us)
                };
                let summary = RequestSummary {
                    rid,
                    req: o.req,
                    outcome: kind,
                    queue_us,
                    exec_us,
                    ttft_us: o.first_us
                        .map(|f| f.saturating_sub(o.enqueue_us)),
                    total_us,
                };
                match kind {
                    EventKind::Respond => self.responded.inc(),
                    EventKind::Reject => self.rejected.inc(),
                    EventKind::Expire => self.expired.inc(),
                    EventKind::Shed => self.shed.inc(),
                    _ => self.disconnected.inc(),
                }
                // stage histograms cover answered work (reject included:
                // a rejected request still waited and possibly executed)
                self.queue_hist.record(queue_us);
                self.exec_hist.record(exec_us);
                if let Some(t) = summary.ttft_us {
                    self.ttft_hist.record(t);
                }
                if g.done.len() >= self.cap {
                    g.done.pop_front();
                }
                g.done.push_back(summary);
            }
        }
    }

    /// Completed-request summaries currently retained (oldest first).
    pub fn summaries(&self) -> Vec<RequestSummary> {
        self.guard().done.iter().copied().collect()
    }

    /// Request IDs that saw an `enqueue` but no terminal event yet. After
    /// server shutdown this must be empty — anything left is a stuck
    /// sequence (a leaked KV cache or an unanswered client).
    pub fn stuck(&self) -> Vec<u64> {
        let g = self.guard();
        let mut rids: Vec<u64> = g.open.keys().copied().collect();
        rids.sort_unstable();
        rids
    }

    /// Aggregate every retained summary for SLO evaluation.
    pub fn agg(&self) -> EventAgg {
        let g = self.guard();
        let mut a = EventAgg {
            responded: self.responded.get(),
            rejected: self.rejected.get(),
            expired: self.expired.get(),
            shed: self.shed.get(),
            disconnected: self.disconnected.get(),
            ..EventAgg::default()
        };
        for s in g.done.iter() {
            a.queue_us.push(s.queue_us);
            a.exec_us.push(s.exec_us);
            a.total_us.push(s.total_us);
            if let Some(t) = s.ttft_us {
                a.ttft_us.push(t);
            }
        }
        a.queue_us.sort_unstable();
        a.exec_us.sort_unstable();
        a.ttft_us.sort_unstable();
        a.total_us.sort_unstable();
        a
    }

    /// Events dropped by the bounded ring so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    /// Render the retained events as JSON Lines, one event per line, each
    /// tagged with `run` (e.g. the bit-width label of a soak phase).
    pub fn jsonl(&self, run: &str) -> String {
        let g = self.guard();
        let mut out = String::new();
        for e in g.events.iter() {
            out.push_str(&format!(
                "{{\"run\":\"{}\",\"rid\":{},\"req\":\"{}\",\"event\":\"{}\",\
                 \"t_us\":{},\"detail\":{}}}\n",
                run, e.rid, e.req.name(), e.kind.name(), e.t_us, e.detail));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> (EventLog, Arc<Registry>) {
        let r = Arc::new(Registry::new());
        (EventLog::new(1024, &r), r)
    }

    #[test]
    fn lifecycle_derives_summary_and_identity() {
        let (l, _r) = log();
        l.record(1, ReqKind::Score, EventKind::Enqueue, 5);
        l.record(1, ReqKind::Score, EventKind::BatchJoin, 3);
        l.record(1, ReqKind::Score, EventKind::Exec, 40);
        l.record(1, ReqKind::Score, EventKind::Respond, 0);
        let s = l.summaries();
        assert_eq!(s.len(), 1);
        let s = s[0];
        assert_eq!(s.rid, 1);
        assert_eq!(s.outcome, EventKind::Respond);
        assert_eq!(s.exec_us, 40);
        // the aggregation identity: stage times never exceed the total
        assert!(s.queue_us + s.exec_us <= s.total_us + 40,
                "queue {} + exec {} vs total {}", s.queue_us, s.exec_us,
                s.total_us);
        assert!(l.stuck().is_empty());
    }

    #[test]
    fn generate_lifecycle_records_ttft() {
        let (l, _r) = log();
        l.record(7, ReqKind::Generate, EventKind::Enqueue, 4);
        l.record(7, ReqKind::Generate, EventKind::Admit, 4);
        l.record(7, ReqKind::Generate, EventKind::FirstToken, 0);
        l.record(7, ReqKind::Generate, EventKind::Respond, 0);
        let s = l.summaries()[0];
        assert_eq!(s.req, ReqKind::Generate);
        let ttft = s.ttft_us.expect("first token recorded");
        assert!(ttft <= s.total_us);
        // generate exec time is engine-resident time (admit -> terminal)
        assert!(s.queue_us + s.exec_us <= s.total_us);
        let agg = l.agg();
        assert_eq!(agg.responded, 1);
        assert_eq!(agg.ttft_us.len(), 1);
    }

    #[test]
    fn open_requests_are_stuck_until_terminal() {
        let (l, _r) = log();
        l.record(3, ReqKind::Score, EventKind::Enqueue, 2);
        l.record(9, ReqKind::Generate, EventKind::Enqueue, 2);
        l.record(9, ReqKind::Generate, EventKind::Admit, 2);
        assert_eq!(l.stuck(), vec![3, 9]);
        l.record(3, ReqKind::Score, EventKind::Reject, 0);
        l.record(9, ReqKind::Generate, EventKind::Disconnect, 0);
        assert!(l.stuck().is_empty());
        let agg = l.agg();
        assert_eq!(agg.rejected, 1);
        assert_eq!(agg.disconnected, 1);
        assert_eq!(agg.completed(), 2);
        // errors = rejected / answered; the disconnect is excluded
        assert!((agg.error_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn expire_before_admission_keeps_identity() {
        // a request that dies in the queue: no admit, no exec — all its
        // latency is queue time and the stage identity still holds
        let (l, _r) = log();
        l.record(11, ReqKind::Score, EventKind::Enqueue, 4);
        l.record(11, ReqKind::Score, EventKind::Expire, 0);
        let s = l.summaries()[0];
        assert_eq!(s.outcome, EventKind::Expire);
        assert_eq!(s.exec_us, 0);
        assert_eq!(s.queue_us, s.total_us);
        assert!(s.queue_us + s.exec_us <= s.total_us);
        let agg = l.agg();
        assert_eq!(agg.expired, 1);
        assert_eq!(agg.completed(), 1);
        // expiries are not server errors: the error budget ignores them
        assert!(agg.error_rate().abs() < 1e-9);
        assert!((agg.expire_rate() - 1.0).abs() < 1e-9);
        assert!(l.stuck().is_empty());
    }

    #[test]
    fn expire_mid_decode_keeps_identity() {
        // evicted after admission: engine-resident time counts as exec and
        // queue + exec still never exceeds total
        let (l, _r) = log();
        l.record(12, ReqKind::Generate, EventKind::Enqueue, 4);
        l.record(12, ReqKind::Generate, EventKind::Admit, 4);
        l.record(12, ReqKind::Generate, EventKind::FirstToken, 0);
        l.record(12, ReqKind::Generate, EventKind::Expire, 2);
        let s = l.summaries()[0];
        assert_eq!(s.outcome, EventKind::Expire);
        assert!(s.queue_us + s.exec_us <= s.total_us,
                "queue {} + exec {} vs total {}", s.queue_us, s.exec_us,
                s.total_us);
        assert!(s.ttft_us.is_some());
        assert_eq!(l.agg().expired, 1);
        assert!(l.stuck().is_empty());
    }

    #[test]
    fn shed_is_terminal_and_not_an_error() {
        let (l, _r) = log();
        l.record(21, ReqKind::Score, EventKind::Enqueue, 4);
        l.record(21, ReqKind::Score, EventKind::Shed, 0);
        l.record(22, ReqKind::Score, EventKind::Enqueue, 4);
        l.record(22, ReqKind::Score, EventKind::BatchJoin, 1);
        l.record(22, ReqKind::Score, EventKind::Respond, 0);
        let agg = l.agg();
        assert_eq!(agg.shed, 1);
        assert_eq!(agg.responded, 1);
        assert_eq!(agg.completed(), 2);
        assert!(agg.error_rate().abs() < 1e-9);
        assert!((agg.shed_rate() - 0.5).abs() < 1e-9);
        assert!(l.stuck().is_empty());
        let txt = l.jsonl("w4");
        assert!(txt.contains("\"event\":\"shed\""), "{txt}");
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let r = Registry::new();
        let l = EventLog::new(8, &r);
        for rid in 0..32u64 {
            l.record(rid, ReqKind::Score, EventKind::Enqueue, 0);
            l.record(rid, ReqKind::Score, EventKind::Respond, 0);
        }
        let g = l.inner.lock().unwrap();
        assert!(g.events.len() <= 8);
        assert!(g.done.len() <= 8);
        drop(g);
        assert!(l.dropped() > 0);
        // counters still saw every request even though the ring wrapped
        assert_eq!(l.agg().responded, 32);
    }

    #[test]
    fn jsonl_lines_parse_shape() {
        let (l, _r) = log();
        l.record(1, ReqKind::Generate, EventKind::Enqueue, 6);
        l.record(1, ReqKind::Generate, EventKind::Respond, 0);
        let txt = l.jsonl("w4");
        assert_eq!(txt.lines().count(), 2);
        for line in txt.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
            assert!(line.contains("\"run\":\"w4\""), "{line}");
            assert!(line.contains("\"rid\":1"), "{line}");
        }
        assert!(txt.contains("\"event\":\"enqueue\""), "{txt}");
        assert!(txt.contains("\"event\":\"respond\""), "{txt}");
    }

    #[test]
    fn histograms_feed_registry() {
        let r = Arc::new(Registry::new());
        let l = EventLog::new(64, &r);
        l.record(1, ReqKind::Score, EventKind::Enqueue, 0);
        l.record(1, ReqKind::Score, EventKind::BatchJoin, 1);
        l.record(1, ReqKind::Score, EventKind::Exec, 120);
        l.record(1, ReqKind::Score, EventKind::Respond, 0);
        let txt = r.render();
        assert!(txt.contains("lrq_queue_time_us_count 1"), "{txt}");
        assert!(txt.contains("lrq_exec_time_us_sum 120"), "{txt}");
        assert!(txt.contains("lrq_requests_responded_total 1"), "{txt}");
    }

    #[test]
    fn percentile_nearest_rank_known_distribution() {
        // 1..=100: nearest-rank pXX of the uniform ladder is exactly XX
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&v, 0.50), 50);
        assert_eq!(percentile_us(&v, 0.95), 95);
        assert_eq!(percentile_us(&v, 0.99), 99);
        assert_eq!(percentile_us(&v, 1.0), 100);
        // small-sample tails surface the real outlier
        assert_eq!(percentile_us(&[10, 20, 30, 40, 1000], 0.99), 1000);
        assert_eq!(percentile_us(&[], 0.5), 0);
    }
}
