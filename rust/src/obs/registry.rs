//! Telemetry registry: counters, gauges, fixed-bucket histograms, and a
//! named registry that renders Prometheus text-exposition snapshots.
//!
//! Hot-path contract: recording into any instrument is a handful of relaxed
//! atomic ops — no locks, no allocation. The registry's mutex is touched
//! only at registration and render time (both cold).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

/// Monotonic counter. `const`-constructible so it can back both registered
/// instruments (`Arc<Counter>`) and the engine-global statics in [`engine`].
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Relaxed)
    }
}

/// Instantaneous signed value (queue depths, active sequences).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn set(&self, n: i64) {
        self.v.store(n, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.v.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Relaxed)
    }
}

/// Fixed-bucket histogram: `bounds` are inclusive upper bounds in recording
/// units; one implicit `+Inf` overflow bucket is appended. Recording is a
/// linear scan over a handful of bounds plus three relaxed adds.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

/// Default request-latency bounds in microseconds: 50µs .. 1s.
pub const LATENCY_US_BOUNDS: &[u64] = &[
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000,
    250_000, 500_000, 1_000_000,
];

impl Histogram {
    pub fn new(bounds: &[u64]) -> Histogram {
        let mut b = bounds.to_vec();
        b.sort_unstable();
        b.dedup();
        let buckets = (0..b.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds: b,
            buckets,
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.count.fetch_add(1, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Upper-bound estimate of quantile `p` (0..=1): the smallest bucket
    /// bound whose cumulative count covers `ceil(p * count)`. Returns 0 on
    /// an empty histogram; values past the last bound report that bound
    /// (the `+Inf` bucket has no finite upper edge).
    pub fn quantile(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((n as f64 * p).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Relaxed);
            if cum >= rank {
                return self.bounds.get(i).copied().unwrap_or_else(|| {
                    self.bounds.last().copied().unwrap_or(0)
                });
            }
        }
        self.bounds.last().copied().unwrap_or(0)
    }
}

enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Entry {
    name: String,
    help: String,
    inst: Instrument,
}

/// A named set of instruments rendered together. Registration returns the
/// existing instrument when the name is already present (same kind), so
/// independent components can share counters by name.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.entries.lock().map(|g| g.len()).unwrap_or(0);
        write!(f, "Registry({n} instruments)")
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut g = self.entries.lock().unwrap();
        for e in g.iter() {
            if e.name == name {
                if let Instrument::Counter(c) = &e.inst {
                    return c.clone();
                }
            }
        }
        let c = Arc::new(Counter::new());
        g.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            inst: Instrument::Counter(c.clone()),
        });
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut g = self.entries.lock().unwrap();
        for e in g.iter() {
            if e.name == name {
                if let Instrument::Gauge(v) = &e.inst {
                    return v.clone();
                }
            }
        }
        let v = Arc::new(Gauge::new());
        g.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            inst: Instrument::Gauge(v.clone()),
        });
        v
    }

    pub fn histogram(&self, name: &str, help: &str, bounds: &[u64])
                     -> Arc<Histogram> {
        let mut g = self.entries.lock().unwrap();
        for e in g.iter() {
            if e.name == name {
                if let Instrument::Histogram(h) = &e.inst {
                    return h.clone();
                }
            }
        }
        let h = Arc::new(Histogram::new(bounds));
        g.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            inst: Instrument::Histogram(h.clone()),
        });
        h
    }

    /// Prometheus text-exposition snapshot of every registered instrument.
    pub fn render(&self) -> String {
        let g = self.entries.lock().unwrap();
        let mut out = String::new();
        for e in g.iter() {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            match &e.inst {
                Instrument::Counter(c) => {
                    out.push_str(&format!("# TYPE {} counter\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, c.get()));
                }
                Instrument::Gauge(v) => {
                    out.push_str(&format!("# TYPE {} gauge\n", e.name));
                    out.push_str(&format!("{} {}\n", e.name, v.get()));
                }
                Instrument::Histogram(h) => {
                    out.push_str(&format!("# TYPE {} histogram\n", e.name));
                    let mut cum = 0u64;
                    for (i, b) in h.buckets.iter().enumerate() {
                        cum += b.load(Relaxed);
                        let le = match h.bounds.get(i) {
                            Some(bound) => bound.to_string(),
                            None => "+Inf".to_string(),
                        };
                        out.push_str(&format!(
                            "{}_bucket{{le=\"{}\"}} {}\n",
                            e.name, le, cum
                        ));
                    }
                    out.push_str(&format!("{}_sum {}\n", e.name, h.sum()));
                    out.push_str(&format!("{}_count {}\n", e.name, h.count()));
                }
            }
        }
        out
    }
}

/// Engine-global monotonic counters, tallied directly by the kernels
/// (`infer/kernels.rs`, `infer/decode.rs`, `infer/ops.rs`, `infer/pool.rs`)
/// without plumbing a registry handle through every call. Each tally is one
/// relaxed atomic add on a coarse-grained path (per GEMM call / per tile
/// unpack / per attend), never inside an inner dot-product loop. The counts
/// are process-wide totals across all model instances.
pub mod engine {
    use super::Counter;

    /// bytes of weight codes unpacked from packed bitstreams
    pub static BYTES_UNPACKED: Counter = Counter::new();
    /// register-blocked weight-tile executions (tile × token-block passes)
    pub static TILES_EXECUTED: Counter = Counter::new();
    /// planned-plan bytes streamed through the GEMM micro-kernels
    pub static PLAN_BYTES_STREAMED: Counter = Counter::new();
    /// jobs executed by the persistent worker pool (shards, all callers)
    pub static POOL_JOBS: Counter = Counter::new();
    /// activation rows quantized to u8 codes
    pub static ACT_ROWS_QUANTIZED: Counter = Counter::new();
    /// tokens appended to quantized KV caches (per layer track pair)
    pub static KV_TOKENS_APPENDED: Counter = Counter::new();
    /// cached KV rows dequantized + attended during incremental decode
    pub static KV_ROWS_ATTENDED: Counter = Counter::new();
    /// tokens embedded (all forward entry points)
    pub static TOKENS_EMBEDDED: Counter = Counter::new();

    pub static ALL: &[(&str, &str, &Counter)] = &[
        ("lrq_engine_bytes_unpacked_total",
         "bytes of weight codes unpacked from packed bitstreams",
         &BYTES_UNPACKED),
        ("lrq_engine_tiles_executed_total",
         "register-blocked weight tile executions",
         &TILES_EXECUTED),
        ("lrq_engine_plan_bytes_streamed_total",
         "planned tile bytes streamed through GEMM micro-kernels",
         &PLAN_BYTES_STREAMED),
        ("lrq_engine_pool_jobs_total",
         "jobs executed by the persistent worker pool",
         &POOL_JOBS),
        ("lrq_engine_act_rows_quantized_total",
         "activation rows quantized to u8 codes",
         &ACT_ROWS_QUANTIZED),
        ("lrq_engine_kv_tokens_appended_total",
         "tokens appended to quantized KV caches",
         &KV_TOKENS_APPENDED),
        ("lrq_engine_kv_rows_attended_total",
         "cached KV rows dequantized and attended during decode",
         &KV_ROWS_ATTENDED),
        ("lrq_engine_tokens_embedded_total",
         "tokens embedded across all forward entry points",
         &TOKENS_EMBEDDED),
    ];

    /// Prometheus text lines for the engine-global counters.
    pub fn render() -> String {
        let mut out = String::new();
        for (name, help, c) in ALL {
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", c.get()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("lrq_test_total", "a counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same name returns the same instrument
        let c2 = r.counter("lrq_test_total", "a counter");
        c2.inc();
        assert_eq!(c.get(), 6);
        let g = r.gauge("lrq_test_depth", "a gauge");
        g.set(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
        let txt = r.render();
        assert!(txt.contains("lrq_test_total 6"), "{txt}");
        assert!(txt.contains("lrq_test_depth 2"), "{txt}");
        assert!(txt.contains("# TYPE lrq_test_total counter"), "{txt}");
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::new(&[10, 100, 1000]);
        assert_eq!(h.quantile(0.5), 0); // empty
        for v in [1u64, 5, 50, 200, 2000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 2256);
        // ranks: 2 in <=10, 1 in <=100, 1 in <=1000, 1 overflow
        assert_eq!(h.quantile(0.2), 10);
        assert_eq!(h.quantile(0.5), 100);
        assert_eq!(h.quantile(0.8), 1000);
        // overflow bucket reports the last finite bound
        assert_eq!(h.quantile(1.0), 1000);
    }

    #[test]
    fn histogram_renders_cumulative_prometheus_buckets() {
        let r = Registry::new();
        let h = r.histogram("lrq_test_lat_us", "latency", &[10, 100]);
        h.record(5);
        h.record(50);
        h.record(500);
        let txt = r.render();
        assert!(txt.contains("lrq_test_lat_us_bucket{le=\"10\"} 1"), "{txt}");
        assert!(txt.contains("lrq_test_lat_us_bucket{le=\"100\"} 2"), "{txt}");
        assert!(txt.contains("lrq_test_lat_us_bucket{le=\"+Inf\"} 3"),
                "{txt}");
        assert!(txt.contains("lrq_test_lat_us_sum 555"), "{txt}");
        assert!(txt.contains("lrq_test_lat_us_count 3"), "{txt}");
    }

    /// TSan-facing hammer: 8 threads pound one counter, one gauge, and one
    /// histogram through their `Arc` handles while a 9th keeps rendering
    /// snapshots. Totals must be exact — lost updates or torn reads under
    /// contention are precisely what this lane exists to catch.
    #[test]
    fn concurrent_hammer_keeps_exact_totals() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        const THREADS: usize = 8;
        const OPS: u64 = 2_000;

        let r = Arc::new(Registry::new());
        let c = r.counter("lrq_hammer_total", "hammered counter");
        let g = r.gauge("lrq_hammer_depth", "hammered gauge");
        let h = r.histogram("lrq_hammer_lat_us", "hammered hist",
                            &[10, 100, 1000]);

        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let (r, stop) = (r.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut snaps = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    // concurrent renders must never tear or panic
                    let txt = r.render();
                    assert!(txt.contains("lrq_hammer_total"), "{txt}");
                    snaps += 1;
                }
                snaps
            })
        };

        let workers: Vec<_> = (0..THREADS)
            .map(|_| {
                let (c, g, h) = (c.clone(), g.clone(), h.clone());
                std::thread::spawn(move || {
                    for i in 0..OPS {
                        c.inc();
                        g.add(1);
                        h.record(i % 2_000);
                        g.add(-1);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().expect("hammer worker panicked");
        }
        stop.store(true, Ordering::SeqCst);
        assert!(reader.join().expect("render reader panicked") > 0);

        let n = THREADS as u64 * OPS;
        assert_eq!(c.get(), n);
        assert_eq!(g.get(), 0, "every add(1) was matched by add(-1)");
        assert_eq!(h.count(), n);
        // each thread records 0..OPS once: sum = THREADS * OPS*(OPS-1)/2
        assert_eq!(h.sum(), THREADS as u64 * (OPS * (OPS - 1) / 2));
        let txt = r.render();
        assert!(txt.contains(&format!("lrq_hammer_total {n}")), "{txt}");
        assert!(txt.contains(&format!("lrq_hammer_lat_us_count {n}")),
                "{txt}");
    }

    #[test]
    fn engine_counters_render_and_accumulate() {
        let before = engine::TILES_EXECUTED.get();
        engine::TILES_EXECUTED.add(7);
        assert!(engine::TILES_EXECUTED.get() >= before + 7);
        let txt = engine::render();
        assert!(txt.contains("lrq_engine_tiles_executed_total"), "{txt}");
        assert!(txt.contains("lrq_engine_bytes_unpacked_total"), "{txt}");
    }
}
