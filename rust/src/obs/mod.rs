//! Observability: telemetry registry, structured tracing, per-layer
//! profiling, and exporters for the native serving stack (DESIGN.md §9).
//!
//! Zero external dependencies; everything here is `std` + atomics. The
//! subsystem has four parts:
//!
//! * [`registry`] — [`Counter`] / [`Gauge`] / [`Histogram`] primitives
//!   (lock-free relaxed atomics on the hot path) plus a named [`Registry`]
//!   that renders a Prometheus-style text snapshot. Engine-global monotonic
//!   counters (bytes unpacked, tiles executed, KV traffic) live in
//!   [`registry::engine`] as statics so kernels can tally without plumbing
//!   a handle through every call.
//! * [`trace`] — structured spans with per-request trace IDs, emitted as a
//!   `chrome://tracing`-compatible JSON array (`ph:"X"` complete events,
//!   `ph:"b"/"e"` async request envelopes) behind a runtime flag. Recording
//!   is thread-local (one uncontended mutex per thread) with periodic
//!   aggregation into the trace file; when disabled every probe is a single
//!   relaxed atomic load.
//! * [`profile`] — [`Profiler`]: per-layer × per-kernel-kind time/call/
//!   item/byte accumulators (GEMM vs activation-quant vs norm vs attention
//!   vs KV-cache ...), owned by each [`crate::infer::NativeModel`] and
//!   aggregated into a [`ProfileReport`] (`lrq stats`, `--profile`).
//! * [`export`] — the Prometheus text snapshot combinator and an optional
//!   `std::net`-only HTTP exporter for scraping a live server.
//! * [`events`] — [`EventLog`]: bounded per-request lifecycle event log for
//!   the serving path (enqueue → admit/batch-join → exec → first-token →
//!   respond/reject/disconnect), exportable as JSONL and aggregated into
//!   queue-time / exec-time / TTFT histograms in the registry. Powers the
//!   soak harness's SLO evaluator ([`crate::loadgen`], DESIGN.md §10).
//!
//! The shard level of the span taxonomy (request → batch → shard → layer →
//! kernel) costs one probe per worker-pool job, so it is compiled in only
//! under the `obs-trace` cargo feature; everything else is runtime-flagged.

pub mod events;
pub mod export;
pub mod profile;
pub mod registry;
pub mod trace;

pub use events::{EventAgg, EventKind, EventLog, ReqKind, RequestSummary};
pub use export::HttpExporter;
pub use profile::{KernelKind, ProfileReport, Profiler, MODEL_SLOT};
pub use registry::{Counter, Gauge, Histogram, Registry};
