//! Per-layer / per-kernel-kind profiling: where does forward time actually
//! go — GEMM vs dequant epilogue vs norm/softmax vs KV-cache traffic?
//!
//! Each [`crate::infer::NativeModel`] owns one [`Profiler`] (shared by
//! clones through the execution state), sized to its layer count plus one
//! extra slot for model-level work (embedding, head, sampling). Hooks in
//! the forward path call [`Profiler::t0`] / [`Profiler::rec`] around each
//! kernel region; when profiling is disabled `t0` is a single relaxed load
//! and `rec` returns on its first branch, so the steady-state overhead is
//! a few nanoseconds per region. Accumulators are relaxed atomics — safe
//! to read live from another thread, exact once the engine is quiesced.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::time::{Duration, Instant};

/// Kernel region taxonomy. `items`/`bytes` units per kind are noted inline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// integer/FP GEMM incl. dequant epilogue; items = tile×block passes,
    /// bytes = plan bytes streamed
    Gemm,
    /// activation quantization to u8 codes; items = rows
    ActQuant,
    /// RMSNorm; items = rows
    Norm,
    /// rotary embedding; items = rows
    Rope,
    /// attention scores+mix (incl. cached-KV dequant reads); items = query
    /// rows, bytes = KV rows read
    Attn,
    /// KV-cache append (quantize + store); items = tokens
    KvAppend,
    /// elementwise glue: residual adds, SiLU-gate; items = rows
    Eltwise,
    /// token embedding gather; items = tokens
    Embed,
    /// LM head logits; items = rows
    Head,
    /// top-k sampling; items = tokens
    Sample,
}

impl KernelKind {
    pub const COUNT: usize = 10;

    pub const ALL: [KernelKind; KernelKind::COUNT] = [
        KernelKind::Gemm,
        KernelKind::ActQuant,
        KernelKind::Norm,
        KernelKind::Rope,
        KernelKind::Attn,
        KernelKind::KvAppend,
        KernelKind::Eltwise,
        KernelKind::Embed,
        KernelKind::Head,
        KernelKind::Sample,
    ];

    fn idx(self) -> usize {
        match self {
            KernelKind::Gemm => 0,
            KernelKind::ActQuant => 1,
            KernelKind::Norm => 2,
            KernelKind::Rope => 3,
            KernelKind::Attn => 4,
            KernelKind::KvAppend => 5,
            KernelKind::Eltwise => 6,
            KernelKind::Embed => 7,
            KernelKind::Head => 8,
            KernelKind::Sample => 9,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            KernelKind::Gemm => "gemm",
            KernelKind::ActQuant => "actq",
            KernelKind::Norm => "norm",
            KernelKind::Rope => "rope",
            KernelKind::Attn => "attn",
            KernelKind::KvAppend => "kvapp",
            KernelKind::Eltwise => "eltw",
            KernelKind::Embed => "embed",
            KernelKind::Head => "head",
            KernelKind::Sample => "sample",
        }
    }
}

/// Layer index that attributes work to the model-level slot (embedding,
/// head, sampling) instead of a transformer layer.
pub const MODEL_SLOT: usize = usize::MAX;

#[derive(Debug, Default)]
struct Cell {
    ns: AtomicU64,
    calls: AtomicU64,
    items: AtomicU64,
    bytes: AtomicU64,
}

#[derive(Debug, Default)]
struct Slot {
    kinds: [Cell; KernelKind::COUNT],
    /// decode tokens this layer has stepped (token-attribution accounting)
    step_tokens: AtomicU64,
}

/// Per-layer × per-kind accumulators; see module docs.
#[derive(Debug)]
pub struct Profiler {
    enabled: AtomicBool,
    layers: usize,
    /// `layers + 1` slots; the last is the model-level slot
    slots: Vec<Slot>,
}

impl Profiler {
    pub fn new(layers: usize) -> Profiler {
        let slots = (0..layers + 1).map(|_| Slot::default()).collect();
        Profiler { enabled: AtomicBool::new(false), layers, slots }
    }

    /// Placeholder for execution states not yet bound to a model.
    pub fn disabled() -> Profiler {
        Profiler::new(0)
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Relaxed)
    }

    fn slot(&self, layer: usize) -> &Slot {
        &self.slots[layer.min(self.layers)]
    }

    /// Region start: `Some(now)` when profiling, else `None`. One relaxed
    /// load when disabled.
    #[inline]
    pub fn t0(&self) -> Option<Instant> {
        if self.is_enabled() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a region opened by [`Profiler::t0`] (no-op on `None`),
    /// attributing elapsed time plus `items`/`bytes` to `(layer, kind)`.
    #[inline]
    pub fn rec(&self, layer: usize, kind: KernelKind, t0: Option<Instant>,
               items: u64, bytes: u64) {
        let Some(t0) = t0 else { return };
        let ns = t0.elapsed().as_nanos() as u64;
        let cell = &self.slot(layer).kinds[kind.idx()];
        cell.ns.fetch_add(ns, Relaxed);
        cell.calls.fetch_add(1, Relaxed);
        cell.items.fetch_add(items, Relaxed);
        cell.bytes.fetch_add(bytes, Relaxed);
    }

    /// Attribute `n` decode-step tokens to `layer` (token accounting:
    /// after a generate run, each layer's total equals the decode tokens
    /// produced).
    #[inline]
    pub fn add_step_tokens(&self, layer: usize, n: u64) {
        if self.is_enabled() {
            self.slot(layer).step_tokens.fetch_add(n, Relaxed);
        }
    }

    pub fn step_tokens(&self, layer: usize) -> u64 {
        self.slot(layer).step_tokens.load(Relaxed)
    }

    /// Total profiled time across every slot and kind.
    pub fn total(&self) -> Duration {
        let ns: u64 = self
            .slots
            .iter()
            .flat_map(|s| s.kinds.iter())
            .map(|c| c.ns.load(Relaxed))
            .sum();
        Duration::from_nanos(ns)
    }

    pub fn reset(&self) {
        for s in &self.slots {
            for c in &s.kinds {
                c.ns.store(0, Relaxed);
                c.calls.store(0, Relaxed);
                c.items.store(0, Relaxed);
                c.bytes.store(0, Relaxed);
            }
            s.step_tokens.store(0, Relaxed);
        }
    }

    /// Snapshot the accumulators into an owned report.
    pub fn report(&self) -> ProfileReport {
        let rows = self
            .slots
            .iter()
            .map(|s| LayerProfile {
                kinds: KernelKind::ALL
                    .iter()
                    .map(|&k| {
                        let c = &s.kinds[k.idx()];
                        KindStat {
                            kind: k,
                            ns: c.ns.load(Relaxed),
                            calls: c.calls.load(Relaxed),
                            items: c.items.load(Relaxed),
                            bytes: c.bytes.load(Relaxed),
                        }
                    })
                    .collect(),
                step_tokens: s.step_tokens.load(Relaxed),
            })
            .collect();
        ProfileReport { layers: self.layers, rows }
    }
}

/// One `(kind)` accumulator snapshot within a layer.
#[derive(Clone, Debug)]
pub struct KindStat {
    pub kind: KernelKind,
    pub ns: u64,
    pub calls: u64,
    pub items: u64,
    pub bytes: u64,
}

/// One layer's (or the model slot's) profile.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub kinds: Vec<KindStat>,
    pub step_tokens: u64,
}

impl LayerProfile {
    pub fn total_ns(&self) -> u64 {
        self.kinds.iter().map(|k| k.ns).sum()
    }
}

/// Owned snapshot of a [`Profiler`]; renders the `lrq stats` / `--profile`
/// table.
#[derive(Clone, Debug)]
pub struct ProfileReport {
    pub layers: usize,
    /// `layers + 1` rows; the last is the model-level slot
    pub rows: Vec<LayerProfile>,
}

impl ProfileReport {
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.rows.iter().map(|r| r.total_ns()).sum())
    }

    pub fn kind_ns(&self, kind: KernelKind) -> u64 {
        self.rows
            .iter()
            .flat_map(|r| r.kinds.iter())
            .filter(|k| k.kind == kind)
            .map(|k| k.ns)
            .sum()
    }

    fn kind_items(&self, kind: KernelKind) -> u64 {
        self.rows
            .iter()
            .flat_map(|r| r.kinds.iter())
            .filter(|k| k.kind == kind)
            .map(|k| k.items)
            .sum()
    }

    fn kind_bytes(&self, kind: KernelKind) -> u64 {
        self.rows
            .iter()
            .flat_map(|r| r.kinds.iter())
            .filter(|k| k.kind == kind)
            .map(|k| k.bytes)
            .sum()
    }

    /// Fraction of `wall` covered by profiled regions (sanity: the
    /// breakdown should explain most of the measured wall time).
    pub fn coverage(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.total().as_secs_f64() / wall.as_secs_f64()
    }

    /// Per-layer × per-kind time table (milliseconds), with a TOTAL row,
    /// a share line per kind, and GEMM traffic totals.
    pub fn render(&self) -> String {
        let ms = |ns: u64| ns as f64 * 1e-6;
        let mut out = String::new();
        out.push_str("layer ");
        for k in KernelKind::ALL {
            out.push_str(&format!("{:>9}", k.label()));
        }
        out.push_str(&format!("{:>10}  {:>7}\n", "total_ms", "steptok"));
        for (i, row) in self.rows.iter().enumerate() {
            let label = if i == self.layers {
                "model".to_string()
            } else {
                format!("L{i:02}")
            };
            out.push_str(&format!("{label:<6}"));
            for k in &row.kinds {
                out.push_str(&format!("{:>9.2}", ms(k.ns)));
            }
            out.push_str(&format!("{:>10.2}  {:>7}\n", ms(row.total_ns()),
                                  row.step_tokens));
        }
        let total_ns: u64 = self.rows.iter().map(|r| r.total_ns()).sum();
        out.push_str(&format!("{:<6}", "TOTAL"));
        for k in KernelKind::ALL {
            out.push_str(&format!("{:>9.2}", ms(self.kind_ns(k))));
        }
        out.push_str(&format!("{:>10.2}\n", ms(total_ns)));
        if total_ns > 0 {
            out.push_str("share ");
            for k in KernelKind::ALL {
                out.push_str(&format!(
                    "{:>8.1}%",
                    self.kind_ns(k) as f64 / total_ns as f64 * 100.0
                ));
            }
            out.push('\n');
        }
        out.push_str(&format!(
            "gemm traffic: {} tile-passes, {:.1} MiB plan bytes streamed\n",
            self.kind_items(KernelKind::Gemm),
            self.kind_bytes(KernelKind::Gemm) as f64 / (1024.0 * 1024.0)
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profiler_records_nothing() {
        let p = Profiler::new(2);
        assert!(!p.is_enabled());
        assert!(p.t0().is_none());
        p.rec(0, KernelKind::Gemm, p.t0(), 10, 10);
        p.add_step_tokens(0, 5);
        assert_eq!(p.total(), Duration::ZERO);
        assert_eq!(p.step_tokens(0), 0);
    }

    #[test]
    fn records_attribute_to_layer_and_kind() {
        let p = Profiler::new(2);
        p.set_enabled(true);
        let t = p.t0();
        assert!(t.is_some());
        std::thread::sleep(Duration::from_millis(1));
        p.rec(1, KernelKind::Gemm, t, 8, 64);
        p.rec(1, KernelKind::Norm, p.t0(), 4, 0);
        // out-of-range layers land in the model slot instead of panicking
        p.rec(MODEL_SLOT, KernelKind::Head, p.t0(), 1, 0);
        p.add_step_tokens(0, 3);
        p.add_step_tokens(0, 2);
        assert_eq!(p.step_tokens(0), 5);
        let rep = p.report();
        assert_eq!(rep.rows.len(), 3);
        let gemm = &rep.rows[1].kinds[0];
        assert_eq!(gemm.kind, KernelKind::Gemm);
        assert_eq!(gemm.calls, 1);
        assert_eq!(gemm.items, 8);
        assert_eq!(gemm.bytes, 64);
        assert!(gemm.ns >= 1_000_000, "gemm ns {}", gemm.ns);
        assert_eq!(rep.rows[2].kinds[8].calls, 1); // head in model slot
        assert!(rep.total() >= Duration::from_millis(1));
        assert!(rep.kind_ns(KernelKind::Gemm) >= 1_000_000);
        let txt = rep.render();
        assert!(txt.contains("L01"), "{txt}");
        assert!(txt.contains("model"), "{txt}");
        assert!(txt.contains("TOTAL"), "{txt}");
        assert!(txt.contains("gemm traffic"), "{txt}");
        p.reset();
        assert_eq!(p.total(), Duration::ZERO);
        assert_eq!(p.step_tokens(0), 0);
    }

    #[test]
    fn coverage_is_ratio_of_wall() {
        let p = Profiler::new(1);
        p.set_enabled(true);
        let t = p.t0();
        std::thread::sleep(Duration::from_millis(2));
        p.rec(0, KernelKind::Attn, t, 1, 1);
        let rep = p.report();
        let cov = rep.coverage(Duration::from_millis(4));
        assert!(cov > 0.2 && cov <= 1.5, "cov {cov}");
        assert_eq!(rep.coverage(Duration::ZERO), 0.0);
    }
}
