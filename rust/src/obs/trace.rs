//! Structured tracing: `chrome://tracing`-compatible span recording behind
//! a runtime flag.
//!
//! Format: the file is a JSON array of trace events (the Chrome Trace Event
//! format), one event per line. Spans are `ph:"X"` complete events with
//! `ts`/`dur` in microseconds since the trace epoch; requests are wrapped in
//! `ph:"b"`/`ph:"e"` async envelopes keyed by their trace ID so overlapping
//! in-flight requests render as parallel tracks. [`shutdown`] writes a final
//! instant event and the closing bracket; a trace truncated by a crash is
//! still loadable (the viewer tolerates a missing `]`).
//!
//! Recording is thread-local: each thread owns an uncontended
//! `Arc<Mutex<Vec<String>>>` buffer registered in a global list, appends
//! pre-serialized event lines to it, and drains into the shared file sink
//! every [`FLUSH_AT`] events. [`shutdown`] drains every registered buffer —
//! including those of threads that have already exited — so no completed
//! span is lost. When tracing is disabled, [`begin`] and [`enabled`] are a
//! single relaxed atomic load and every `complete*` call returns before
//! formatting anything.

use std::fs::File;
use std::io::{BufWriter, Error, ErrorKind, Result, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Thread-local buffer drain threshold (events).
const FLUSH_AT: usize = 256;

struct Sink {
    out: BufWriter<File>,
    events: u64,
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

type Buf = Arc<Mutex<Vec<String>>>;

/// Every thread's buffer, kept alive past thread exit so [`shutdown`] can
/// drain stragglers.
static BUFS: Mutex<Vec<Buf>> = Mutex::new(Vec::new());

thread_local! {
    static TL: (u64, Buf) = {
        let buf: Buf = Arc::new(Mutex::new(Vec::new()));
        BUFS.lock().unwrap().push(buf.clone());
        // Relaxed: a uniqueness tick for thread ids — no other memory is
        // published through it, the buffer itself travels via the mutex.
        (NEXT_TID.fetch_add(1, Ordering::Relaxed), buf)
    };
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn ts_us(t: Instant) -> f64 {
    t.saturating_duration_since(epoch()).as_secs_f64() * 1e6
}

/// Minimal JSON string escaping for span names (ours are plain ASCII, but a
/// stray quote must not corrupt the file).
fn escape(s: &str) -> String {
    if s.contains(['"', '\\']) {
        s.replace('\\', "\\\\").replace('"', "\\\"")
    } else {
        s.to_string()
    }
}

fn write_lines(lines: Vec<String>) {
    if lines.is_empty() {
        return;
    }
    let mut g = SINK.lock().unwrap();
    if let Some(sink) = g.as_mut() {
        for l in &lines {
            let _ = sink.out.write_all(l.as_bytes());
            let _ = sink.out.write_all(b"\n");
        }
        sink.events += lines.len() as u64;
    }
}

fn push_line(line: String) {
    TL.with(|(_, buf)| {
        let drained = {
            let mut g = buf.lock().unwrap();
            g.push(line);
            if g.len() >= FLUSH_AT {
                std::mem::take(&mut *g)
            } else {
                Vec::new()
            }
        };
        write_lines(drained);
    });
}

fn drain_all() -> Vec<String> {
    let bufs: Vec<Buf> = BUFS.lock().unwrap().clone();
    let mut all = Vec::new();
    for b in bufs {
        let mut g = b.lock().unwrap();
        all.append(&mut *g);
    }
    all
}

/// Start tracing into `path`. Errors if a trace is already active.
pub fn init(path: &Path) -> Result<()> {
    let mut g = SINK.lock().unwrap();
    if g.is_some() {
        return Err(Error::new(ErrorKind::AlreadyExists,
                              "trace already active"));
    }
    let mut out = BufWriter::new(File::create(path)?);
    out.write_all(b"[\n")?;
    *g = Some(Sink { out, events: 0 });
    drop(g);
    // discard events buffered after a previous shutdown — their timestamps
    // belong to the old trace
    drain_all();
    let _ = epoch();
    // Release: publishes the sink + epoch initialised above to any thread
    // whose relaxed probe observes the flag flip and starts emitting.
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Is tracing active? One relaxed load — the universal probe gate.
#[inline]
pub fn enabled() -> bool {
    // Relaxed: a stale read only costs one dropped/extra event; emitters
    // take the sink mutex before writing, which orders the actual data.
    ENABLED.load(Ordering::Relaxed)
}

/// Span start: `Some(now)` when tracing, `None` (and nothing else) when not.
#[inline]
pub fn begin() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

fn emit_x<F>(start: Instant, dur: Duration, lane: Option<u64>, f: F)
where
    F: FnOnce() -> (String, Option<String>),
{
    let (name, args) = f();
    let tid = lane.unwrap_or_else(|| TL.with(|(tid, _)| *tid));
    let args = args.map(|a| format!(",\"args\":{a}")).unwrap_or_default();
    push_line(format!(
        "{{\"name\":\"{}\",\"cat\":\"lrq\",\"ph\":\"X\",\"ts\":{:.3},\
         \"dur\":{:.3},\"pid\":1,\"tid\":{}{}}},",
        escape(&name),
        ts_us(start),
        dur.as_secs_f64() * 1e6,
        tid,
        args
    ));
}

/// Complete the span opened by [`begin`] (no-op on `None`). The closure
/// builds `(name, args)` and runs only when tracing is active; `args`, when
/// present, must be a JSON object literal (e.g. `{"rows":4}`).
pub fn complete<F>(t0: Option<Instant>, f: F)
where
    F: FnOnce() -> (String, Option<String>),
{
    let Some(t0) = t0 else { return };
    if !enabled() {
        return;
    }
    emit_x(t0, t0.elapsed(), None, f);
}

/// Emit a span with an externally measured start/duration (e.g. a request's
/// queue+exec window timed by the caller).
pub fn complete_at<F>(start: Instant, dur: Duration, f: F)
where
    F: FnOnce() -> (String, Option<String>),
{
    if !enabled() {
        return;
    }
    emit_x(start, dur, None, f);
}

/// Open an async envelope (`ph:"b"`) keyed by `id` — one per in-flight
/// request, so overlapping requests render as parallel tracks.
pub fn async_begin(name: &str, id: u64) {
    if !enabled() {
        return;
    }
    push_line(format!(
        "{{\"name\":\"{}\",\"cat\":\"lrq\",\"ph\":\"b\",\"id\":{},\
         \"ts\":{:.3},\"pid\":1,\"tid\":0}},",
        escape(name),
        id,
        ts_us(Instant::now())
    ));
}

/// Close the async envelope opened by [`async_begin`].
pub fn async_end(name: &str, id: u64) {
    if !enabled() {
        return;
    }
    push_line(format!(
        "{{\"name\":\"{}\",\"cat\":\"lrq\",\"ph\":\"e\",\"id\":{},\
         \"ts\":{:.3},\"pid\":1,\"tid\":0}},",
        escape(name),
        id,
        ts_us(Instant::now())
    ));
}

/// Stop tracing, drain every thread buffer, close the file. Returns the
/// number of events written; `Ok(0)` when no trace was active.
pub fn shutdown() -> Result<u64> {
    ENABLED.store(false, Ordering::SeqCst);
    let lines = drain_all();
    let mut g = SINK.lock().unwrap();
    let Some(mut sink) = g.take() else {
        return Ok(0);
    };
    for l in &lines {
        sink.out.write_all(l.as_bytes())?;
        sink.out.write_all(b"\n")?;
    }
    sink.events += lines.len() as u64;
    sink.out.write_all(
        format!(
            "{{\"name\":\"trace_end\",\"cat\":\"lrq\",\"ph\":\"i\",\
             \"ts\":{:.3},\"pid\":1,\"tid\":0,\"s\":\"g\"}}\n]\n",
            ts_us(Instant::now())
        )
        .as_bytes(),
    )?;
    sink.out.flush()?;
    Ok(sink.events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "lrq_trace_{}_{}_{}.json",
            std::process::id(),
            tag,
            NEXT_TID.fetch_add(1, Ordering::Relaxed)
        ))
    }

    #[test]
    fn disabled_probes_are_inert() {
        // may race with another test enabling tracing; begin() must still
        // be safe to drop on the floor either way
        let t = begin();
        complete(t, || ("never".to_string(), None));
        assert!(!enabled() || t.is_some());
    }

    #[test]
    fn trace_file_roundtrip() {
        let path = temp_path("roundtrip");
        init(&path).unwrap();
        assert!(enabled());
        // a second init must refuse while active
        assert!(init(&path).is_err());
        let t0 = begin();
        std::thread::sleep(Duration::from_millis(1));
        complete(t0, || {
            ("layer0".to_string(), Some("{\"rows\":4}".to_string()))
        });
        async_begin("request", 7);
        complete_at(Instant::now(), Duration::from_micros(250), || {
            ("decode_step".to_string(), None)
        });
        async_end("request", 7);
        // spans recorded on another thread must survive its exit
        std::thread::spawn(|| {
            let t = begin();
            complete(t, || ("shard".to_string(), None));
        })
        .join()
        .unwrap();
        let n = shutdown().unwrap();
        assert!(n >= 5, "events {n}");
        let txt = std::fs::read_to_string(&path).unwrap();
        assert!(txt.starts_with("[\n"), "{txt}");
        assert!(txt.trim_end().ends_with(']'), "{txt}");
        assert!(txt.contains("\"ph\":\"X\""), "{txt}");
        assert!(txt.contains("\"name\":\"layer0\""), "{txt}");
        assert!(txt.contains("\"args\":{\"rows\":4}"), "{txt}");
        assert!(txt.contains("\"ph\":\"b\""), "{txt}");
        assert!(txt.contains("\"ph\":\"e\""), "{txt}");
        assert!(txt.contains("\"name\":\"shard\""), "{txt}");
        assert!(txt.contains("trace_end"), "{txt}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn escape_handles_quotes() {
        assert_eq!(escape("plain"), "plain");
        assert_eq!(escape("a\"b"), "a\\\"b");
        assert_eq!(escape("a\\b"), "a\\\\b");
    }
}
