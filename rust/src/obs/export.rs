//! Exporters: Prometheus text snapshots and a zero-dependency HTTP
//! endpoint (`std::net` only) for scraping a live server.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
// Relaxed: the exporter's stop flag is an independent latch polled once per
// accept timeout — no other memory is published through it.
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{engine, Registry};

/// One text-exposition snapshot: every passed registry plus the
/// engine-global kernel counters ([`engine`]).
pub fn snapshot(regs: &[&Registry]) -> String {
    let mut out = String::new();
    for r in regs {
        out.push_str(&r.render());
    }
    out.push_str(&engine::render());
    out
}

/// Minimal HTTP exporter: one accept loop on a background thread, every
/// request answered with the current [`snapshot`]. Not a web server — a
/// scrape endpoint.
///
/// The listener runs non-blocking: the loop polls `accept` and sleeps
/// briefly between checks of the stop flag, so shutdown terminates the
/// thread deterministically within one poll interval. (The previous design
/// blocked in `accept` and "woke" the loop with a self-connect — racy when
/// the connect beat the flag store or loopback was unavailable, leaking a
/// blocked thread.)
pub struct HttpExporter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Stop-flag poll interval of the accept loop (and the shutdown latency
/// ceiling).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

impl HttpExporter {
    /// Bind `bind` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and serve
    /// snapshots of `regs` until [`HttpExporter::shutdown`] / drop.
    pub fn start(bind: &str, regs: Vec<Arc<Registry>>)
                 -> std::io::Result<HttpExporter> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || loop {
            if stop2.load(Relaxed) {
                return;
            }
            match listener.accept() {
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
                Ok((mut c, _peer)) => {
                    // the accepted stream reverts to blocking I/O with a
                    // read timeout; only the accept itself polls
                    let _ = c.set_nonblocking(false);
                    let _ =
                        c.set_read_timeout(Some(Duration::from_millis(250)));
                    let mut req = [0u8; 1024];
                    let _ = c.read(&mut req);
                    let refs: Vec<&Registry> =
                        regs.iter().map(|r| r.as_ref()).collect();
                    let body = snapshot(&refs);
                    let _ = write!(
                        c,
                        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; \
                         version=0.0.4\r\nContent-Length: {}\r\nConnection: \
                         close\r\n\r\n{}",
                        body.len(),
                        body
                    );
                }
            }
        });
        Ok(HttpExporter { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn stop_inner(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop.store(true, Relaxed);
            // the non-blocking loop observes the flag within ACCEPT_POLL;
            // no self-connect needed, and the join is bounded
            let _ = h.join();
        }
    }

    pub fn shutdown(mut self) {
        self.stop_inner();
    }
}

impl Drop for HttpExporter {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpStream;

    #[test]
    fn snapshot_merges_registries_and_engine_counters() {
        let r = Registry::new();
        r.counter("lrq_export_test_total", "x").add(3);
        let txt = snapshot(&[&r]);
        assert!(txt.contains("lrq_export_test_total 3"), "{txt}");
        assert!(txt.contains("lrq_engine_tiles_executed_total"), "{txt}");
    }

    #[test]
    fn http_exporter_serves_snapshot() {
        let reg = Arc::new(Registry::new());
        reg.counter("lrq_http_test_total", "x").add(9);
        // sandboxes without loopback: skip rather than fail
        let Ok(exp) = HttpExporter::start("127.0.0.1:0", vec![reg.clone()])
        else {
            eprintln!("skipping http exporter test: cannot bind loopback");
            return;
        };
        let Ok(mut c) = TcpStream::connect(exp.addr()) else {
            eprintln!("skipping http exporter test: cannot connect");
            exp.shutdown();
            return;
        };
        c.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        c.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("lrq_http_test_total 9"), "{resp}");
        exp.shutdown();
    }

    #[test]
    fn shutdown_joins_without_needing_a_connection() {
        // the old self-connect wakeup leaked the accept thread when no
        // client ever arrived; the polled loop must join on its own
        let reg = Arc::new(Registry::new());
        let Ok(exp) = HttpExporter::start("127.0.0.1:0", vec![reg]) else {
            eprintln!("skipping exporter shutdown test: cannot bind");
            return;
        };
        let t0 = std::time::Instant::now();
        exp.shutdown(); // joins; a hang here fails the test via timeout
        assert!(t0.elapsed() < Duration::from_secs(5),
                "shutdown took {:?}", t0.elapsed());
    }
}
