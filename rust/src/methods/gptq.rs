//! GPTQ (Frantar et al., 2023): layer-wise quantization with second-order
//! error compensation. Implemented from scratch as one of the weight-only
//! comparators in Tables 7-8 (the paper quotes its numbers from Huang et al.;
//! we run it for real).
//!
//! For each linear with inputs `X` at its act point: `H = 2·XᵀX/n + λI`;
//! quantize columns in order, propagating the rounding error to the not-yet-
//! quantized columns through the upper-Cholesky factor of `H⁻¹`.

use anyhow::{bail, Result};

use crate::quant::{grid_search_scales, qmax, ChannelGrid};
use crate::tensor::{cholesky, tri_inverse_lower, Tensor};

use super::{BlockContext, BlockQuantResult, LINEAR_ACT_POINT};

/// Damping fraction of the mean diagonal (GPTQ's `percdamp`).
const PERCDAMP: f64 = 0.01;

/// Upper-Cholesky factor `U` of `H⁻¹` (so `H⁻¹ = Uᵀ·U` with U upper-tri,
/// matching the GPTQ reference implementation).
fn hinv_cholesky_upper(h: &[f64], n: usize) -> Result<Vec<f64>> {
    // H = L·Lᵀ ; H⁻¹ = L⁻ᵀ·L⁻¹
    let l = cholesky(h, n)?;
    let linv = tri_inverse_lower(&l, n);
    // H⁻¹[i][j] = Σ_k L⁻¹[k][i]·L⁻¹[k][j]
    let mut hinv = vec![0.0f64; n * n];
    for k in 0..n {
        for i in 0..=k {
            let a = linv[k * n + i];
            if a == 0.0 {
                continue;
            }
            for j in 0..=k {
                hinv[i * n + j] += a * linv[k * n + j];
            }
        }
    }
    // Cholesky of H⁻¹, returned transposed (upper).
    let lh = cholesky(&hinv, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = lh[i * n + j];
        }
    }
    Ok(u)
}

/// Accumulated Hessian for one act point: `XᵀX` over all calib batches.
pub fn hessian(acts: &[&Tensor]) -> (Vec<f64>, usize) {
    let dim = acts[0].as_2d().1;
    let mut h = vec![0.0f64; dim * dim];
    let mut count = 0usize;
    for a in acts {
        let (t, d) = a.as_2d();
        assert_eq!(d, dim);
        count += t;
        for i in 0..t {
            let row = &a.data[i * d..(i + 1) * d];
            for (p, &x) in row.iter().enumerate() {
                if x == 0.0 {
                    continue;
                }
                let xd = x as f64;
                let hrow = &mut h[p * d..(p + 1) * d];
                for (hv, &y) in hrow.iter_mut().zip(row) {
                    *hv += xd * y as f64;
                }
            }
        }
    }
    (h, count)
}

/// GPTQ-quantize one weight matrix given its input Hessian.
pub fn gptq_quantize(w: &Tensor, grid: &ChannelGrid, h: &[f64], n_samples: usize)
                     -> Result<Tensor> {
    let (rows, cols) = w.rc();
    if h.len() != cols * cols {
        bail!("hessian size mismatch");
    }
    // scale + damp
    let mut hd: Vec<f64> = h.iter().map(|&v| 2.0 * v / n_samples.max(1) as f64)
        .collect();
    let mean_diag: f64 = (0..cols).map(|i| hd[i * cols + i]).sum::<f64>()
        / cols as f64;
    let damp = (PERCDAMP * mean_diag).max(1e-8);
    // dead columns (no signal) get unit curvature
    for i in 0..cols {
        if hd[i * cols + i] <= 0.0 {
            hd[i * cols + i] = 1.0;
        }
        hd[i * cols + i] += damp;
    }
    let u = hinv_cholesky_upper(&hd, cols)?;

    // work on a mutable copy of W; emit codes column by column
    let mut wm = w.clone();
    let mut codes = vec![0.0f32; rows * cols];
    for i in 0..cols {
        let dii = u[i * cols + i];
        for r in 0..rows {
            let s = grid.scale[r];
            let z = grid.zp[r];
            let x = wm.data[r * cols + i];
            let q = (x / s + z).round().clamp(0.0, grid.qmax);
            codes[r * cols + i] = q;
            let deq = (q - z) * s;
            let err = ((x - deq) as f64) / dii;
            // propagate to columns j > i
            let urow = &u[i * cols..(i + 1) * cols];
            let wrow = &mut wm.data[r * cols..(r + 1) * cols];
            for j in (i + 1)..cols {
                wrow[j] -= (err * urow[j]) as f32;
            }
        }
    }
    Ok(Tensor::new(vec![rows, cols], codes))
}

pub fn quantize_block(ctx: &BlockContext) -> Result<BlockQuantResult> {
    let acts = match ctx.acts_q {
        Some(a) if !a.is_empty() => a,
        _ => bail!("GPTQ needs captured activations (acts_q)"),
    };
    let qm = qmax(ctx.scheme.w_bits);
    // Hessian per act point (shared by its consumers)
    let mut hs: Vec<(Vec<f64>, usize)> = Vec::with_capacity(4);
    for p in 0..4 {
        let point_acts: Vec<&Tensor> = acts.iter().map(|b| &b[p]).collect();
        hs.push(hessian(&point_acts));
    }
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    for (li, w) in ctx.weights.ws.iter().enumerate() {
        let g = grid_search_scales(w, qm, 32);
        let (h, n) = &hs[LINEAR_ACT_POINT[li]];
        codes.push(gptq_quantize(w, &g, h, *n)?);
        grids.push(g);
    }
    Ok(BlockQuantResult {
        grids,
        codes,
        norm_attn: ctx.weights.norm_attn.clone(),
        norm_ffn: ctx.weights.norm_ffn.clone(),
        loss_trace: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_grid;
    use crate::rng::Rng;

    #[test]
    fn identity_hessian_equals_rtn() {
        // with H = I the compensation term never fires a correction that
        // changes the rounded value of *already optimal* RTN codes
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[6, 10], 0.1);
        let g = rtn_grid(&w, 255.0);
        let mut h = vec![0.0f64; 100];
        for i in 0..10 {
            h[i * 10 + i] = 1.0;
        }
        // n_samples=2 cancels the 2/n scaling
        let codes = gptq_quantize(&w, &g, &h, 2).unwrap();
        let rtn = crate::quant::quantize_int_codes(&w, &g, None);
        // identity H: error propagation terms u[i][j>i] = 0 -> exactly RTN
        assert_eq!(codes.data, rtn.data);
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        // with correlated inputs, GPTQ's compensated codes give lower
        // ||XWᵀ - XŴᵀ||² than plain RTN — the whole point of the method
        let mut rng = Rng::new(2);
        let n = 24usize;
        let t = 400usize;
        // correlated features: x = base + small noise
        let mut x = Tensor::zeros(&[t, n]);
        for i in 0..t {
            let b = rng.normal();
            for j in 0..n {
                x.data[i * n + j] = b + 0.3 * rng.normal();
            }
        }
        let w = Tensor::randn(&mut rng, &[8, n], 0.1);
        let g = rtn_grid(&w, 7.0); // 3-bit so errors matter
        let (h, cnt) = hessian(&[&x]);
        let codes_g = gptq_quantize(&w, &g, &h, cnt).unwrap();
        let codes_r = crate::quant::quantize_int_codes(&w, &g, None);
        let deq = |codes: &Tensor| {
            let mut d = codes.clone();
            for r in 0..8 {
                for c in 0..n {
                    d.data[r * n + c] =
                        (codes.data[r * n + c] - g.zp[r]) * g.scale[r];
                }
            }
            d
        };
        let y = x.matmul_bt(&w);
        let err_g = y.mse(&x.matmul_bt(&deq(&codes_g)));
        let err_r = y.mse(&x.matmul_bt(&deq(&codes_r)));
        assert!(err_g < err_r, "gptq {err_g} vs rtn {err_r}");
    }

    #[test]
    fn hinv_cholesky_is_factor_of_inverse() {
        let mut rng = Rng::new(3);
        let n = 8;
        let x = Tensor::randn(&mut rng, &[32, n], 1.0);
        let g = x.matmul_at(&x);
        let mut h: Vec<f64> = g.data.iter().map(|&v| v as f64).collect();
        for i in 0..n {
            h[i * n + i] += 1.0;
        }
        let u = hinv_cholesky_upper(&h, n).unwrap();
        // UᵀU must equal H⁻¹, i.e. H·(UᵀU) = I
        let mut utu = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += u[k * n + i] * u[k * n + j];
                }
                utu[i * n + j] = acc;
            }
        }
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += h[i * n + k] * utu[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((acc - want).abs() < 1e-6, "({i},{j}) {acc}");
            }
        }
    }
}
