//! Per-channel input-scaling folds — the algebra behind SmoothQuant and AWQ.
//!
//! Scaling the activation at a quant point by `1/s_j` per channel while
//! keeping the block function *exactly* identical requires compensating
//! transforms on the surrounding weights:
//!
//! | point     | activation           | divide by s folds into | multiply back |
//! |-----------|----------------------|------------------------|---------------|
//! | `attn_in` | rmsnorm(x, na)       | `na ·= 1/s`            | wq/wk/wv cols ×s |
//! | `o_in`    | attention output     | `wv` rows ·= 1/s       | `wo` cols ×s  |
//! | `ffn_in`  | rmsnorm(h, nf)       | `nf ·= 1/s`            | wg/wu cols ×s |
//! | `down_in` | silu(g)·u            | `wu` rows ·= 1/s       | `wd` cols ×s  |
//!
//! (`o_in` works because attention mixes across *positions*, not channels;
//! `down_in` works because the gated product is linear in the `up` branch.)

use anyhow::{bail, Result};

use crate::model::BlockWeights;
use crate::tensor::Tensor;

fn scale_cols(w: &mut Tensor, s: &[f32]) {
    let (rows, cols) = w.rc();
    assert_eq!(cols, s.len());
    for r in 0..rows {
        let row = w.row_mut(r);
        for (x, &sv) in row.iter_mut().zip(s) {
            *x *= sv;
        }
    }
}

fn scale_rows(w: &mut Tensor, s_inv: &[f32]) {
    let (rows, _cols) = w.rc();
    assert_eq!(rows, s_inv.len());
    for r in 0..rows {
        let sv = s_inv[r];
        for x in w.row_mut(r) {
            *x *= sv;
        }
    }
}

/// Apply per-point smoothing scales (length = point dim, all > 0) to a block.
/// `scales[p][j]` divides the activation channel j at point p.
pub fn fold_block(bw: &BlockWeights, scales: &[Vec<f32>; 4])
                  -> Result<BlockWeights> {
    let mut out = bw.clone();
    for (p, s) in scales.iter().enumerate() {
        if s.iter().any(|&v| !(v > 0.0) || !v.is_finite()) {
            bail!("fold point {p}: non-positive scale");
        }
    }
    let inv = |s: &[f32]| -> Vec<f32> { s.iter().map(|&v| 1.0 / v).collect() };

    // attn_in: na /= s ; wq/wk/wv columns ×= s
    {
        let s = &scales[0];
        let si = inv(s);
        for (x, &v) in out.norm_attn.data.iter_mut().zip(&si) {
            *x *= v;
        }
        for i in 0..3 {
            scale_cols(&mut out.ws[i], s);
        }
    }
    // o_in: wv rows /= s ; wo columns ×= s
    {
        let s = &scales[1];
        scale_rows(&mut out.ws[2], &inv(s));
        scale_cols(&mut out.ws[3], s);
    }
    // ffn_in: nf /= s ; wg/wu columns ×= s
    {
        let s = &scales[2];
        let si = inv(s);
        for (x, &v) in out.norm_ffn.data.iter_mut().zip(&si) {
            *x *= v;
        }
        scale_cols(&mut out.ws[4], s);
        scale_cols(&mut out.ws[5], s);
    }
    // down_in: wu rows /= s ; wd columns ×= s
    {
        let s = &scales[3];
        scale_rows(&mut out.ws[5], &inv(s));
        scale_cols(&mut out.ws[6], s);
    }
    Ok(out)
}

/// SmoothQuant-style scales from activation/weight channel magnitudes:
/// `s_j = amax_act_j^α / amax_w_j^(1-α)`, clamped away from 0.
pub fn smooth_scales(amax_act: &[f32], amax_w: &[f32], alpha: f32) -> Vec<f32> {
    amax_act
        .iter()
        .zip(amax_w)
        .map(|(&a, &w)| {
            let a = a.max(1e-5);
            let w = w.max(1e-5);
            (a.powf(alpha) / w.powf(1.0 - alpha)).max(1e-5)
        })
        .collect()
}

/// Per-input-channel |W| max across a set of consumer weights (columns).
pub fn weight_col_amax(consumers: &[&Tensor]) -> Vec<f32> {
    let cols = consumers[0].rc().1;
    let mut out = vec![0.0f32; cols];
    for w in consumers {
        let (rows, c) = w.rc();
        assert_eq!(c, cols);
        for r in 0..rows {
            for (o, &x) in out.iter_mut().zip(w.row(r)) {
                *o = o.max(x.abs());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{BlockWeights, ModelDim};
    use crate::rng::Rng;

    fn block(rng: &mut Rng) -> BlockWeights {
        let d = 16;
        let f = 24;
        BlockWeights {
            ws: vec![
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[d, d], 0.1),
                Tensor::randn(rng, &[f, d], 0.1),
                Tensor::randn(rng, &[f, d], 0.1),
                Tensor::randn(rng, &[d, f], 0.1),
            ],
            norm_attn: Tensor::ones(&[d]),
            norm_ffn: Tensor::ones(&[d]),
        }
    }

    fn unit_scales() -> [Vec<f32>; 4] {
        [vec![1.0; 16], vec![1.0; 16], vec![1.0; 16], vec![1.0; 24]]
    }

    #[test]
    fn identity_fold_is_noop() {
        let mut rng = Rng::new(1);
        let bw = block(&mut rng);
        let out = fold_block(&bw, &unit_scales()).unwrap();
        for i in 0..7 {
            assert!(out.ws[i].rmse(&bw.ws[i]) < 1e-7);
        }
        assert!(out.norm_attn.rmse(&bw.norm_attn) < 1e-7);
    }

    #[test]
    fn fold_unfold_roundtrip() {
        // folding by s then by 1/s must restore the block
        let mut rng = Rng::new(2);
        let bw = block(&mut rng);
        let mut scales = unit_scales();
        for s in scales.iter_mut() {
            for v in s.iter_mut() {
                *v = 0.5 + rng.next_f32();
            }
        }
        let inv: [Vec<f32>; 4] = [
            scales[0].iter().map(|v| 1.0 / v).collect(),
            scales[1].iter().map(|v| 1.0 / v).collect(),
            scales[2].iter().map(|v| 1.0 / v).collect(),
            scales[3].iter().map(|v| 1.0 / v).collect(),
        ];
        let once = fold_block(&bw, &scales).unwrap();
        let back = fold_block(&once, &inv).unwrap();
        for i in 0..7 {
            assert!(back.ws[i].rmse(&bw.ws[i]) < 1e-6, "w{i}");
        }
        assert!(back.norm_attn.rmse(&bw.norm_attn) < 1e-6);
        assert!(back.norm_ffn.rmse(&bw.norm_ffn) < 1e-6);
    }

    #[test]
    fn rejects_bad_scales() {
        let mut rng = Rng::new(3);
        let bw = block(&mut rng);
        let mut scales = unit_scales();
        scales[1][3] = 0.0;
        assert!(fold_block(&bw, &scales).is_err());
    }

    #[test]
    fn smooth_scales_interpolate() {
        let act = vec![8.0, 2.0];
        let w = vec![2.0, 2.0];
        // alpha=0 -> 1/w^1 ; alpha=1 -> act
        let s0 = smooth_scales(&act, &w, 0.0);
        assert!((s0[0] - 0.5).abs() < 1e-6);
        let s1 = smooth_scales(&act, &w, 1.0);
        assert!((s1[0] - 8.0).abs() < 1e-6);
        // alpha=0.5 geometric mean behaviour: sqrt(8)/sqrt(2) = 2
        let sh = smooth_scales(&act, &w, 0.5);
        assert!((sh[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn weight_col_amax_across_consumers() {
        let a = Tensor::new(vec![1, 2], vec![1.0, -3.0]);
        let b = Tensor::new(vec![2, 2], vec![0.5, 4.0, -2.0, 0.1]);
        assert_eq!(weight_col_amax(&[&a, &b]), vec![2.0, 4.0]);
        let _ = ModelDim {
            name: "x".into(), vocab: 1, d: 1, heads: 1, layers: 1, ff: 1,
            seq: 1, train_batch: 1, calib_batch: 1, recon_batch: 1, rank: 1,
        };
    }
}
