//! Driver for the learned methods (FlexRound / LRQ / LRQ-no-bias): owns the
//! Adam state threading through the `recon_*` AOT artifact, the minibatch
//! rotation, and the finalize step that folds learned parameters into integer
//! codes (Appendix G: inference keeps only `(s1, z, codes)`).

use anyhow::{bail, Context, Result};
use xla::Literal;

use crate::config::Method;
use crate::coordinator::engine::Engine;
use crate::model::BlockWeights;
use crate::quant::{grid_search_scales, qmax, quantize_int_codes, ChannelGrid,
                   LrqParams};
use crate::rng::Rng;
use crate::runtime::{scalar_from_lit, scalar_lit, to_lit, Exec, Runtime};
use crate::tensor::Tensor;

use super::{BlockContext, BlockQuantResult};

/// Learnable bundle layout per linear (mirrors python theta_spec).
fn theta_leaves(method: Method) -> usize {
    match method {
        Method::Lrq => 5,        // ds1 l2 u2 r2 c2
        Method::LrqNoBias => 3,  // ds1 l2 u2
        Method::FlexRound => 2,  // ds1 s2
        _ => unreachable!(),
    }
}

fn artifact_name(method: Method, cfg: &str, rank: usize) -> String {
    match method {
        Method::Lrq => format!("recon_lrq_{cfg}_r{rank}"),
        Method::LrqNoBias => format!("recon_lrq_nobias_{cfg}_r{rank}"),
        Method::FlexRound => format!("recon_fr_{cfg}"),
        _ => unreachable!(),
    }
}

/// Initial theta literals for one linear (RTN start — see recon.py).
fn init_theta(method: Method, rng: &mut Rng, cout: usize, cin: usize,
              rank: usize) -> Result<Vec<Literal>> {
    let z = |d: &[usize]| to_lit(&Tensor::zeros(d));
    Ok(match method {
        Method::Lrq => vec![
            z(&[cout])?,
            z(&[cout, rank])?,
            to_lit(&Tensor::randn(rng, &[rank, cin], 0.01))?,
            z(&[cout])?,
            z(&[cin])?,
        ],
        Method::LrqNoBias => vec![
            z(&[cout])?,
            z(&[cout, rank])?,
            to_lit(&Tensor::randn(rng, &[rank, cin], 0.01))?,
        ],
        Method::FlexRound => vec![z(&[cout])?, z(&[cout, cin])?],
        _ => unreachable!(),
    })
}

/// Split a [B,S,D] calib batch into recon_batch-sized minibatch literals.
fn minibatches(x_q: &[Tensor], y_t: &[Tensor], rb: usize)
               -> Result<Vec<(Literal, Literal)>> {
    let mut out = Vec::new();
    for (x, y) in x_q.iter().zip(y_t) {
        let b = x.dims[0];
        let mut lo = 0;
        while lo + rb <= b {
            out.push((to_lit(&x.slice_outer(lo, lo + rb))?,
                      to_lit(&y.slice_outer(lo, lo + rb))?));
            lo += rb;
        }
    }
    if out.is_empty() {
        bail!("no reconstruction minibatches (batch < recon_batch?)");
    }
    Ok(out)
}

pub struct ReconOutcome {
    pub grids: Vec<ChannelGrid>,
    pub codes: Vec<Tensor>,
    pub loss_trace: Vec<f32>,
}

/// Run `steps` of block reconstruction and finalize integer codes.
#[allow(clippy::too_many_arguments)]
pub fn run_recon(rt: &Runtime, engine: &Engine, method: Method,
                 ctx: &BlockContext, weights: &BlockWeights,
                 rank: usize) -> Result<ReconOutcome> {
    let dim = ctx.dim;
    let exec: std::rc::Rc<Exec> =
        rt.exec(&artifact_name(method, &dim.name, rank))?;
    let qm = qmax(ctx.scheme.w_bits);
    let mut rng = Rng::new(ctx.recon.seed ^ (ctx.block_index as u64) << 32);

    // frozen inputs: ws, norms, s1_init (grid-searched), z
    let grids0: Vec<ChannelGrid> = weights
        .ws
        .iter()
        .map(|w| grid_search_scales(w, qm, 40))
        .collect();
    let mut frozen: Vec<Literal> = Vec::new();
    for w in &weights.ws {
        frozen.push(to_lit(w)?);
    }
    frozen.push(to_lit(&weights.norm_attn)?);
    frozen.push(to_lit(&weights.norm_ffn)?);
    for g in &grids0 {
        frozen.push(to_lit(&Tensor::new(vec![g.rows()], g.scale.clone()))?);
    }
    for g in &grids0 {
        frozen.push(to_lit(&Tensor::new(vec![g.rows()], g.zp.clone()))?);
    }

    // learnable state: theta, m, v (literals threaded through the artifact)
    let nleaves = theta_leaves(method);
    let mut theta: Vec<Literal> = Vec::new();
    for (w, _g) in weights.ws.iter().zip(&grids0) {
        let (co, ci) = w.rc();
        theta.extend(init_theta(method, &mut rng, co, ci, rank)?);
    }
    let zeros_like = |lits: &[Literal]| -> Result<Vec<Literal>> {
        lits.iter()
            .map(|l| {
                let n = l.element_count();
                // shape doesn't matter to XLA beyond element count + layout;
                // reuse the literal's own shape via manifest-free path:
                let shape = l.array_shape()?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                let _ = n;
                to_lit(&Tensor::zeros(&dims))
            })
            .collect()
    };
    let mut m = zeros_like(&theta)?;
    let mut v = zeros_like(&theta)?;

    // act-quant tail (static scales from calibrated stats + scheme flags)
    let tail = engine.act_tail(ctx.stats, &ctx.scheme, true)?;

    let batches = minibatches(ctx.x_q, ctx.y_t, dim.recon_batch)?;
    let mut loss_trace = Vec::with_capacity(ctx.recon.steps);
    for step in 0..ctx.recon.steps {
        let (x_lit, y_lit) = &batches[step % batches.len()];
        let t_lit = scalar_lit(step as f32);
        // warmup + cosine decay (same schedule as pre-training) keeps the
        // higher paper-style peak learning rates stable
        let lr = scalar_lit(crate::coordinator::trainer::lr_at(
            step, ctx.recon.steps, ctx.recon.lr));
        let mut inputs: Vec<&Literal> = Vec::with_capacity(
            2 + frozen.len() + 3 * theta.len() + 2 + tail.len());
        inputs.push(x_lit);
        inputs.push(y_lit);
        inputs.extend(frozen.iter());
        inputs.extend(theta.iter());
        inputs.extend(m.iter());
        inputs.extend(v.iter());
        inputs.push(&t_lit);
        inputs.push(&lr);
        inputs.extend(tail.iter());
        let mut outs = exec.run(&inputs)
            .with_context(|| format!("recon step {step}"))?;
        let nt = theta.len();
        if outs.len() != 1 + 3 * nt {
            bail!("recon output count {} != {}", outs.len(), 1 + 3 * nt);
        }
        loss_trace.push(scalar_from_lit(&outs[0])?);
        // rotate state: outputs replace theta/m/v
        let rest = outs.split_off(1);
        let mut it = rest.into_iter();
        theta = (&mut it).take(nt).collect();
        m = (&mut it).take(nt).collect();
        v = (&mut it).take(nt).collect();
    }

    // finalize: read back theta, fold into integer codes (Appendix G)
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    let spec = &exec.spec.outputs; // theta dims start at output index 1
    let mut li = 0usize;
    for (wi, w) in weights.ws.iter().enumerate() {
        let (co, ci) = w.rc();
        let read = |k: usize, dims: &[usize]| -> Result<Tensor> {
            crate::runtime::from_lit(&theta[k], dims)
        };
        let _ = &spec;
        let (ds1, s_exp) = match method {
            Method::Lrq => {
                let ds1 = read(li, &[co])?;
                let p = LrqParams {
                    ds1: ds1.data.clone(),
                    l2: read(li + 1, &[co, rank])?,
                    u2: read(li + 2, &[rank, ci])?,
                    r2: read(li + 3, &[co])?.data,
                    c2: read(li + 4, &[ci])?.data,
                };
                (ds1, p.exponent())
            }
            Method::LrqNoBias => {
                let ds1 = read(li, &[co])?;
                let p = LrqParams {
                    ds1: ds1.data.clone(),
                    l2: read(li + 1, &[co, rank])?,
                    u2: read(li + 2, &[rank, ci])?,
                    r2: vec![0.0; co],
                    c2: vec![0.0; ci],
                };
                (ds1, p.exponent())
            }
            Method::FlexRound => {
                (read(li, &[co])?, read(li + 1, &[co, ci])?)
            }
            _ => unreachable!(),
        };
        li += nleaves;
        let grid = ChannelGrid {
            scale: grids0[wi]
                .scale
                .iter()
                .zip(&ds1.data)
                .map(|(&s, &d)| s * d.exp())
                .collect(),
            zp: grids0[wi].zp.clone(),
            qmax: qm,
        };
        codes.push(quantize_int_codes(w, &grid, Some(&s_exp)));
        grids.push(grid);
    }
    Ok(ReconOutcome { grids, codes, loss_trace })
}

/// Method entry point used by the dispatcher.
pub fn quantize_block(rt: &Runtime, engine: &Engine, method: Method,
                      ctx: &BlockContext,
                      smoothed: Option<&BlockWeights>)
                      -> Result<BlockQuantResult> {
    let weights = smoothed.unwrap_or(ctx.weights);
    let rank = match method {
        Method::FlexRound => 0,
        _ => {
            let r = if ctx.recon.rank > 0 { ctx.recon.rank }
                    else { ctx.dim.rank };
            r
        }
    };
    let out = run_recon(rt, engine, method, ctx, weights, rank)?;
    Ok(BlockQuantResult {
        grids: out.grids,
        codes: out.codes,
        norm_attn: weights.norm_attn.clone(),
        norm_ffn: weights.norm_ffn.clone(),
        loss_trace: out.loss_trace,
    })
}
