//! Quantization method drivers. Learning-free methods (RTN, SmoothQuant,
//! GPTQ, AWQ) run natively on the [`crate::tensor`] substrate; learned
//! methods (FlexRound, LRQ and ablations) drive the AOT `recon_*` artifacts
//! through [`recon_driver`].
//!
//! Every driver consumes a [`BlockContext`] and produces a
//! [`BlockQuantResult`]: per-linear grids + integer codes (+ possibly
//! transformed norm weights, for the smoothing-based methods).

pub mod awq;
pub mod fold;
pub mod gptq;
pub mod recon_driver;
pub mod rtn;
pub mod smoothquant;

use anyhow::Result;

use crate::config::{Method, ReconConfig, Scheme};
use crate::coordinator::engine::{BlockStats, Engine};
use crate::model::{BlockWeights, ModelDim};
use crate::quant::{ChannelGrid, PackedMatrix};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Everything a method needs to quantize one Transformer block.
pub struct BlockContext<'a> {
    pub dim: &'a ModelDim,
    pub weights: &'a BlockWeights,
    /// quant-stream block inputs (x̃), one [B,S,D] tensor per calib batch
    pub x_q: &'a [Tensor],
    /// FP block outputs on the FP stream (the reconstruction target)
    pub y_t: &'a [Tensor],
    /// activations at the 4 quant points, computed on the quant stream
    /// (only present when the method asked for them)
    pub acts_q: Option<&'a [[Tensor; 4]]>,
    /// calibrated FP activation stats (static scales)
    pub stats: &'a BlockStats,
    pub scheme: Scheme,
    pub recon: ReconConfig,
    pub block_index: usize,
}

/// Which act point feeds each of the 7 linears (canonical order).
pub const LINEAR_ACT_POINT: [usize; 7] = [0, 0, 0, 1, 2, 2, 3];

/// Result of quantizing one block.
pub struct BlockQuantResult {
    /// per-linear (grid, integer codes) in canonical order
    pub grids: Vec<ChannelGrid>,
    pub codes: Vec<Tensor>,
    /// norm weights (transformed for smoothing-based methods)
    pub norm_attn: Tensor,
    pub norm_ffn: Tensor,
    /// reconstruction loss trace (empty for learning-free methods)
    pub loss_trace: Vec<f32>,
}

impl BlockQuantResult {
    /// Dequantized Ŵ per linear.
    pub fn whats(&self) -> Vec<Tensor> {
        self.grids
            .iter()
            .zip(&self.codes)
            .map(|(g, c)| {
                let (rows, cols) = c.rc();
                let mut data = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    let s = g.scale[r];
                    let z = g.zp[r];
                    for cc in 0..cols {
                        data.push((c.data[r * cols + cc] - z) * s);
                    }
                }
                Tensor::new(vec![rows, cols], data)
            })
            .collect()
    }

    /// Pack into the storage format.
    pub fn packed(&self, bits: u32) -> Result<Vec<PackedMatrix>> {
        self.grids
            .iter()
            .zip(&self.codes)
            .map(|(g, c)| PackedMatrix::from_codes(c, &g.scale, &g.zp, bits))
            .collect()
    }
}

/// Does this method need per-point activations (`acts_q`) captured?
pub fn needs_acts(method: Method) -> bool {
    matches!(method, Method::Gptq | Method::Awq) || method.uses_smooth()
}

#[cfg(test)]
pub(crate) mod testsupport {
    use crate::model::{BlockWeights, ModelDim};
    use crate::rng::Rng;
    use crate::tensor::Tensor;

    pub fn test_dim() -> ModelDim {
        ModelDim {
            name: "t".into(), vocab: 64, d: 16, heads: 2, layers: 2, ff: 24,
            seq: 8, train_batch: 2, calib_batch: 2, recon_batch: 2, rank: 4,
        }
    }

    pub fn test_block(rng: &mut Rng, dim: &ModelDim) -> BlockWeights {
        let shapes = dim.block_weight_shapes();
        BlockWeights {
            ws: shapes
                .iter()
                .map(|(co, ci)| Tensor::randn(rng, &[*co, *ci], 0.1))
                .collect(),
            norm_attn: Tensor::ones(&[dim.d]),
            norm_ffn: Tensor::ones(&[dim.d]),
        }
    }
}

/// Dispatch a method over one block.
#[allow(clippy::too_many_arguments)]
pub fn quantize_block(rt: &Runtime, engine: &Engine, method: Method,
                      ctx: &BlockContext) -> Result<BlockQuantResult> {
    match method {
        Method::Fp16 => unreachable!("FP16 is not a quantization method"),
        Method::Rtn => rtn::quantize_block(ctx),
        Method::SmoothQuant => smoothquant::quantize_block(ctx),
        Method::Gptq => gptq::quantize_block(ctx),
        Method::Awq => awq::quantize_block(ctx),
        Method::FlexRound | Method::Lrq | Method::LrqNoBias =>
            recon_driver::quantize_block(rt, engine, method, ctx, None),
        Method::SqFlexRound | Method::SqLrq => {
            // Appendix L: SmoothQuant preprocessing, then reconstruction
            // starts from the smoothed weights.
            let (smoothed, _scales) = smoothquant::smooth_block(ctx)?;
            let inner = if method == Method::SqLrq {
                Method::Lrq
            } else {
                Method::FlexRound
            };
            recon_driver::quantize_block(rt, engine, inner, ctx,
                                         Some(&smoothed))
        }
    }
}
