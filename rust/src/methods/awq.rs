//! AWQ (Lin et al., 2023): activation-aware weight quantization. Per act
//! point, grid-search the smoothing exponent α so that scaling salient
//! channels (large activation magnitude) up before RTN minimizes the layer
//! output error — then fold the scales exactly like SmoothQuant.
//!
//! One of the weight-only comparators of Table 8 (the paper quotes numbers
//! from Huang et al.; we run the search for real).

use anyhow::{bail, Result};

use crate::quant::{qmax, quantize_int_codes, rtn_grid};
use crate::tensor::Tensor;

use super::fold::{fold_block, smooth_scales, weight_col_amax};
use super::{BlockContext, BlockQuantResult, LINEAR_ACT_POINT};

/// α candidates (AWQ reference sweeps 20 points in [0, 1]).
const ALPHA_GRID: usize = 11;

/// Sampled rows of X used for the output-error objective.
const SAMPLE_ROWS: usize = 128;

fn sample_rows(acts: &[&Tensor], max_rows: usize) -> Tensor {
    let d = acts[0].as_2d().1;
    let mut data = Vec::new();
    let mut rows = 0usize;
    'outer: for a in acts {
        let (t, _) = a.as_2d();
        for i in 0..t {
            data.extend_from_slice(&a.data[i * d..(i + 1) * d]);
            rows += 1;
            if rows >= max_rows {
                break 'outer;
            }
        }
    }
    Tensor::new(vec![rows, d], data)
}

/// Quantization output error `||XWᵀ - XŴᵀ||²` for consumers of one point
/// under per-channel scales `s` (W·s quantized, X/s compensated — evaluated
/// in the *scaled* space which is what runs at inference).
fn point_error(x: &Tensor, consumers: &[&Tensor], s: &[f32], qm: f32) -> f64 {
    let (t, d) = x.as_2d();
    // x_scaled = x / s
    let mut xs = x.clone();
    for i in 0..t {
        let row = &mut xs.data[i * d..(i + 1) * d];
        for (v, &sv) in row.iter_mut().zip(s) {
            *v /= sv;
        }
    }
    let mut err = 0.0f64;
    for w in consumers {
        // w_scaled = w · s (columns)
        let (rows, cols) = w.rc();
        let mut wsc = (*w).clone();
        for r in 0..rows {
            let row = wsc.row_mut(r);
            for (v, &sv) in row.iter_mut().zip(s) {
                *v *= sv;
            }
        }
        let g = rtn_grid(&wsc, qm);
        let codes = quantize_int_codes(&wsc, &g, None);
        let mut deq = codes;
        for r in 0..rows {
            for c in 0..cols {
                deq.data[r * cols + c] =
                    (deq.data[r * cols + c] - g.zp[r]) * g.scale[r];
            }
        }
        let y_ref = x.matmul_bt(w);
        let y_q = xs.matmul_bt(&deq);
        err += y_ref.mse(&y_q) * (t * rows) as f64;
    }
    err
}

/// Search the best α per act point; returns the four scale vectors.
pub fn search_scales(ctx: &BlockContext) -> Result<[Vec<f32>; 4]> {
    let acts = match ctx.acts_q {
        Some(a) if !a.is_empty() => a,
        _ => bail!("AWQ needs captured activations (acts_q)"),
    };
    let qm = qmax(ctx.scheme.w_bits);
    let bw = ctx.weights;
    let consumers_per_point: [Vec<&Tensor>; 4] = [
        vec![&bw.ws[0], &bw.ws[1], &bw.ws[2]],
        vec![&bw.ws[3]],
        vec![&bw.ws[4], &bw.ws[5]],
        vec![&bw.ws[6]],
    ];
    let mut scales: [Vec<f32>; 4] = Default::default();
    for p in 0..4 {
        let point_acts: Vec<&Tensor> = acts.iter().map(|b| &b[p]).collect();
        let x = sample_rows(&point_acts, SAMPLE_ROWS);
        let amax_a = {
            let mut m = point_acts[0].col_amax();
            for a in &point_acts[1..] {
                for (o, v) in m.iter_mut().zip(a.col_amax()) {
                    *o = o.max(v);
                }
            }
            m
        };
        let amax_w = weight_col_amax(&consumers_per_point[p]);
        let mut best = (f64::INFINITY, vec![1.0f32; amax_a.len()]);
        for k in 0..ALPHA_GRID {
            let alpha = k as f32 / (ALPHA_GRID - 1) as f32;
            let s = smooth_scales(&amax_a, &amax_w, alpha);
            let e = point_error(&x, &consumers_per_point[p], &s, qm);
            if e < best.0 {
                best = (e, s);
            }
        }
        scales[p] = best.1;
    }
    Ok(scales)
}

pub fn quantize_block(ctx: &BlockContext) -> Result<BlockQuantResult> {
    let scales = search_scales(ctx)?;
    let smoothed = fold_block(ctx.weights, &scales)?;
    let qm = qmax(ctx.scheme.w_bits);
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    for w in &smoothed.ws {
        let g = rtn_grid(w, qm);
        codes.push(quantize_int_codes(w, &g, None));
        grids.push(g);
    }
    let _ = LINEAR_ACT_POINT; // consumer mapping is implicit in the fold
    Ok(BlockQuantResult {
        grids,
        codes,
        norm_attn: smoothed.norm_attn,
        norm_ffn: smoothed.norm_ffn,
        loss_trace: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ReconConfig, Scheme};
    use crate::coordinator::engine::BlockStats;
    use crate::methods::testsupport::{test_block, test_dim};
    use crate::rng::Rng;

    fn salient_acts(rng: &mut Rng, d: usize, f: usize) -> [Tensor; 4] {
        let mut make = |dimn: usize| {
            let mut t = Tensor::randn(rng, &[16, dimn], 1.0);
            for r in 0..16 {
                t.data[r * dimn] *= 20.0; // salient channel 0
            }
            t
        };
        [make(d), make(d), make(d), make(f)]
    }

    #[test]
    fn search_prefers_nonzero_alpha_with_salient_channels() {
        let dim = test_dim();
        let mut rng = Rng::new(1);
        let bw = test_block(&mut rng, &dim);
        let a = [salient_acts(&mut rng, 16, 24)];
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim, weights: &bw, x_q: &[], y_t: &[], acts_q: Some(&a),
            stats: &stats, scheme: Scheme::weight_only(3),
            recon: ReconConfig::default(), block_index: 0,
        };
        let scales = search_scales(&ctx).unwrap();
        // salient channel should get scale >= median (protected)
        for p in 0..4 {
            let mut sorted = scales[p].clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = sorted[sorted.len() / 2];
            assert!(scales[p][0] >= med,
                    "point {p}: salient channel not protected");
        }
    }

    #[test]
    fn awq_not_worse_than_rtn_on_output_error() {
        let dim = test_dim();
        let mut rng = Rng::new(2);
        let bw = test_block(&mut rng, &dim);
        let a = [salient_acts(&mut rng, 16, 24)];
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim, weights: &bw, x_q: &[], y_t: &[], acts_q: Some(&a),
            stats: &stats, scheme: Scheme::weight_only(3),
            recon: ReconConfig::default(), block_index: 0,
        };
        // α=0 gives ~unit scales => RTN baseline is inside the search grid,
        // so the searched α can only do better on the objective
        let acts0: Vec<&Tensor> = a.iter().map(|b| &b[0]).collect();
        let x = sample_rows(&acts0, 64);
        let consumers = vec![&bw.ws[0], &bw.ws[1], &bw.ws[2]];
        let qm = qmax(3);
        let uniform = vec![1.0f32; 16];
        let e_rtn = point_error(&x, &consumers, &uniform, qm);
        let scales = search_scales(&ctx).unwrap();
        let e_awq = point_error(&x, &consumers, &scales[0], qm);
        assert!(e_awq <= e_rtn * 1.001, "awq {e_awq} vs rtn {e_rtn}");
    }
}
