//! SmoothQuant (Xiao et al., 2022): migrate activation-quantization
//! difficulty into the weights with a uniform per-channel smoothing
//! transform, then RTN-quantize the smoothed weights.
//!
//! `s_j = amax_act_j^α / amax_w_j^(1-α)` per input channel of each quant
//! point; the transform is folded exactly into the surrounding weights/norms
//! ([`super::fold`]), so the FP block function is unchanged while activations
//! become flatter. α follows the paper's Appendix I (0.8 for Llama-style
//! models).

use anyhow::{bail, Result};

use crate::model::BlockWeights;
use crate::quant::{qmax, quantize_int_codes, rtn_grid};
use crate::tensor::Tensor;

use super::fold::{fold_block, smooth_scales, weight_col_amax};
use super::{BlockContext, BlockQuantResult};

pub const DEFAULT_ALPHA: f32 = 0.8;

/// Per-channel activation amax at each of the 4 points, from the captured
/// quant-stream activations.
fn act_amax(ctx: &BlockContext) -> Result<[Vec<f32>; 4]> {
    let acts = match ctx.acts_q {
        Some(a) if !a.is_empty() => a,
        _ => bail!("SmoothQuant needs captured activations (acts_q)"),
    };
    let mut out: [Vec<f32>; 4] = Default::default();
    for batch in acts {
        for (p, t) in batch.iter().enumerate() {
            let amax = t.col_amax();
            if out[p].is_empty() {
                out[p] = amax;
            } else {
                for (o, a) in out[p].iter_mut().zip(amax) {
                    *o = o.max(a);
                }
            }
        }
    }
    Ok(out)
}

/// Compute the smoothing transform for a block: returns the smoothed weights
/// and the per-point scales used.
pub fn smooth_block(ctx: &BlockContext)
                    -> Result<(BlockWeights, [Vec<f32>; 4])> {
    smooth_block_alpha(ctx, DEFAULT_ALPHA)
}

pub fn smooth_block_alpha(ctx: &BlockContext, alpha: f32)
                          -> Result<(BlockWeights, [Vec<f32>; 4])> {
    let amax_a = act_amax(ctx)?;
    let bw = ctx.weights;
    // weight-side amax per input channel, consumers per point
    let w_amax: [Vec<f32>; 4] = [
        weight_col_amax(&[&bw.ws[0], &bw.ws[1], &bw.ws[2]]), // attn_in: qkv
        weight_col_amax(&[&bw.ws[3]]),                       // o_in: wo
        weight_col_amax(&[&bw.ws[4], &bw.ws[5]]),            // ffn_in: g/u
        weight_col_amax(&[&bw.ws[6]]),                       // down_in: wd
    ];
    let scales: [Vec<f32>; 4] = [
        smooth_scales(&amax_a[0], &w_amax[0], alpha),
        smooth_scales(&amax_a[1], &w_amax[1], alpha),
        smooth_scales(&amax_a[2], &w_amax[2], alpha),
        smooth_scales(&amax_a[3], &w_amax[3], alpha),
    ];
    // fold divides the activation by s — i.e. multiplies consumer weight
    // columns by s — exactly SmoothQuant's W ← W·diag(s), X ← X·diag(1/s).
    let smoothed = fold_block(bw, &scales)?;
    Ok((smoothed, scales))
}

pub fn quantize_block(ctx: &BlockContext) -> Result<BlockQuantResult> {
    let (smoothed, _scales) = smooth_block(ctx)?;
    let qm = qmax(ctx.scheme.w_bits);
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    for w in &smoothed.ws {
        let g = rtn_grid(w, qm);
        codes.push(quantize_int_codes(w, &g, None));
        grids.push(g);
    }
    Ok(BlockQuantResult {
        grids,
        codes,
        norm_attn: smoothed.norm_attn,
        norm_ffn: smoothed.norm_ffn,
        loss_trace: Vec::new(),
    })
}

/// Quantize a pre-smoothed block with RTN (used by SQ+recon variants to
/// produce the *weights* the reconstruction starts from).
pub fn rtn_on(bw: &BlockWeights, w_bits: u32) -> (Vec<crate::quant::ChannelGrid>, Vec<Tensor>) {
    let qm = qmax(w_bits);
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    for w in &bw.ws {
        let g = rtn_grid(w, qm);
        codes.push(quantize_int_codes(w, &g, None));
        grids.push(g);
    }
    (grids, codes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ReconConfig, Scheme};
    use crate::coordinator::engine::BlockStats;
    use crate::model::ModelDim;
    use crate::rng::Rng;

    fn dim() -> ModelDim {
        ModelDim {
            name: "t".into(), vocab: 64, d: 16, heads: 2, layers: 2, ff: 24,
            seq: 8, train_batch: 2, calib_batch: 2, recon_batch: 2, rank: 4,
        }
    }

    fn acts(rng: &mut Rng, d: usize, f: usize, outlier: bool)
            -> [Tensor; 4] {
        let mut make = |dimn: usize| {
            let mut t = Tensor::randn(rng, &[6, dimn], 1.0);
            if outlier {
                // channel 0 is a big outlier — the SmoothQuant motivation
                for r in 0..6 {
                    t.data[r * dimn] *= 50.0;
                }
            }
            t
        };
        [make(d), make(d), make(d), make(f)]
    }

    #[test]
    fn smoothing_flattens_outlier_channels() {
        let dim = dim();
        let mut rng = Rng::new(1);
        let bw = crate::methods::testsupport::test_block(&mut rng, &dim);
        let a = [acts(&mut rng, 16, 24, true)];
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim, weights: &bw, x_q: &[], y_t: &[], acts_q: Some(&a),
            stats: &stats, scheme: Scheme::w8a8_static(),
            recon: ReconConfig::default(), block_index: 0,
        };
        let (_sm, scales) = smooth_block(&ctx).unwrap();
        // the outlier channel gets the largest divisor at every point
        for p in 0..4 {
            let s = &scales[p];
            let max = s.iter().cloned().fold(0.0f32, f32::max);
            assert!((s[0] - max).abs() < 1e-6,
                    "point {p}: outlier channel not maximal: {s:?}");
        }
    }

    #[test]
    fn quantize_block_produces_grids() {
        let dim = dim();
        let mut rng = Rng::new(2);
        let bw = crate::methods::testsupport::test_block(&mut rng, &dim);
        let a = [acts(&mut rng, 16, 24, false)];
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim, weights: &bw, x_q: &[], y_t: &[], acts_q: Some(&a),
            stats: &stats, scheme: Scheme::w8a8_static(),
            recon: ReconConfig::default(), block_index: 0,
        };
        let res = quantize_block(&ctx).unwrap();
        assert_eq!(res.grids.len(), 7);
        // smoothed norms differ from the originals
        assert!(res.norm_attn.rmse(&bw.norm_attn) > 1e-6);
    }

    #[test]
    fn needs_acts() {
        let dim = dim();
        let mut rng = Rng::new(3);
        let bw = crate::methods::testsupport::test_block(&mut rng, &dim);
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim, weights: &bw, x_q: &[], y_t: &[], acts_q: None,
            stats: &stats, scheme: Scheme::w8a8_static(),
            recon: ReconConfig::default(), block_index: 0,
        };
        assert!(quantize_block(&ctx).is_err());
    }
}
