//! RTN (round-to-nearest) baseline: per-channel asymmetric grids straight
//! from the weight ranges — no calibration, no learning. The starting point
//! of every other method.

use anyhow::Result;

use crate::quant::{qmax, quantize_int_codes, rtn_grid};

use super::{BlockContext, BlockQuantResult};

pub fn quantize_block(ctx: &BlockContext) -> Result<BlockQuantResult> {
    let qm = qmax(ctx.scheme.w_bits);
    let mut grids = Vec::with_capacity(7);
    let mut codes = Vec::with_capacity(7);
    for w in &ctx.weights.ws {
        let g = rtn_grid(w, qm);
        codes.push(quantize_int_codes(w, &g, None));
        grids.push(g);
    }
    Ok(BlockQuantResult {
        grids,
        codes,
        norm_attn: ctx.weights.norm_attn.clone(),
        norm_ffn: ctx.weights.norm_ffn.clone(),
        loss_trace: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ReconConfig, Scheme};
    use crate::coordinator::engine::BlockStats;

    use crate::rng::Rng;


    use crate::methods::testsupport::{test_block, test_dim};

    #[test]
    fn rtn_block_roundtrip() {
        let dim = test_dim();
        let mut rng = Rng::new(1);
        let bw = test_block(&mut rng, &dim);
        let stats: BlockStats = Default::default();
        let ctx = BlockContext {
            dim: &dim,
            weights: &bw,
            x_q: &[],
            y_t: &[],
            acts_q: None,
            stats: &stats,
            scheme: Scheme::weight_only(8),
            recon: ReconConfig::default(),
            block_index: 0,
        };
        let res = quantize_block(&ctx).unwrap();
        assert_eq!(res.grids.len(), 7);
        let whats = res.whats();
        for (i, w) in bw.ws.iter().enumerate() {
            // 8-bit RTN error per element bounded by scale/2
            let g = &res.grids[i];
            let (rows, cols) = w.rc();
            for r in 0..rows {
                for c in 0..cols {
                    let d = (whats[i].data[r * cols + c] - w.data[r * cols + c])
                        .abs();
                    assert!(d <= g.scale[r] * 0.5 + 1e-6);
                }
            }
        }
    }
}
