//! Evaluation harness: perplexity, multiple-choice accuracy (lm-eval-harness
//! scoring rule), and the accumulated-RMSE diagnostic of Figs. 3/6/7.

use anyhow::{bail, Result};

use crate::config::Scheme;
use crate::coordinator::engine::{BlockStats, Engine};
use crate::data::{Corpus, TaskSet};
use crate::model::{QuantizedModel, Weights};
use crate::rng::Rng;
use crate::tensor::Tensor;

/// A model to evaluate: FP baseline or a quantized checkpoint with its
/// runtime activation ranges + scheme flags.
pub enum ModelView<'a> {
    Fp(&'a Weights),
    Quant {
        model: &'a QuantizedModel,
        stats: &'a [BlockStats],
        scheme: Scheme,
    },
}

impl<'a> ModelView<'a> {
    fn forward(&self, engine: &Engine, ids: &[i32], targets: &[i32])
               -> Result<(f32, Tensor)> {
        match self {
            ModelView::Fp(w) => engine.fp_forward(w, ids, targets),
            ModelView::Quant { model, stats, scheme } =>
                engine.q_forward(model, stats, scheme, ids, targets),
        }
    }
}

/// Mean perplexity over a held-out LM stream (the WikiText-2 analogue).
pub fn perplexity(engine: &Engine, view: &ModelView, corpus: &Corpus,
                  n_batches: usize, seed: u64) -> Result<f64> {
    let dim = &engine.dim;
    let mut rng = Rng::new(seed);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let (ids, tgt) = corpus.eval_stream(dim.calib_batch, dim.seq, &mut rng);
        let (loss, _) = view.forward(engine, &ids, &tgt)?;
        nll += loss as f64;
        count += 1;
    }
    Ok((nll / count.max(1) as f64).exp())
}

/// Multiple-choice accuracy: per item, pick the choice maximizing the summed
/// log-prob of its continuation tokens given the prefix.
pub fn mc_accuracy(engine: &Engine, view: &ModelView, tasks: &TaskSet)
                   -> Result<f64> {
    let dim = &engine.dim;
    if tasks.is_empty() {
        bail!("empty task set");
    }
    // flatten (task, choice) into scoring rows
    struct Row {
        score_from: usize, // first predicted position of the continuation
        score_to: usize,
    }
    let mut rows = Vec::new();
    let mut ids_rows: Vec<Vec<i32>> = Vec::new();
    for t in tasks.tasks.iter() {
        let plen = t.prefix.len();
        for ch in t.choices.iter() {
            let mut seq: Vec<i32> = Vec::with_capacity(dim.seq);
            seq.extend_from_slice(&t.prefix);
            seq.extend_from_slice(ch);
            if seq.len() > dim.seq {
                bail!("task longer than model seq ({} > {})", seq.len(),
                      dim.seq);
            }
            seq.resize(dim.seq, 0);
            // target[pos] = token at pos+1 is scored at pos; the first
            // continuation token sits at index plen → scored at plen-1
            rows.push(Row {
                score_from: plen - 1,
                score_to: plen - 1 + ch.len(),
            });
            ids_rows.push(seq);
        }
    }

    // batch rows through the engine
    let b = dim.calib_batch;
    let mut scores = vec![0.0f32; rows.len()];
    let mut i = 0usize;
    while i < rows.len() {
        let hi = (i + b).min(rows.len());
        let mut ids = Vec::with_capacity(b * dim.seq);
        let mut tgt = Vec::with_capacity(b * dim.seq);
        for r in i..hi {
            let row = &ids_rows[r];
            ids.extend_from_slice(row);
            let mut t: Vec<i32> = row[1..].to_vec();
            t.push(0);
            tgt.extend(t);
        }
        // pad the final partial batch by repeating the last row
        for _ in hi..(i + b) {
            let row = &ids_rows[hi - 1];
            ids.extend_from_slice(row);
            let mut t: Vec<i32> = row[1..].to_vec();
            t.push(0);
            tgt.extend(t);
        }
        let (_, logp) = view.forward(engine, &ids, &tgt)?;
        for r in i..hi {
            let within = r - i;
            let rowp = &logp.data[within * dim.seq..(within + 1) * dim.seq];
            let s: f32 = rowp[rows[r].score_from..rows[r].score_to].iter()
                .sum();
            scores[r] = s;
        }
        i = hi;
    }

    // argmax per task
    let mut correct = 0usize;
    let n_choices = tasks.tasks[0].choices.len();
    for (ti, t) in tasks.tasks.iter().enumerate() {
        let base = ti * n_choices;
        let mut best = 0usize;
        for c in 1..n_choices {
            if scores[base + c] > scores[base + best] {
                best = c;
            }
        }
        if best == t.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / tasks.len() as f64)
}

/// Accumulated RMSE between the FP stream and the quantized stream, per block
/// (Fig. 3): run the same ids through both and record
/// `RMSE(X_fp[b+1], X̃[b+1])` for every block.
pub fn rmse_curve(engine: &Engine, weights: &Weights, qm: &QuantizedModel,
                  stats: &[BlockStats], scheme: &Scheme, ids: &[i32])
                  -> Result<Vec<f64>> {
    let mut x_fp = engine.embed(&weights.emb, ids)?;
    let mut x_q = engine.embed(&qm.emb, ids)?;
    let mut out = Vec::with_capacity(weights.blocks.len());
    for (bw, (qb, st)) in weights.blocks.iter()
        .zip(qm.blocks.iter().zip(stats)) {
        x_fp = engine.block_fp(&x_fp, bw)?.y;
        let whats = qb.dequant_ws();
        x_q = engine.block_q(&x_q, &whats, &qb.norm_attn, &qb.norm_ffn, st,
                             scheme)?;
        out.push(x_fp.rmse(&x_q));
    }
    Ok(out)
}

/// Paper-style CSR/MMLU summary for one model view.
pub struct EvalSummary {
    pub csr_acc: f64,
    pub mmlu_acc: f64,
    pub ppl: f64,
}

pub fn evaluate(engine: &Engine, view: &ModelView, corpus: &Corpus,
                csr: &TaskSet, mmlu: &TaskSet, ppl_batches: usize,
                seed: u64) -> Result<EvalSummary> {
    Ok(EvalSummary {
        csr_acc: mc_accuracy(engine, view, csr)?,
        mmlu_acc: mc_accuracy(engine, view, mmlu)?,
        ppl: perplexity(engine, view, corpus, ppl_batches, seed)?,
    })
}
