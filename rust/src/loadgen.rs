//! Load generator + declarative SLO evaluator for the serving path — the
//! soak harness behind `lrq soak` (DESIGN.md §10).
//!
//! [`run`] drives a [`crate::serve::Server`] with many client threads in one
//! of two modes:
//!
//! * **closed-loop** — each worker submits a request, waits for the answer,
//!   submits the next. Concurrency is fixed (`clients`), arrival rate adapts
//!   to the server (latencies stay honest on slow CI machines).
//! * **open-loop** — workers submit on a fixed global schedule
//!   (`rate_per_sec` across all workers) without waiting, then drain the
//!   pending responses at the end. Queueing shows up as queue-time/latency
//!   growth instead of throttling the offered load — the production-shaped
//!   measurement.
//!
//! The traffic is a seeded, reproducible mix: score and generate requests,
//! deliberately oversized requests (expected rejects — exercising the
//! validation path), mid-flight client disconnects (the receiver is dropped
//! right after submission), and long-context stragglers (near-`seq_len`
//! prompts that hold decode slots). Counting happens client-side in a
//! [`LoadOutcome`]; stage timings come from the server's
//! [`EventLog`](crate::obs::EventLog), aggregated into an
//! [`EventAgg`](crate::obs::EventAgg) that [`SloSpec::evaluate`] checks
//! against declared ceilings (p50/p99 latency, TTFT, queue time, error
//! rate, stuck sequences).

use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use crate::obs::events::percentile_us;
use crate::obs::EventAgg;
use crate::rng::Rng;
use crate::serve::{Server, EXPIRED_PREFIX, SHED_PREFIX};

/// How workers pace their submissions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadMode {
    /// submit → wait → submit: concurrency fixed, rate adapts to the server
    Closed,
    /// fixed arrival schedule (`rate_per_sec`), responses drained at the end
    Open,
}

/// One load run, fully seeded (same spec → same traffic).
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub mode: LoadMode,
    /// concurrent client threads
    pub clients: usize,
    /// requests per client thread
    pub requests: usize,
    /// open-loop: total offered arrivals/sec across all clients
    pub rate_per_sec: f64,
    /// fraction of requests that are score (the rest generate)
    pub score_frac: f32,
    /// fraction submitted deliberately oversized (expected rejects)
    pub oversized_frac: f32,
    /// fraction whose client disconnects right after submitting
    pub disconnect_frac: f32,
    /// fraction that are long-context stragglers (near-`seq` prompts)
    pub straggler_frac: f32,
    /// score payload length range (tokens), inclusive lower bound
    pub score_len: (usize, usize),
    /// generate prompt length range (tokens)
    pub prompt_len: (usize, usize),
    /// tokens to generate per generate request
    pub max_new: usize,
    /// top-k sampling width (`<= 1` = greedy)
    pub top_k: usize,
    /// token id space of generated payloads
    pub vocab: usize,
    /// the server's context length (oversized = beyond it)
    pub seq: usize,
    pub seed: u64,
    /// open-loop: how long to wait for each pending response at drain time
    pub drain_timeout: Duration,
    /// per-request deadline (ms) attached to every submission
    /// ([`crate::serve::Client::with_deadline`]); `None` = no deadline
    pub deadline_ms: Option<u64>,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            mode: LoadMode::Closed,
            clients: 4,
            requests: 16,
            rate_per_sec: 200.0,
            score_frac: 0.5,
            oversized_frac: 0.0,
            disconnect_frac: 0.0,
            straggler_frac: 0.0,
            score_len: (4, 24),
            prompt_len: (2, 8),
            max_new: 4,
            top_k: 1,
            vocab: 64,
            seq: 32,
            seed: 0x50AB,
            drain_timeout: Duration::from_secs(30),
            deadline_ms: None,
        }
    }
}

/// Client-side accounting of one load run.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadOutcome {
    /// requests that reached the server's channel
    pub submitted: u64,
    /// successful responses received
    pub ok: u64,
    /// error responses received (validation or engine failure)
    pub rejected: u64,
    /// deadline-expiry responses (the answer starts with
    /// [`EXPIRED_PREFIX`]) — counted apart from `rejected` because they are
    /// a latency outcome, not a validation failure
    pub expired: u64,
    /// shed-load responses (the answer starts with [`SHED_PREFIX`]) — fast
    /// retriable rejections from admission control
    pub shed: u64,
    /// receivers we deliberately dropped (injected disconnects)
    pub disconnected: u64,
    /// responses that never arrived (server dropped the request, or the
    /// drain timeout expired) — nonzero means requests were lost
    pub lost: u64,
    /// generated tokens across successful generate responses
    pub gen_tokens: u64,
    /// wall-clock time of the whole run (submission through drain)
    pub wall: Duration,
}

impl LoadOutcome {
    fn absorb(&mut self, o: &LoadOutcome) {
        self.submitted += o.submitted;
        self.ok += o.ok;
        self.rejected += o.rejected;
        self.expired += o.expired;
        self.shed += o.shed;
        self.disconnected += o.disconnected;
        self.lost += o.lost;
        self.gen_tokens += o.gen_tokens;
    }

    /// Successful requests per second over the run's wall clock.
    pub fn req_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.ok as f64 / self.wall.as_secs_f64()
    }
}

/// What one worker decided to send.
enum Payload {
    Score(Vec<i32>),
    Generate { prompt: Vec<i32>, max_new: usize },
}

fn draw_payload(spec: &LoadSpec, rng: &mut Rng) -> Payload {
    let oversized = spec.oversized_frac > 0.0 && rng.coin(spec.oversized_frac);
    let straggler = spec.straggler_frac > 0.0 && rng.coin(spec.straggler_frac);
    let score = rng.coin(spec.score_frac);
    let tok = |r: &mut Rng| r.below(spec.vocab.max(2)) as i32;
    if score {
        let len = if oversized {
            // beyond the context window: the server must reject, not crash
            spec.seq + 1 + rng.below(8)
        } else if straggler {
            spec.seq.max(2) // exactly the full context: a maximal valid row
        } else {
            let (lo, hi) = spec.score_len;
            rng.range(lo.max(2), hi.max(lo.max(2)) + 1)
        };
        Payload::Score((0..len).map(|_| tok(rng)).collect())
    } else {
        let (plen, max_new) = if oversized {
            // prompt + max_new overflows the context: expected reject
            (spec.seq, spec.max_new.max(1))
        } else if straggler {
            // long prompt, still valid: holds a decode slot for the full
            // budget and stresses prefill
            let plen = spec.seq.saturating_sub(spec.max_new).max(1);
            (plen, spec.max_new.max(1))
        } else {
            let (lo, hi) = spec.prompt_len;
            (rng.range(lo.max(1), hi.max(lo.max(1)) + 1),
             spec.max_new.max(1))
        };
        Payload::Generate {
            prompt: (0..plen).map(|_| tok(rng)).collect(),
            max_new,
        }
    }
}

/// A pending open-loop response, either workload kind.
enum Pending {
    Score(std::sync::mpsc::Receiver<
            Result<crate::serve::ScoreResponse, String>>),
    Generate(std::sync::mpsc::Receiver<
            Result<crate::serve::GenerateResponse, String>>),
}

/// Drive `server` with `spec`. Returns the merged client-side outcome;
/// server-side stage timings live in the server's event log.
pub fn run(server: &Server, spec: &LoadSpec) -> LoadOutcome {
    let t0 = Instant::now();
    let mut outcome = LoadOutcome::default();
    let mut handles = Vec::new();
    for k in 0..spec.clients.max(1) {
        let client = match spec.deadline_ms {
            Some(ms) => server.client()
                .with_deadline(Duration::from_millis(ms)),
            None => server.client(),
        };
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng =
                Rng::new(spec.seed ^ (k as u64).wrapping_mul(0x9E37));
            let mut out = LoadOutcome::default();
            let mut pending: Vec<Pending> = Vec::new();
            let start = Instant::now();
            // open-loop inter-arrival: each of `clients` workers carries an
            // interleaved slice of the global schedule
            let step = spec.clients.max(1) as f64 / spec.rate_per_sec.max(0.1);
            let offset = k as f64 / spec.rate_per_sec.max(0.1);
            for i in 0..spec.requests {
                if spec.mode == LoadMode::Open {
                    let due = start
                        + Duration::from_secs_f64(offset + i as f64 * step);
                    let now = Instant::now();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                }
                let disconnect = spec.disconnect_frac > 0.0
                    && rng.coin(spec.disconnect_frac);
                match draw_payload(&spec, &mut rng) {
                    Payload::Score(ids) => match client.submit(ids) {
                        Err(_) => out.lost += 1, // server gone
                        Ok(rx) => {
                            out.submitted += 1;
                            if disconnect {
                                out.disconnected += 1; // rx dropped here
                            } else {
                                pending.push(Pending::Score(rx));
                            }
                        }
                    },
                    Payload::Generate { prompt, max_new } => {
                        match client.submit_generate(prompt, max_new,
                                                     spec.top_k,
                                                     spec.seed ^ i as u64) {
                            Err(_) => out.lost += 1,
                            Ok(rx) => {
                                out.submitted += 1;
                                if disconnect {
                                    out.disconnected += 1;
                                } else {
                                    pending.push(Pending::Generate(rx));
                                }
                            }
                        }
                    }
                }
                // closed-loop: wait for this answer before the next submit
                if spec.mode == LoadMode::Closed {
                    if let Some(p) = pending.pop() {
                        absorb_response(&mut out, p, spec.drain_timeout);
                    }
                }
            }
            // open-loop drain: collect everything still in flight
            for p in pending {
                absorb_response(&mut out, p, spec.drain_timeout);
            }
            out
        }));
    }
    for h in handles {
        if let Ok(o) = h.join() {
            outcome.absorb(&o);
        }
    }
    outcome.wall = t0.elapsed();
    outcome
}

fn absorb_response(out: &mut LoadOutcome, p: Pending, timeout: Duration) {
    match p {
        Pending::Score(rx) => match rx.recv_timeout(timeout) {
            Ok(Ok(_)) => out.ok += 1,
            Ok(Err(msg)) => absorb_error(out, &msg),
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => out.lost += 1,
        },
        Pending::Generate(rx) => match rx.recv_timeout(timeout) {
            Ok(Ok(r)) => {
                out.ok += 1;
                out.gen_tokens += r.tokens.len() as u64;
            }
            Ok(Err(msg)) => absorb_error(out, &msg),
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => out.lost += 1,
        },
    }
}

/// Classify an error response by its stable message prefix: deadline
/// expiries and shed-load rejections are distinct client-visible outcomes
/// (an expiry means "too slow", a shed means "retry later"); everything
/// else is a plain reject.
fn absorb_error(out: &mut LoadOutcome, msg: &str) {
    if msg.starts_with(EXPIRED_PREFIX) {
        out.expired += 1;
    } else if msg.starts_with(SHED_PREFIX) {
        out.shed += 1;
    } else {
        out.rejected += 1;
    }
}

// ---------------------------------------------------------------- SLOs ----

/// Declarative SLOs checked against a run's [`EventAgg`]. `None` ceilings
/// are not evaluated; `max_stuck` (default 0) always is.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloSpec {
    /// median end-to-end latency ceiling (ms)
    pub p50_ms: Option<f64>,
    /// p99 end-to-end latency ceiling (ms)
    pub p99_ms: Option<f64>,
    /// p99 time-to-first-token ceiling (ms, generate requests)
    pub ttft_p99_ms: Option<f64>,
    /// p99 queue-time ceiling (ms)
    pub queue_p99_ms: Option<f64>,
    /// max rejected / answered (injected oversized traffic budgets this)
    pub max_error_rate: Option<f64>,
    /// max expired / answered (deadline misses under the offered load)
    pub max_expire_rate: Option<f64>,
    /// max shed / answered (admission-control rejections under overload)
    pub max_shed_rate: Option<f64>,
    /// max requests left without a terminal event (stuck sequences)
    pub max_stuck: u64,
}

/// One evaluated SLO.
#[derive(Clone, Debug)]
pub struct SloCheck {
    pub name: &'static str,
    pub limit: f64,
    pub actual: f64,
    pub pass: bool,
}

/// Every evaluated SLO of a run.
#[derive(Clone, Debug)]
pub struct SloReport {
    pub checks: Vec<SloCheck>,
}

impl SloReport {
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.pass)
    }

    /// Human-readable verdict table, one line per check.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for c in &self.checks {
            s.push_str(&format!(
                "  {:5} {:14} {:10.2} (limit {:.2})\n",
                if c.pass { "ok" } else { "FAIL" },
                c.name, c.actual, c.limit));
        }
        s
    }
}

impl SloSpec {
    /// Evaluate against a run's aggregated stage timings plus the number of
    /// stuck (never-terminated) requests observed after shutdown.
    pub fn evaluate(&self, agg: &EventAgg, stuck: u64) -> SloReport {
        let ms = |us: u64| us as f64 / 1e3;
        let mut checks = Vec::new();
        let mut push = |name, limit: Option<f64>, actual: f64| {
            if let Some(l) = limit {
                checks.push(SloCheck {
                    name,
                    limit: l,
                    actual,
                    pass: actual <= l,
                });
            }
        };
        push("p50_ms", self.p50_ms, ms(percentile_us(&agg.total_us, 0.50)));
        push("p99_ms", self.p99_ms, ms(percentile_us(&agg.total_us, 0.99)));
        push("ttft_p99_ms", self.ttft_p99_ms,
             ms(percentile_us(&agg.ttft_us, 0.99)));
        push("queue_p99_ms", self.queue_p99_ms,
             ms(percentile_us(&agg.queue_us, 0.99)));
        push("error_rate", self.max_error_rate, agg.error_rate());
        push("expire_rate", self.max_expire_rate, agg.expire_rate());
        push("shed_rate", self.max_shed_rate, agg.shed_rate());
        // zero-stuck is the one non-optional SLO: a stuck sequence is a
        // leaked KV cache and an unanswered client
        checks.push(SloCheck {
            name: "stuck_seqs",
            limit: self.max_stuck as f64,
            actual: stuck as f64,
            pass: stuck <= self.max_stuck,
        });
        SloReport { checks }
    }
}

// ------------------------------------------------- BENCH_serve.json -------

/// One per-bit-width row of `BENCH_serve.json`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeBenchRow {
    pub w_bits: u32,
    /// sustained successful requests/sec over the run
    pub req_s: f64,
    /// decode tokens per second of decode execution
    pub decode_tok_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub ttft_p99_ms: f64,
    pub queue_p99_ms: f64,
    pub error_rate: f64,
    /// deadline-expiry fraction of answered requests
    pub expire_rate: f64,
    /// admission-control shed fraction of answered requests
    pub shed_rate: f64,
    /// degraded-plan downshift/restore transitions during the run
    pub degrade_shifts: u64,
    pub stuck: u64,
}

/// Render the soak run's `BENCH_serve.json` (hand-rolled flat JSON — the
/// schema [`crate::bench::json_key_numbers`] and the compare gate scan).
pub fn render_bench_serve(smoke: bool, cfg: &str, rows: &[ServeBenchRow])
                          -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!(
        "  \"bench\": \"serve\",\n  \"smoke\": {smoke},\n  \
         \"config\": \"{cfg}\",\n"));
    s.push_str("  \"per_bit\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"w_bits\": {}, \"req_s\": {:.2}, \
             \"decode_tok_s\": {:.1}, \"p50_ms\": {:.2}, \
             \"p99_ms\": {:.2}, \"ttft_p99_ms\": {:.2}, \
             \"queue_p99_ms\": {:.2}, \"error_rate\": {:.4}, \
             \"expire_rate\": {:.4}, \"shed_rate\": {:.4}, \
             \"degrade_shifts\": {}, \"stuck\": {}}}{}\n",
            r.w_bits, r.req_s, r.decode_tok_s, r.p50_ms, r.p99_ms,
            r.ttft_p99_ms, r.queue_p99_ms, r.error_rate, r.expire_rate,
            r.shed_rate, r.degrade_shifts, r.stuck,
            if i + 1 < rows.len() { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{MockScorer, Server, ServerConfig};

    fn mock_server() -> Server {
        Server::start(
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            || Ok(Box::new(MockScorer { batch: 8, seq: 32, calls: 0 })),
        )
        .unwrap()
    }

    #[test]
    fn closed_loop_scores_complete() {
        let server = mock_server();
        let spec = LoadSpec {
            clients: 3,
            requests: 10,
            score_frac: 1.0, // MockScorer has no decode
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 30);
        assert_eq!(out.ok, 30);
        assert_eq!(out.lost, 0);
        assert!(out.req_per_sec() > 0.0);
    }

    #[test]
    fn open_loop_drains_everything() {
        let mut server = mock_server();
        let spec = LoadSpec {
            mode: LoadMode::Open,
            clients: 2,
            requests: 12,
            rate_per_sec: 400.0,
            score_frac: 1.0,
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 24);
        assert_eq!(out.ok + out.rejected, 24);
        assert_eq!(out.lost, 0);
        server.shutdown();
        // every submission got a terminal lifecycle event
        assert!(server.events().stuck().is_empty());
    }

    #[test]
    fn oversized_and_disconnects_are_counted_not_fatal() {
        let mut server = mock_server();
        let spec = LoadSpec {
            clients: 2,
            requests: 20,
            score_frac: 1.0,
            oversized_frac: 0.3,
            disconnect_frac: 0.3,
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 40);
        // all non-disconnected submissions were answered one way or another
        assert_eq!(out.ok + out.rejected + out.disconnected, 40);
        assert_eq!(out.lost, 0);
        assert!(out.rejected > 0, "oversized traffic must be rejected");
        assert!(out.disconnected > 0);
        server.shutdown();
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        // the server saw the injected disconnects for requests whose answer
        // failed to send (closed-loop: the drop happens before the batch
        // answers, so every injected disconnect is observable server-side)
        assert!(agg.disconnected > 0);
        assert!(agg.error_rate() > 0.0);
    }

    // ---- per-fault-kind terminal-event contracts -------------------------
    //
    // One test per injected fault kind, each at frac 1.0 with a pinned
    // seed: every submission must reach exactly the matching terminal
    // lifecycle event in the server's event log, and an injected fault must
    // never surface as `lost` (a lost response is a real bug, faults are
    // expected traffic). All use score_frac 1.0 — MockScorer has no decode.

    #[test]
    fn injected_oversized_requests_all_terminate_as_rejects() {
        let mut server = mock_server();
        let spec = LoadSpec {
            clients: 2,
            requests: 10,
            score_frac: 1.0,
            oversized_frac: 1.0,
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 20);
        assert_eq!(out.rejected, 20, "every oversized request must reject");
        assert_eq!(out.ok, 0);
        assert_eq!(out.lost, 0, "a reject is an answer, never a loss");
        server.shutdown();
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        assert_eq!(agg.rejected, 20);
        assert_eq!(agg.responded, 0);
        assert_eq!(agg.error_rate(), 1.0);
        for s in ev.summaries() {
            assert_eq!(s.outcome, crate::obs::events::EventKind::Reject,
                       "rid {} ended as {:?}", s.rid, s.outcome);
        }
    }

    #[test]
    fn injected_disconnects_all_terminate_as_disconnects() {
        let mut server = mock_server();
        let spec = LoadSpec {
            clients: 2,
            requests: 10,
            score_frac: 1.0,
            disconnect_frac: 1.0,
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 20);
        assert_eq!(out.disconnected, 20);
        assert_eq!(out.ok, 0);
        assert_eq!(out.rejected, 0);
        assert_eq!(out.lost, 0, "a disconnect is the client's choice, \
                                 never a loss");
        server.shutdown();
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        // closed-loop drops the receiver before the 2ms batch window
        // closes, so every injected disconnect lands server-side too
        assert_eq!(agg.disconnected, 20);
        assert_eq!(agg.responded, 0);
        // disconnects are client-caused and excluded from the error budget
        assert_eq!(agg.error_rate(), 0.0);
        for s in ev.summaries() {
            assert_eq!(s.outcome, crate::obs::events::EventKind::Disconnect,
                       "rid {} ended as {:?}", s.rid, s.outcome);
        }
    }

    #[test]
    fn injected_stragglers_all_terminate_as_responses() {
        let mut server = mock_server();
        let spec = LoadSpec {
            clients: 2,
            requests: 10,
            score_frac: 1.0,
            straggler_frac: 1.0,
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 20);
        // a straggler is a maximal *valid* row: it must succeed, just slowly
        assert_eq!(out.ok, 20);
        assert_eq!(out.rejected, 0);
        assert_eq!(out.lost, 0);
        server.shutdown();
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        assert_eq!(agg.responded, 20);
        assert_eq!(agg.error_rate(), 0.0);
        for s in ev.summaries() {
            assert_eq!(s.outcome, crate::obs::events::EventKind::Respond,
                       "rid {} ended as {:?}", s.rid, s.outcome);
        }
    }

    #[test]
    fn zero_deadline_requests_all_terminate_as_expiries() {
        let mut server = mock_server();
        let spec = LoadSpec {
            clients: 2,
            requests: 5,
            score_frac: 1.0,
            deadline_ms: Some(0), // expires the instant it is submitted
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 10);
        assert_eq!(out.expired, 10, "zero deadline must expire everything");
        assert_eq!((out.ok, out.rejected, out.shed, out.lost), (0, 0, 0, 0));
        server.shutdown();
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        let agg = ev.agg();
        assert_eq!(agg.expired, 10);
        assert!(agg.expire_rate() > 0.99);
        // the stage identity holds for Expire outcomes too: expiry closes
        // the queue stage, so attributed stages never exceed the total
        for s in ev.summaries() {
            assert_eq!(s.outcome, crate::obs::events::EventKind::Expire,
                       "rid {} ended as {:?}", s.rid, s.outcome);
            assert!(s.queue_us + s.exec_us <= s.total_us,
                    "rid {}: queue {} + exec {} > total {}",
                    s.rid, s.queue_us, s.exec_us, s.total_us);
        }
    }

    #[test]
    fn chaos_dropped_responses_account_for_every_loss() {
        use crate::serve::FaultPlan;
        use std::sync::Arc;
        let mut p = FaultPlan::new();
        p.drop_response = Some(3);
        let plan = Arc::new(p);
        let mut server = Server::start_with(
            ServerConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            Some(plan.clone()),
            || Ok(Box::new(MockScorer { batch: 8, seq: 32, calls: 0 })),
        )
        .unwrap();
        let spec = LoadSpec {
            clients: 2,
            requests: 6,
            score_frac: 1.0,
            // a dropped response otherwise burns the full drain timeout
            drain_timeout: Duration::from_millis(200),
            ..LoadSpec::default()
        };
        let out = run(&server, &spec);
        assert_eq!(out.submitted, 12);
        // the chaos ledger accounts for every loss the clients saw
        assert_eq!(out.lost, plan.drops_fired(),
                   "losses {} vs drops fired {}", out.lost,
                   plan.drops_fired());
        assert_eq!(out.lost, 1);
        assert_eq!(out.ok, 11);
        server.shutdown();
        // the drop is a terminal Disconnect server-side — never stuck
        let ev = server.events();
        assert!(ev.stuck().is_empty(), "stuck {:?}", ev.stuck());
        assert_eq!(ev.agg().disconnected, 1);
    }

    #[test]
    fn slo_evaluation_passes_and_fails() {
        let agg = EventAgg {
            responded: 99,
            rejected: 1,
            total_us: (1..=100u64).map(|i| i * 1000).collect(),
            queue_us: (1..=100u64).map(|i| i * 10).collect(),
            ttft_us: (1..=100u64).map(|i| i * 100).collect(),
            ..EventAgg::default()
        };
        // generous ceilings: everything passes
        let ok = SloSpec {
            p50_ms: Some(60.0),
            p99_ms: Some(120.0),
            ttft_p99_ms: Some(15.0),
            queue_p99_ms: Some(2.0),
            max_error_rate: Some(0.05),
            max_expire_rate: Some(0.0),
            max_shed_rate: Some(0.0),
            max_stuck: 0,
        }
        .evaluate(&agg, 0);
        assert!(ok.passed(), "{}", ok.render());
        assert_eq!(ok.checks.len(), 8);
        // p99 of the 1..100ms ladder is 99ms: a 50ms ceiling must fail,
        // and one stuck sequence must fail the zero-stuck default
        let bad = SloSpec {
            p99_ms: Some(50.0),
            ..SloSpec::default()
        }
        .evaluate(&agg, 1);
        assert!(!bad.passed());
        let failed: Vec<&str> = bad.checks.iter().filter(|c| !c.pass)
            .map(|c| c.name).collect();
        assert_eq!(failed, vec!["p99_ms", "stuck_seqs"]);
        assert!(bad.render().contains("FAIL"));
    }

    #[test]
    fn bench_serve_json_is_scannable() {
        let rows = [
            ServeBenchRow {
                w_bits: 4, req_s: 120.5, decode_tok_s: 900.0,
                p50_ms: 2.2, p99_ms: 9.9, ttft_p99_ms: 4.0,
                queue_p99_ms: 1.0, error_rate: 0.01, stuck: 0,
            },
            ServeBenchRow { w_bits: 8, req_s: 100.0, ..Default::default() },
        ];
        let txt = render_bench_serve(true, "micro", &rows);
        let req = crate::bench::json_key_numbers(&txt, "req_s");
        assert_eq!(req, vec![120.5, 100.0]);
        let dec = crate::bench::json_key_numbers(&txt, "decode_tok_s");
        assert_eq!(dec.len(), 2);
        // the compare gate reads the same schema: a 50% drop is flagged
        let worse = render_bench_serve(true, "micro", &[
            ServeBenchRow { w_bits: 4, req_s: 50.0, decode_tok_s: 900.0,
                            ..Default::default() },
            ServeBenchRow { w_bits: 8, req_s: 100.0, ..Default::default() },
        ]);
        let regs = crate::bench::regressions(&txt, &worse, "req_s", 0.30);
        assert_eq!(regs.len(), 1, "{regs:?}");
    }
}
