"""Model configurations shared by the AOT compile path and (via the manifest)
the Rust coordinator.

Every artifact is lowered with fixed shapes taken from one of these configs.
The Rust side never imports this file; `aot.py` serializes everything the
runtime needs into ``artifacts/manifest.txt``.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d: int          # model width
    heads: int
    layers: int
    ff: int         # gated-FFN inner width (~8/3 * d, Llama-style)
    seq: int
    train_batch: int
    calib_batch: int   # batch used by block_fwd / block_fwd_q streaming
    recon_batch: int   # batch per reconstruction Adam step (paper uses 2)
    rank: int          # default LRQ rank (~40% learnable-param ratio, Table 29)
    ranks: List[int] = field(default_factory=list)  # ranks emitted for Fig. 4(a)

    @property
    def head_dim(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads


CONFIGS = {
    # ~1.6M params / block quantities sized so interpret-mode Pallas on CPU
    # stays fast; used by tests and the rank/calibration studies.
    "tiny": ModelConfig(
        name="tiny", vocab=512, d=128, heads=4, layers=4, ff=352, seq=64,
        train_batch=16, calib_batch=8, recon_batch=4,
        rank=32, ranks=[4, 8, 16, 32, 64, 128],
    ),
    # the e2e / headline-table model (~26M params)
    "small": ModelConfig(
        name="small", vocab=2048, d=256, heads=8, layers=8, ff=704, seq=64,
        train_batch=8, calib_batch=8, recon_batch=4,
        rank=64, ranks=[64],
    ),
}

# Canonical per-block weight order — the layout contract with rust/src/model/layout.rs.
BLOCK_WEIGHTS = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]
BLOCK_NORMS = ["norm_attn", "norm_ffn"]

# The four activation-quantization points of Figure 8 (inputs of the 7 linears,
# deduplicated: qkv share one input, gate/up share one input).
ACT_POINTS = ["attn_in", "o_in", "ffn_in", "down_in"]


def block_weight_shapes(cfg: ModelConfig):
    """[(name, (Cout, Cin))] in canonical order. y = x @ W.T convention."""
    d, f = cfg.d, cfg.ff
    return [
        ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
        ("wg", (f, d)), ("wu", (f, d)), ("wd", (d, f)),
    ]


def act_point_dims(cfg: ModelConfig):
    """Feature dim at each activation-quant point."""
    return {"attn_in": cfg.d, "o_in": cfg.d, "ffn_in": cfg.d, "down_in": cfg.ff}
