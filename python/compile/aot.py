"""AOT lowering: every L1/L2 computation -> HLO **text** + a manifest the Rust
runtime parses (rust/src/runtime/manifest.rs).

HLO text (not serialized HloModuleProto) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Every artifact function takes FLAT positional array arguments so the input
order is unambiguous; the manifest records (name, dtype, dims) per input and
output in exactly that order.

Usage:  cd python && python -m compile.aot --out ../artifacts [--cfg tiny,small]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, ModelConfig, block_weight_shapes, ACT_POINTS
from . import model as M
from . import recon as R
from . import train as T
from .kernels.lrq_fakequant import lrq_fakequant_kernel
from .kernels.quant_matmul import quant_matmul

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(s):
    return "i32" if s.dtype == jnp.int32 else "f32"


class Artifact:
    def __init__(self, name, fn, inputs, outputs):
        """inputs: [(name, ShapeDtypeStruct)], outputs: [(name, dims)]."""
        self.name = name
        self.fn = fn
        self.inputs = inputs
        self.outputs = outputs

    def lower(self):
        args = [s for _, s in self.inputs]
        return to_hlo_text(jax.jit(self.fn).lower(*args))


# ---------------------------------------------------------------------------
# builders — each returns an Artifact with flat, documented I/O
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig):
    pspec = T.param_spec(cfg)
    step = T.make_train_step(cfg)
    n = len(pspec)

    def fn(*args):
        flat_p = args[:n]
        flat_m = args[n:2 * n]
        flat_v = args[2 * n:3 * n]
        ids, targets, t, lr = args[3 * n:]
        p = T.params_from_flat(cfg, flat_p)
        m = T.params_from_flat(cfg, flat_m)
        v = T.params_from_flat(cfg, flat_v)
        loss, p2, m2, v2 = step(p, m, v, ids, targets, t, lr)
        out = [loss]
        out += list(jax.tree_util.tree_leaves(p2))
        out += list(jax.tree_util.tree_leaves(m2))
        out += list(jax.tree_util.tree_leaves(v2))
        return tuple(out)

    b, s = cfg.train_batch, cfg.seq
    inputs = []
    for prefix in ("p", "m", "v"):
        inputs += [(f"{prefix}.{nm}", spec(sh)) for nm, sh in pspec]
    inputs += [("ids", spec((b, s), I32)), ("targets", spec((b, s), I32)),
               ("t", spec(())), ("lr", spec(()))]
    outputs = [("loss", ())]
    for prefix in ("p", "m", "v"):
        outputs += [(f"{prefix}.{nm}", sh) for nm, sh in pspec]
    return Artifact(f"train_step_{cfg.name}", fn, inputs, outputs)


def build_embed(cfg: ModelConfig):
    b, s = cfg.calib_batch, cfg.seq

    def fn(emb, ids):
        return (M.embed(emb, ids),)

    return Artifact(
        f"embed_{cfg.name}", fn,
        [("emb", spec((cfg.vocab, cfg.d))), ("ids", spec((b, s), I32))],
        [("x", (b, s, cfg.d))])


def build_head_loss(cfg: ModelConfig):
    b, s = cfg.calib_batch, cfg.seq

    def fn(x, final_norm, head, targets):
        loss, logp = M.head_logprobs(x, final_norm, head, targets)
        return (loss, logp)

    return Artifact(
        f"head_loss_{cfg.name}", fn,
        [("x", spec((b, s, cfg.d))), ("final_norm", spec((cfg.d,))),
         ("head", spec((cfg.vocab, cfg.d))), ("targets", spec((b, s), I32))],
        [("loss", ()), ("logp", (b, s))])


def _weight_inputs(cfg, prefix="w"):
    return [(f"{prefix}.{nm}", spec(sh)) for nm, sh in block_weight_shapes(cfg)]


def _norm_inputs(cfg):
    return [("norm_attn", spec((cfg.d,))), ("norm_ffn", spec((cfg.d,)))]


def build_block_fwd(cfg: ModelConfig):
    """FP block forward + activation stats at the 4 quant points."""
    b, s = cfg.calib_batch, cfg.seq
    from .configs import act_point_dims
    dims = act_point_dims(cfg)

    def fn(x, *wn):
        ws, norms = wn[:7], wn[7:9]
        nq = M.NoQuant()
        y = M.block_fwd(cfg, ws, norms, x, nq)
        out = [y]
        for p in ACT_POINTS:
            mn, mx, amax = nq.stats[p]
            out += [mn, mx, amax, nq.acts[p]]
        return tuple(out)

    inputs = [("x", spec((b, s, cfg.d)))] + _weight_inputs(cfg) + _norm_inputs(cfg)
    outputs = [("y", (b, s, cfg.d))]
    for p in ACT_POINTS:
        outputs += [(f"{p}.min", ()), (f"{p}.max", ()), (f"{p}.amax", (dims[p],)),
                    (f"{p}.act", (b, s, dims[p]))]
    return Artifact(f"block_fwd_{cfg.name}", fn, inputs, outputs)


def _actq_inputs():
    ins = []
    for p in ACT_POINTS:
        ins += [(f"scale.{p}", spec(())), (f"zp.{p}", spec(()))]
    ins += [("act_on", spec(())), ("per_token", spec(())), ("kv_on", spec(())),
            ("qmax_a", spec(())), ("qmax_kv", spec(()))]
    return ins


def build_block_fwd_q(cfg: ModelConfig):
    """Quantized block forward: weights arrive already fake-quantized (Ŵ);
    activation/KV quantization is runtime-flag dispatched."""
    b, s = cfg.calib_batch, cfg.seq

    def fn(x, *rest):
        ws, norms = rest[:7], rest[7:9]
        rest = rest[9:]
        static = {}
        for i, p in enumerate(ACT_POINTS):
            static[p] = (rest[2 * i], rest[2 * i + 1])
        act_on, per_token, kv_on, qmax_a, qmax_kv = rest[8:]
        aq = M.ActQuant(static, (act_on, per_token, kv_on), qmax_a, qmax_kv)
        return (M.block_fwd(cfg, ws, norms, x, aq),)

    inputs = ([("x", spec((b, s, cfg.d)))] + _weight_inputs(cfg, "what")
              + _norm_inputs(cfg) + _actq_inputs())
    return Artifact(f"block_fwd_q_{cfg.name}", fn, inputs,
                    [("y", (b, s, cfg.d))])


def build_recon(cfg: ModelConfig, method: str, rank: int):
    b, s = cfg.recon_batch, cfg.seq
    step = R.make_recon_step(cfg, method, rank)
    shapes = block_weight_shapes(cfg)
    # learnable bundle spec per layer
    theta_names, theta_specs = [], []
    for nm, (cout, cin) in shapes:
        for tn, tsh in R.theta_spec(method, cout, cin, rank):
            theta_names.append(f"{nm}.{tn}")
            theta_specs.append(spec(tsh))
    nt = len(theta_specs)
    bundle_sizes = [len(R.theta_spec(method, co, ci, rank))
                    for _, (co, ci) in shapes]

    def unflatten_theta(flat):
        out, i = [], 0
        for sz in bundle_sizes:
            out.append(tuple(flat[i:i + sz]))
            i += sz
        return tuple(out)

    def fn(*args):
        i = 0
        x_q, y_t = args[0], args[1]; i = 2
        ws = args[i:i + 7]; i += 7
        norms = args[i:i + 2]; i += 2
        s1_inits = args[i:i + 7]; i += 7
        zs = args[i:i + 7]; i += 7
        theta = unflatten_theta(args[i:i + nt]); i += nt
        m = unflatten_theta(args[i:i + nt]); i += nt
        v = unflatten_theta(args[i:i + nt]); i += nt
        t, lr = args[i], args[i + 1]; i += 2
        static = tuple((args[i + 2 * j], args[i + 2 * j + 1])
                       for j in range(4)); i += 8
        act_on, per_token, kv_on, qmax_w, qmax_a, qmax_kv = args[i:i + 6]
        loss, th2, m2, v2 = step(
            x_q, y_t, ws, norms, s1_inits, zs, theta, m, v, t, lr,
            static, (act_on, per_token, kv_on), qmax_w, qmax_a, qmax_kv)
        out = [loss]
        for tree in (th2, m2, v2):
            out += list(jax.tree_util.tree_leaves(tree))
        return tuple(out)

    inputs = [("x_q", spec((b, s, cfg.d))), ("y_t", spec((b, s, cfg.d)))]
    inputs += _weight_inputs(cfg)
    inputs += _norm_inputs(cfg)
    inputs += [(f"s1.{nm}", spec((sh[0],))) for nm, sh in shapes]
    inputs += [(f"z.{nm}", spec((sh[0],))) for nm, sh in shapes]
    for prefix in ("theta", "m", "v"):
        inputs += [(f"{prefix}.{tn}", ts)
                   for tn, ts in zip(theta_names, theta_specs)]
    inputs += [("t", spec(())), ("lr", spec(()))]
    for p in ACT_POINTS:
        inputs += [(f"scale.{p}", spec(())), (f"zp.{p}", spec(()))]
    inputs += [("act_on", spec(())), ("per_token", spec(())),
               ("kv_on", spec(())), ("qmax_w", spec(())),
               ("qmax_a", spec(())), ("qmax_kv", spec(()))]

    outputs = [("loss", ())]
    for prefix in ("theta", "m", "v"):
        outputs += [(f"{prefix}.{tn}", tuple(ts.shape))
                    for tn, ts in zip(theta_names, theta_specs)]
    suffix = f"_r{rank}" if method in ("lrq", "lrq_nobias") else ""
    return Artifact(f"recon_{method}_{cfg.name}{suffix}", fn, inputs, outputs)


def build_kernel_fakequant(cfg: ModelConfig):
    """Standalone L1 LRQ fake-quant kernel (bench + cross-layer golden test).
    Shape: the gate projection (ff x d), default rank."""
    cout, cin, r = cfg.ff, cfg.d, cfg.rank

    def fn(w, s1, z, l2, u2, r2, c2, qmax):
        return (lrq_fakequant_kernel(w, s1, z, l2, u2, r2, c2, qmax),)

    return Artifact(
        f"kernel_fakequant_{cfg.name}", fn,
        [("w", spec((cout, cin))), ("s1", spec((cout,))), ("z", spec((cout,))),
         ("l2", spec((cout, r))), ("u2", spec((r, cin))),
         ("r2", spec((cout,))), ("c2", spec((cin,))), ("qmax", spec(()))],
        [("what", (cout, cin))])


def build_kernel_qmm(cfg: ModelConfig):
    """Standalone L1 dequant-matmul kernel (serving GEMM bench)."""
    t = cfg.calib_batch * cfg.seq
    k, n = cfg.d, cfg.ff

    def fn(x, wq, s1, z):
        return (quant_matmul(x, wq, s1, z),)

    return Artifact(
        f"kernel_qmm_{cfg.name}", fn,
        [("x", spec((t, k))), ("wq", spec((n, k))),
         ("s1", spec((n,))), ("z", spec((n,)))],
        [("y", (t, n))])


def artifacts_for(cfg: ModelConfig):
    arts = [
        build_train_step(cfg),
        build_embed(cfg),
        build_head_loss(cfg),
        build_block_fwd(cfg),
        build_block_fwd_q(cfg),
        build_recon(cfg, "fr", 0),
        build_recon(cfg, "lrq_nobias", cfg.rank),
        build_kernel_fakequant(cfg),
        build_kernel_qmm(cfg),
    ]
    for r in cfg.ranks:
        arts.append(build_recon(cfg, "lrq", r))
    return arts


# ---------------------------------------------------------------------------
# manifest + driver
# ---------------------------------------------------------------------------

def manifest_lines(cfgs, arts_by_cfg):
    lines = ["version 1"]
    for cfg in cfgs:
        lines.append(
            f"config {cfg.name} vocab {cfg.vocab} d {cfg.d} heads {cfg.heads}"
            f" layers {cfg.layers} ff {cfg.ff} seq {cfg.seq}"
            f" train_batch {cfg.train_batch} calib_batch {cfg.calib_batch}"
            f" recon_batch {cfg.recon_batch} rank {cfg.rank}")
        lines.append("ranks " + cfg.name + " "
                     + " ".join(str(r) for r in cfg.ranks))
    for cfg in cfgs:
        for art in arts_by_cfg[cfg.name]:
            lines.append(f"artifact {art.name} {art.name}.hlo.txt")
            for nm, s in art.inputs:
                dims = " ".join(str(d) for d in s.shape)
                lines.append(f"in {nm} {_dt(s)} {dims}".rstrip())
            for nm, dims in art.outputs:
                ds = " ".join(str(d) for d in dims)
                lines.append(f"out {nm} f32 {ds}".rstrip())
            lines.append("end")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--cfg", default="tiny,small")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to rebuild")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cfgs = [CONFIGS[c] for c in args.cfg.split(",")]
    arts_by_cfg = {}
    for cfg in cfgs:
        arts_by_cfg[cfg.name] = artifacts_for(cfg)

    only = args.only.split(",") if args.only else None
    for cfg in cfgs:
        for art in arts_by_cfg[cfg.name]:
            path = os.path.join(args.out, f"{art.name}.hlo.txt")
            if only and not any(o in art.name for o in only):
                continue
            text = art.lower()
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {art.name}: {len(text)} chars, "
                  f"{len(art.inputs)} in / {len(art.outputs)} out",
                  flush=True)

    mpath = os.path.join(args.out, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("\n".join(manifest_lines(cfgs, arts_by_cfg)) + "\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
