"""L2: full-model pre-training step (AdamW) — produces the FP baseline that the
PTQ pipeline quantizes, and the e2e driver's loss curve.

The Rust coordinator owns all state (params, Adam moments, step counter) and
threads it through this artifact; Python never runs at training time.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import lm_loss

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


def make_train_step(cfg: ModelConfig):
    """step(params, m, v, ids, targets, t, lr) -> (loss, params', m', v')."""

    def step(params, m, v, ids, targets, t, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, ids, targets))(params)

        tn = t + 1.0
        bc1 = 1.0 - ADAM_B1 ** tn
        bc2 = 1.0 - ADAM_B2 ** tn
        tree_map = jax.tree_util.tree_map
        m2 = tree_map(lambda m_, g: ADAM_B1 * m_ + (1.0 - ADAM_B1) * g,
                      m, grads)
        v2 = tree_map(lambda v_, g: ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g,
                      v, grads)
        params2 = tree_map(
            lambda p, m_, v_: p - lr * ((m_ / bc1)
            / (jnp.sqrt(v_ / bc2) + ADAM_EPS) + WEIGHT_DECAY * p),
            params, m2, v2)
        return loss, params2, m2, v2

    return step


def param_spec(cfg: ModelConfig):
    """(name, shape) list for the full-model parameter vector, in the canonical
    flatten order — the layout contract with rust/src/model/layout.rs."""
    spec = [("emb", (cfg.vocab, cfg.d))]
    d, f = cfg.d, cfg.ff
    for i in range(cfg.layers):
        for nm, shape in [
            ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d)),
            ("wg", (f, d)), ("wu", (f, d)), ("wd", (d, f)),
            ("norm_attn", (d,)), ("norm_ffn", (d,)),
        ]:
            spec.append((f"blocks.{i}.{nm}", shape))
    spec.append(("final_norm", (d,)))
    spec.append(("head", (cfg.vocab, d)))
    return spec


def params_from_flat(cfg: ModelConfig, flat):
    """Rebuild the nested (emb, blocks, final_norm, head) pytree from the flat
    canonical-order list (the order of param_spec)."""
    it = iter(flat)
    emb = next(it)
    blocks = []
    for _ in range(cfg.layers):
        ws = tuple(next(it) for _ in range(7))
        norms = tuple(next(it) for _ in range(2))
        blocks.append((ws, norms))
    final_norm = next(it)
    head = next(it)
    return (emb, tuple(blocks), final_norm, head)
