"""L2: block-wise reconstruction step (the learning core of LRQ/FlexRound).

One artifact = one Adam step over a block's learnable quantization parameters:

    loss = || block_q(x_q; θ) − y_target ||²  ,   θ ← Adam(θ, ∇loss)

where ``y_target = block_fp(x_fp)`` is precomputed by the Rust coordinator via
the ``block_fwd`` artifact (BRECQ recipe: x_fp streams through FP blocks, x_q
through already-quantized ones).

Methods
-------
* ``lrq``        θ = {s1, L2, U2, r2, c2} per linear  (Eq. 2) — forward runs
                 the fused Pallas fake-quant kernel (L1 on the hot path).
* ``lrq_nobias`` θ = {s1, L2, U2}  (Appendix B ablation, S2 = L2U2)
* ``fr``         θ = {s1, S2} full scaling matrix      (Eq. 1, FlexRound)

Zero-points ``z`` are frozen after RTN init (inputs, not learnables).
Adam state (m, v, t) is threaded through the artifact by the coordinator.
"""

import jax
import jax.numpy as jnp

from . import quant
from .configs import ModelConfig, block_weight_shapes, ACT_POINTS
from .model import ActQuant, block_fwd
from .kernels.lrq_fakequant import lrq_fakequant

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def fakequant_layer(method, w, s1_init, z, theta, qmax_w):
    """Ŵ for one linear given its learnable bundle ``theta``.

    The quantization step is parameterized multiplicatively,
    ``s1 = s1_init · exp(ds1)`` with learnable ``ds1`` (init 0): Adam's
    step magnitude is ~lr regardless of gradient scale, so learning ``s1``
    directly would move it by O(lr) *absolute* — a 50 % jump for typical
    steps — whereas ``ds1`` moves it by O(lr) *relative* and keeps it
    positive. At init (``ds1 = 0``) this is exactly the paper's RTN start.
    """
    if method == "lrq":
        ds1, l2, u2, r2, c2 = theta
        s1 = s1_init * jnp.exp(ds1)
        return lrq_fakequant(w, s1, z, l2, u2, r2, c2, qmax_w)
    if method == "lrq_nobias":
        ds1, l2, u2 = theta
        s1 = s1_init * jnp.exp(ds1)
        zeros_r = jnp.zeros((w.shape[0],), w.dtype)
        zeros_c = jnp.zeros((w.shape[1],), w.dtype)
        return lrq_fakequant(w, s1, z, l2, u2, zeros_r, zeros_c, qmax_w)
    if method == "fr":
        ds1, s2 = theta
        s1 = s1_init * jnp.exp(ds1)
        return quant.fakequant_weight(w, s1, z, s2, qmax_w)
    raise ValueError(method)


def theta_spec(method, cout, cin, rank):
    """(name, shape) list for one linear's learnable bundle — the layout
    contract mirrored in rust/src/methods/."""
    if method == "lrq":
        return [("ds1", (cout,)), ("l2", (cout, rank)), ("u2", (rank, cin)),
                ("r2", (cout,)), ("c2", (cin,))]
    if method == "lrq_nobias":
        return [("ds1", (cout,)), ("l2", (cout, rank)), ("u2", (rank, cin))]
    if method == "fr":
        return [("ds1", (cout,)), ("s2", (cout, cin))]
    raise ValueError(method)


def make_recon_step(cfg: ModelConfig, method: str, rank: int):
    """Returns step(x_q, y_t, ws, norms, s1_inits, zs, theta, m, v, t, lr,
    static_scales, flags, qmaxes) -> (loss, theta', m', v')."""

    def step(x_q, y_t, ws, norms, s1_inits, zs, theta, m, v, t, lr,
             static_scales, flags, qmax_w, qmax_a, qmax_kv):

        def loss_fn(theta_):
            whats = tuple(
                fakequant_layer(method, w, s1i, z, th, qmax_w)
                for w, s1i, z, th in zip(ws, s1_inits, zs, theta_))
            static = {p: static_scales[i] for i, p in enumerate(ACT_POINTS)}
            aq = ActQuant(static, flags, qmax_a, qmax_kv)
            y = block_fwd(cfg, whats, norms, x_q, aq)
            diff = y - y_t
            return jnp.mean(diff * diff)

        loss, grads = jax.value_and_grad(loss_fn)(theta)

        tn = t + 1.0
        bc1 = 1.0 - ADAM_B1 ** tn
        bc2 = 1.0 - ADAM_B2 ** tn
        tree_map = jax.tree_util.tree_map
        m2 = tree_map(lambda m_, g: ADAM_B1 * m_ + (1.0 - ADAM_B1) * g,
                      m, grads)
        v2 = tree_map(lambda v_, g: ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g,
                      v, grads)
        theta2 = tree_map(
            lambda p, m_, v_: p - lr * (m_ / bc1)
            / (jnp.sqrt(v_ / bc2) + ADAM_EPS),
            theta, m2, v2)
        return loss, theta2, m2, v2

    return step


def init_theta(method, cfg: ModelConfig, rank: int, seed: int = 0):
    """Reference initializer (mirrored in rust/src/methods/): ds1 = 0
    (i.e. s1 = s1_init from RTN), L2 = 0, U2 ~ N(0, 0.01), r2 = c2 = 0 —
    so L2U2 + r2 + c2 = 0 and learning starts exactly from RTN (paper §2.3)."""
    key = jax.random.PRNGKey(seed)
    thetas = []
    for name, (cout, cin) in block_weight_shapes(cfg):
        key, sub = jax.random.split(key)
        if method == "lrq":
            thetas.append((jnp.zeros((cout,), jnp.float32),
                           jnp.zeros((cout, rank), jnp.float32),
                           0.01 * jax.random.normal(sub, (rank, cin), jnp.float32),
                           jnp.zeros((cout,), jnp.float32),
                           jnp.zeros((cin,), jnp.float32)))
        elif method == "lrq_nobias":
            thetas.append((jnp.zeros((cout,), jnp.float32),
                           jnp.zeros((cout, rank), jnp.float32),
                           0.01 * jax.random.normal(sub, (rank, cin), jnp.float32)))
        elif method == "fr":
            thetas.append((jnp.zeros((cout,), jnp.float32),
                           jnp.zeros((cout, cin), jnp.float32)))
        else:
            raise ValueError(method)
    return tuple(thetas)
