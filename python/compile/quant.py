"""Fake-quantization math shared by the L2 model, the reconstruction step, and
the pure-jnp kernel oracles (kernels/ref.py).

Conventions
-----------
* Weights are ``W[Cout, Cin]`` with ``y = x @ W.T``.
* Weight quantization is per-channel (per-Cout-row) **asymmetric** over an
  unsigned grid ``[0, qmax]`` (``qmax = 2^bits - 1``): step ``s1[Cout]``,
  zero-point ``z[Cout]`` (frozen after RTN init, as in FlexRound/LRQ).
* ``round``/``clip`` use the straight-through estimator so that
  ``s1, L2, U2, r2, c2`` (and ``S2`` for FlexRound) receive gradients.
* Activations/KV use asymmetric fake-quant, either per-token (reduce over the
  trailing feature dim) or per-tensor static with calibrated scale/zero-point.
"""

import jax
import jax.numpy as jnp

EPS = 1e-9


def ste(hard, soft):
    """Straight-through: value of ``hard``, gradient of ``soft``."""
    return soft + jax.lax.stop_gradient(hard - soft)


# ---------------------------------------------------------------------------
# weight-side
# ---------------------------------------------------------------------------

def rtn_range(w, qmax):
    """Per-channel asymmetric RTN grid: (s1, z), both [Cout]."""
    wmin = jnp.minimum(w.min(axis=1), 0.0)
    wmax = jnp.maximum(w.max(axis=1), 0.0)
    s1 = (wmax - wmin) / qmax
    s1 = jnp.maximum(s1, EPS)
    z = jnp.clip(jnp.round(-wmin / s1), 0.0, qmax)
    return s1, z


def lrq_exponent(l2, u2, r2, c2):
    """S = L2 @ U2 + r2 + c2 with numpy-style broadcasting (paper App. M)."""
    return l2 @ u2 + r2[:, None] + c2[None, :]


def fakequant_weight(w, s1, z, s_exp, qmax):
    """``Ŵ = s1 ⊙ (clip(round(W/(s1·exp(S)) + z), 0, qmax) - z)`` with STE.

    ``s_exp`` is the exponent matrix: ``S2`` (FlexRound) or
    ``L2U2 + r2 + c2`` (LRQ); zeros recover plain RTN.
    """
    div = s1[:, None] * jnp.exp(s_exp)
    q_soft = w / div + z[:, None]
    q = ste(jnp.clip(jnp.round(q_soft), 0.0, qmax), q_soft)
    return (q - z[:, None]) * s1[:, None]


def quantize_weight_int(w, s1, z, s_exp, qmax):
    """Integer codes (no STE) — what is stored/packed at inference time."""
    div = s1[:, None] * jnp.exp(s_exp)
    return jnp.clip(jnp.round(w / div + z[:, None]), 0.0, qmax)


# ---------------------------------------------------------------------------
# activation / KV-cache side
# ---------------------------------------------------------------------------

def per_token_range(x, qmax):
    """Asymmetric per-token (trailing-dim) grid: scale/zp with shape x[..., :1]."""
    xmin = jnp.minimum(x.min(axis=-1, keepdims=True), 0.0)
    xmax = jnp.maximum(x.max(axis=-1, keepdims=True), 0.0)
    scale = jnp.maximum((xmax - xmin) / qmax, EPS)
    zp = jnp.clip(jnp.round(-xmin / scale), 0.0, qmax)
    return scale, zp


def fakequant_act(x, scale, zp, qmax):
    """Asymmetric fake-quant with given grid (static or per-token), STE."""
    q_soft = x / scale + zp
    q = ste(jnp.clip(jnp.round(q_soft), 0.0, qmax), q_soft)
    return (q - zp) * scale


def fakequant_per_token(x, qmax):
    scale, zp = per_token_range(x, qmax)
    return fakequant_act(x, scale, zp, qmax)


def fakequant_static(x, scale, zp, qmax):
    """Per-tensor static: scalar scale/zp calibrated offline by the L3 pass."""
    return fakequant_act(x, scale, zp, qmax)


def select_act_quant(x, static_scale, static_zp, act_on, per_token, qmax):
    """Runtime-flag dispatch (flags are f32 0/1 scalars fed by the Rust side).

    Computes both paths and selects — branchless so a single HLO artifact
    serves FP / per-tensor-static / per-token rows of every table.
    """
    x_tok = fakequant_per_token(x, qmax)
    x_st = fakequant_static(x, static_scale, static_zp, qmax)
    x_q = jnp.where(per_token > 0.5, x_tok, x_st)
    return jnp.where(act_on > 0.5, x_q, x)
