"""L1 Pallas kernel: asymmetric per-token fake-quantization.

Used for per-token activation quantization (Tables 5-6) and per-token KV-cache
quantization (all W/A/KV8 tables). One grid step owns a ``(bt, D)`` stripe of
tokens: min/max reductions along the feature dim stay in VMEM, the quant /
dequant is pure VPU work. The trailing dim is never split so each token's
grid lives entirely in one tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

EPS = 1e-9


def _pick_block(n: int, cap: int) -> int:
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return n


def _kernel(x_ref, qmax_ref, o_ref):
    x = x_ref[...]
    qmax = qmax_ref[0, 0]
    xmin = jnp.minimum(x.min(axis=-1, keepdims=True), 0.0)
    xmax = jnp.maximum(x.max(axis=-1, keepdims=True), 0.0)
    scale = jnp.maximum((xmax - xmin) / qmax, EPS)
    zp = jnp.clip(jnp.round(-xmin / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(x / scale + zp), 0.0, qmax)
    o_ref[...] = (q - zp) * scale


def per_token_quant_kernel(x, qmax, *, bt: int = 256):
    """Raw kernel over x[..., D] flattened to (T, D) token stripes."""
    shape = x.shape
    d = shape[-1]
    t = 1
    for s in shape[:-1]:
        t *= s
    x2 = x.reshape(t, d)
    bt = _pick_block(t, bt)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x2, qm)
    return out.reshape(shape)


@jax.custom_vjp
def per_token_quant(x, qmax):
    """Differentiable per-token fake-quant: Pallas forward, STE backward."""
    return per_token_quant_kernel(x, qmax)


def _fwd(x, qmax):
    return per_token_quant_kernel(x, qmax), (x, qmax)


def _bwd(res, g):
    x, qmax = res
    _, vjp = jax.vjp(lambda x_: ref.per_token_quant_ref(x_, qmax), x)
    (gx,) = vjp(g)
    return gx, jnp.zeros_like(qmax)


per_token_quant.defvjp(_fwd, _bwd)
