"""L1 Pallas kernel: weight-only dequantize-and-matmul (the serving GEMM).

``y[T, N] = x[T, K] @ ((wq[N, K] - z[N]) * s1[N]).T``

This is the TPU analogue of the LUT-GEMM kernel the paper uses for Figure 5 /
Table 15: integer weight codes are dequantized *inside* the kernel, tile by
tile in VMEM, immediately before the MXU contraction — HBM only ever holds the
packed codes. On CPU-PJRT the codes are carried as integer-valued f32 (the
Rust side stores the true packed int3/4/8 buffers and unpacks per call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, cap: int) -> int:
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return n


def _kernel(x_ref, wq_ref, s1_ref, z_ref, o_ref):
    w = (wq_ref[...] - z_ref[...]) * s1_ref[...]   # dequant in VMEM (VPU)
    o_ref[...] = x_ref[...] @ w.T                  # MXU contraction


def quant_matmul(x, wq, s1, z, *, bt: int = 256, bn: int = 128):
    """x[T,K] (or [..., K]) times per-channel-quantized wq[N,K]."""
    shape = x.shape
    k = shape[-1]
    t = 1
    for s in shape[:-1]:
        t *= s
    x2 = x.reshape(t, k)
    n = wq.shape[0]
    bt = _pick_block(t, bt)
    bn = _pick_block(n, bn)
    s1c = s1.reshape(n, 1)
    zc = z.reshape(n, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(t // bt, n // bn),
        in_specs=[
            pl.BlockSpec((bt, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), x.dtype),
        interpret=True,
    )(x2, wq, s1c, zc)
    return out.reshape(shape[:-1] + (n,))
