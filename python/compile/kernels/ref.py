"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest asserts kernel == ref under assert_allclose)."""

import jax.numpy as jnp

from .. import quant


def lrq_fakequant_ref(w, s1, z, l2, u2, r2, c2, qmax):
    """Ŵ for LRQ (Eq. 2): s1 ⊙ round(W / (s1 ⊙ exp(L2U2 + r2 + c2)))."""
    s_exp = quant.lrq_exponent(l2, u2, r2, c2)
    return quant.fakequant_weight(w, s1, z, s_exp, qmax)


def flexround_fakequant_ref(w, s1, z, s2, qmax):
    """Ŵ for FlexRound (Eq. 1): full weight-scaling matrix S2."""
    return quant.fakequant_weight(w, s1, z, s2, qmax)


def per_token_quant_ref(x, qmax):
    """Asymmetric per-token fake-quant over the trailing dim."""
    return quant.fakequant_per_token(x, qmax)


def quant_matmul_ref(x, wq, s1, z):
    """Dequantize-then-matmul: y = x @ ((wq - z) * s1).T.

    ``wq`` holds integer codes carried in f32 (CPU-PJRT simulation of the
    packed int3/4/8 weights the Rust side stores).
    """
    w = (wq - z[:, None]) * s1[:, None]
    return x @ w.T


def lrq_scale_ref(l2, u2, r2, c2):
    """The exponent matrix S = L2U2 + r2 + c2 itself (App. M broadcasting)."""
    return quant.lrq_exponent(l2, u2, r2, c2)
