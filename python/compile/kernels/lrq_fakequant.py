"""L1 Pallas kernel: fused LRQ fake-quantization.

Computes ``Ŵ = s1 ⊙ (clip(round(W / (s1 ⊙ exp(L2U2 + r2 + c2)) + z), 0, qmax) - z)``
tile-by-tile **without ever materializing the full scale matrix S = L2U2+r2+c2**
— this is the memory saving the paper reports in Table 13 (23.5 GB for LRQ vs
25.4 GB for FlexRound on Llama-7B).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks ``(Cout/bm,
Cin/bn)`` weight tiles; each step holds a ``(bm, r)`` slice of L2 and an
``(r, bn)`` slice of U2 in VMEM, forms the ``(bm, bn)`` scale tile on the MXU,
then applies exp/div/round/clip/mul on the VPU. Lowered with
``interpret=True`` — CPU PJRT cannot execute Mosaic custom-calls; real-TPU
performance is estimated analytically (EXPERIMENTS.md §Perf).

The wrapper carries a ``jax.custom_vjp`` whose backward pass replays the
straight-through-estimator gradients of the jnp oracle, so the kernel sits on
the *forward hot path of the reconstruction step* while staying differentiable
w.r.t. ``s1, L2, U2, r2, c2``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (keeps BlockSpecs exact)."""
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return n


def _kernel(w_ref, s1_ref, z_ref, l2_ref, u2_ref, r2_ref, c2_ref, qmax_ref,
            o_ref):
    # (bm, r) @ (r, bn) on the MXU, biases broadcast on the VPU.
    s = l2_ref[...] @ u2_ref[...] + r2_ref[...] + c2_ref[...]
    s1 = s1_ref[...]          # (bm, 1)
    z = z_ref[...]            # (bm, 1)
    qmax = qmax_ref[0, 0]
    div = s1 * jnp.exp(s)
    q = jnp.clip(jnp.round(w_ref[...] / div + z), 0.0, qmax)
    o_ref[...] = (q - z) * s1


def lrq_fakequant_kernel(w, s1, z, l2, u2, r2, c2, qmax, *,
                         bm: int = 128, bn: int = 128):
    """Raw (non-differentiable) tiled kernel. qmax is a scalar array."""
    cout, cin = w.shape
    r = l2.shape[1]
    bm = _pick_block(cout, bm)
    bn = _pick_block(cin, bn)
    grid = (cout // bm, cin // bn)
    s1c = s1.reshape(cout, 1)
    zc = z.reshape(cout, 1)
    r2c = r2.reshape(cout, 1)
    c2r = c2.reshape(1, cin)
    qm = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),      # W tile
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),       # s1
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),       # z
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),       # L2 slice
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),       # U2 slice
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),       # r2
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),       # c2
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),        # qmax
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cout, cin), w.dtype),
        interpret=True,
    )(w, s1c, zc, l2, u2, r2c, c2r, qm)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def lrq_fakequant(w, s1, z, l2, u2, r2, c2, qmax):
    """Differentiable fused fake-quant: Pallas forward, STE-oracle backward."""
    return lrq_fakequant_kernel(w, s1, z, l2, u2, r2, c2, qmax)


def _fwd(w, s1, z, l2, u2, r2, c2, qmax):
    out = lrq_fakequant_kernel(w, s1, z, l2, u2, r2, c2, qmax)
    return out, (w, s1, z, l2, u2, r2, c2, qmax)


def _bwd(res, g):
    w, s1, z, l2, u2, r2, c2, qmax = res
    # Replay the STE gradients of the jnp oracle. w/z/qmax are frozen at
    # reconstruction time; their cotangents are still produced for
    # completeness (custom_vjp requires one per primal).
    _, vjp = jax.vjp(
        lambda w_, s1_, z_, l2_, u2_, r2_, c2_:
            ref.lrq_fakequant_ref(w_, s1_, z_, l2_, u2_, r2_, c2_, qmax),
        w, s1, z, l2, u2, r2, c2)
    gw, gs1, gz, gl2, gu2, gr2, gc2 = vjp(g)
    return gw, gs1, gz, gl2, gu2, gr2, gc2, jnp.zeros_like(qmax)


lrq_fakequant.defvjp(_fwd, _bwd)
